//! Use case 2 (§5.2, Figs 5–7): unseen class introduction at runtime.
//!
//! Three staged runs: the filtered baseline (Fig 5), the new class
//! arriving with online learning disabled (Fig 6 — accuracy collapses),
//! and with online learning enabled (Fig 7 — dip, then recovery). The
//! class filter IP removes class 0 during offline training and early
//! online operation; the MCU lifts the filter after 5 online iterations.
//!
//! ```sh
//! cargo run --release --example class_introduction -- [orderings]
//! ```

use tm_fpga::coordinator::{report, run_figure, Figure, SweepOptions};

fn main() -> anyhow::Result<()> {
    let orderings: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(24);
    let opts = SweepOptions { orderings, threads: 0, seed: 42 };

    let baseline = run_figure(Figure::Fig5, &opts)?;
    let frozen = run_figure(Figure::Fig6, &opts)?;
    let online = run_figure(Figure::Fig7, &opts)?;
    for r in [&baseline, &frozen, &online] {
        print!("{}", report::figure_summary(r));
        println!();
    }

    // The §5.2 story in one table: validation accuracy around the event.
    println!("validation accuracy around the class introduction (iter 5→6):");
    println!("{:<44} {:>7} {:>7} {:>7}", "scenario", "it 5", "it 6", "it 16");
    for (name, r) in [
        ("Fig 5  filtered throughout (baseline)", &baseline),
        ("Fig 6  class appears, learning disabled", &frozen),
        ("Fig 7  class appears, learning enabled", &online),
    ] {
        println!(
            "{:<44} {:>6.1}% {:>6.1}% {:>6.1}%",
            name,
            r.validation.mean_at(5) * 100.0,
            r.validation.mean_at(6) * 100.0,
            r.validation.mean_at(16) * 100.0
        );
    }
    let recovered = online.validation.mean_at(16) - frozen.validation.mean_at(16);
    println!(
        "\nonline learning recovers {:+.1}% validation accuracy vs the frozen system \
         (paper: \"the accuracy soon recovered, showing a significantly positive outcome\")",
        recovered * 100.0
    );
    Ok(())
}
