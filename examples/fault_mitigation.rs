//! Use case 3 (§5.3, Figs 8–9): runtime fault mitigation with labelled
//! online learning, plus the §5.3.2 monitor/retrain strategy.
//!
//! Part 1 stages the paper's experiment: 20% of TAs forced stuck-at-0
//! after 5 online iterations (via the fault controller's AND/OR gate
//! mappings, programmed over AXI), with online learning off (Fig 8) and
//! on (Fig 9 — the TM retrains "around" the faulty TAs).
//!
//! Part 2 demonstrates the further mitigation strategy: continuous
//! accuracy monitoring detects a clause-killing fault burst and triggers
//! an on-chip retrain with the over-provisioned clause reserve enabled.
//!
//! ```sh
//! cargo run --release --example fault_mitigation -- [orderings]
//! ```

use tm_fpga::coordinator::{
    monitor_and_retrain, report, run_figure, AccuracyMonitor, Figure,
    RetrainPolicy, SweepOptions,
};
use tm_fpga::data::blocks::{BlockPlan, SetAllocation};
use tm_fpga::data::iris;
use tm_fpga::tm::*;

fn main() -> anyhow::Result<()> {
    let orderings: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(24);
    let opts = SweepOptions { orderings, threads: 0, seed: 42 };

    // --- Part 1: Figs 8 and 9 ---
    let frozen = run_figure(Figure::Fig8, &opts)?;
    let online = run_figure(Figure::Fig9, &opts)?;
    print!("{}", report::figure_summary(&frozen));
    println!();
    print!("{}", report::figure_summary(&online));
    println!(
        "\nonline-set accuracy at iteration 16: frozen {:.1}% vs online learning {:.1}% \
         (paper: recovery \"on par with the fault-free system\")\n",
        frozen.online.mean_at(16) * 100.0,
        online.online.mean_at(16) * 100.0
    );

    // --- Part 2: §5.3.2 monitor + retrain with the clause reserve ---
    let shape = TmShape::iris();
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 11)?;
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper())?;
    let train = sets.offline.pack(&shape);
    let eval = sets.validation.pack(&shape);

    let mut params = TmParams::paper_offline(&shape);
    params.active_clauses = 12; // hold 4 clauses in reserve
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(2);
    let mut rands = StepRands::draw(&mut rng, &shape);
    for _ in 0..10 {
        for (x, y) in &train {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &rands);
        }
    }
    println!("monitor demo: trained with 12/16 clauses, validation {:.1}%",
        tm.accuracy(&eval, &params) * 100.0);

    // Kill 10 of the 12 active clauses per class (complement-pair
    // stuck-at-1 makes a clause unsatisfiable).
    let mut map = FaultMap::none(&shape);
    for c in 0..shape.classes {
        for j in 0..10 {
            map.set(c, j, 0, Fault::StuckAt1);
            map.set(c, j, shape.features, Fault::StuckAt1);
        }
    }
    tm.set_fault_map(map);
    println!("fault burst injected: validation {:.1}%", tm.accuracy(&eval, &params) * 100.0);

    let mut monitor = AccuracyMonitor::new(0.15);
    let policy = RetrainPolicy {
        threshold: 0.62,
        warmup: 10,
        retrain_clauses: 16,
        retrain_epochs: 20,
    };
    let spot: Vec<_> = train.iter().cycle().take(120).cloned().collect();
    let out = monitor_and_retrain(
        &mut tm, &mut params, &mut monitor, &policy, &spot, &train, &eval, 77,
    )?;
    println!(
        "monitor: triggered={} (EWMA {:.2} < {:.2} after {} spot checks)",
        out.triggered, out.estimate_at_trigger, policy.threshold, out.spot_checks
    );
    println!(
        "after on-chip retrain with the 16-clause reserve: validation {:.1}%",
        out.accuracy_after * 100.0
    );
    Ok(())
}
