//! Rapid hyper-parameter search (§5 intro): "the fast execution time
//! allows entire datasets to be analyzed in a matter of seconds, allowing
//! the optimum hyper-parameters for a given dataset to be discovered
//! within a short period of time."
//!
//! Runs a (s, T) grid over cross-validated orderings, prints the ranked
//! surface and the wall-clock, and checks the paper's chosen cell
//! (s = 1.375, T = 15) is competitive.
//!
//! ```sh
//! cargo run --release --example hyperparam_search -- [orderings]
//! ```

use tm_fpga::coordinator::{run_sweep, SweepConfig};

fn main() -> anyhow::Result<()> {
    let orderings: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(24);
    let cfg = SweepConfig { orderings, ..Default::default() };
    let cells = cfg.s_grid.len() * cfg.t_grid.len();

    let t0 = std::time::Instant::now();
    let points = run_sweep(&cfg)?;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "swept {cells} (s, T) cells × {orderings} orderings × {} epochs in {dt:.2}s",
        cfg.epochs
    );
    println!("\nrank  {:<7} {:<5} {:>9} {:>10}", "s", "T", "val acc", "train acc");
    for (i, p) in points.iter().enumerate() {
        let marker = if (p.s - 1.375).abs() < 1e-6 && p.t == 15 { "  <- paper §5" } else { "" };
        println!(
            "{:>4}  {:<7} {:<5} {:>8.1}% {:>9.1}%{}",
            i + 1,
            p.s,
            p.t,
            p.val_accuracy * 100.0,
            p.train_accuracy * 100.0,
            marker
        );
    }
    let paper = points
        .iter()
        .position(|p| (p.s - 1.375).abs() < 1e-6 && p.t == 15)
        .expect("paper cell in grid");
    println!(
        "\nthe paper's (1.375, 15) ranks {}/{} on validation accuracy",
        paper + 1,
        points.len()
    );
    Ok(())
}
