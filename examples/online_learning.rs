//! Use case 1 (§5.1, Fig 4): limited initial training data + labelled
//! online learning.
//!
//! Trains on only 20 offline datapoints, then runs 16 labelled online
//! iterations (s = 1) and shows the accuracy gains on the validation and
//! online sets — the paper's ≈+12% — averaged over cross-validation
//! orderings.
//!
//! ```sh
//! cargo run --release --example online_learning -- [orderings]
//! ```

use tm_fpga::coordinator::{report, run_figure, Figure, SweepOptions};

fn main() -> anyhow::Result<()> {
    let orderings: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(48);
    let opts = SweepOptions { orderings, threads: 0, seed: 42 };
    let r = run_figure(Figure::Fig4, &opts)?;
    print!("{}", report::figure_summary(&r));

    println!("\niter  offline  validation  online   (means over {orderings} orderings)");
    for i in 0..r.offline.len() {
        println!(
            "{:4}  {:6.1}%  {:9.1}%  {:6.1}%",
            i,
            r.offline.mean_at(i) * 100.0,
            r.validation.mean_at(i) * 100.0,
            r.online.mean_at(i) * 100.0
        );
    }
    println!(
        "\npaper Fig 4: starts 83 / 79.5 / 79.5%; validation & online rise ≈+12%, offline ≈+5%"
    );
    println!(
        "this run   : starts {:.0} / {:.0} / {:.0}%; deltas {:+.1} / {:+.1} / {:+.1}%",
        r.offline.mean_at(0) * 100.0,
        r.validation.mean_at(0) * 100.0,
        r.online.mean_at(0) * 100.0,
        r.offline.delta() * 100.0,
        r.validation.delta() * 100.0,
        r.online.delta() * 100.0
    );
    Ok(())
}
