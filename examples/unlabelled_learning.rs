//! Future-work demo (§7): confidence-driven online learning with
//! *unlabelled* data, and unseen-class detection from class confidences.
//!
//! Part 1 — pseudo-labelling: after offline training, online datapoints
//! arrive without labels; the TM trains on its own prediction whenever
//! the vote margin clears a threshold. Compares frozen vs pseudo-labelled
//! accuracy across orderings and shows pseudo-label precision by margin.
//!
//! Part 2 — unseen-class detection: a machine trained on two classes
//! flags foreign datapoints by their low best-class vote sum.
//!
//! ```sh
//! cargo run --release --example unlabelled_learning -- [orderings]
//! ```

use tm_fpga::coordinator::unlabelled::{
    unlabelled_pass, PseudoLabelPolicy, UnseenClassDetector,
};
use tm_fpga::data::blocks::{all_orderings, BlockPlan, SetAllocation};
use tm_fpga::data::{iris, synthetic, ClassFilter};
use tm_fpga::tm::*;

fn main() -> anyhow::Result<()> {
    let orderings: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(12);

    // --- Part 1: pseudo-labelled online learning on iris ---
    let shape = TmShape::iris();
    let p_off = TmParams::paper_offline(&shape);
    let p_on = TmParams::paper_online(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 20)?;
    println!("=== §7 pseudo-labelled online learning ({orderings} orderings) ===\n");
    for margin in [0, 2, 5] {
        let mut frozen_acc = 0.0;
        let mut learned_acc = 0.0;
        let mut precision = (0usize, 0usize);
        for (i, ord) in all_orderings(5).iter().take(orderings).enumerate() {
            let sets = plan.sets(ord, SetAllocation::paper())?;
            let train = sets.offline.truncate(20).pack(&shape);
            let online = sets.online.pack(&shape);
            let mut tm = MultiTm::new(&shape)?;
            let mut rng = Xoshiro256::new(100 + i as u64);
            let mut rands = StepRands::draw(&mut rng, &shape);
            for _ in 0..10 {
                for (x, y) in &train {
                    rands.refill(&mut rng, &shape);
                    train_step(&mut tm, x, *y, &p_off, &rands);
                }
            }
            frozen_acc += tm.accuracy(&online, &p_off);
            for _ in 0..8 {
                let s = unlabelled_pass(
                    &mut tm,
                    &online,
                    &p_off,
                    &p_on,
                    PseudoLabelPolicy { min_margin: margin },
                    &mut rng,
                    &mut rands,
                )?;
                precision.0 += s.pseudo_correct;
                precision.1 += s.trained;
            }
            learned_acc += tm.accuracy(&online, &p_off);
        }
        let n = orderings as f64;
        println!(
            "margin ≥ {margin}: frozen {:.1}% -> pseudo-labelled {:.1}%  \
             (pseudo-label precision {:.1}%, {} steps)",
            frozen_acc / n * 100.0,
            learned_acc / n * 100.0,
            precision.0 as f64 / precision.1.max(1) as f64 * 100.0,
            precision.1
        );
    }

    // --- Part 2: unseen-class detection on the prototype task ---
    println!("\n=== §7 unseen-class detection (synthetic prototypes) ===\n");
    let shape = TmShape { classes: 3, max_clauses: 8, features: 16, states: 100 };
    let mut params = TmParams::paper_offline(&shape);
    params.s = 3.0;
    params.active_classes = 2;
    let d = synthetic::prototype_dataset(3, 60, 16, 0.05, 9)?;
    let train = ClassFilter::removing(2).apply(&d.truncate(120)).pack(&shape);
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(7);
    let mut rands = StepRands::draw(&mut rng, &shape);
    for _ in 0..20 {
        for (x, y) in &train {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &rands);
        }
    }
    let tail = d.subset(&(120..180).collect::<Vec<_>>());
    let unseen = ClassFilter::removing(0)
        .apply(&ClassFilter::removing(1).apply(&tail))
        .pack(&shape);
    let known = ClassFilter::removing(2).apply(&tail).pack(&shape);
    println!("{:>12} {:>14} {:>14}", "threshold", "unseen flagged", "known flagged");
    for thr in [1, 2, 4] {
        let det = UnseenClassDetector { min_best_sum: thr };
        println!(
            "{:>12} {:>13.0}% {:>13.0}%",
            thr,
            det.flag_rate(&mut tm, &unseen, &params) * 100.0,
            det.flag_rate(&mut tm, &known, &params) * 100.0
        );
    }
    println!("\n(class 2 was withheld at training time — its rows score low on every known class)");
    Ok(())
}
