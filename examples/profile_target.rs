// profiling target: tight train+infer loop
use tm_fpga::data::{blocks::BlockPlan, iris, SetAllocation};
use tm_fpga::tm::*;
fn main() {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 21).unwrap();
    let data = plan.sets(&[0,1,2,3,4], SetAllocation::paper()).unwrap().online.pack(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(1);
    let mut rands = StepRands::draw(&mut rng, &shape);
    let mode = std::env::args().nth(1).unwrap_or_default();
    if mode == "train" {
        for _ in 0..5000 { for (x,y) in &data { rands.refill(&mut rng,&shape); train_step(&mut tm,x,*y,&params,&rands); } }
    } else {
        for _ in 0..200 { for (x,y) in &data { rands.refill(&mut rng,&shape); train_step(&mut tm,x,*y,&params,&rands); } }
        let mut sink = 0usize;
        for _ in 0..200000 { for (x,_) in &data { sink = sink.wrapping_add(tm.predict(x,&params)); } }
        std::hint::black_box(sink);
    }
}
