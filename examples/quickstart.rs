//! Quickstart — the end-to-end driver proving all layers compose.
//!
//! Runs the paper's full Fig-3 flow (offline training → accuracy analysis
//! → 16 interleaved online-learning/analysis iterations) on the real iris
//! workload through the cycle-level FPGA system model, prints the UART
//! log and power/cycle report, and — when `make artifacts` has been run —
//! cross-checks the final machine's accuracy through the PJRT-executed
//! Pallas/JAX artifact (L1/L2) against the native path (L3), asserting
//! they agree exactly.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use tm_fpga::data::{blocks::BlockPlan, iris};
use tm_fpga::fpga::system::{FpgaSystem, SystemConfig};
use tm_fpga::fpga::SetId;
use tm_fpga::runtime::{default_artifacts_dir, Client, TmExecutor};
use tm_fpga::tm::TmParams;

fn main() -> anyhow::Result<()> {
    // 1. Data: the embedded iris dataset, booleanised to the paper's 16
    //    inputs, split into 5 stratified cross-validation blocks.
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42)?;
    let blocks: Vec<_> = (0..plan.n_blocks()).map(|i| plan.block(i).clone()).collect();

    // 2. The paper's §5 configuration: 16 clauses, s=1.375 offline / 1.0
    //    online, T=15, 10 offline epochs, 16 online iterations.
    let cfg = SystemConfig::paper();
    let mut sys = FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4])?;

    // 3. Run the Fig-3 flow end to end on the cycle-level system model.
    let rep = sys.run()?;
    println!("=== UART stream (accuracy reports offloaded to the MCU) ===");
    for line in &rep.uart_log {
        println!("{line}");
    }
    println!("\n=== run report ===");
    println!("total cycles        : {}", rep.total_cycles);
    println!(
        "  @100 MHz that is  : {:.2} ms of FPGA time",
        rep.total_cycles as f64 / 100e6 * 1e3
    );
    println!(
        "handshake stalls    : {} cycles over {} reports",
        rep.handshake.stall_cycles, rep.handshake.transactions
    );
    println!("dropped datapoints  : {}", rep.dropped_datapoints);
    println!(
        "power estimate      : {:.3} W total = {:.3} W MCU + {:.3} W fabric (paper: 1.725 = 1.4 + 0.325)",
        rep.power.total_w, rep.power.mcu_w, rep.power.fabric_w
    );
    println!(
        "online accuracy     : {:.1}% -> {:.1}% over {} iterations",
        rep.online_curve[0] * 100.0,
        rep.online_curve.last().unwrap() * 100.0,
        rep.online_curve.len() - 1
    );

    // 4. Cross-check through the AOT artifacts: the PJRT CPU client loads
    //    the HLO text lowered from the Pallas/JAX step and must agree with
    //    the native machine on every prediction.
    let dir = default_artifacts_dir();
    if dir.join("meta.json").exists() {
        let client = Client::cpu()?;
        let exe = TmExecutor::load(&client, &dir)?;
        let params = TmParams::paper_offline(sys.tm.shape());
        let shape = sys.tm.shape().clone();
        let mut val_rows = Vec::new();
        for row in 0..sys.bank.set_len(SetId::Validation) {
            let ((bits, label), _) =
                sys.bank.read(SetId::Validation, row, tm_fpga::fpga::Port::A)?;
            val_rows.push((tm_fpga::tm::Input::pack(&shape, &bits), label));
        }
        let native = sys.tm.accuracy(&val_rows, &params);
        let pjrt = exe.accuracy(&sys.tm, &val_rows, &params)?;
        assert!((native - pjrt).abs() < 1e-9, "layer mismatch!");
        println!(
            "\n=== three-layer cross-check ===\nvalidation accuracy: native {:.2}% == PJRT(Pallas artifact) {:.2}%  ✓ all layers compose",
            native * 100.0,
            pjrt * 100.0
        );
    } else {
        println!("\n(run `make artifacts` to enable the PJRT cross-check)");
    }
    Ok(())
}
