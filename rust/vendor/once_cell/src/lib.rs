//! Minimal offline shim of `once_cell` (only `sync::Lazy`), backed by
//! `std::sync::OnceLock`. The build image carries no registry crates; this
//! covers the one use in `rust/src/data/iris.rs`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// Lazily-initialised value; the closure runs at most once, on first
    /// deref. `F` defaults to a fn pointer so `static X: Lazy<T>` works.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Lazy { cell: OnceLock::new(), init }
        }

        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(|| (this.init)())
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        static N: Lazy<u64> = Lazy::new(|| 41 + 1);

        #[test]
        fn initialises_once_and_derefs() {
            assert_eq!(*N, 42);
            assert_eq!(*N, 42);
            let local: Lazy<String> = Lazy::new(|| "x".repeat(3));
            assert_eq!(local.len(), 3);
        }
    }
}
