//! Minimal, API-compatible subset of the `anyhow` crate for offline
//! builds (the build image carries no registry). Covers exactly what this
//! repo uses: [`Error`], [`Result`], the [`Context`] trait on `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent. Causes are flattened
//! into the message eagerly (`outer: inner: root`), so `{e}` and `{e:#}`
//! render the same chain the real crate prints with `{:#}`.

use std::fmt;

/// Flattened error: a message with any `std::error::Error` source chain
/// already joined in.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a displayable message (the `anyhow!` entry
    /// point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting its error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "root cause");
    }

    #[test]
    fn context_wraps_outermost_first() {
        let e: Result<()> = Err(io_err()).context("reading x");
        let e = e.with_context(|| format!("loading {}", "y")).unwrap_err();
        assert_eq!(e.to_string(), "loading y: reading x: root cause");
        assert_eq!(format!("{e:#}"), "loading y: reading x: root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fallthrough {}", n))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "n too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fallthrough 1");
    }
}
