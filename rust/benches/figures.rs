//! Bench: regenerate every figure of the paper's evaluation (§5) and time
//! the sweeps. One row per figure — the full 120-ordering run is the
//! paper-fidelity setting; `FIG_ORDERINGS=n` scales it down for quick
//! runs.
//!
//! ```sh
//! cargo bench --bench figures              # 120 orderings, as the paper
//! FIG_ORDERINGS=24 cargo bench --bench figures
//! ```

mod harness;

use tm_fpga::coordinator::{report::figure_summary, run_figure, Figure, SweepOptions};

fn main() {
    let orderings: usize = std::env::var("FIG_ORDERINGS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120);
    let opts = SweepOptions { orderings, threads: 0, seed: 42 };

    println!(
        "regenerating Figures 4-9 over {} cross-validation orderings\n",
        orderings
    );
    let mut rows = Vec::new();
    for fig in Figure::all() {
        let mut result = None;
        let r = harness::bench(
            &format!("{} ({} orderings)", fig.name(), orderings),
            0,
            1,
            (orderings * 17) as u64, // analysis points produced
            || {
                result = Some(run_figure(fig, &opts).expect("figure run"));
            },
        );
        print!("{}", figure_summary(result.as_ref().unwrap()));
        println!();
        rows.push(r);
    }
    harness::report(&rows);
    println!(
        "\n(cf. §5 intro: the cross-validation infrastructure analyses entire \
         datasets in seconds)"
    );
}
