//! Bench: the §6 performance & power tables.
//!
//! Performance rows: the modelled FPGA datapath (2-cycle inference +
//! feedback, one datapoint per clock pipelined, at the 100 MHz reference
//! clock) against measured software paths — the word-parallel engine
//! (lazy bit-sliced randomness + word-batched feedback), the
//! sample-sliced bitplane inference engine (64 samples per AND off
//! cached dataset bitplanes), the scalar oracle (eager `StepRands`, the
//! L2 parity twin), the naive scalar baseline (the paper's "software
//! implementation" comparator), and the PJRT AOT-artifact path. The
//! online-monitor scenario (train 1 / re-score 1 on a converged machine)
//! compares full re-scoring against the incremental dirty-clause engine
//! and prints the measured speedup and dirty fraction.
//!
//! Power rows: the calibrated activity model's decomposition (paper:
//! 1.725 W total, 1.4 W MCU) across gating scenarios.
//!
//! Also emits the next free machine-readable `BENCH_<n>.json` at the repo
//! root (one row per microbenchmark — see EXPERIMENTS.md §Perf for the
//! methodology and recorded numbers); the filename bumps per run so the
//! committed perf trajectory is append-only across PRs.
//!
//! ```sh
//! cargo bench --bench perf_table                  # PERF_ITERS=50 default
//! PERF_ITERS=200 cargo bench --bench perf_table
//! ```

mod harness;

use anyhow::Result;
use tm_fpga::coordinator::perf;

fn main() {
    // `cargo bench --bench perf_table -- --validate [--against PREV] F...`
    // runs the BENCH_<n>.json schema checker / regression gate instead of
    // the benchmarks (the CI bench-compare step). Cargo injects a literal
    // `--bench` into every bench binary's argv — drop it before parsing
    // so it can neither mask `--validate` nor read as a file name.
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    if args.first().map(String::as_str) == Some("--validate") {
        std::process::exit(harness::validate_main(&args[1..]));
    }
    // `-- --write-stub <note> <perf_row name>...` authors a zeroed,
    // schema-valid BENCH_<n>.json through the real renderer — the
    // committed-stub path for toolchain-less environments.
    if args.first().map(String::as_str) == Some("--write-stub") {
        if args.len() < 2 {
            eprintln!("usage: -- --write-stub <meta note> [perf_row name]...");
            std::process::exit(2);
        }
        let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
        match harness::write_zero_stub(&root, &args[1], &args[2..]) {
            Ok(path) => {
                println!("wrote {path}");
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("failed to write stub: {e}");
                std::process::exit(1);
            }
        }
    }

    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    println!("=== §6 performance table ===\n");
    let iters = std::env::var("PERF_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    // Named bindings (not vec indices) so inserting a row can never
    // silently re-point a ratio at the wrong column.
    let fpga_row = perf::fpga_model_row();
    let engine_row = perf::engine_row(iters)?;
    let planes_row = perf::plane_infer_row(iters)?;
    let native_row = perf::native_row(iters)?;
    let naive_row = perf::baseline_row(iters)?;
    let fpga = fpga_row.train_dps;
    let engine = engine_row.train_dps;
    let oracle = native_row.train_dps;
    let naive = naive_row.train_dps;
    let mut rows = vec![fpga_row, engine_row, planes_row, native_row, naive_row];
    match perf::pjrt_row(100) {
        Ok(Some(r)) => rows.push(r),
        Ok(None) => eprintln!("(PJRT row skipped: run `make artifacts`)"),
        Err(e) => eprintln!("(PJRT row failed: {e:#})"),
    }
    match perf::pjrt_epoch_row(30) {
        Ok(Some(r)) => rows.push(r),
        Ok(None) => {}
        Err(e) => eprintln!("(PJRT epoch row failed: {e:#})"),
    }
    print!("{}", perf::perf_table(&rows));
    println!(
        "\nmodelled FPGA vs naive software: {:.0}× on training throughput \
         (the paper's \"minutes … down to a matter of seconds\")",
        fpga / naive
    );
    println!(
        "word-parallel engine vs scalar oracle: {:.1}× training \
         datapoints/s (PR-1 acceptance floor: 5×)",
        engine / oracle
    );

    // The ISSUE-2 acceptance comparison: sample-sliced vs row-major
    // batched inference on a 1k-row single-word batch.
    let (row_major, plane, transpose_s) = perf::plane_comparison(1000, (iters / 2).max(5))?;
    println!(
        "sample-sliced planes vs row-major evaluate_batch (1k rows): \
         {:.1}× ({:.0} vs {:.0} rows/s; transpose {:.3} ms, amortised by \
         the dataset-side plane caches) — PR-2 acceptance floor: 4×",
        plane / row_major,
        plane,
        row_major,
        transpose_s * 1e3
    );

    // The ISSUE-3 acceptance comparison: the interleaved online-monitor
    // loop (train 1 step, re-score a 1k-row cached batch, repeat) with
    // full re-scoring vs the incremental dirty-clause engine, on a
    // converged machine under the paper's online config (s = 1, T = 15 —
    // the regime where the T-threshold makes flips rare).
    let (cold_rs, inc_rs, dirty) = perf::online_monitor_comparison(1000, (iters * 2).max(40))?;
    println!(
        "incremental dirty-clause re-scoring vs full evaluate_planes \
         (online-monitor loop, 1k-row batch): {:.1}× ({:.0} vs {:.0} \
         re-scores/s; converged dirty-fraction {:.3}) — PR-3 acceptance \
         floor: 5×",
        inc_rs / cold_rs,
        inc_rs,
        cold_rs,
        dirty
    );

    // The ISSUE-5 acceptance comparison: converged-phase training epochs
    // through the per-step lazy engine vs the lane-speculative trainer
    // (64 samples per clause AND, mid-lane flip repair), bit-identity
    // asserted inside the driver. The floor applies to the converged
    // phase, where the T-threshold has made flips per lane rare; the
    // printed mean flips/lane is the regime check.
    let (train_per_step, train_lane, train_flips) =
        perf::train_lane_comparison(1024, (iters / 10).max(2))?;
    println!(
        "lane-speculative training vs per-step engine (converged epochs, \
         4×32-clause×128-literal shape, 1k rows): {:.1}× ({:.0} vs {:.0} \
         steps/s; mean flips/lane {:.2}) — PR-5 acceptance floor: 3×",
        train_lane / train_per_step,
        train_lane,
        train_per_step,
        train_flips
    );

    // The ISSUE-4 acceptance comparison: request-at-a-time serving
    // through the sharded micro-batching front door on a 1k-request
    // burst trace — batch-1 single-shard vs micro-batched (64-wide),
    // single-shard and sharded.
    let (serve_b1, serve_m1, serve_m4, serve_width) =
        perf::serve_comparison(1000, 4, (iters / 10).max(3))?;
    println!(
        "micro-batched serving vs batch-1 (1k-request trace, 1 shard): \
         {:.1}× ({:.0} vs {:.0} samples/s; mean batch width {:.1}) — \
         PR-4 acceptance floor: 3×",
        serve_m1 / serve_b1,
        serve_m1,
        serve_b1,
        serve_width
    );
    println!(
        "sharded micro-batched serving (4 shards) vs batch-1: {:.1}× \
         ({:.0} vs {:.0} samples/s)",
        serve_m4 / serve_b1,
        serve_m4,
        serve_b1
    );

    // The ISSUE-6 recovery-latency scenario: worst-case shard recovery
    // (decode + CRC-verify the snapshot, replay the retained log suffix)
    // as a function of checkpoint cadence, on a 512-update Learn log.
    // Dense checkpoints buy short replay at a per-interval snapshot
    // cost; the trade-off is quantified in EXPERIMENTS.md §Robustness.
    let recovery_reps = (iters / 10).max(3);
    let mut recovery = Vec::new();
    for interval in [8u64, 64, 256] {
        let (secs, replayed) = perf::recovery_comparison(512, interval, recovery_reps)?;
        recovery.push((interval, secs, replayed));
    }
    for (interval, secs, replayed) in &recovery {
        println!(
            "recovery restore+replay (ckpt interval {interval}, 512-update log): \
             {:.3} ms ({replayed} updates replayed)",
            secs * 1e3
        );
    }

    // The ISSUE-10 restart-latency scenario: the durable hub's full
    // cold start — WAL segment scan, manifest + checkpoint CRC
    // verification, snapshot restore, keyed suffix replay — against a
    // real data directory left by a 500-update write-ahead run, as a
    // function of the hub's checkpoint cadence. 500 is deliberately not
    // a multiple of any cadence, so each row replays a nonempty,
    // cadence-sized suffix.
    let mut cold_start = Vec::new();
    for cadence in [8u64, 64, 256] {
        let (secs, replayed) = perf::durable_cold_start_comparison(500, cadence, recovery_reps)?;
        cold_start.push((cadence, secs, replayed));
    }
    for (cadence, secs, replayed) in &cold_start {
        println!(
            "hub cold start open+replay (checkpoint_every {cadence}, 500-update WAL): \
             {:.3} ms ({replayed} updates replayed)",
            secs * 1e3
        );
    }

    println!("\n=== §6 power table ===\n");
    match perf::power_table() {
        Ok(rows) => {
            print!("{}", perf::power_table_text(&rows));
            println!("\npaper reference: 1.725 W total, of which 1.4 W microcontroller");
        }
        Err(e) => eprintln!("power table failed: {e:#}"),
    }

    // Micro-rows: the primitive costs behind the table.
    println!("\n=== microbenchmarks ===\n");
    use tm_fpga::data::{blocks::BlockPlan, iris, SetAllocation};
    use tm_fpga::tm::*;
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 21)?;
    let data = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper())?.online.pack(&shape);
    let n_rows = data.len() as u64;
    let mut micro = Vec::new();

    {
        // Seed baseline: eager StepRands refill + scalar train_step.
        let mut tm = MultiTm::new(&shape)?;
        let mut rng = Xoshiro256::new(1);
        let mut rands = StepRands::draw(&mut rng, &shape);
        micro.push(harness::bench(
            "train_step x60 (scalar oracle, eager rands)",
            3,
            20,
            n_rows,
            || {
                for (x, y) in &data {
                    rands.refill(&mut rng, &shape);
                    train_step(&mut tm, x, *y, &params, &rands);
                }
            },
        ));
    }
    {
        // Bit-parallel feedback on the same eager draws (isolates the
        // word-batched apply from the lazy-randomness win).
        let mut tm = MultiTm::new(&shape)?;
        let mut rng = Xoshiro256::new(1);
        let mut rands = StepRands::draw(&mut rng, &shape);
        micro.push(harness::bench(
            "train_step_fast x60 (bit-parallel, eager rands)",
            3,
            20,
            n_rows,
            || {
                for (x, y) in &data {
                    rands.refill(&mut rng, &shape);
                    train_step_fast(&mut tm, x, *y, &params, &rands);
                }
            },
        ));
    }
    {
        // The full word-parallel engine: lazy bit-sliced randomness.
        let mut tm = MultiTm::new(&shape)?;
        let mut rng = Xoshiro256::new(1);
        micro.push(harness::bench(
            "train_epoch x60 (word-parallel engine)",
            3,
            20,
            n_rows,
            || {
                tm.train_epoch(&data, &params, &mut rng);
            },
        ));

        let mut sink = 0usize;
        micro.push(harness::bench("infer x60 (per-row predict)", 3, 20, n_rows, || {
            for (x, _) in &data {
                sink = sink.wrapping_add(tm.predict(x, &params));
            }
        }));
        let inputs: Vec<Input> = data.iter().map(|(x, _)| x.clone()).collect();
        micro.push(harness::bench("infer x60 (predict_batch)", 3, 20, n_rows, || {
            sink = sink.wrapping_add(tm.predict_batch(&inputs, &params).len());
        }));
        let batch = PlaneBatch::from_labelled(&shape, &data);
        micro.push(harness::bench("infer x60 (predict_planes, cached)", 3, 20, n_rows, || {
            sink = sink.wrapping_add(tm.predict_planes(batch.planes(), &params).len());
        }));
        // Steady-state incremental re-score (machine untouched between
        // calls → every clause served clean; the floor the online-monitor
        // loop approaches as flips dry up).
        let mut cache = RescoreCache::new();
        micro.push(harness::bench("infer x60 (rescore cache, clean)", 3, 20, n_rows, || {
            sink = sink.wrapping_add(cache.predict(&tm, batch.planes(), &params).len());
        }));
        std::hint::black_box(sink);

        // The ISSUE-2 batch: 1k rows, single-word shape — row-major vs
        // sample-sliced, plus the one-off transpose cost both amortise.
        let big: Vec<Input> =
            data.iter().map(|(x, _)| x.clone()).cycle().take(1000).collect();
        micro.push(harness::bench("transpose 1k rows -> bitplanes", 3, 20, 1000, || {
            std::hint::black_box(BitPlanes::from_inputs(&shape, &big));
        }));
        let planes = BitPlanes::from_inputs(&shape, &big);
        let mut acc = 0i32;
        micro.push(harness::bench("evaluate_batch 1k rows (row-major)", 3, 20, 1000, || {
            acc = acc.wrapping_add(tm.evaluate_batch(&big, &params, EvalMode::Infer)[0]);
        }));
        micro.push(harness::bench(
            "evaluate_planes 1k rows (sample-sliced)",
            3,
            20,
            1000,
            || {
                acc = acc.wrapping_add(tm.evaluate_planes(&planes, &params, EvalMode::Infer)[0]);
            },
        ));
        std::hint::black_box(acc);
    }
    {
        let mut rng = Xoshiro256::new(1);
        let mut rands = StepRands::draw(&mut rng, &shape);
        micro.push(harness::bench("StepRands refill (eager)", 3, 20, 1, || {
            rands.refill(&mut rng, &shape);
        }));
        let bern = BernoulliPlan::new(params.p_weaken());
        micro.push(harness::bench("BernoulliPlan 64-bit mask", 3, 20, 64, || {
            std::hint::black_box(bern.mask(&mut rng));
        }));
    }
    harness::report(&micro);
    println!(
        "\neager StepRands cost the engine avoids: {} next_u64 draws per step (iris shape)",
        tm_fpga::tm::engine::eager_draws_per_step(&shape)
    );

    // Headline rows land in the JSON trajectory too.
    let mut json_rows = micro;
    json_rows.push(harness::BenchResult {
        name: "perf_row: train dp/s (word-parallel engine)".into(),
        mean_s: if engine > 0.0 { 1.0 / engine } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: train dp/s (scalar oracle)".into(),
        mean_s: if oracle > 0.0 { 1.0 / oracle } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: infer rows/s 1k batch (row-major)".into(),
        mean_s: if row_major > 0.0 { 1.0 / row_major } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: infer rows/s 1k batch (sample-sliced planes)".into(),
        mean_s: if plane > 0.0 { 1.0 / plane } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: online-monitor re-scores/s 1k batch (full evaluate_planes)".into(),
        mean_s: if cold_rs > 0.0 { 1.0 / cold_rs } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: online-monitor re-scores/s 1k batch (incremental dirty-clause)"
            .into(),
        mean_s: if inc_rs > 0.0 { 1.0 / inc_rs } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: train steps/s converged epoch (per-step lazy engine)".into(),
        mean_s: if train_per_step > 0.0 { 1.0 / train_per_step } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: train steps/s converged epoch (lane-speculative)".into(),
        mean_s: if train_lane > 0.0 { 1.0 / train_lane } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: serve samples/s 1k trace (batch-1, 1 shard)".into(),
        mean_s: if serve_b1 > 0.0 { 1.0 / serve_b1 } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: serve samples/s 1k trace (micro-batched, 1 shard)".into(),
        mean_s: if serve_m1 > 0.0 { 1.0 / serve_m1 } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: serve samples/s 1k trace (micro-batched, 4 shards)".into(),
        mean_s: if serve_m4 > 0.0 { 1.0 / serve_m4 } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    for (interval, secs, _) in &recovery {
        json_rows.push(harness::BenchResult {
            name: format!(
                "perf_row: recovery restore+replay (ckpt interval {interval}, 512-update log)"
            ),
            mean_s: *secs,
            min_s: 0.0,
            max_s: 0.0,
            reps: recovery_reps,
            items_per_rep: 1,
        });
    }
    for (cadence, secs, _) in &cold_start {
        json_rows.push(harness::BenchResult {
            name: format!(
                "perf_row: hub cold start open+replay (checkpoint_every {cadence}, \
                 500-update WAL)"
            ),
            mean_s: *secs,
            min_s: 0.0,
            max_s: 0.0,
            reps: recovery_reps,
            items_per_rep: 1,
        });
    }
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    match harness::write_json_next(&root, &json_rows) {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => {
            // A lost BENCH_<n>.json must fail the perf-smoke step loudly:
            // otherwise the CI regression gate silently compares against
            // the committed zero stubs and reads as green.
            anyhow::bail!("failed to write bench json: {e}");
        }
    }
    Ok(())
}
