//! Bench: the §6 performance & power tables.
//!
//! Performance rows: the modelled FPGA datapath (2-cycle inference +
//! feedback, one datapoint per clock pipelined, at the 100 MHz reference
//! clock) against measured software paths — the optimized native
//! bit-parallel implementation, the naive scalar baseline (the paper's
//! "software implementation" comparator), and the PJRT AOT-artifact path.
//!
//! Power rows: the calibrated activity model's decomposition (paper:
//! 1.725 W total, 1.4 W MCU) across gating scenarios.
//!
//! ```sh
//! make artifacts && cargo bench --bench perf_table
//! ```

mod harness;

use tm_fpga::coordinator::perf;

fn main() {
    println!("=== §6 performance table ===\n");
    let iters = std::env::var("PERF_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let mut rows = vec![
        perf::fpga_model_row(),
        perf::native_row(iters),
        perf::baseline_row(iters),
    ];
    match perf::pjrt_row(100) {
        Ok(Some(r)) => rows.push(r),
        Ok(None) => eprintln!("(PJRT row skipped: run `make artifacts`)"),
        Err(e) => eprintln!("(PJRT row failed: {e:#})"),
    }
    match perf::pjrt_epoch_row(30) {
        Ok(Some(r)) => rows.push(r),
        Ok(None) => {}
        Err(e) => eprintln!("(PJRT epoch row failed: {e:#})"),
    }
    print!("{}", perf::perf_table(&rows));

    let fpga = rows[0].train_dps;
    let naive = rows[2].train_dps;
    println!(
        "\nmodelled FPGA vs naive software: {:.0}× on training throughput \
         (the paper's \"minutes … down to a matter of seconds\")",
        fpga / naive
    );

    println!("\n=== §6 power table ===\n");
    match perf::power_table() {
        Ok(rows) => {
            print!("{}", perf::power_table_text(&rows));
            println!("\npaper reference: 1.725 W total, of which 1.4 W microcontroller");
        }
        Err(e) => eprintln!("power table failed: {e:#}"),
    }

    // Micro-rows: the primitive costs behind the table.
    println!("\n=== microbenchmarks ===\n");
    use tm_fpga::data::{blocks::BlockPlan, iris, SetAllocation};
    use tm_fpga::tm::*;
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 21).unwrap();
    let data = plan
        .sets(&[0, 1, 2, 3, 4], SetAllocation::paper())
        .unwrap()
        .online
        .pack(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(1);
    let mut rands = StepRands::draw(&mut rng, &shape);
    let mut micro = Vec::new();
    micro.push(harness::bench("train_step x60 (native)", 3, 20, 60, || {
        for (x, y) in &data {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &rands);
        }
    }));
    let mut sink = 0usize;
    micro.push(harness::bench("infer x60 (native)", 3, 20, 60, || {
        for (x, _) in &data {
            sink = sink.wrapping_add(tm.predict(x, &params));
        }
    }));
    std::hint::black_box(sink);
    micro.push(harness::bench("StepRands refill", 3, 20, 1, || {
        rands.refill(&mut rng, &shape);
    }));
    harness::report(&micro);
}
