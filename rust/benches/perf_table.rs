//! Bench: the §6 performance & power tables.
//!
//! Performance rows: the modelled FPGA datapath (2-cycle inference +
//! feedback, one datapoint per clock pipelined, at the 100 MHz reference
//! clock) against measured software paths — the word-parallel engine
//! (lazy bit-sliced randomness + word-batched feedback), the scalar
//! oracle (eager `StepRands`, the L2 parity twin), the naive scalar
//! baseline (the paper's "software implementation" comparator), and the
//! PJRT AOT-artifact path.
//!
//! Power rows: the calibrated activity model's decomposition (paper:
//! 1.725 W total, 1.4 W MCU) across gating scenarios.
//!
//! Also emits machine-readable `BENCH_1.json` at the repo root (one row
//! per microbenchmark — see EXPERIMENTS.md §Perf for the methodology and
//! recorded numbers) so the perf trajectory is tracked across PRs.
//!
//! ```sh
//! cargo bench --bench perf_table                  # PERF_ITERS=50 default
//! PERF_ITERS=200 cargo bench --bench perf_table
//! ```

mod harness;

use tm_fpga::coordinator::perf;

fn main() {
    println!("=== §6 performance table ===\n");
    let iters = std::env::var("PERF_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let mut rows = vec![
        perf::fpga_model_row(),
        perf::engine_row(iters),
        perf::native_row(iters),
        perf::baseline_row(iters),
    ];
    match perf::pjrt_row(100) {
        Ok(Some(r)) => rows.push(r),
        Ok(None) => eprintln!("(PJRT row skipped: run `make artifacts`)"),
        Err(e) => eprintln!("(PJRT row failed: {e:#})"),
    }
    match perf::pjrt_epoch_row(30) {
        Ok(Some(r)) => rows.push(r),
        Ok(None) => {}
        Err(e) => eprintln!("(PJRT epoch row failed: {e:#})"),
    }
    print!("{}", perf::perf_table(&rows));

    let fpga = rows[0].train_dps;
    let engine = rows[1].train_dps;
    let oracle = rows[2].train_dps;
    let naive = rows[3].train_dps;
    println!(
        "\nmodelled FPGA vs naive software: {:.0}× on training throughput \
         (the paper's \"minutes … down to a matter of seconds\")",
        fpga / naive
    );
    println!(
        "word-parallel engine vs scalar oracle: {:.1}× training \
         datapoints/s (PR-1 acceptance floor: 5×)",
        engine / oracle
    );

    println!("\n=== §6 power table ===\n");
    match perf::power_table() {
        Ok(rows) => {
            print!("{}", perf::power_table_text(&rows));
            println!("\npaper reference: 1.725 W total, of which 1.4 W microcontroller");
        }
        Err(e) => eprintln!("power table failed: {e:#}"),
    }

    // Micro-rows: the primitive costs behind the table.
    println!("\n=== microbenchmarks ===\n");
    use tm_fpga::data::{blocks::BlockPlan, iris, SetAllocation};
    use tm_fpga::tm::*;
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 21).unwrap();
    let data = plan
        .sets(&[0, 1, 2, 3, 4], SetAllocation::paper())
        .unwrap()
        .online
        .pack(&shape);
    let n_rows = data.len() as u64;
    let mut micro = Vec::new();

    {
        // Seed baseline: eager StepRands refill + scalar train_step.
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(1);
        let mut rands = StepRands::draw(&mut rng, &shape);
        micro.push(harness::bench(
            "train_step x60 (scalar oracle, eager rands)",
            3,
            20,
            n_rows,
            || {
                for (x, y) in &data {
                    rands.refill(&mut rng, &shape);
                    train_step(&mut tm, x, *y, &params, &rands);
                }
            },
        ));
    }
    {
        // Bit-parallel feedback on the same eager draws (isolates the
        // word-batched apply from the lazy-randomness win).
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(1);
        let mut rands = StepRands::draw(&mut rng, &shape);
        micro.push(harness::bench(
            "train_step_fast x60 (bit-parallel, eager rands)",
            3,
            20,
            n_rows,
            || {
                for (x, y) in &data {
                    rands.refill(&mut rng, &shape);
                    train_step_fast(&mut tm, x, *y, &params, &rands);
                }
            },
        ));
    }
    {
        // The full word-parallel engine: lazy bit-sliced randomness.
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(1);
        micro.push(harness::bench(
            "train_epoch x60 (word-parallel engine)",
            3,
            20,
            n_rows,
            || {
                tm.train_epoch(&data, &params, &mut rng);
            },
        ));

        let mut sink = 0usize;
        micro.push(harness::bench("infer x60 (per-row predict)", 3, 20, n_rows, || {
            for (x, _) in &data {
                sink = sink.wrapping_add(tm.predict(x, &params));
            }
        }));
        let inputs: Vec<Input> = data.iter().map(|(x, _)| x.clone()).collect();
        micro.push(harness::bench("infer x60 (predict_batch)", 3, 20, n_rows, || {
            sink = sink.wrapping_add(tm.predict_batch(&inputs, &params).len());
        }));
        std::hint::black_box(sink);
    }
    {
        let mut rng = Xoshiro256::new(1);
        let mut rands = StepRands::draw(&mut rng, &shape);
        micro.push(harness::bench("StepRands refill (eager)", 3, 20, 1, || {
            rands.refill(&mut rng, &shape);
        }));
        let bern = BernoulliPlan::new(params.p_weaken());
        micro.push(harness::bench("BernoulliPlan 64-bit mask", 3, 20, 64, || {
            std::hint::black_box(bern.mask(&mut rng));
        }));
    }
    harness::report(&micro);
    println!(
        "\neager StepRands cost the engine avoids: {} next_u64 draws per step (iris shape)",
        tm_fpga::tm::engine::eager_draws_per_step(&shape)
    );

    // Headline engine-vs-oracle rows land in BENCH_1.json too.
    let mut json_rows = micro;
    json_rows.push(harness::BenchResult {
        name: "perf_row: train dp/s (word-parallel engine)".into(),
        mean_s: if engine > 0.0 { 1.0 / engine } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    json_rows.push(harness::BenchResult {
        name: "perf_row: train dp/s (scalar oracle)".into(),
        mean_s: if oracle > 0.0 { 1.0 / oracle } else { 0.0 },
        min_s: 0.0,
        max_s: 0.0,
        reps: iters,
        items_per_rep: 1,
    });
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let path = format!("{root}/BENCH_1.json");
    match harness::write_json(&path, &json_rows) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
