//! Minimal shared bench harness (the offline image has no criterion):
//! warmup + timed repetitions with mean/min/max and throughput reporting.
#![allow(dead_code)] // each bench binary uses a subset of the harness

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub reps: usize,
    /// Items processed per repetition (for throughput lines); 0 = none.
    pub items_per_rep: u64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.items_per_rep == 0 {
            0.0
        } else {
            self.items_per_rep as f64 / self.mean_s
        }
    }
}

/// Time `f` for `reps` repetitions after `warmup` untimed ones.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    reps: usize,
    items_per_rep: u64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    BenchResult {
        name: name.to_string(),
        mean_s: mean,
        min_s: times.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: times.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        reps,
        items_per_rep,
    }
}

/// Next `BENCH_<n>.json` path under `root`: one past the highest
/// existing index (gap-tolerant — BENCH_1 was generated but never
/// committed in PR 1), so each perf_table run appends a fresh file to
/// the perf trajectory instead of overwriting it.
pub fn next_bench_path(root: &str) -> String {
    let mut max_n = 0u32;
    if let Ok(entries) = std::fs::read_dir(root) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if let Some(num) =
                name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json"))
            {
                if let Ok(v) = num.parse::<u32>() {
                    max_n = max_n.max(v);
                }
            }
        }
    }
    format!("{root}/BENCH_{}.json", max_n + 1)
}

/// Write results as machine-readable JSON (one object per row:
/// `{name, mean_s, min_s, max_s, items_per_rep, throughput}`) so the perf
/// trajectory can be tracked across PRs (see EXPERIMENTS.md §Perf).
pub fn write_json(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut s = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"name\": {:?}, \"mean_s\": {:.9}, \"min_s\": {:.9}, \"max_s\": {:.9}, \
             \"items_per_rep\": {}, \"throughput\": {:.3}}}{}\n",
            r.name,
            r.mean_s,
            r.min_s,
            r.max_s,
            r.items_per_rep,
            r.throughput(),
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    std::fs::write(path, s)
}

/// Print a results table.
pub fn report(results: &[BenchResult]) {
    println!(
        "{:<46} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "mean", "min", "max", "throughput"
    );
    for r in results {
        let tp = if r.items_per_rep > 0 {
            format!("{:.0}/s", r.throughput())
        } else {
            "-".to_string()
        };
        println!(
            "{:<46} {:>11.3}ms {:>11.3}ms {:>11.3}ms {:>14}",
            r.name,
            r.mean_s * 1e3,
            r.min_s * 1e3,
            r.max_s * 1e3,
            tp
        );
    }
}
