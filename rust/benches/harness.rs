//! Minimal shared bench harness (the offline image has no criterion):
//! warmup + timed repetitions with mean/min/max and throughput reporting.
#![allow(dead_code)] // each bench binary uses a subset of the harness

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub reps: usize,
    /// Items processed per repetition (for throughput lines); 0 = none.
    pub items_per_rep: u64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.items_per_rep == 0 {
            0.0
        } else {
            self.items_per_rep as f64 / self.mean_s
        }
    }
}

/// Time `f` for `reps` repetitions after `warmup` untimed ones.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    reps: usize,
    items_per_rep: u64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    BenchResult {
        name: name.to_string(),
        mean_s: mean,
        min_s: times.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: times.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        reps,
        items_per_rep,
    }
}

/// Next `BENCH_<n>.json` path under `root`: one past the highest
/// existing index (gap-tolerant — BENCH_1 was generated but never
/// committed in PR 1), so each perf_table run appends a fresh file to
/// the perf trajectory instead of overwriting it. The returned path is
/// only a *candidate*: two concurrent runs can compute the same index,
/// so writers must claim it atomically — use [`write_json_next`], which
/// retries past whoever won the race.
pub fn next_bench_path(root: &str) -> String {
    format!("{root}/BENCH_{}.json", max_bench_index(root) + 1)
}

/// Highest existing `BENCH_<n>.json` index under `root` (0 when none).
fn max_bench_index(root: &str) -> u32 {
    let mut max_n = 0u32;
    if let Ok(entries) = std::fs::read_dir(root) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if let Some(num) =
                name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json"))
            {
                if let Ok(v) = num.parse::<u32>() {
                    max_n = max_n.max(v);
                }
            }
        }
    }
    max_n
}

/// Render results as machine-readable JSON (one object per row:
/// `{name, mean_s, min_s, max_s, items_per_rep, throughput}`) so the perf
/// trajectory can be tracked across PRs (see EXPERIMENTS.md §Perf).
fn render_json(results: &[BenchResult]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"name\": {:?}, \"mean_s\": {:.9}, \"min_s\": {:.9}, \"max_s\": {:.9}, \
             \"items_per_rep\": {}, \"throughput\": {:.3}}}{}\n",
            r.name,
            r.mean_s,
            r.min_s,
            r.max_s,
            r.items_per_rep,
            r.throughput(),
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    s
}

/// Write results to the next free `BENCH_<n>.json` under `root`,
/// tolerating concurrent writers: the full body is written to a
/// process-private temp file first, then the target name is claimed
/// atomically (`hard_link` fails with `AlreadyExists` if a concurrent
/// run took the index — rescan and retry one higher). Two racing runs
/// therefore end up with two distinct files instead of one clobbering
/// the other, and a reader never observes a half-written
/// `BENCH_<n>.json` (on filesystems without hard links the O_EXCL
/// fallback keeps the no-clobber claim atomic but the content lands a
/// write call later). Returns the claimed path.
pub fn write_json_next(root: &str, results: &[BenchResult]) -> std::io::Result<String> {
    let body = render_json(results);
    let tmp = format!("{root}/.BENCH.tmp.{}", std::process::id());
    std::fs::write(&tmp, &body)?;
    let claimed = claim_next_bench(root, &tmp, &body);
    // The temp file must not outlive the call on any path (the pattern
    // is gitignored as a crash backstop, but errors should not leak it).
    let _ = std::fs::remove_file(&tmp);
    claimed
}

/// Author a zeroed, schema-valid `BENCH_<n>.json` stub — the committed
/// placeholder for environments without a Rust toolchain (every stub so
/// far was hand-written to the same shape; this folds that pattern into
/// the real renderer + atomic claim path so a future stub can't drift
/// from the schema the `--validate` checker enforces). `meta_note`
/// becomes the leading `meta:` row (items 0 — skipped by the regression
/// gate like every zero row); each entry of `perf_rows` becomes a zeroed
/// headline row with `items_per_rep` 1. Invoke via
/// `cargo bench --bench perf_table -- --write-stub <note> <row>...`.
pub fn write_zero_stub(
    root: &str,
    meta_note: &str,
    perf_rows: &[String],
) -> std::io::Result<String> {
    let zero = |name: String, items: u64| BenchResult {
        name,
        mean_s: 0.0,
        min_s: 0.0,
        max_s: 0.0,
        reps: 0,
        items_per_rep: items,
    };
    let mut rows = vec![zero(format!("meta: {meta_note}"), 0)];
    for name in perf_rows {
        rows.push(zero(name.clone(), 1));
    }
    write_json_next(root, &rows)
}

/// The claim loop of [`write_json_next`]: find the next free index and
/// take it atomically; the caller owns temp-file cleanup.
fn claim_next_bench(root: &str, tmp: &str, body: &str) -> std::io::Result<String> {
    loop {
        let target = next_bench_path(root);
        match std::fs::hard_link(tmp, &target) {
            Ok(()) => return Ok(target),
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                // Lost the race for this index; the rescan inside
                // next_bench_path now sees the winner and goes higher.
                continue;
            }
            Err(_) => {
                // Filesystem without hard links: claim the name with
                // O_EXCL (atomic, no clobber) and write the body through
                // the claimed handle straight away.
                match std::fs::OpenOptions::new().write(true).create_new(true).open(&target) {
                    Ok(mut f) => {
                        use std::io::Write;
                        if let Err(e) = f.write_all(body.as_bytes()) {
                            // Never leave a claimed-but-truncated file
                            // for the schema checker to trip over.
                            drop(f);
                            let _ = std::fs::remove_file(&target);
                            return Err(e);
                        }
                        return Ok(target);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => continue,
                    Err(e) => return Err(e),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// `--validate` mode: BENCH_<n>.json schema checking + regression gate
// (the CI bench-compare step; see .github/workflows/ci.yml).
// ---------------------------------------------------------------------

/// One parsed row of a `BENCH_<n>.json` file.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub items_per_rep: u64,
    pub throughput: f64,
}

impl BenchRow {
    /// A zero stub: authored without a toolchain (mean 0), carries no
    /// measurement — schema-checked but exempt from the regression gate.
    pub fn is_zero_stub(&self) -> bool {
        self.mean_s == 0.0
    }
}

/// Parse and schema-check one bench JSON document: a non-empty array of
/// objects with exactly the six known keys, finite non-negative timing
/// fields, integral `items_per_rep`, unique non-empty names, and a
/// `throughput` consistent with `items_per_rep / mean_s` (within the
/// file format's 3-decimal rounding) wherever both are non-zero.
pub fn parse_bench_rows(text: &str) -> anyhow::Result<Vec<BenchRow>> {
    use tm_fpga::runtime::json::Json;
    let doc = Json::parse(text)?;
    let arr = doc.as_arr()?;
    anyhow::ensure!(!arr.is_empty(), "bench json must contain at least one row");
    let mut rows = Vec::with_capacity(arr.len());
    let mut seen = std::collections::BTreeSet::new();
    for (i, row) in arr.iter().enumerate() {
        let obj = row.as_obj().map_err(|e| anyhow::anyhow!("row {i}: {e}"))?;
        const KEYS: [&str; 6] =
            ["name", "mean_s", "min_s", "max_s", "items_per_rep", "throughput"];
        for k in obj.keys() {
            anyhow::ensure!(
                KEYS.contains(&k.as_str()),
                "row {i}: unknown key {k:?} (schema allows {KEYS:?})"
            );
        }
        let num = |key: &str| -> anyhow::Result<f64> {
            match row.get(key).map_err(|e| anyhow::anyhow!("row {i}: {e}"))? {
                Json::Num(v) => Ok(*v),
                _ => anyhow::bail!("row {i}: {key} must be a number"),
            }
        };
        let name = row
            .get("name")
            .and_then(|v| v.as_str())
            .map_err(|e| anyhow::anyhow!("row {i}: {e}"))?
            .to_string();
        anyhow::ensure!(!name.is_empty(), "row {i}: empty name");
        anyhow::ensure!(seen.insert(name.clone()), "row {i}: duplicate name {name:?}");
        let mean_s = num("mean_s")?;
        let min_s = num("min_s")?;
        let max_s = num("max_s")?;
        let throughput = num("throughput")?;
        for (key, v) in
            [("mean_s", mean_s), ("min_s", min_s), ("max_s", max_s), ("throughput", throughput)]
        {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "row {i} ({name}): {key} must be finite and >= 0, got {v}"
            );
        }
        let items = row
            .get("items_per_rep")
            .map_err(|e| anyhow::anyhow!("row {i}: {e}"))?
            .as_usize()
            .map_err(|e| anyhow::anyhow!("row {i} ({name}): items_per_rep: {e}"))?
            as u64;
        if mean_s > 0.0 && items > 0 {
            let expect = items as f64 / mean_s;
            // mean_s is written with 9 decimals and throughput with 3:
            // the recomputation can differ by the mean's quantisation
            // (relative 1e-9/mean_s — large for nanosecond-scale rows)
            // plus the throughput's own absolute rounding.
            let tol = expect * (1e-9 / mean_s + 1e-6) + 0.01;
            anyhow::ensure!(
                (throughput - expect).abs() <= tol,
                "row {i} ({name}): throughput {throughput} inconsistent with \
                 items_per_rep/mean_s = {expect:.3}"
            );
        }
        rows.push(BenchRow { name, mean_s, min_s, max_s, items_per_rep: items, throughput });
    }
    Ok(rows)
}

/// Read + schema-check one bench JSON file; returns its rows.
pub fn validate_bench_file(path: &str) -> anyhow::Result<Vec<BenchRow>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    parse_bench_rows(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

/// Regression gate: rows of `cur` that got slower by more than
/// `max_regression` (e.g. 0.25 = +25%) vs the same-named row in `prev`.
/// Gates on the **fastest** repetition (`min_s`) when both artifacts
/// recorded one — the noise-immune statistic on heterogeneous CI
/// runners — falling back to `mean_s` for headline `perf_row:` entries
/// that record only a mean. Zero stubs on either side carry no
/// measurement and are skipped, as are rows without a prior
/// counterpart.
pub fn bench_regressions(
    prev: &[BenchRow],
    cur: &[BenchRow],
    max_regression: f64,
) -> Vec<String> {
    let mut out = Vec::new();
    for c in cur {
        if c.is_zero_stub() {
            continue;
        }
        let Some(p) = prev.iter().find(|p| p.name == c.name) else { continue };
        if p.is_zero_stub() {
            continue;
        }
        let (metric, cur_t, prev_t) = if c.min_s > 0.0 && p.min_s > 0.0 {
            ("min", c.min_s, p.min_s)
        } else {
            ("mean", c.mean_s, p.mean_s)
        };
        if cur_t > prev_t * (1.0 + max_regression) {
            out.push(format!(
                "{}: {metric} {cur_t:.6}s vs prior {prev_t:.6}s (+{:.1}%, gate {:.0}%)",
                c.name,
                (cur_t / prev_t - 1.0) * 100.0,
                max_regression * 100.0
            ));
        }
    }
    out
}

/// Allowed slowdown before the regression gate trips.
pub const MAX_REGRESSION: f64 = 0.25;

/// Wire-telemetry schema self-check, run as part of `--validate`: the
/// versioned per-model telemetry map that `stats` and `bye` frames
/// carry must round-trip bit-identically through the public codec,
/// declare [`tm_fpga::net::TELEMETRY_VERSION`], keep the width
/// histogram at `WIDTH_BUCKETS` buckets, and leave the eight v1 scalar
/// counters byte-identical when the map is empty. Schema drift here
/// breaks every deployed consumer of the stats frame, so CI gates on it
/// next to the bench-JSON schema.
pub fn telemetry_schema_check() -> anyhow::Result<()> {
    use tm_fpga::net::proto::{parse_response, width_bucket, WIDTH_BUCKETS};
    use tm_fpga::net::{ModelTelemetry, Response, WireStats, TELEMETRY_VERSION};
    let mut hist = [0u64; WIDTH_BUCKETS];
    hist[width_bucket(1)] += 3;
    hist[width_bucket(6)] += 2;
    hist[width_bucket(64)] += 1;
    let stats = WireStats {
        infers: 9,
        learns: 4,
        preds: 9,
        shed: 1,
        deadline: 2,
        admission: 3,
        quarantined: 1,
        frame_errors: 0,
        telemetry: vec![
            ModelTelemetry {
                model: "tenant-a".to_string(),
                evictions: 2,
                rehydrations: 2,
                full_flushes: 5,
                deadline_flushes: 1,
                final_flushes: 1,
                width_hist: hist,
                queue_depths: vec![0, 3],
            },
            ModelTelemetry { model: "tenant-b".to_string(), ..Default::default() },
        ],
    };
    for resp in
        [Response::Stats { id: 7, stats: stats.clone() }, Response::Bye { stats: stats.clone() }]
    {
        let wire = resp.encode();
        anyhow::ensure!(
            wire.contains(&format!(" tv={TELEMETRY_VERSION} models=")),
            "telemetry frame must declare its version: {wire:?}"
        );
        let back = parse_response(wire.trim_end())
            .map_err(|e| anyhow::anyhow!("telemetry frame failed to re-parse: {e:#}\n{wire:?}"))?;
        anyhow::ensure!(
            back == resp,
            "telemetry map did not round-trip:\n sent {resp:?}\n got {back:?}"
        );
    }
    // With no telemetry rows the frame is the pinned v1 byte surface.
    let v1 = Response::Bye { stats: WireStats { telemetry: Vec::new(), ..stats } }.encode();
    anyhow::ensure!(
        !v1.contains("tv=") && !v1.contains("models="),
        "empty telemetry must leave the v1 frame untouched: {v1:?}"
    );
    Ok(())
}

/// Entry point of the bench binaries' `--validate` mode
/// (`cargo bench --bench perf_table -- --validate [--against PREV.json]
/// FILE...`): telemetry-schema self-check, then schema-check every
/// file; with `--against`, additionally fail on any measured row
/// regressing more than [`MAX_REGRESSION`] vs the prior file. Returns
/// the process exit code.
pub fn validate_main(args: &[String]) -> i32 {
    let mut against: Option<String> = None;
    let mut files: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--against" {
            match it.next() {
                Some(p) => against = Some(p.clone()),
                None => {
                    eprintln!("--against requires a path");
                    return 2;
                }
            }
        } else {
            files.push(a.clone());
        }
    }
    if files.is_empty() {
        eprintln!("usage: -- --validate [--against PREV.json] BENCH_*.json");
        return 2;
    }
    let mut failed = false;
    match telemetry_schema_check() {
        Ok(()) => println!("ok: wire telemetry schema (round-trip + v1 byte surface)"),
        Err(e) => {
            eprintln!("SCHEMA FAIL (wire telemetry): {e:#}");
            failed = true;
        }
    }
    let mut parsed: Vec<(String, Vec<BenchRow>)> = Vec::new();
    for f in &files {
        match validate_bench_file(f) {
            Ok(rows) => {
                println!("ok: {f} ({} rows)", rows.len());
                parsed.push((f.clone(), rows));
            }
            Err(e) => {
                eprintln!("SCHEMA FAIL: {e:#}");
                failed = true;
            }
        }
    }
    if let Some(prev_path) = against {
        match validate_bench_file(&prev_path) {
            Ok(prev) => {
                for (f, cur) in &parsed {
                    let regressions = bench_regressions(&prev, cur, MAX_REGRESSION);
                    if regressions.is_empty() {
                        println!(
                            "regression gate: {f} vs {prev_path}: OK \
                             (no measured row slower than +{:.0}%)",
                            MAX_REGRESSION * 100.0
                        );
                    } else {
                        failed = true;
                        for r in &regressions {
                            eprintln!("PERF REGRESSION: {f} vs {prev_path}: {r}");
                        }
                    }
                }
            }
            Err(e) => {
                eprintln!("SCHEMA FAIL (baseline): {e:#}");
                failed = true;
            }
        }
    }
    i32::from(failed)
}

/// Print a results table.
pub fn report(results: &[BenchResult]) {
    println!(
        "{:<46} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "mean", "min", "max", "throughput"
    );
    for r in results {
        let tp = if r.items_per_rep > 0 {
            format!("{:.0}/s", r.throughput())
        } else {
            "-".to_string()
        };
        println!(
            "{:<46} {:>11.3}ms {:>11.3}ms {:>11.3}ms {:>14}",
            r.name,
            r.mean_s * 1e3,
            r.min_s * 1e3,
            r.max_s * 1e3,
            tp
        );
    }
}
