//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Booleanisation**: 4-bit binary code (paper default) vs 4-bit
//!    thermometer — thermometer makes iris markedly easier, overshooting
//!    the paper's starting accuracies.
//! 2. **s-style**: the inaction-biased reading of `s` (DESIGN.md
//!    interpretation note) vs canonical Granmo semantics on the Fig-4
//!    flow — canonical at s=1 erodes the offline fit.
//! 3. **Clause over-provisioning**: accuracy as the clause-number port
//!    sweeps 4..16 (the §3.1.1 resource/accuracy trade).
//! 4. **Replay** (§5.1 future work): offline-set retention with and
//!    without interleaved replay rows.
//! 5. **T sweep**: the threshold's effect on feedback issue rate, hence
//!    switching activity (power proxy).
//!
//! ```sh
//! cargo bench --bench ablations
//! ```

mod harness;

use tm_fpga::coordinator::{retention, run_with_replay};
use tm_fpga::data::blocks::{all_orderings, BlockPlan, SetAllocation};
use tm_fpga::data::iris;
use tm_fpga::tm::params::SStyle;
use tm_fpga::tm::*;

const ORDERINGS: usize = 12;
const EPOCHS: usize = 10;

/// Offline-train + report (validation accuracy, mean switching updates /
/// step) for one configuration.
fn eval_config(
    data: &tm_fpga::data::BoolDataset,
    params: &TmParams,
    shape: &TmShape,
    seed: u64,
) -> (f64, f64) {
    let plan = BlockPlan::stratified(data, 5, seed).unwrap();
    let mut acc = 0.0;
    let mut updates = 0u64;
    let mut steps = 0u64;
    for (i, ord) in all_orderings(5).iter().take(ORDERINGS).enumerate() {
        let sets = plan.sets(ord, SetAllocation::paper()).unwrap();
        let train = sets.offline.truncate(20).pack(shape);
        let val = sets.validation.pack(shape);
        let mut tm = MultiTm::new(shape).unwrap();
        let mut rng = Xoshiro256::new(seed + i as u64);
        let mut rands = StepRands::draw(&mut rng, shape);
        for _ in 0..EPOCHS {
            for (x, y) in &train {
                rands.refill(&mut rng, shape);
                let act = train_step(&mut tm, x, *y, params, &rands);
                updates += act.total_updates() as u64;
                steps += 1;
            }
        }
        acc += tm.accuracy(&val, params);
    }
    (acc / ORDERINGS as f64, updates as f64 / steps as f64)
}

fn main() {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);

    println!("=== ablation 1: booleanisation (validation accuracy) ===\n");
    let (bin, _) = eval_config(iris::booleanised(), &params, &shape, 33);
    let (thermo, _) = eval_config(iris::booleanised_thermometer(), &params, &shape, 33);
    println!("binary code (paper default) : {:5.1}%", bin * 100.0);
    println!("thermometer                 : {:5.1}%  (Δ {:+.1}%)", thermo * 100.0, (thermo - bin) * 100.0);
    println!("paper's §5 starting accuracies match the binary-code row.\n");

    println!("=== ablation 2: s-style on the Fig-4 online flow ===\n");
    for style in [SStyle::InactionBiased, SStyle::Canonical] {
        let mut off_delta = 0.0;
        let mut onl_delta = 0.0;
        let n = 8;
        for (i, ord) in all_orderings(5).iter().take(n).enumerate() {
            // run_with_replay(None) is the plain behavioural Fig-4 flow;
            // switch the style via a scoped param tweak below.
            let out = run_fig4_with_style(ord, *&style, 60 + i as u64);
            off_delta += out.0;
            onl_delta += out.1;
        }
        println!(
            "{:<16} offline Δ {:+5.1}%   online Δ {:+5.1}%",
            format!("{style:?}"),
            off_delta / n as f64 * 100.0,
            onl_delta / n as f64 * 100.0
        );
    }
    println!("(the paper's rising offline curve needs the inaction-biased mapping)\n");

    println!("=== ablation 3: clause-number port sweep (§3.1.1) ===\n");
    for clauses in [4usize, 8, 12, 16] {
        let mut p = params.clone();
        p.active_clauses = clauses;
        let (acc, upd) = eval_config(iris::booleanised(), &p, &shape, 44);
        println!(
            "active clauses {:>2} : validation {:5.1}%  ({:.0} TA updates/step)",
            clauses,
            acc * 100.0,
            upd
        );
    }
    println!();

    println!("=== ablation 4: replay vs catastrophic forgetting (§5.1) ===\n");
    let n = 8;
    for interval in [None, Some(10), Some(5), Some(2)] {
        let mut r = 0.0;
        for (i, ord) in all_orderings(5).iter().take(n).enumerate() {
            let out = run_with_replay(ord, 8, interval, 40 + i as u64).unwrap();
            r += retention(&out.offline_curve);
        }
        let label = match interval {
            None => "no replay        ".to_string(),
            Some(k) => format!("replay every {k:>2}  "),
        };
        println!("{label}: offline-set retention {:5.1}%", r / n as f64 * 100.0);
    }
    println!();

    println!("=== ablation 5: threshold T vs switching activity ===\n");
    for t in [1i32, 4, 8, 15, 30] {
        let mut p = params.clone();
        p.t = t;
        let (acc, upd) = eval_config(iris::booleanised(), &p, &shape, 55);
        println!(
            "T = {:>2} : validation {:5.1}%  {:.0} TA updates/step (power proxy)",
            t,
            acc * 100.0,
            upd
        );
    }
    println!();

    println!("=== ablation 6: cyclic-buffer capacity vs data loss (§3.5.2) ===\n");
    for cap in [4usize, 16, 64, 256] {
        let mut cfg = tm_fpga::fpga::SystemConfig::paper();
        cfg.online_iterations = 8;
        cfg.online_buffer_capacity = cap;
        cfg.online_production_interval = 2; // fast source stresses the buffer
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
        let blocks: Vec<_> = (0..5).map(|i| plan.block(i).clone()).collect();
        let mut sys =
            tm_fpga::fpga::FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let rep = sys.run().unwrap();
        println!(
            "capacity {:>4} : dropped {:>4} datapoints, final online acc {:5.1}%",
            cap,
            rep.dropped_datapoints,
            rep.online_curve[8] * 100.0
        );
    }
    println!();

    println!("=== ablation 7: MCU handshake latency vs total cycles (§3.7/§6) ===\n");
    for lat in [1u64, 25, 100, 1000] {
        let mut cfg = tm_fpga::fpga::SystemConfig::paper();
        cfg.online_iterations = 8;
        cfg.online_buffer_capacity = 4096; // isolate the stall effect
        cfg.mcu_handshake_latency = lat;
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
        let blocks: Vec<_> = (0..5).map(|i| plan.block(i).clone()).collect();
        let mut sys =
            tm_fpga::fpga::FpgaSystem::new(cfg, &blocks, &[0, 1, 2, 3, 4]).unwrap();
        let rep = sys.run().unwrap();
        println!(
            "latency {:>4} cycles : total {:>6} cycles ({:>5} in stalls, {:4.1}%)",
            lat,
            rep.total_cycles,
            rep.handshake.stall_cycles,
            rep.handshake.stall_cycles as f64 / rep.total_cycles as f64 * 100.0
        );
    }
    println!("\n(curves are identical across latencies — the handshake is the only coupling, §6)");
}

/// Fig-4 behavioural flow with a chosen s-style; returns (offline delta,
/// online delta).
fn run_fig4_with_style(ordering: &[usize], style: SStyle, seed: u64) -> (f64, f64) {
    let shape = TmShape::iris();
    let plan = BlockPlan::stratified(iris::booleanised(), 5, seed).unwrap();
    let sets = plan.sets(ordering, SetAllocation::paper()).unwrap();
    let train = sets.offline.truncate(20).pack(&shape);
    let full_train = sets.offline.pack(&shape);
    let online = sets.online.pack(&shape);
    let mut p_off = TmParams::paper_offline(&shape);
    let mut p_on = TmParams::paper_online(&shape);
    p_off.s_style = style;
    p_on.s_style = style;
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(seed);
    let mut rands = StepRands::draw(&mut rng, &shape);
    for _ in 0..10 {
        for (x, y) in &train {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &p_off, &rands);
        }
    }
    let off0 = tm.accuracy(&full_train, &p_off);
    let onl0 = tm.accuracy(&online, &p_off);
    for _ in 0..16 {
        for (x, y) in &online {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &p_on, &rands);
        }
    }
    (
        tm.accuracy(&full_train, &p_off) - off0,
        tm.accuracy(&online, &p_off) - onl0,
    )
}
