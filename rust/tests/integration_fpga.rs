//! Integration: the cycle-level FPGA system as a whole — determinism,
//! cycle accounting against the §6 timing claims, clock-gating power
//! behaviour, fault-controller programming over AXI, and the UART report
//! stream.

use tm_fpga::data::blocks::BlockPlan;
use tm_fpga::data::iris;
use tm_fpga::fpga::mcu::McuAction;
use tm_fpga::fpga::system::{FpgaSystem, SystemConfig};
use tm_fpga::fpga::Module;
use tm_fpga::tm::{Fault, FaultMap};

fn blocks() -> Vec<tm_fpga::data::BoolDataset> {
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
    (0..5).map(|i| plan.block(i).clone()).collect()
}

fn quick_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::paper();
    cfg.online_iterations = 4;
    cfg
}

#[test]
fn run_is_fully_deterministic() {
    let b = blocks();
    let mut a = FpgaSystem::new(quick_cfg(), &b, &[0, 1, 2, 3, 4]).unwrap();
    let mut c = FpgaSystem::new(quick_cfg(), &b, &[0, 1, 2, 3, 4]).unwrap();
    let ra = a.run().unwrap();
    let rc = c.run().unwrap();
    assert_eq!(ra.offline_curve, rc.offline_curve);
    assert_eq!(ra.total_cycles, rc.total_cycles);
    assert_eq!(ra.uart_log, rc.uart_log);
    assert_eq!(a.tm.ta().states(), c.tm.ta().states());
}

#[test]
fn cycle_accounting_matches_section6_model() {
    // One analysis pass over a 60-row set costs fill(3) + 60 cycles of
    // compute/stream, plus the handshake stall. Check the aggregate:
    // every analysis record's cycle count is >= rows and close to rows+3.
    let b = blocks();
    let mut sys = FpgaSystem::new(quick_cfg(), &b, &[0, 1, 2, 3, 4]).unwrap();
    let rep = sys.run().unwrap();
    for rec in &rep.records {
        let stored_rows = match rec.set {
            tm_fpga::fpga::SetId::OfflineTrain => 30,
            _ => 60,
        };
        assert!(rec.cycles >= stored_rows);
        assert!(
            rec.cycles <= stored_rows + 3,
            "analysis of {stored_rows} rows took {} cycles",
            rec.cycles
        );
    }
    // Totals: handshake stalls are part of total cycles.
    assert!(rep.total_cycles > rep.handshake.stall_cycles);
}

#[test]
fn tm_core_duty_cycle_reflects_gating() {
    let b = blocks();
    let mut sys = FpgaSystem::new(quick_cfg(), &b, &[0, 1, 2, 3, 4]).unwrap();
    sys.run().unwrap();
    let core = sys.clock.activity(Module::TmCore);
    let total = core.active_cycles + core.gated_cycles;
    assert_eq!(total, sys.clock.now());
    assert!(core.active_cycles > 0);
    assert!(
        core.gated_cycles > 0,
        "the core must be gated during handshakes/waits (§6)"
    );
    // Over-provision slice never enabled with all 16 clauses active.
    assert_eq!(sys.clock.activity(Module::TmOverProvision).active_cycles, 0);
}

#[test]
fn disabled_online_learning_consumes_less_power() {
    let b = blocks();
    let mut on_cfg = quick_cfg();
    on_cfg.online_iterations = 6;
    let mut off_cfg = on_cfg.clone();
    off_cfg.online_learning = false;
    let mut sys_on = FpgaSystem::new(on_cfg, &b, &[0, 1, 2, 3, 4]).unwrap();
    let mut sys_off = FpgaSystem::new(off_cfg, &b, &[0, 1, 2, 3, 4]).unwrap();
    let rep_on = sys_on.run().unwrap();
    let rep_off = sys_off.run().unwrap();
    assert!(
        rep_off.power.fabric_w < rep_on.power.fabric_w,
        "idle TM (clock-gated) must draw less fabric power: {:.3} !< {:.3}",
        rep_off.power.fabric_w,
        rep_on.power.fabric_w
    );
    assert!(rep_off.tm_toggles < rep_on.tm_toggles);
}

#[test]
fn fault_injection_via_mcu_reaches_tm_and_costs_axi_cycles() {
    let b = blocks();
    let mut sys = FpgaSystem::new(quick_cfg(), &b, &[0, 1, 2, 3, 4]).unwrap();
    let shape = sys.tm.shape().clone();
    let map = FaultMap::even_spread(&shape, 0.2, Fault::StuckAt0, 5).unwrap();
    let n = map.count();
    sys.mcu.schedule(2, McuAction::InjectFaults(map));
    let before_axi = sys.clock.activity(Module::AxiInterface).active_cycles;
    sys.run().unwrap();
    assert_eq!(sys.tm.fault().count(), n);
    let axi = sys.clock.activity(Module::AxiInterface).active_cycles - before_axi;
    // 2 writes per TA at 4 cycles each + handshakes.
    assert!(
        axi >= 2 * 4 * n as u64,
        "AXI busy {axi} cycles must cover {} fault writes",
        2 * n
    );
}

#[test]
fn s_and_t_ports_change_behaviour_at_runtime() {
    let b = blocks();
    let mut cfg = quick_cfg();
    cfg.online_iterations = 6;
    let mut sys = FpgaSystem::new(cfg.clone(), &b, &[0, 1, 2, 3, 4]).unwrap();
    // Crank offline s via the port before iteration 2: higher s means the
    // analysis params differ from the run without the action.
    sys.mcu.schedule(2, McuAction::SetT(1));
    let with_action = sys.run().unwrap();
    let mut plain = FpgaSystem::new(cfg, &b, &[0, 1, 2, 3, 4]).unwrap();
    let plain_rep = plain.run().unwrap();
    assert_ne!(
        with_action.offline_curve[2..],
        plain_rep.offline_curve[2..],
        "T port write must alter subsequent analyses"
    );
    assert_eq!(
        with_action.offline_curve[..2],
        plain_rep.offline_curve[..2],
        "behaviour before the write is identical"
    );
}

#[test]
fn uart_log_covers_every_analysis_point() {
    let b = blocks();
    let mut cfg = quick_cfg();
    cfg.online_iterations = 3;
    let mut sys = FpgaSystem::new(cfg, &b, &[0, 1, 2, 3, 4]).unwrap();
    let rep = sys.run().unwrap();
    // 3 sets × (3+1) analysis points.
    assert_eq!(rep.uart_log.len(), 12);
    for it in 0..=3 {
        for set in ["offline", "validation", "online"] {
            assert!(
                rep.uart_log
                    .iter()
                    .any(|l| l.contains(&format!("iter={it} ")) && l.contains(set)),
                "missing report iter={it} set={set}"
            );
        }
    }
}

#[test]
fn clause_output_faults_injectable_via_mcu() {
    // §7 future work: clause-output-level fault injection. Killing all
    // positive clauses of class 0 at iteration 2 makes class 0
    // unpredictable (sum can never go positive) — visible in the
    // analysis records after the event.
    let b = blocks();
    let mut cfg = quick_cfg();
    cfg.online_iterations = 4;
    cfg.online_learning = false;
    let mut sys = FpgaSystem::new(cfg, &b, &[0, 1, 2, 3, 4]).unwrap();
    let kills: Vec<(usize, usize, Option<bool>)> =
        (0..16).step_by(2).map(|j| (0, j, Some(false))).collect();
    sys.mcu.schedule(2, McuAction::InjectClauseFaults(kills));
    let rep = sys.run().unwrap();
    assert_eq!(sys.tm.clause_fault_count(), 8);
    // Offline set (10 class-0 rows of 30): accuracy after the event is
    // capped at 2/3 + (class-0 ties resolved to 0 when all sums equal)…
    // concretely it must not exceed the pre-event value and class-0
    // recall collapses. Compare analysis points.
    let before: Vec<_> = rep.records.iter().filter(|r| r.iteration == 1).collect();
    let after: Vec<_> = rep.records.iter().filter(|r| r.iteration == 3).collect();
    let mean = |rs: &[&tm_fpga::fpga::AccuracyRecord]| {
        rs.iter().map(|r| r.accuracy()).sum::<f64>() / rs.len() as f64
    };
    assert!(
        mean(&after) < mean(&before),
        "killing class-0's positive clauses must hurt: {:.3} !< {:.3}",
        mean(&after),
        mean(&before)
    );
}

#[test]
fn over_provisioned_class_can_be_enabled_later() {
    // Train with 2 active classes, enable the third mid-run: the class
    // mask must admit it and analysis totals stay constant (the data has
    // 3 classes throughout).
    let b = blocks();
    let mut cfg = quick_cfg();
    cfg.online_iterations = 6;
    cfg.active_classes = 2;
    let mut sys = FpgaSystem::new(cfg, &b, &[0, 1, 2, 3, 4]).unwrap();
    sys.mcu.schedule(3, McuAction::SetActiveClasses(3));
    let rep = sys.run().unwrap();
    // After enabling class 2, accuracy on full sets can use all classes;
    // before, class-2 rows are always wrong -> accuracy ceiling 2/3.
    for rec in rep.records.iter().filter(|r| r.iteration < 3) {
        assert!(rec.accuracy() <= 2.0 / 3.0 + 1e-9);
    }
    let late: Vec<_> = rep.records.iter().filter(|r| r.iteration >= 5).collect();
    assert!(
        late.iter().any(|r| r.accuracy() > 2.0 / 3.0),
        "enabled third class should lift the ceiling eventually"
    );
}
