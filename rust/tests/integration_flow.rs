//! Integration: coordinator-level flows — figure staging, replay,
//! monitor/retrain, sweep — driving the full system model.

use tm_fpga::coordinator::{
    configure, retention, run_sweep, run_with_replay, Figure, SweepConfig,
};
use tm_fpga::fpga::mcu::McuAction;

#[test]
fn figure_staging_matches_paper_protocol() {
    // Fig 4: plain config.
    let (cfg, sched) = configure(Figure::Fig4, 1).unwrap();
    assert!(cfg.online_learning && cfg.initial_filter.is_none());
    assert!(sched.is_empty());
    assert_eq!(cfg.offline_epochs, 10);
    assert_eq!(cfg.offline_train_len, Some(20));
    assert_eq!(cfg.online_iterations, 16);
    assert_eq!(cfg.s_offline, 1.375);
    assert_eq!(cfg.s_online, 1.0);
    assert_eq!(cfg.t, 15);

    // Fig 5: filter on, never lifted.
    let (cfg, sched) = configure(Figure::Fig5, 1).unwrap();
    assert_eq!(cfg.initial_filter, Some(0));
    assert!(sched.is_empty());

    // Fig 6: filter lifted before pass 6, learning off.
    let (cfg, sched) = configure(Figure::Fig6, 1).unwrap();
    assert!(!cfg.online_learning);
    assert_eq!(sched.len(), 1);
    assert_eq!(sched[0].0, 6);
    assert!(matches!(sched[0].1, McuAction::SetFilter { enabled: false, class: 0 }));

    // Fig 8/9: 20% stuck-at-0, same map for the same seed.
    let (_, s8) = configure(Figure::Fig8, 9).unwrap();
    let (_, s9) = configure(Figure::Fig9, 9).unwrap();
    match (&s8[0].1, &s9[0].1) {
        (McuAction::InjectFaults(a), McuAction::InjectFaults(b)) => {
            assert_eq!(a, b, "frozen/online comparisons share the fault map");
            let shape = tm_fpga::tm::TmShape::iris();
            assert_eq!(a.count(), (0.2 * shape.num_tas() as f64).round() as usize);
        }
        _ => panic!("figs 8/9 must inject faults"),
    }
}

#[test]
fn replay_flow_improves_retention_without_hurting_online() {
    let ord = [1, 3, 0, 4, 2];
    let plain = run_with_replay(&ord, 10, None, 5).unwrap();
    let replay = run_with_replay(&ord, 10, Some(4), 5).unwrap();
    // Both flows still learn the online set.
    assert!(plain.online_curve[10] >= plain.online_curve[0] - 0.05);
    assert!(replay.online_curve[10] >= replay.online_curve[0] - 0.05);
    // Retention is comparable or better with replay (strict win asserted
    // on the multi-ordering average in the unit tests).
    let (rp, rr) = (retention(&plain.offline_curve), retention(&replay.offline_curve));
    assert!(rr > rp - 0.05, "replay {rr:.3} vs plain {rp:.3}");
}

#[test]
fn sweep_finds_sane_region() {
    let cfg = SweepConfig {
        s_grid: vec![1.375, 8.0],
        t_grid: vec![1, 15],
        orderings: 6,
        epochs: 10,
        threads: 2,
        seed: 3,
    };
    let pts = run_sweep(&cfg).unwrap();
    assert_eq!(pts.len(), 4);
    let best = &pts[0];
    let worst = pts.last().unwrap();
    assert!(
        best.val_accuracy > worst.val_accuracy,
        "grid must discriminate configurations"
    );
    // T=1 clamps sums to ±1 and should not be the winner at any s.
    assert_ne!(best.t, 1, "degenerate T must not win");
}

#[test]
fn large_machine_multiword_end_to_end() {
    // The paper's pre-synthesis parameters allow "arbitrarily-sized
    // machines" (§3.1). A 40-feature machine spans two literal words —
    // exercising the multi-word bit-packing paths (clause eval, fault
    // masks, action cache) through full training, faults and
    // over-provisioning.
    use tm_fpga::data::synthetic::prototype_dataset;
    use tm_fpga::tm::*;
    let shape = TmShape { classes: 4, max_clauses: 12, features: 40, states: 64 };
    assert_eq!(shape.words(), 2, "this test must cover the 2-word path");
    let d = prototype_dataset(4, 50, 40, 0.05, 17).unwrap();
    let train = d.truncate(120).pack(&shape);
    let test = d.subset(&(120..200).collect::<Vec<_>>()).pack(&shape);
    let mut params = TmParams::paper_offline(&shape);
    params.active_clauses = 10; // over-provisioned reserve of 2
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(23);
    let mut rands = StepRands::draw(&mut rng, &shape);
    for _ in 0..15 {
        for (x, y) in &train {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &rands);
        }
    }
    let acc = tm.accuracy(&test, &params);
    assert!(acc > 0.85, "multi-word machine must learn prototypes: {acc:.3}");
    // Fault gates across the word boundary.
    tm.set_fault_map(FaultMap::even_spread(&shape, 0.15, Fault::StuckAt0, 5).unwrap());
    let acc_faulty = tm.accuracy(&test, &params);
    assert!((0.0..=1.0).contains(&acc_faulty));
    // Continue training around the faults with the reserve enabled.
    params.active_clauses = 12;
    for _ in 0..15 {
        for (x, y) in &train {
            rands.refill(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &rands);
        }
    }
    let acc_recovered = tm.accuracy(&test, &params);
    assert!(
        acc_recovered >= acc_faulty - 0.05,
        "retraining must not regress: {acc_recovered:.3} vs {acc_faulty:.3}"
    );
    // Action cache stayed coherent through it all.
    let mut tm2 = tm.clone();
    tm2.rebuild_actions();
    for c in 0..4 {
        for j in 0..12 {
            assert_eq!(tm.action_words(c, j), tm2.action_words(c, j));
        }
    }
}

#[test]
fn all_figures_run_on_two_orderings_without_error() {
    // Smoke over the full figure set (shape assertions live in
    // integration_figures.rs with more orderings).
    let opts = tm_fpga::coordinator::SweepOptions { orderings: 2, threads: 1, seed: 1 };
    for fig in Figure::all() {
        let r = tm_fpga::coordinator::run_figure(fig, &opts).unwrap();
        assert_eq!(r.offline.len(), 17, "{fig:?}");
        assert_eq!(r.orderings, 2);
        assert!(r.mean_cycles > 0.0);
        assert!(r.mean_power_w > 1.4 && r.mean_power_w < 2.0);
    }
}
