//! Integration: the headline reproduction — every figure's *shape*
//! (who wins, direction and rough magnitude of the deltas, crossovers at
//! the iteration-5 events) against the paper's §5 narrative, at a
//! moderate ordering count for CI speed.
//!
//! The full 120-ordering sweep (`cargo bench --bench figures` or
//! `tmfpga fig all`) is recorded in EXPERIMENTS.md.

use tm_fpga::coordinator::{run_figure, Figure, SweepOptions};

fn opts() -> SweepOptions {
    SweepOptions { orderings: 12, threads: 0, seed: 42 }
}

#[test]
fn fig4_labelled_online_learning() {
    let r = run_figure(Figure::Fig4, &opts()).unwrap();
    // Paper: starts 83 / 79.5 / 79.5%; online & validation rise ~+12%,
    // offline rises least (~+5%).
    assert!(
        (0.75..=0.92).contains(&r.offline.mean_at(0)),
        "offline start {:.3} near the paper's 83%",
        r.offline.mean_at(0)
    );
    assert!(r.online.delta() > 0.08, "online Δ {:+.3} ≈ paper +12%", r.online.delta());
    assert!(r.validation.delta() > 0.04, "validation Δ {:+.3}", r.validation.delta());
    assert!(r.offline.delta() > -0.02, "offline must not collapse (paper: +5%)");
    assert!(
        r.offline.delta() < r.online.delta(),
        "offline gains least (§5.1)"
    );
    // Offline training set has the highest starting accuracy (§5.1).
    assert!(r.offline.mean_at(0) > r.validation.mean_at(0));
    assert!(r.offline.mean_at(0) > r.online.mean_at(0));
}

#[test]
fn fig5_filtered_baseline_improves_with_oscillation() {
    let r = run_figure(Figure::Fig5, &opts()).unwrap();
    // Paper: "an increase in accuracy over online training. Oscillations
    // were present."
    assert!(r.online.delta() > 0.0, "online Δ {:+.3}", r.online.delta());
    assert!(
        r.online.mean_at(16) > r.online.mean_at(0) + 0.03,
        "visible improvement on the training stream"
    );
    // No catastrophic event: no single-step drop beyond noise.
    let (_, drop) = r.online.max_drop();
    assert!(drop > -0.15, "baseline has no event-scale drop, got {drop:.3}");
}

#[test]
fn fig6_frozen_system_cannot_absorb_new_class() {
    let r = run_figure(Figure::Fig6, &opts()).unwrap();
    // Sharp drop when the class appears in the analysis sets…
    let (at, drop) = r.validation.max_drop();
    assert_eq!(at, 6);
    assert!(drop < -0.1, "validation drop {drop:.3}");
    // …and no recovery: the last point stays near the post-drop level.
    let post = r.validation.mean_at(6);
    let end = r.validation.mean_at(16);
    assert!((end - post).abs() < 0.05, "frozen system cannot recover");
    // All three sets drop (the paper's Fig 6 shows all sets falling).
    assert!(r.offline.mean_at(16) < r.offline.mean_at(4) - 0.1);
    assert!(r.online.mean_at(16) < r.online.mean_at(4) - 0.1);
}

#[test]
fn fig7_online_learning_absorbs_new_class() {
    let frozen = run_figure(Figure::Fig6, &opts()).unwrap();
    let online = run_figure(Figure::Fig7, &opts()).unwrap();
    // Dip at the event…
    let (at, drop) = online.online.max_drop();
    assert_eq!(at, 6);
    assert!(drop < -0.02);
    // …then recovery clearly above the frozen baseline (paper: "the
    // accuracy soon recovered, showing a significantly positive outcome
    // compared to without online training").
    assert!(
        online.validation.mean_at(16) > frozen.validation.mean_at(16) + 0.1,
        "{:.3} !> {:.3}+0.1",
        online.validation.mean_at(16),
        frozen.validation.mean_at(16)
    );
    // Recovery also beats the dip point.
    assert!(online.online.mean_at(16) > online.online.mean_at(6) + 0.05);
}

#[test]
fn fig8_faults_degrade_frozen_system() {
    let r = run_figure(Figure::Fig8, &opts()).unwrap();
    let (at, drop) = r.offline.max_drop();
    assert_eq!(at, 6, "fault effect lands in analysis 6");
    assert!(drop < 0.0, "offline drop {drop:.3}");
    // Frozen: whatever the faults did persists to the end.
    let post = r.online.mean_at(6);
    assert!((r.online.mean_at(16) - post).abs() < 0.02, "no recovery without learning");
}

#[test]
fn fig9_online_learning_retrains_around_faults() {
    let frozen = run_figure(Figure::Fig8, &opts()).unwrap();
    let online = run_figure(Figure::Fig9, &opts()).unwrap();
    let fault_free = run_figure(Figure::Fig4, &opts()).unwrap();
    // Recovery beats the frozen system…
    assert!(
        online.online.mean_at(16) > frozen.online.mean_at(16) + 0.05,
        "{:.3} !> {:.3}",
        online.online.mean_at(16),
        frozen.online.mean_at(16)
    );
    // …and lands on par with the fault-free Fig-4 system (§5.3.1: "final
    // accuracy increases after 16 iterations being on par with the
    // fault-free system").
    let d = online.online.mean_at(16) - fault_free.online.mean_at(16);
    assert!(d.abs() < 0.08, "fault-mitigated vs fault-free gap {d:.3}");
}

#[test]
fn power_is_consistent_across_figures() {
    // Every figure's mean power stays in the paper's envelope, and the
    // learning-disabled runs (6, 8) consume no more than their learning
    // twins (7, 9) — clock gating at work.
    let f6 = run_figure(Figure::Fig6, &opts()).unwrap();
    let f7 = run_figure(Figure::Fig7, &opts()).unwrap();
    let f8 = run_figure(Figure::Fig8, &opts()).unwrap();
    let f9 = run_figure(Figure::Fig9, &opts()).unwrap();
    for r in [&f6, &f7, &f8, &f9] {
        assert!((1.45..1.95).contains(&r.mean_power_w), "{:.3} W", r.mean_power_w);
    }
    assert!(f6.mean_power_w <= f7.mean_power_w + 1e-6);
    assert!(f8.mean_power_w <= f9.mean_power_w + 1e-6);
}
