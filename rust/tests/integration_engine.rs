//! Word-parallel engine ⇄ scalar oracle differential suite.
//!
//! The engine (`tm::engine`) must be **bit-identical** to the scalar
//! oracle (`tm::feedback::train_step`) given the same eager [`StepRands`]
//! draws — TA-state trajectories, activity counts and predictions — over
//! shapes that exercise every datapath corner: single- and multi-word
//! literal rows, TA fault gates, clause-number/class over-provisioning,
//! both `s`-styles and boost. The lazy-randomness mode has no bitwise
//! oracle (that is the point: it draws less), so it is held to
//! statistical equivalence on the paper's iris workload instead.

use tm_fpga::data::{blocks::BlockPlan, iris, SetAllocation};
use tm_fpga::testkit::gen;
use tm_fpga::tm::params::SStyle;
use tm_fpga::tm::*;

/// Run `steps` random training steps through both paths and assert
/// bitwise agreement at every step.
fn assert_bit_identical(shape: &TmShape, params: &TmParams, fault_rate: f64, seed: u64, steps: usize) {
    let mut oracle = MultiTm::new(shape).unwrap();
    let mut fast = MultiTm::new(shape).unwrap();
    if fault_rate > 0.0 {
        let map =
            FaultMap::even_spread(shape, fault_rate, Fault::StuckAt0, seed ^ 0xF417).unwrap();
        oracle.set_fault_map(map.clone());
        fast.set_fault_map(map);
    }
    let mut rng = Xoshiro256::new(seed);
    for step in 0..steps {
        let x = gen::input(&mut rng, shape);
        let target = step % shape.classes;
        let r = StepRands::draw(&mut rng, shape);
        let a = train_step(&mut oracle, &x, target, params, &r);
        let b = train_step_fast(&mut fast, &x, target, params, &r);
        assert_eq!(a, b, "activity diverged at step {step}");
        assert_eq!(
            oracle.ta().states(),
            fast.ta().states(),
            "TA states diverged at step {step}"
        );
        assert_eq!(
            oracle.predict(&x, params),
            fast.predict(&x, params),
            "prediction diverged at step {step}"
        );
    }
}

#[test]
fn bit_parity_iris_offline() {
    let s = TmShape::iris();
    assert_bit_identical(&s, &TmParams::paper_offline(&s), 0.0, 0xA0, 400);
}

#[test]
fn bit_parity_iris_online_s1() {
    let s = TmShape::iris();
    assert_bit_identical(&s, &TmParams::paper_online(&s), 0.0, 0xA1, 400);
}

#[test]
fn bit_parity_under_faults_and_overprovisioning() {
    let s = TmShape::iris();
    let mut p = TmParams::paper_offline(&s);
    p.active_clauses = 12;
    p.active_classes = 2;
    assert_bit_identical(&s, &p, 0.20, 0xA2, 300);
}

#[test]
fn bit_parity_multiword_shapes() {
    // 80 literals (2 words, second partial) and 128 literals (2 full).
    for (i, s) in [
        TmShape { classes: 3, max_clauses: 8, features: 40, states: 16 },
        TmShape { classes: 2, max_clauses: 4, features: 64, states: 8 },
    ]
    .into_iter()
    .enumerate()
    {
        let mut p = TmParams::paper_offline(&s);
        p.t = 5;
        assert_bit_identical(&s, &p, 0.0, 0xB0 + i as u64, 250);
        assert_bit_identical(&s, &p, 0.15, 0xC0 + i as u64, 250);
    }
}

#[test]
fn bit_parity_canonical_style_and_boost() {
    let s = TmShape::iris();
    let mut p = TmParams::paper_offline(&s);
    p.s = 2.0;
    p.s_style = SStyle::Canonical;
    assert_bit_identical(&s, &p, 0.0, 0xD0, 250);
    p.boost_true_positive = true;
    assert_bit_identical(&s, &p, 0.0, 0xD1, 250);
}

/// The lazy-randomness engine must learn iris like the oracle does:
/// same workload, same epoch count — accuracies within a few points.
#[test]
fn lazy_engine_statistically_matches_oracle_on_iris() {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 20).unwrap();
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
    let train = sets.offline.pack(&shape);
    let val = sets.validation.pack(&shape);

    // Average over a few seeds: both paths are stochastic learners.
    let runs = 4;
    let epochs = 15;
    let mut acc_oracle = (0.0, 0.0);
    let mut acc_lazy = (0.0, 0.0);
    for seed in 0..runs {
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(100 + seed);
        let mut rands = StepRands::draw(&mut rng, &shape);
        for _ in 0..epochs {
            for (x, y) in &train {
                rands.refill(&mut rng, &shape);
                train_step(&mut tm, x, *y, &params, &rands);
            }
        }
        acc_oracle.0 += tm.accuracy(&train, &params) / runs as f64;
        acc_oracle.1 += tm.accuracy(&val, &params) / runs as f64;

        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(200 + seed);
        for _ in 0..epochs {
            tm.train_epoch(&train, &params, &mut rng);
        }
        acc_lazy.0 += tm.accuracy(&train, &params) / runs as f64;
        acc_lazy.1 += tm.accuracy(&val, &params) / runs as f64;
    }
    assert!(acc_oracle.0 > 0.7, "oracle train acc {:.3}", acc_oracle.0);
    assert!(acc_lazy.0 > 0.7, "lazy train acc {:.3}", acc_lazy.0);
    assert!(
        (acc_lazy.0 - acc_oracle.0).abs() < 0.12,
        "train accuracy gap: lazy {:.3} vs oracle {:.3}",
        acc_lazy.0,
        acc_oracle.0
    );
    assert!(
        (acc_lazy.1 - acc_oracle.1).abs() < 0.15,
        "validation accuracy gap: lazy {:.3} vs oracle {:.3}",
        acc_lazy.1,
        acc_oracle.1
    );
}

/// Batched inference agrees with per-row inference on a trained machine,
/// and the epoch driver is deterministic in its seed.
#[test]
fn batched_paths_consistent_on_trained_machine() {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 9).unwrap();
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
    let train = sets.offline.pack(&shape);
    let val = sets.validation.pack(&shape);

    let mut a = MultiTm::new(&shape).unwrap();
    let mut b = MultiTm::new(&shape).unwrap();
    let mut rng_a = Xoshiro256::new(4242);
    let mut rng_b = Xoshiro256::new(4242);
    for _ in 0..10 {
        let sa = a.train_epoch(&train, &params, &mut rng_a);
        let sb = b.train_epoch(&train, &params, &mut rng_b);
        assert_eq!(sa, sb, "epoch stats must be deterministic in the seed");
    }
    assert_eq!(a.ta().states(), b.ta().states());

    // predict_batch == predict, accuracy_batch == accuracy.
    let inputs: Vec<Input> = val.iter().map(|(x, _)| x.clone()).collect();
    let preds = a.predict_batch(&inputs, &params);
    for (i, x) in inputs.iter().enumerate() {
        assert_eq!(preds[i], a.predict(x, &params), "row {i}");
    }
    let acc_batch = a.accuracy_batch(&val, &params);
    let acc_scalar = a.accuracy(&val, &params);
    assert!((acc_batch - acc_scalar).abs() < 1e-12);
    assert!(acc_batch > 0.5, "trained machine should beat chance: {acc_batch:.3}");
}

/// The engine's action cache survives long mixed workloads (fast +
/// lazy + clause faults interleaved) — rebuild always agrees.
#[test]
fn mixed_workload_keeps_action_cache_coherent() {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let feedback_plan = FeedbackPlan::new(&params);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(0xC0DE);
    for step in 0..500 {
        let x = gen::input(&mut rng, &shape);
        match step % 3 {
            0 => {
                let r = StepRands::draw(&mut rng, &shape);
                train_step_fast(&mut tm, &x, step % 3, &params, &r);
            }
            1 => {
                train_step_lazy(&mut tm, &x, step % 3, &params, &feedback_plan, &mut rng);
            }
            _ => {
                // Clause faults toggle the evaluation path mid-run.
                tm.set_clause_fault(0, (step / 3) % 16, Some(step % 2 == 0));
                let r = StepRands::draw(&mut rng, &shape);
                train_step_fast(&mut tm, &x, step % 3, &params, &r);
                tm.set_clause_fault(0, (step / 3) % 16, None);
            }
        }
    }
    assert_eq!(tm.clause_fault_count(), 0);
    let mut rebuilt = tm.clone();
    rebuilt.rebuild_actions();
    for c in 0..3 {
        for j in 0..16 {
            assert_eq!(
                tm.action_words(c, j),
                rebuilt.action_words(c, j),
                "cache incoherent at ({c},{j})"
            );
        }
    }
    assert!(tm.ta().states().iter().all(|&v| v <= shape.max_state()));
}
