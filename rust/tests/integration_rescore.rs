//! Incremental dirty-clause re-scoring ⇄ cold-pass differential suite.
//!
//! `RescoreCache::evaluate` must be **bit-identical** to a cold
//! `MultiTm::evaluate_planes` pass at every point of an interleaved
//! online run, over every invalidation corner: randomized train/infer
//! schedules through both the eager (`train_step_fast`) and lazy
//! (`train_step_lazy`) engines, mid-run TA fault-map injection and raw
//! fault-map edits, clause-output force overrides, run-time parameter
//! moves (T, active clauses, active classes), multiword shapes,
//! non-multiple-of-64 batches, machine clones, checkpoint-style bulk
//! state reloads, and batches whose content changes under the cache
//! (fingerprint invalidation).

use tm_fpga::serve::{restore, snapshot_bytes};
use tm_fpga::testkit::gen;
use tm_fpga::tm::*;

fn random_rows(
    shape: &TmShape,
    n: usize,
    rng: &mut Xoshiro256,
) -> Vec<(Input, usize)> {
    gen::rows(rng, shape, n)
}

/// Machine with uniformly random TA states (random include patterns),
/// plus the continued RNG stream for dataset draws.
fn random_machine(shape: &TmShape, seed: u64) -> (MultiTm, Xoshiro256) {
    let mut rng = Xoshiro256::new(seed);
    let tm = gen::machine(&mut rng, shape);
    (tm, rng)
}

/// One re-score point: the incremental result must equal the cold pass
/// bit-for-bit, in both modes, and the prediction/accuracy wrappers must
/// agree with their cold twins. The caller's cache stays pure-Infer (the
/// monitor regime it models); Train mode goes through a throwaway cache,
/// since a mode switch rebuilds an entry by design.
fn assert_rescore_matches(
    cache: &mut RescoreCache,
    tm: &MultiTm,
    batch: &PlaneBatch,
    params: &TmParams,
    ctx: &str,
) {
    let inc = cache.evaluate(tm, batch.planes(), params, EvalMode::Infer);
    let cold = tm.evaluate_planes(batch.planes(), params, EvalMode::Infer);
    assert_eq!(inc, cold, "{ctx}: sums diverged (Infer)");
    let mut train_cache = RescoreCache::new();
    let inc_t = train_cache.evaluate(tm, batch.planes(), params, EvalMode::Train);
    let cold_t = tm.evaluate_planes(batch.planes(), params, EvalMode::Train);
    assert_eq!(inc_t, cold_t, "{ctx}: sums diverged (Train)");
    assert_eq!(
        cache.predict(tm, batch.planes(), params),
        tm.predict_planes(batch.planes(), params),
        "{ctx}: predictions diverged"
    );
    let a = cache.accuracy(tm, batch, params);
    let b = tm.accuracy_planes(batch, params);
    assert_eq!(a, b, "{ctx}: accuracy diverged");
}

#[test]
fn randomized_interleaved_schedules_stay_bit_identical() {
    for (si, shape) in [
        TmShape::iris(),                                                 // 1 word
        TmShape { classes: 4, max_clauses: 6, features: 40, states: 8 }, // 2 words, partial
    ]
    .iter()
    .enumerate()
    {
        let (mut tm, mut rng) = random_machine(shape, 0x0D17 + si as u64);
        let mut params = TmParams::paper_offline(shape);
        let n = [70usize, 129][si]; // engages multi-lane + partial tails
        let rows = random_rows(shape, n, &mut rng);
        let batch = PlaneBatch::from_labelled(shape, &rows);
        let mut cache = RescoreCache::new();
        let mut rands = StepRands::draw(&mut rng, shape);
        let plan = FeedbackPlan::new(&params);
        for step in 0..120usize {
            // Randomized interleave: train (both engines), mutate faults
            // and forces mid-run, wobble the run-time parameters.
            match rng.next_below(10) {
                0..=4 => {
                    let (x, y) = &rows[rng.next_below(rows.len())];
                    rands.refill(&mut rng, shape);
                    train_step_fast(&mut tm, x, *y, &params, &rands);
                }
                5..=6 => {
                    let (x, y) = &rows[rng.next_below(rows.len())];
                    train_step_lazy(&mut tm, x, *y, &params, &plan, &mut rng);
                }
                7 => {
                    let c = rng.next_below(shape.classes);
                    let j = rng.next_below(shape.max_clauses);
                    let force = match rng.next_below(3) {
                        0 => None,
                        1 => Some(false),
                        _ => Some(true),
                    };
                    tm.set_clause_fault(c, j, force);
                }
                8 => {
                    let rate = [0.0, 0.1, 0.25][rng.next_below(3)];
                    let kind =
                        if rng.next_f32() < 0.5 { Fault::StuckAt0 } else { Fault::StuckAt1 };
                    let map =
                        FaultMap::even_spread(shape, rate, kind, 0xFA + step as u64).unwrap();
                    tm.set_fault_map(map);
                }
                _ => {
                    params.t = [1, 5, 15][rng.next_below(3)];
                    if rng.next_f32() < 0.3 {
                        params.active_clauses = [2, 4, shape.max_clauses][rng.next_below(3)];
                        params.active_classes = 1 + rng.next_below(shape.classes);
                    }
                }
            }
            if step % 3 == 0 {
                assert_rescore_matches(
                    &mut cache,
                    &tm,
                    &batch,
                    &params,
                    &format!("shape {si} step {step}"),
                );
            }
        }
        // The schedule must have exercised the incremental path, not
        // degenerated into rebuild-every-time.
        assert!(cache.stats().clean_clauses > 0, "shape {si}: no clean serves");
        assert!(cache.stats().dirty_clauses > 0, "shape {si}: no dirty re-scores");
    }
}

#[test]
fn raw_fault_map_edits_conservatively_invalidate() {
    let shape = TmShape::iris();
    let (mut tm, mut rng) = random_machine(&shape, 0x2222);
    let params = TmParams::paper_offline(&shape);
    let rows = random_rows(&shape, 50, &mut rng);
    let batch = PlaneBatch::from_labelled(&shape, &rows);
    let mut cache = RescoreCache::new();
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "before edit");
    // Editing gates through the raw write port must dirty the cache even
    // though no TA state moved.
    tm.fault_map_mut().set(0, 0, 3, Fault::StuckAt1);
    tm.fault_map_mut().set(1, 2, 17, Fault::StuckAt0);
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "after edit");
}

#[test]
fn checkpoint_reload_and_clone_are_safe() {
    let shape = TmShape::iris();
    let (mut tm, mut rng) = random_machine(&shape, 0x3333);
    let params = TmParams::paper_offline(&shape);
    let rows = random_rows(&shape, 65, &mut rng);
    let batch = PlaneBatch::from_labelled(&shape, &rows);
    let mut cache = RescoreCache::new();
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "initial");
    // Clone + diverge: the same cache must rebuild for the clone (fresh
    // uid), then again for the original, and stay exact for both.
    let mut fork = tm.clone();
    let mut rands = StepRands::draw(&mut rng, &shape);
    for step in 0..10 {
        let (x, y) = &rows[step % rows.len()];
        rands.refill(&mut rng, &shape);
        train_step_fast(&mut fork, x, *y, &params, &rands);
    }
    assert_rescore_matches(&mut cache, &fork, &batch, &params, "diverged clone");
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "original after clone");
    // Checkpoint-style bulk reload: from_states machines carry fresh
    // uids; a reload of *different* states must never read stale masks.
    let reloaded = MultiTm::from_states(&shape, fork.ta().states().to_vec()).unwrap();
    assert_rescore_matches(&mut cache, &reloaded, &batch, &params, "bulk reload");
}

#[test]
fn fingerprint_invalidation_tracks_batch_content() {
    let shape = TmShape::iris();
    let (tm, mut rng) = random_machine(&shape, 0x4444);
    let params = TmParams::paper_offline(&shape);
    let rows_a = random_rows(&shape, 40, &mut rng);
    let mut rows_b = rows_a.clone();
    // Same length, exactly one feature flipped: a guaranteed-distinct batch.
    let mut bits: Vec<bool> =
        (0..shape.features).map(|k| rows_a[7].0.literal(k)).collect();
    bits[0] = !bits[0];
    rows_b[7].0 = Input::pack(&shape, &bits);
    let batch_a = PlaneBatch::from_labelled(&shape, &rows_a);
    let batch_b = PlaneBatch::from_labelled(&shape, &rows_b);
    assert_ne!(
        batch_a.planes().fingerprint(),
        batch_b.planes().fingerprint(),
        "content change must move the fingerprint"
    );
    // A re-transpose of identical content keeps the fingerprint (and the
    // cache entry).
    let batch_a2 = PlaneBatch::from_labelled(&shape, &rows_a);
    assert_eq!(batch_a.planes().fingerprint(), batch_a2.planes().fingerprint());

    let mut cache = RescoreCache::new();
    assert_rescore_matches(&mut cache, &tm, &batch_a, &params, "batch a");
    let builds_after_a = cache.stats().cold_builds;
    assert_rescore_matches(&mut cache, &tm, &batch_b, &params, "batch b");
    assert!(
        cache.stats().cold_builds > builds_after_a,
        "different content must cold-build"
    );
    // Alternating batches stays exact (both entries live side by side).
    assert_rescore_matches(&mut cache, &tm, &batch_a2, &params, "batch a again");
    assert_rescore_matches(&mut cache, &tm, &batch_b, &params, "batch b again");
}

#[test]
fn online_convergence_drives_dirty_fraction_down() {
    // The paper's scenario: under the online config (s = 1) on a trained
    // machine, T-threshold feedback is rare — later re-scores must serve
    // mostly clean clauses, and every point must stay bit-identical.
    let shape = TmShape::iris();
    let p_off = TmParams::paper_offline(&shape);
    let p_on = TmParams::paper_online(&shape);
    let mut rng = Xoshiro256::new(0x5555);
    let rows = random_rows(&shape, 60, &mut rng);
    let mut tm = MultiTm::new(&shape).unwrap();
    for _ in 0..10 {
        tm.train_epoch(&rows, &p_off, &mut rng);
    }
    let batch = PlaneBatch::from_labelled(&shape, &rows);
    let mut cache = RescoreCache::new();
    let mut rands = StepRands::draw(&mut rng, &shape);
    for step in 0..80usize {
        let (x, y) = &rows[step % rows.len()];
        rands.refill(&mut rng, &shape);
        train_step_fast(&mut tm, x, *y, &p_on, &rands);
        assert_rescore_matches(&mut cache, &tm, &batch, &p_off, &format!("step {step}"));
    }
    let stats = cache.stats();
    assert!(
        stats.dirty_fraction() < 0.5,
        "converged online run should be mostly clean, got {:.3} ({stats:?})",
        stats.dirty_fraction()
    );
}

/// The mutation-clock / checkpoint contract (ISSUE 7 satellite 3): a
/// machine restored from snapshot bytes carries a **fresh** uid, so a
/// RescoreCache entry built against the pre-snapshot machine can never
/// validate against the restored one — the first re-score after restore
/// must cold-rebuild even though neither the TA states nor the batch
/// fingerprint moved.
#[test]
fn restored_snapshot_gets_fresh_uid_and_forces_cold_rescore() {
    let shape = TmShape::iris();
    let (tm, mut rng) = random_machine(&shape, 0x6666);
    let params = TmParams::paper_offline(&shape);
    let rows = random_rows(&shape, 40, &mut rng);
    let batch = PlaneBatch::from_labelled(&shape, &rows);
    let mut cache = RescoreCache::new();
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "before snapshot");

    let snap = restore(&snapshot_bytes(&tm, &params, 7)).unwrap();
    assert_eq!(snap.seq, 7);
    assert_eq!(
        snap.machine.state_digest(),
        tm.state_digest(),
        "restore must reproduce the TA state bit-for-bit"
    );
    assert_ne!(
        snap.machine.uid(),
        tm.uid(),
        "restore must mint a fresh mutation clock, not resurrect the snapshot's"
    );

    // Same batch fingerprint, same states — but the uid moved, so the
    // cache must treat the restored machine as unknown.
    let cold_before = cache.stats().cold_builds;
    assert_rescore_matches(&mut cache, &snap.machine, &batch, &params, "restored");
    assert!(
        cache.stats().cold_builds > cold_before,
        "stale entry validated against a restored machine uid"
    );

    // Restores never alias each other either: snapshotting the restored
    // machine and restoring again mints yet another uid.
    let again = restore(&snapshot_bytes(&snap.machine, &params, 8)).unwrap();
    assert_ne!(again.machine.uid(), snap.machine.uid());
    assert_ne!(again.machine.uid(), tm.uid());
    assert_eq!(again.machine.state_digest(), tm.state_digest());
}

/// Training the restored machine moves only *its* clock: the cache must
/// rebuild whenever it alternates between the original and the diverged
/// restore (their uids never alias), and both machines re-score exactly
/// at every point.
#[test]
fn restored_machine_clock_is_independent_of_the_original() {
    let shape = TmShape::iris();
    let (tm, mut rng) = random_machine(&shape, 0x7777);
    let params = TmParams::paper_offline(&shape);
    let rows = random_rows(&shape, 50, &mut rng);
    let batch = PlaneBatch::from_labelled(&shape, &rows);
    let mut snap = restore(&snapshot_bytes(&tm, &params, 1)).unwrap();

    let mut cache = RescoreCache::new();
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "original");
    let builds_after_original = cache.stats().cold_builds;

    // Diverge the restored machine: its evaluations must never be served
    // from the original's entry (or vice versa).
    let mut rands = StepRands::draw(&mut rng, &shape);
    for step in 0..20 {
        let (x, y) = &rows[step % rows.len()];
        rands.refill(&mut rng, &shape);
        train_step_fast(&mut snap.machine, x, *y, &params, &rands);
    }
    assert_rescore_matches(&mut cache, &snap.machine, &batch, &params, "diverged restore");
    assert!(
        cache.stats().cold_builds > builds_after_original,
        "diverged restore must not be served from the original's entry"
    );
    assert_rescore_matches(&mut cache, &tm, &batch, &params, "original after divergence");
    assert_rescore_matches(&mut cache, &snap.machine, &batch, &params, "restore again");
}
