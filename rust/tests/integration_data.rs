//! Integration: the data pipeline end to end — raw iris → booleanisation
//! → stratified blocks → ROM bank → memory manager / online path — and
//! cross-subsystem consistency between the behavioural and RTL views.

use tm_fpga::data::blocks::{all_orderings, BlockPlan, SetAllocation};
use tm_fpga::data::{iris, BoolDataset, ClassFilter};
use tm_fpga::fpga::memmgr::MemoryManager;
use tm_fpga::fpga::rom::{Port, RomBank, SetId};
use tm_fpga::tm::{Input, TmShape};

fn blocks() -> Vec<BoolDataset> {
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
    (0..5).map(|i| plan.block(i).clone()).collect()
}

#[test]
fn rom_bank_agrees_with_block_plan_sets() {
    // The RTL view (RomBank streaming) must produce exactly the rows the
    // behavioural view (BlockPlan::sets) produces, in the same order.
    let shape = TmShape::iris();
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
    for ord in all_orderings(5).iter().step_by(17) {
        let sets = plan.sets(ord, SetAllocation::paper()).unwrap();
        let mut bank = RomBank::new(&blocks(), ord, (1, 2, 2)).unwrap();
        let mm = MemoryManager::new(&shape);
        for (set_id, expected) in [
            (SetId::OfflineTrain, &sets.offline),
            (SetId::Validation, &sets.validation),
            (SetId::OnlineTrain, &sets.online),
        ] {
            let (rows, _) = mm.stream(&mut bank, set_id, Port::A, None).unwrap();
            assert_eq!(rows.len(), expected.len());
            for (i, (input, label)) in rows.iter().enumerate() {
                assert_eq!(*label, expected.labels[i], "{set_id:?} row {i}");
                assert_eq!(*input, Input::pack(&shape, &expected.rows[i]));
            }
        }
    }
}

#[test]
fn filter_consistency_across_views() {
    let shape = TmShape::iris();
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
    let ord = [3, 1, 4, 0, 2];
    let sets = plan.sets(&ord, SetAllocation::paper()).unwrap();
    let mut bank = RomBank::new(&blocks(), &ord, (1, 2, 2)).unwrap();
    let mut mm = MemoryManager::new(&shape);
    mm.filter = ClassFilter::removing(1);
    let behavioural = ClassFilter::removing(1).apply(&sets.validation);
    let (rtl, _) = mm.stream(&mut bank, SetId::Validation, Port::A, None).unwrap();
    assert_eq!(rtl.len(), behavioural.len());
    for (i, (_, label)) in rtl.iter().enumerate() {
        assert_eq!(*label, behavioural.labels[i]);
    }
}

#[test]
fn every_ordering_partitions_data() {
    // Across any ordering, the three sets are disjoint by construction
    // and cover all 150 rows.
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 42).unwrap();
    for ord in all_orderings(5).iter().take(24) {
        let sets = plan.sets(ord, SetAllocation::paper()).unwrap();
        assert_eq!(
            sets.offline.len() + sets.validation.len() + sets.online.len(),
            150
        );
        // Class balance preserved per set (stratified blocks).
        assert_eq!(sets.offline.class_counts(), vec![10, 10, 10]);
        assert_eq!(sets.validation.class_counts(), vec![20, 20, 20]);
        assert_eq!(sets.online.class_counts(), vec![20, 20, 20]);
    }
}

#[test]
fn booleanisation_is_deterministic_and_16_wide() {
    let a = iris::booleanised();
    let b = iris::booleanizer().unwrap().encode(iris::raw()).unwrap();
    assert_eq!(a.rows, b.rows);
    assert!(a.rows.iter().all(|r| r.len() == 16));
}

#[test]
fn packed_inputs_have_balanced_literals() {
    // Property: literal k and its complement k+16 are never equal.
    let shape = TmShape::iris();
    for row in &iris::booleanised().rows {
        let x = Input::pack(&shape, row);
        for k in 0..16 {
            assert_ne!(x.literal(k), x.literal(k + 16));
        }
    }
}

#[test]
fn rotation_representatives_reconstruct_the_sweep() {
    use tm_fpga::data::blocks::{expand_rotations, rotation_representatives};
    let reps = rotation_representatives(5);
    let mut all = expand_rotations(&reps);
    all.sort();
    let mut want = all_orderings(5);
    want.sort();
    assert_eq!(all, want);
}
