//! Multi-tenant model hub suite: handle-scoped routing over the wire,
//! LRU eviction/rehydration bit-identity, typed refusals, and the
//! committed legacy-protocol transcript that pins the v1 byte surface
//! across protocol growth.

use std::collections::BTreeSet;
use tm_fpga::coordinator::{run_hub_soak, HubSoakConfig};
use tm_fpga::hub::{HubConfig, HubError, HubNetBackend, ModelHub, RouteError, SingleModel};
use tm_fpga::net::{
    run_sim, ClientOp, ClientScript, NetConfig, Outcome, Request, PROTO_CAPS, PROTO_VERSION,
    TELEMETRY_VERSION,
};
use tm_fpga::serve::{BatcherConfig, ScalarOracle};
use tm_fpga::tm::{Input, MultiTm, ShardUpdate, TmParams, TmShape, UpdateKind, Xoshiro256};

fn shape() -> TmShape {
    TmShape::iris()
}

/// Random machine with realistic include density (testkit seeding).
fn machine(seed: u64) -> MultiTm {
    let mut rng = Xoshiro256::new(seed);
    tm_fpga::testkit::gen::machine(&mut rng, &shape())
}

fn send(at: u64, req: Request) -> ClientOp {
    ClientOp::Send { at, bytes: req.encode().into_bytes() }
}

/// A deterministic feature row for request `salt`.
fn bit_row(salt: u64) -> Vec<bool> {
    let mut rng = Xoshiro256::new(salt ^ 0x0FF5_E7);
    (0..shape().features).map(|_| rng.next_f32() < 0.5).collect()
}

/// One-frame-per-tick batching config: every infer full-flushes in its
/// arrival tick, so a transcript's frame order is strictly sequential.
fn sequential_cfg() -> NetConfig {
    let batch = BatcherConfig { max_batch: 1, latency_budget: 4, expect_literals: None };
    NetConfig { batch, write_buffer_cap: 64, max_in_flight: 64, ..NetConfig::default() }
}

/// The committed legacy-session transcript (see the file's header for
/// the format and what it pins).
const V1_SESSION: &str = include_str!("proto/v1_session.txt");

/// Parse the transcript into scripted sends (one per tick) and the
/// expected frames in delivery order.
fn load_transcript(text: &str) -> (Vec<ClientOp>, Vec<String>) {
    let mut ops = Vec::new();
    let mut expected = Vec::new();
    let mut at = 1u64;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(frame) = line.strip_prefix("> ") {
            ops.push(ClientOp::Send { at, bytes: format!("{frame}\n").into_bytes() });
            at += 1;
        } else if let Some(frame) = line.strip_prefix("< ") {
            expected.push(frame.to_string());
        } else {
            panic!("transcript: unparseable line {line:?}");
        }
    }
    (ops, expected)
}

/// Token-wise frame match; an expected `key=*` matches any actual
/// token with the same key.
fn frame_matches(expected: &str, actual: &str) -> bool {
    let want: Vec<&str> = expected.split_whitespace().collect();
    let got: Vec<&str> = actual.split_whitespace().collect();
    want.len() == got.len()
        && want.iter().zip(&got).all(|(w, g)| {
            if let Some(key) = w.strip_suffix("=*") {
                g.starts_with(key) && g.as_bytes().get(key.len()) == Some(&b'=')
            } else {
                w == g
            }
        })
}

/// Protocol compat: the committed v1 transcript replays with identical
/// frames on the legacy single-model backend and on a hub hosting the
/// same machine — and both match the pinned byte surface token-wise.
#[test]
fn committed_v1_transcript_replays_identically_on_both_backends() {
    let (ops, expected) = load_transcript(V1_SESSION);
    assert!(!ops.is_empty() && !expected.is_empty(), "transcript is empty");
    let scripts = vec![ClientScript { connect_at: 0, ops }];
    let ncfg = sequential_cfg();

    let tm = machine(0x1E6A);
    let params = TmParams::paper_online(&shape());
    let oracle = ScalarOracle::new(tm.clone(), params.clone(), 0xBA5E);
    let (orep, otr) =
        run_sim(SingleModel(oracle), scripts.clone(), &shape(), ncfg.clone()).unwrap();

    let mut hub = ModelHub::new(HubConfig::default());
    hub.create("default", tm, params, 0xBA5E).unwrap();
    let (hrep, htr) = run_sim(hub, scripts, &shape(), ncfg).unwrap();

    let oframes = otr.delivered(0);
    let hframes = htr.delivered(0);
    assert_eq!(oframes, hframes, "legacy session diverged between backends");
    assert_eq!(orep.stats, hrep.stats);
    assert_eq!(orep.outcomes, hrep.outcomes);

    assert_eq!(oframes.len(), expected.len(), "frame count drifted: {oframes:?}");
    for (want, got) in expected.iter().zip(&oframes) {
        assert!(
            frame_matches(want, got.trim_end()),
            "transcript pinned {want:?}, server sent {got:?}"
        );
    }
}

/// Acceptance: four tenants with independent traces and per-tenant
/// scalar oracles interleave on one hub under a two-replica budget with
/// forced mid-trace eviction — zero diffs in outcomes, drive stats and
/// final replica digests, and every tenant demonstrably churned.
#[test]
fn hub_soak_four_tenants_agree_under_forced_eviction() {
    let cfg = HubSoakConfig {
        tenants: 4,
        events_per_tenant: 96,
        rounds: 4,
        warmup_epochs: 1,
        budget_models: 2,
        evict_period: 2,
        seed: 0xC0FF_EE01,
        ..HubSoakConfig::default()
    };
    let rep = run_hub_soak(&cfg).unwrap();
    assert!(rep.agrees(), "hub soak diverged: {:?}", rep.tenants);
    assert_eq!(rep.tenants.len(), 4);
    for t in &rep.tenants {
        assert!(t.responses > 0, "tenant served nothing: {t:?}");
        assert!(t.evictions >= 1, "no eviction forced mid-trace: {t:?}");
        assert!(t.rehydrations >= 1, "evicted but never rehydrated: {t:?}");
    }
    let (hits, misses) = rep.plane_cache;
    assert!(hits + misses > 0, "bitplane cache never consulted");
}

/// v2 routing end to end: the session binds a default model, infers and
/// learns route by `model=`, an unknown name is refused typed *before*
/// any batcher sees it, and the versioned stats frame carries telemetry
/// rows for exactly the hosted models.
#[test]
fn v2_routing_is_model_scoped_and_unknown_models_never_batch() {
    let params = TmParams::paper_online(&shape());
    let mut hub = ModelHub::new(HubConfig::default());
    hub.create("alpha", machine(0xA1FA), params.clone(), 11).unwrap();
    hub.create("beta", machine(0xBE7A), params, 22).unwrap();

    let ops = vec![
        send(1, Request::Hello { version: PROTO_VERSION, model: Some("alpha".into()) }),
        send(2, Request::Infer { id: 1, ttl: None, model: None, bits: bit_row(1) }),
        send(3, Request::Infer { id: 2, ttl: None, model: Some("beta".into()), bits: bit_row(2) }),
        send(4, Request::Infer { id: 3, ttl: None, model: Some("ghost".into()), bits: bit_row(3) }),
        send(5, Request::Learn { id: 4, label: 1, model: Some("beta".into()), bits: bit_row(4) }),
        send(6, Request::Stats { id: 5 }),
        send(7, Request::Drain { id: 6 }),
    ];
    let scripts = vec![ClientScript { connect_at: 0, ops }];
    let (rep, tr) = run_sim(hub, scripts, &shape(), sequential_cfg()).unwrap();

    let frames = tr.delivered(0);
    assert_eq!(frames[0], format!("ok hello v={PROTO_VERSION} caps={PROTO_CAPS}\n"));
    assert!(matches!(rep.outcomes[&(0, 1)], Outcome::Pred(_)));
    assert!(matches!(rep.outcomes[&(0, 2)], Outcome::Pred(_)));
    assert_eq!(rep.outcomes[&(0, 3)], Outcome::UnknownModel);
    assert_eq!(rep.outcomes[&(0, 4)], Outcome::LearnAck(1));
    assert_eq!(rep.stats.unknown_model, 1, "{:?}", rep.stats);
    assert_eq!(rep.stats.infers, 2, "ghost infer must never reach a batcher: {:?}", rep.stats);
    assert!(
        frames.iter().any(|f| f.starts_with("err id=3 kind=unknown-model")),
        "{frames:?}"
    );

    let labels: BTreeSet<&str> = rep.telemetry.iter().map(|t| t.model.as_str()).collect();
    assert_eq!(labels, BTreeSet::from(["alpha", "beta"]));
    let stats_frame = frames.iter().find(|f| f.starts_with("stats id=5")).unwrap();
    assert!(
        stats_frame.contains(&format!(" tv={TELEMETRY_VERSION} models=")),
        "{stats_frame:?}"
    );
}

/// Eviction determinism: a model force-evicted every few updates lands
/// on states bit-identical to a never-evicted mirror applying the same
/// `(base_seed, seq)`-keyed update log, and checkpoint refresh keeps
/// the retained log bounded.
#[test]
fn eviction_and_rehydration_are_bit_identical_to_a_hot_mirror() {
    let shape = shape();
    let params = TmParams::paper_online(&shape);
    let tm = machine(0x4E11);
    let base_seed = 0x5EED;
    let mut hub = ModelHub::new(HubConfig { checkpoint_every: 4, ..HubConfig::default() });
    let h = hub.create("m", tm.clone(), params.clone(), base_seed).unwrap();
    let mut mirror = tm;

    let mut rng = Xoshiro256::new(0xD1CE);
    for seq in 1..=24u64 {
        let bits: Vec<bool> = (0..shape.features).map(|_| rng.next_f32() < 0.5).collect();
        let kind = UpdateKind::Learn { input: Input::pack(&shape, &bits), label: seq as usize % 3 };
        assert_eq!(hub.update(h, kind.clone()).unwrap(), seq);
        let _ = mirror.apply_update(&ShardUpdate { seq, kind }, &params, base_seed);
        if seq % 6 == 0 {
            hub.evict(h).unwrap();
            assert!(!hub.is_hot(h), "evict must leave the model cold");
        }
    }
    assert_eq!(hub.lifecycle(h).0, 4, "four forced evictions");
    assert_eq!(hub.digest(h).unwrap(), mirror.state_digest(), "rehydration diverged");
    assert_eq!(hub.lifecycle(h), (4, 4));
    assert!(hub.retained_log_len(h) <= 4, "checkpoint refresh must bound the log");
}

/// Lifecycle edges: budget exhaustion and eviction races refuse typed
/// with exact accounting — nothing panics, nothing is dropped silently
/// — and unknown names fail at routing, before any batcher.
#[test]
fn hub_refusals_are_typed_not_dropped() {
    let shape = shape();
    let params = TmParams::paper_online(&shape);
    let tm = machine(0xB4D6);

    // A budget below one replica's checkpoint cost refuses creation.
    let mut probe = ModelHub::new(HubConfig::default());
    probe.create("a", tm.clone(), params.clone(), 1).unwrap();
    let cost = probe.resident_bytes();
    assert!(cost > 0);
    let mut tight =
        ModelHub::new(HubConfig { memory_budget: cost - 1, ..HubConfig::default() });
    match tight.create("a", tm.clone(), params.clone(), 1) {
        Err(HubError::BudgetExhausted { need, budget, .. }) => {
            assert_eq!(need, cost);
            assert_eq!(budget, cost - 1);
        }
        other => panic!("want BudgetExhausted, got {other:?}"),
    }

    // An update racing the eviction barrier is refused typed while the
    // barrier is up, and applies transparently once it completes.
    let mut hub = ModelHub::new(HubConfig::default());
    let h = hub.create("m", tm, params, 7).unwrap();
    let bits: Vec<bool> = (0..shape.features).map(|k| k % 2 == 0).collect();
    let kind = UpdateKind::Learn { input: Input::pack(&shape, &bits), label: 0 };
    hub.begin_evict(h).unwrap();
    assert!(matches!(hub.update(h, kind.clone()), Err(HubError::Evicting { .. })));
    hub.finish_evict(h).unwrap();
    assert!(!hub.is_hot(h));
    assert_eq!(hub.update(h, kind).unwrap(), 1, "post-barrier update must rehydrate");
    assert_eq!(hub.lifecycle(h), (1, 1));

    // Unknown names fail typed at routing.
    assert!(hub.resolve("ghost").is_none());
    assert_eq!(hub.bind(Some("ghost")), Err(RouteError::UnknownModel));
}
