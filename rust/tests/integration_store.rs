//! Durability suite for the on-disk store (`tm_fpga::store`) and the
//! durable hub built on it: the seeded crash-restart sweep (a process
//! death injected at *every* WAL/checkpoint write boundary must restart
//! bit-identical to the never-crashed oracle), the on-disk damage
//! matrix (every [`DiskFault`] kind is either repaired with exact
//! counter accounting or refused with a typed error — never a silent
//! wrong answer, never a panic), and cold-start rebuild fidelity
//! including fallback from a corrupted newest checkpoint.

use std::fs;
use std::path::{Path, PathBuf};
use tm_fpga::coordinator::{run_restart_soak, RestartSoakConfig};
use tm_fpga::hub::{HubConfig, ModelHub};
use tm_fpga::serve::{inject_disk_fault, snapshot_bytes, DiskFault};
use tm_fpga::store::{RealDisk, RecoveredModel, Store, StoreConfig, StoreError, SyncPolicy, WalOp};
use tm_fpga::tm::{Input, MultiTm, ShardUpdate, TmParams, TmShape, UpdateKind, Xoshiro256};

fn shape() -> TmShape {
    TmShape::iris()
}

/// Random machine with realistic include density (testkit seeding).
fn machine(seed: u64) -> MultiTm {
    let mut rng = Xoshiro256::new(seed);
    tm_fpga::testkit::gen::machine(&mut rng, &shape())
}

fn tmp(name: &str) -> PathBuf {
    Path::new(env!("CARGO_TARGET_TMPDIR")).join(name)
}

fn copy_tree(src: &Path, dst: &Path) {
    fs::create_dir_all(dst).unwrap();
    for entry in fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        let to = dst.join(entry.file_name());
        if entry.file_type().unwrap().is_dir() {
            copy_tree(&entry.path(), &to);
        } else {
            fs::copy(entry.path(), &to).unwrap();
        }
    }
}

/// One labelled sample as both the in-memory update and its WAL form.
fn learn(shape: &TmShape, rng: &mut Xoshiro256, label: usize) -> (UpdateKind, WalOp) {
    let bits: Vec<bool> = (0..shape.features).map(|_| rng.next_f32() < 0.5).collect();
    (
        UpdateKind::Learn { input: Input::pack(shape, &bits), label },
        WalOp::Learn { label: label as u32, bits },
    )
}

/// The headline acceptance: a process death injected at every durable
/// write boundary (or an even sample of them in debug builds), each
/// followed by a clean restart, must be bit-identical to the
/// never-crashed oracle — every answered inference, every re-answer
/// across the restart, every final state digest — with zero unanswered
/// inferences.
#[test]
fn restart_soak_sweeps_every_crash_point_bit_identically() {
    let full = !cfg!(debug_assertions);
    let cfg = RestartSoakConfig {
        data_dir: tmp("restart_sweep"),
        max_crash_points: if full { 0 } else { 24 },
        ..RestartSoakConfig::default()
    };
    let rep = run_restart_soak(&cfg).unwrap();
    assert!(rep.agrees(), "crash sweep diverged from the oracle: {rep:?}");
    assert!(rep.durable_ops >= 100, "sweep domain too small to mean anything: {rep:?}");
    if full {
        assert!(rep.crash_points >= 100, "release sweep must cover ≥ 100 points: {rep:?}");
    } else {
        assert!(rep.crash_points >= 20, "sampled sweep too sparse: {rep:?}");
    }
    assert!(rep.torn_tails_truncated >= 1, "append-boundary crashes must leave torn tails");
    assert!(rep.wal_records_replayed >= 1, "restarts must replay WAL suffixes: {rep:?}");
    assert!(rep.models_recovered >= 2, "restarts must rebuild models from disk: {rep:?}");
}

fn matrix_cfg() -> StoreConfig {
    // Tiny segments so a short trace still spans ≥ 3 WAL files —
    // required footing for the segment-loss injections.
    StoreConfig { segment_bytes: 256, sync_policy: SyncPolicy::Always, retained_ckpts: 2 }
}

/// Build the known store the damage matrix mutates: two models, where
/// "beta" (one Learn, one ClauseFault, checkpoint at seq 2) anchors the
/// WAL floor so "alpha"'s full history (12 Learns, checkpoints at 4 and
/// 8, an unreplayed 9..=12 tail) stays on disk. Returns the mirror
/// state digests per seq for both models.
fn build_pristine(dir: &Path) -> (Vec<u64>, Vec<u64>) {
    fs::remove_dir_all(dir).ok();
    let (mut store, recovered) = Store::open(Box::new(RealDisk), dir, matrix_cfg()).unwrap();
    assert!(recovered.is_empty(), "fresh dir must hold no models");
    let shape = shape();
    let params = TmParams::paper_offline(&shape);
    let mut m1 = machine(0xA11A);
    let mut m2 = machine(0xBE7A);
    store.log_create(1, "alpha", 7, &snapshot_bytes(&m1, &params, 0)).unwrap();
    store.log_create(2, "beta", 8, &snapshot_bytes(&m2, &params, 0)).unwrap();
    let mut d1 = vec![m1.state_digest()];
    let mut d2 = vec![m2.state_digest()];
    let mut rng = Xoshiro256::new(0x57A6E);

    let (kind, op) = learn(&shape, &mut rng, 1);
    store.log_update(2, 1, &op).unwrap();
    let _ = m2.apply_update(&ShardUpdate { seq: 1, kind }, &params, 8);
    d2.push(m2.state_digest());
    let kind = UpdateKind::ClauseFault { class: 1, clause: 3, force: Some(true) };
    store
        .log_update(2, 2, &WalOp::ClauseFault { class: 1, clause: 3, force: Some(true) })
        .unwrap();
    let _ = m2.apply_update(&ShardUpdate { seq: 2, kind }, &params, 8);
    d2.push(m2.state_digest());
    store.publish_checkpoint(2, 2, &snapshot_bytes(&m2, &params, 2)).unwrap();

    for seq in 1..=12u64 {
        let (kind, op) = learn(&shape, &mut rng, (seq % 3) as usize);
        store.log_update(1, seq, &op).unwrap();
        let _ = m1.apply_update(&ShardUpdate { seq, kind }, &params, 7);
        d1.push(m1.state_digest());
        if seq == 4 || seq == 8 {
            store.publish_checkpoint(1, seq, &snapshot_bytes(&m1, &params, seq)).unwrap();
        }
    }
    store.sync().unwrap();
    (d1, d2)
}

/// Rebuild a durable hub from a recovered store and demand each model
/// resumes at exactly `(name, seq, digest)` — recovery may never hand
/// back plausible-but-different bits.
fn assert_hub_state(store: Store, recovered: Vec<RecoveredModel>, want: &[(&str, u64, u64)]) {
    let cfg = HubConfig { memory_budget: 0, checkpoint_every: 0, plane_cache_batches: 4 };
    let mut hub = ModelHub::open_durable(cfg, store, recovered).unwrap();
    for &(name, seq, digest) in want {
        let h = hub.resolve(name).unwrap_or_else(|| panic!("{name} not recovered"));
        assert_eq!(hub.model_seq(h), Some(seq), "{name} resumed at the wrong seq");
        assert_eq!(hub.digest(h).unwrap(), digest, "{name} rebuilt with different bits");
    }
}

fn seqs(m: &RecoveredModel) -> Vec<u64> {
    m.ops.iter().map(|(s, _)| *s).collect()
}

/// The on-disk damage matrix: every [`DiskFault`] kind against a copy
/// of the same closed store. Repairable damage (torn tail, stale
/// manifest row, corrupt newest checkpoint) recovers with exact counter
/// accounting and bit-identical state; unrepairable damage (bit rot in
/// acked history, lost or emptied segments) is refused with the exact
/// typed error. No kind may panic or recover silently wrong.
#[test]
fn disk_damage_matrix_recovers_or_refuses_typed() {
    let pristine = tmp("store_matrix_pristine");
    let (d1, d2) = build_pristine(&pristine);
    for (i, fault) in DiskFault::full_matrix().into_iter().enumerate() {
        let dir = tmp(&format!("store_matrix_{i}"));
        fs::remove_dir_all(&dir).ok();
        copy_tree(&pristine, &dir);
        let landed = inject_disk_fault(&dir, fault).unwrap();
        assert!(landed, "{fault:?} found nothing to damage — scaffold regressed");
        let result = Store::open(Box::new(RealDisk), &dir, matrix_cfg());
        match fault {
            DiskFault::TornTail { .. } => {
                let (store, recovered) = result.expect("a torn tail is repairable");
                let rep = *store.report();
                assert_eq!(rep.torn_tails_truncated, 1, "{rep:?}");
                assert_eq!(rep.models_recovered, 2, "{rep:?}");
                let alpha = recovered.iter().find(|m| m.name == "alpha").unwrap();
                assert_eq!(alpha.ckpt_seq, 8);
                assert_eq!(
                    seqs(alpha),
                    vec![9, 10, 11],
                    "exactly the torn (unacknowledged) update 12 is lost"
                );
                assert_hub_state(store, recovered, &[("alpha", 11, d1[11]), ("beta", 2, d2[2])]);
            }
            DiskFault::BitFlipWal => match result {
                Err(StoreError::CorruptRecord { .. }) => {}
                Ok(_) => panic!("bit rot in an acked record must refuse, not recover"),
                Err(e) => panic!("want CorruptRecord, got {e:?}"),
            },
            DiskFault::MissingSegment => match result {
                Err(StoreError::MissingSegment { .. }) => {}
                Ok(_) => panic!("a WAL hole must refuse, not replay around it"),
                Err(e) => panic!("want MissingSegment, got {e:?}"),
            },
            DiskFault::ZeroLengthSegment => match result {
                Err(StoreError::MissingSegment { .. }) => {}
                Ok(_) => panic!("an emptied segment must refuse like a deleted one"),
                Err(e) => panic!("want MissingSegment, got {e:?}"),
            },
            DiskFault::StaleManifest => {
                let (store, recovered) = result.expect("a stale manifest row is repairable");
                let rep = *store.report();
                assert!(rep.stale_manifest_entries >= 1, "{rep:?}");
                assert_eq!(rep.models_recovered, 2, "{rep:?}");
                let beta = recovered.iter().find(|m| m.name == "beta").unwrap();
                assert_eq!(
                    beta.ckpt_seq, 2,
                    "the newest verifying checkpoint wins over the rolled-back row"
                );
                assert_hub_state(store, recovered, &[("alpha", 12, d1[12]), ("beta", 2, d2[2])]);
            }
            DiskFault::CorruptCheckpoint => {
                let (store, recovered) =
                    result.expect("a corrupt newest checkpoint must fall back");
                let rep = *store.report();
                assert_eq!(rep.corrupt_checkpoints_rejected, 1, "{rep:?}");
                assert_eq!(rep.models_recovered, 2, "{rep:?}");
                let beta = recovered.iter().find(|m| m.name == "beta").unwrap();
                assert_eq!(beta.ckpt_seq, 0, "fallback lands on the genesis snapshot");
                assert_eq!(seqs(beta), vec![1, 2], "the full suffix replays on top of genesis");
                assert_hub_state(store, recovered, &[("alpha", 12, d1[12]), ("beta", 2, d2[2])]);
            }
        }
        fs::remove_dir_all(&dir).ok();
    }
    fs::remove_dir_all(&pristine).ok();
}

/// Cold-start fidelity through the real hub write path: three tenants
/// (one deliberately cold — created first, it anchors the WAL floor so
/// later histories stay replayable), interleaved write-ahead updates
/// with forced evictions, clean shutdown, then two adversarial reopens.
/// The first must be bit-identical per tenant; the second, after a bit
/// flip in alpha's newest checkpoint, must fall back to an older
/// snapshot, replay the longer WAL suffix, and land on the same bits.
#[test]
fn durable_hub_cold_start_survives_checkpoint_corruption_bit_identically() {
    let dir = tmp("hub_cold_start");
    fs::remove_dir_all(&dir).ok();
    let store_cfg =
        StoreConfig { segment_bytes: 2048, sync_policy: SyncPolicy::Always, retained_ckpts: 2 };
    let build_hub_cfg =
        HubConfig { memory_budget: 0, checkpoint_every: 4, plane_cache_batches: 8 };
    let shape = shape();
    let params = TmParams::paper_online(&shape);

    // Build: write-ahead traffic with forced evictions, clean shutdown.
    let (store, recovered) = Store::open(Box::new(RealDisk), &dir, store_cfg).unwrap();
    assert!(recovered.is_empty());
    let mut hub = ModelHub::open_durable(build_hub_cfg, store, recovered).unwrap();
    hub.create("pin", machine(0x9149), params.clone(), 99).unwrap();
    let ha = hub.create("alpha", machine(0xA1), params.clone(), 11).unwrap();
    let hb = hub.create("beta", machine(0xB2), params.clone(), 22).unwrap();
    let pin_digest = machine(0x9149).state_digest();
    let mut ma = machine(0xA1);
    let mut mb = machine(0xB2);
    let (mut sa, mut sb) = (0u64, 0u64);
    let mut rng = Xoshiro256::new(0xC01D);
    for k in 0..30u64 {
        let (kind, _) = learn(&shape, &mut rng, (k % 3) as usize);
        if k % 2 == 0 {
            sa += 1;
            assert_eq!(hub.update(ha, kind.clone()).unwrap(), sa);
            let _ = ma.apply_update(&ShardUpdate { seq: sa, kind }, &params, 11);
        } else {
            sb += 1;
            assert_eq!(hub.update(hb, kind.clone()).unwrap(), sb);
            let _ = mb.apply_update(&ShardUpdate { seq: sb, kind }, &params, 22);
        }
        if k % 7 == 6 {
            hub.evict(ha).unwrap();
        }
    }
    hub.sync_durable().unwrap();
    drop(hub);

    // First cold start: everything back, bit for bit.
    let (store, recovered) = Store::open(Box::new(RealDisk), &dir, store_cfg).unwrap();
    assert_eq!(recovered.len(), 3, "all three tenants must survive shutdown");
    let alpha = recovered.iter().find(|m| m.name == "alpha").unwrap();
    let (alpha_id, alpha_clean_ckpt) = (alpha.id, alpha.ckpt_seq);
    assert!(alpha_clean_ckpt > 0, "checkpoint refresh never fired during the build");
    assert_hub_state(
        store,
        recovered,
        &[
            ("pin", 0, pin_digest),
            ("alpha", sa, ma.state_digest()),
            ("beta", sb, mb.state_digest()),
        ],
    );

    // Flip one bit mid-file in alpha's newest checkpoint.
    let prefix = format!("m{alpha_id:08}-");
    let mut ckpts: Vec<PathBuf> = fs::read_dir(dir.join("ckpt"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with(&prefix))
        })
        .collect();
    ckpts.sort();
    let newest = ckpts.last().expect("alpha has checkpoints on disk");
    let mut bytes = fs::read(newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    fs::write(newest, &bytes).unwrap();

    // Second cold start: rejected checkpoint, older snapshot + longer
    // replay, identical bits.
    let (store, recovered) = Store::open(Box::new(RealDisk), &dir, store_cfg).unwrap();
    assert_eq!(store.report().corrupt_checkpoints_rejected, 1, "{:?}", store.report());
    let alpha = recovered.iter().find(|m| m.name == "alpha").unwrap();
    assert!(
        alpha.ckpt_seq < alpha_clean_ckpt,
        "fallback must pick an older snapshot ({} vs {alpha_clean_ckpt})",
        alpha.ckpt_seq
    );
    assert_eq!(
        alpha.ops.last().map(|(s, _)| *s),
        Some(sa),
        "the replay suffix must still reach alpha's durable seq"
    );
    assert_hub_state(
        store,
        recovered,
        &[
            ("pin", 0, pin_digest),
            ("alpha", sa, ma.state_digest()),
            ("beta", sb, mb.state_digest()),
        ],
    );
    fs::remove_dir_all(&dir).ok();
}
