//! Differential soak suite for the sharded serving layer (`serve/`):
//! randomized arrival traces with interleaved labelled/unlabelled
//! samples, shard counts 1/2/4, flush-deadline edge cases (batch widths
//! 1 and 64), mid-stream fault injection — every server response pinned
//! **bit-identical** to the scalar `MultiTm` oracle fed the same
//! sequence, and identical across shard counts.

use tm_fpga::coordinator::{run_soak, SoakConfig};
use tm_fpga::serve::{
    run_trace, BatcherConfig, ScalarOracle, ServeConfig, ServeEvent, ShardServer,
};
use tm_fpga::tm::{Input, MultiTm, TmParams, TmShape, UpdateKind, Xoshiro256};

fn shape() -> TmShape {
    TmShape::iris()
}

fn random_input(rng: &mut Xoshiro256, s: &TmShape) -> Input {
    Input::pack(s, &tm_fpga::testkit::gen::bool_vec(rng, s.features, 0.5))
}

/// Random machine with realistic include density (testkit seeding, the
/// same generator the oracle/recovery suites use).
fn random_machine(s: &TmShape, seed: u64) -> MultiTm {
    let mut rng = Xoshiro256::new(seed);
    tm_fpga::testkit::gen::machine(&mut rng, s)
}

/// Drive `events` through a sharded server and the scalar oracle with
/// the same batching config; assert bit-identical responses and return
/// them.
fn differential(
    tm: &MultiTm,
    params: &TmParams,
    events: &[ServeEvent],
    shards: usize,
    bcfg: &BatcherConfig,
    base_seed: u64,
) -> Vec<(u64, usize)> {
    let scfg = ServeConfig::new(shards, params.clone(), base_seed);
    let mut server = ShardServer::new(tm, &scfg).unwrap();
    let drive = run_trace(&mut server, events, bcfg).unwrap();
    let outcome = server.finish().unwrap();

    let mut oracle = ScalarOracle::new(tm.clone(), params.clone(), base_seed);
    let drive2 = run_trace(&mut oracle, events, bcfg).unwrap();
    assert_eq!(drive, drive2, "batching decisions must not depend on the backend");
    let expected = oracle.into_responses();

    assert_eq!(
        outcome.responses, expected,
        "{shards}-shard responses diverged from the scalar oracle"
    );
    assert_eq!(outcome.responses.len() as u64, drive.infer_requests);
    let scored: u64 = outcome.shards.iter().map(|s| s.samples).sum();
    assert_eq!(scored, drive.infer_requests, "every request scored exactly once");
    for st in &outcome.shards {
        assert_eq!(st.updates, drive.updates, "shard {} missed an update", st.shard);
    }
    outcome.responses
}

/// The headline acceptance: randomized interleaved traces agree with
/// the oracle on shard counts 1, 2 and 4, and the responses are
/// identical across shard counts (placement-independent).
#[test]
fn soak_bit_identical_across_shard_counts() {
    for (trial, seed) in [0xA0u64, 0xB1, 0xC2].into_iter().enumerate() {
        let cfg = SoakConfig {
            events: 500,
            warmup_epochs: 2,
            labelled_fraction: 0.25,
            mean_gap: [0.0, 1.0, 3.0][trial],
            latency_budget: [1, 4, 16][trial],
            seed,
            ..Default::default()
        };
        let mut per_shard_responses = Vec::new();
        for shards in [1usize, 2, 4] {
            let rep = run_soak(&SoakConfig { shards, ..cfg.clone() }).unwrap();
            assert!(
                rep.agrees(),
                "trial {trial} shards {shards}: {} mismatches",
                rep.mismatches
            );
            assert!(rep.drive.updates > 0, "trace must interleave labelled samples");
            assert!(rep.drive.infer_requests > 0);
            assert_eq!(rep.responses.len() as u64, rep.drive.infer_requests);
            per_shard_responses.push(rep.responses);
        }
        assert_eq!(
            per_shard_responses[0], per_shard_responses[1],
            "trial {trial}: 1-shard vs 2-shard responses"
        );
        assert_eq!(
            per_shard_responses[1], per_shard_responses[2],
            "trial {trial}: 2-shard vs 4-shard responses"
        );
    }
}

/// Batch width 1: coalescing disabled, every request flushes alone.
#[test]
fn batch_width_one_is_request_at_a_time() {
    let cfg = SoakConfig {
        shards: 2,
        events: 300,
        max_batch: 1,
        labelled_fraction: 0.2,
        warmup_epochs: 2,
        ..Default::default()
    };
    let rep = run_soak(&cfg).unwrap();
    assert!(rep.agrees(), "{} mismatches", rep.mismatches);
    assert_eq!(rep.drive.batches, rep.drive.infer_requests);
    assert_eq!(rep.drive.full_flushes, rep.drive.infer_requests);
    assert_eq!(rep.drive.deadline_flushes, 0);
    assert_eq!(rep.drive.mean_batch_width(), 1.0);
}

/// Batch width 64: a pure burst of unlabelled requests packs full
/// 64-wide lanes exactly (640 requests = ten 64-wide batches, no tail).
#[test]
fn burst_fills_full_64_wide_batches() {
    let cfg = SoakConfig {
        shards: 4,
        events: 640,
        max_batch: 64,
        latency_budget: 1,
        labelled_fraction: 0.0,
        mean_gap: 0.0,
        warmup_epochs: 2,
        ..Default::default()
    };
    let rep = run_soak(&cfg).unwrap();
    assert!(rep.agrees(), "{} mismatches", rep.mismatches);
    assert_eq!(rep.drive.infer_requests, 640);
    assert_eq!(rep.drive.batches, 10);
    assert_eq!(rep.drive.full_flushes, 10);
    assert_eq!(rep.drive.deadline_flushes, 0);
    assert_eq!(rep.drive.final_flushes, 0);
    assert_eq!(rep.drive.mean_batch_width(), 64.0);
    // Round-robin dealt 10 batches over 4 shards: 3/3/2/2.
    let mut per_shard: Vec<u64> = rep.shards.iter().map(|s| s.batches).collect();
    per_shard.sort_unstable();
    assert_eq!(per_shard, vec![2, 2, 3, 3]);
}

/// Deadline flushes dominate under sparse arrivals with a tight budget;
/// a huge budget never deadline-flushes.
#[test]
fn deadline_edge_cases() {
    let base = SoakConfig {
        shards: 2,
        events: 400,
        labelled_fraction: 0.0,
        warmup_epochs: 2,
        ..Default::default()
    };
    // Tight budget, sparse arrivals: no batch survives past its arrival
    // tick, so nothing coalesces across ticks.
    let tight = run_soak(&SoakConfig {
        latency_budget: 0,
        mean_gap: 2.0,
        ..base.clone()
    })
    .unwrap();
    assert!(tight.agrees());
    assert!(
        tight.drive.deadline_flushes > 0,
        "sparse arrivals under budget 0 must deadline-flush"
    );
    // Unbounded budget: only full and final flushes exist.
    let loose = run_soak(&SoakConfig {
        latency_budget: u64::MAX,
        mean_gap: 2.0,
        ..base
    })
    .unwrap();
    assert!(loose.agrees());
    assert_eq!(loose.drive.deadline_flushes, 0);
    assert_eq!(loose.drive.final_flushes, 1);
    assert_eq!(
        loose.drive.full_flushes,
        loose.drive.infer_requests / 64,
        "every non-tail batch fills to 64"
    );
}

/// Mid-stream fault injection: clause-output force edits ride the same
/// sequenced update channel as labelled samples, and the sharded
/// responses stay bit-identical to the oracle through the campaign.
#[test]
fn mid_stream_fault_injection_stays_bit_identical() {
    let s = shape();
    let p = TmParams::paper_offline(&s);
    let tm = random_machine(&s, 0xFA01);
    let mut rng = Xoshiro256::new(0xFA02);
    let mut events = Vec::new();
    let mut tick = 0u64;
    for i in 0..400usize {
        tick += (i % 3 == 0) as u64;
        if i % 37 == 5 {
            // Mid-stream fault campaign. The rotation exercises all
            // three gate states; the Some(true) edit pins *positive*
            // clause (2, 0) high, so class 2 carries a standing +1 vote
            // the fault-free control lacks — predictions provably move.
            let (class, clause, force) = match (i / 37) % 3 {
                0 => (2, 0, Some(true)),
                1 => (0, 3, Some(false)),
                _ => (1, 6, None),
            };
            events.push(ServeEvent::Update {
                at_tick: tick,
                kind: UpdateKind::ClauseFault { class, clause, force },
            });
        } else if i % 5 == 0 {
            events.push(ServeEvent::Update {
                at_tick: tick,
                kind: UpdateKind::Learn {
                    input: random_input(&mut rng, &s),
                    label: i % s.classes,
                },
            });
        } else {
            events.push(ServeEvent::Infer { at_tick: tick, input: random_input(&mut rng, &s) });
        }
    }
    let bcfg = BatcherConfig { max_batch: 32, latency_budget: 2, ..Default::default() };
    let with_faults = differential(&tm, &p, &events, 4, &bcfg, 0xF411);
    assert!(!with_faults.is_empty());

    // Same trace with the fault edits stripped, as a control: the
    // campaign must actually have moved some predictions (forced clause
    // outputs shift votes), otherwise the test proves nothing.
    let stripped: Vec<ServeEvent> = events
        .iter()
        .filter(|e| {
            !matches!(e, ServeEvent::Update { kind: UpdateKind::ClauseFault { .. }, .. })
        })
        .cloned()
        .collect();
    let control = differential(&tm, &p, &stripped, 4, &bcfg, 0xF411);
    assert_eq!(control.len(), with_faults.len(), "same inference requests either way");
    assert_ne!(
        with_faults, control,
        "the fault campaign must actually move some predictions, or the \
         differential above proved nothing about fault handling"
    );
}

/// The whole soak is a pure function of its config: two runs produce
/// identical responses, flush breakdowns and shard assignments.
#[test]
fn soak_is_deterministic_across_runs() {
    let cfg = SoakConfig { events: 350, warmup_epochs: 2, shards: 3, ..Default::default() };
    let a = run_soak(&cfg).unwrap();
    let b = run_soak(&cfg).unwrap();
    assert!(a.agrees() && b.agrees());
    assert_eq!(a.responses, b.responses);
    assert_eq!(a.drive, b.drive);
    let widths_a: Vec<_> = a.shards.iter().map(|s| (s.batches, s.samples)).collect();
    let widths_b: Vec<_> = b.shards.iter().map(|s| (s.batches, s.samples)).collect();
    assert_eq!(widths_a, widths_b, "round-robin placement is deterministic");
}

/// Degenerate traffic mixes: all-labelled traces answer nothing (pure
/// online training), all-unlabelled traces update nothing — both agree
/// with the oracle and terminate cleanly.
#[test]
fn degenerate_traffic_mixes() {
    let all_updates = run_soak(&SoakConfig {
        events: 200,
        labelled_fraction: 1.0,
        warmup_epochs: 1,
        shards: 4,
        ..Default::default()
    })
    .unwrap();
    assert!(all_updates.agrees());
    assert_eq!(all_updates.drive.infer_requests, 0);
    assert_eq!(all_updates.drive.updates, 200);
    assert!(all_updates.responses.is_empty());

    let all_infer = run_soak(&SoakConfig {
        events: 200,
        labelled_fraction: 0.0,
        warmup_epochs: 1,
        shards: 4,
        ..Default::default()
    })
    .unwrap();
    assert!(all_infer.agrees());
    assert_eq!(all_infer.drive.updates, 0);
    assert_eq!(all_infer.responses.len(), 200);
}
