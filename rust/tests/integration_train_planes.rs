//! Lane-speculative trainer ⇄ per-step engine differential suite.
//!
//! `MultiTm::train_plane_batch` must be **bit-identical** to running
//! `train_step_fast` sample-by-sample with the same per-sample
//! [`StepRands`] — TA states, action caches, activity counts and
//! subsequent predictions — across everything that can perturb the
//! lane speculation: non-×64 tails, mid-lane action flips (low-T
//! configs that flip constantly), TA fault maps and clause-force
//! overrides injected between batches, clones, over-provisioned
//! active sets, and multiword literal rows. The lazy twin
//! (`train_plane_batch_lazy`) is held to the same standard against
//! `train_step_lazy`, generator position included; the serve-style
//! keyed path is held to run-partition independence (any chunking of a
//! `Learn` log trains to the same replica as applying it one update at
//! a time).

use tm_fpga::testkit::gen;
use tm_fpga::tm::params::SStyle;
use tm_fpga::tm::train_planes::train_rows_seq;
use tm_fpga::tm::update::{update_rands_into, ShardUpdate, UpdateKind};
use tm_fpga::tm::*;

fn random_rows(s: &TmShape, n: usize, rng: &mut Xoshiro256) -> Vec<(Input, usize)> {
    gen::rows_cyclic(rng, s, n)
}

fn assert_machines_identical(a: &MultiTm, b: &MultiTm, ctx: &str) {
    assert_eq!(a.ta().states(), b.ta().states(), "TA states diverged: {ctx}");
    let s = a.shape();
    for c in 0..s.classes {
        for j in 0..s.max_clauses {
            assert_eq!(
                a.action_words(c, j),
                b.action_words(c, j),
                "action cache diverged at ({c},{j}): {ctx}"
            );
        }
    }
}

/// Drive the same batch schedule through the scalar per-step loop and
/// the lane engine (identical rng streams) and assert bit-identity
/// after every batch.
fn assert_lane_matches_scalar(
    shape: &TmShape,
    params: &TmParams,
    batch_sizes: &[usize],
    fault_rate: f64,
    seed: u64,
) {
    let mut scalar = MultiTm::new(shape).unwrap();
    let mut lane = MultiTm::new(shape).unwrap();
    if fault_rate > 0.0 {
        let map =
            FaultMap::even_spread(shape, fault_rate, Fault::StuckAt0, seed ^ 0x7A17).unwrap();
        scalar.set_fault_map(map.clone());
        lane.set_fault_map(map);
    }
    let mut data_rng = Xoshiro256::new(seed);
    let mut rng_a = Xoshiro256::new(seed ^ 0xA);
    let mut rng_b = Xoshiro256::new(seed ^ 0xA);
    let mut rands = StepRands::draw(&mut rng_a, shape);
    let mut scratch = TrainScratch::seeded(&mut rng_b, shape);
    let mut act_a = EpochStats::default();
    let mut act_b = EpochStats::default();
    for (bi, &n) in batch_sizes.iter().enumerate() {
        let rows = random_rows(shape, n, &mut data_rng);
        for (x, y) in &rows {
            rands.refill(&mut rng_a, shape);
            let a = train_step_fast(&mut scalar, x, *y, params, &rands);
            act_a.steps += 1;
            act_a.activity.type1_clauses += a.type1_clauses;
            act_a.activity.type2_clauses += a.type2_clauses;
            act_a.activity.ta_increments += a.ta_increments;
            act_a.activity.ta_decrements += a.ta_decrements;
        }
        let planes = BitPlanes::from_labelled(shape, &rows);
        let b = train_rows_seq(&mut lane, &rows, &planes, params, &mut rng_b, &mut scratch);
        act_b.steps += b.steps;
        act_b.activity.type1_clauses += b.activity.type1_clauses;
        act_b.activity.type2_clauses += b.activity.type2_clauses;
        act_b.activity.ta_increments += b.activity.ta_increments;
        act_b.activity.ta_decrements += b.activity.ta_decrements;
        assert_eq!(act_a, act_b, "activity diverged after batch {bi} (n = {n})");
        assert_machines_identical(&scalar, &lane, &format!("batch {bi} (n = {n})"));
    }
    // Predictions off the trained machines agree too.
    let probe = random_rows(shape, 40, &mut data_rng);
    for (i, (x, _)) in probe.iter().enumerate() {
        assert_eq!(scalar.predict(x, params), lane.predict(x, params), "probe {i}");
    }
}

#[test]
fn eager_parity_iris_offline_mixed_tails() {
    let s = TmShape::iris();
    let p = TmParams::paper_offline(&s);
    assert_lane_matches_scalar(&s, &p, &[1, 5, 63, 64, 65, 130, 2], 0.0, 0x51);
}

#[test]
fn eager_parity_low_t_flip_storm() {
    // T = 1 keeps selection probability maximal on a fresh machine:
    // actions flip constantly mid-lane, exercising the repair path on
    // nearly every sample.
    let s = TmShape::iris();
    let mut p = TmParams::paper_offline(&s);
    p.t = 1;
    assert_lane_matches_scalar(&s, &p, &[64, 64, 64, 130], 0.0, 0x52);

    // And with boost (reinforcement always fires — maximal movement).
    let mut pb = TmParams::paper_offline(&s);
    pb.t = 2;
    pb.boost_true_positive = true;
    assert_lane_matches_scalar(&s, &pb, &[100, 100], 0.0, 0x53);
}

#[test]
fn eager_parity_online_s1_and_canonical() {
    let s = TmShape::iris();
    assert_lane_matches_scalar(&s, &TmParams::paper_online(&s), &[70, 70], 0.0, 0x54);
    let mut p = TmParams::paper_offline(&s);
    p.s = 2.0;
    p.s_style = SStyle::Canonical;
    assert_lane_matches_scalar(&s, &p, &[70, 70], 0.0, 0x55);
}

#[test]
fn eager_parity_multiword_faults_overprovisioning() {
    for (i, s) in [
        TmShape { classes: 3, max_clauses: 8, features: 40, states: 16 },
        TmShape { classes: 2, max_clauses: 4, features: 64, states: 8 },
    ]
    .into_iter()
    .enumerate()
    {
        let mut p = TmParams::paper_offline(&s);
        p.t = 3;
        p.active_clauses = s.max_clauses - 2;
        p.active_classes = s.classes - 1;
        assert_lane_matches_scalar(&s, &p, &[33, 65, 64], 0.20, 0x60 + i as u64);
    }
}

/// Faults and clause forces injected *between* lane batches: the lane
/// engine must pick up the new effective-literal algebra exactly like
/// the scalar loop does.
#[test]
fn interleaved_fault_and_force_schedule() {
    let s = TmShape::iris();
    let p = TmParams::paper_offline(&s);
    let mut scalar = MultiTm::new(&s).unwrap();
    let mut lane = MultiTm::new(&s).unwrap();
    let mut data_rng = Xoshiro256::new(0x99);
    let mut rng_a = Xoshiro256::new(0x9A);
    let mut rng_b = Xoshiro256::new(0x9A);
    let mut rands = StepRands::draw(&mut rng_a, &s);
    let mut scratch = TrainScratch::seeded(&mut rng_b, &s);
    for round in 0..6 {
        // Mutate both machines identically between batches.
        match round % 3 {
            0 => {
                let map =
                    FaultMap::even_spread(&s, 0.15, Fault::StuckAt1, 40 + round as u64)
                        .unwrap();
                scalar.set_fault_map(map.clone());
                lane.set_fault_map(map);
            }
            1 => {
                scalar.set_clause_fault(0, round % 16, Some(round % 2 == 0));
                lane.set_clause_fault(0, round % 16, Some(round % 2 == 0));
            }
            _ => {
                scalar.set_clause_fault(0, (round - 1) % 16, None);
                lane.set_clause_fault(0, (round - 1) % 16, None);
                scalar.set_fault_map(FaultMap::none(&s));
                lane.set_fault_map(FaultMap::none(&s));
            }
        }
        let rows = random_rows(&s, 40 + round * 13, &mut data_rng);
        for (x, y) in &rows {
            rands.refill(&mut rng_a, &s);
            train_step_fast(&mut scalar, x, *y, &p, &rands);
        }
        let planes = BitPlanes::from_labelled(&s, &rows);
        train_rows_seq(&mut lane, &rows, &planes, &p, &mut rng_b, &mut scratch);
        assert_machines_identical(&scalar, &lane, &format!("round {round}"));
    }
}

/// Clones forked mid-schedule keep bit-parity on both sides of the
/// fork, sharing one scratch across all four machines.
#[test]
fn clones_keep_parity_with_shared_scratch() {
    let s = TmShape::iris();
    let mut p = TmParams::paper_offline(&s);
    p.t = 2; // flip-heavy
    let mut data_rng = Xoshiro256::new(0x77);
    let warm = random_rows(&s, 90, &mut data_rng);
    let cont_a = random_rows(&s, 70, &mut data_rng);
    let cont_b = random_rows(&s, 70, &mut data_rng);

    let mut scalar = MultiTm::new(&s).unwrap();
    let mut lane = MultiTm::new(&s).unwrap();
    let mut rng_a = Xoshiro256::new(0x78);
    let mut rng_b = Xoshiro256::new(0x78);
    let mut rands = StepRands::draw(&mut rng_a, &s);
    let mut scratch = TrainScratch::seeded(&mut rng_b, &s);
    for (x, y) in &warm {
        rands.refill(&mut rng_a, &s);
        train_step_fast(&mut scalar, x, *y, &p, &rands);
    }
    let warm_planes = BitPlanes::from_labelled(&s, &warm);
    train_rows_seq(&mut lane, &warm, &warm_planes, &p, &mut rng_b, &mut scratch);
    assert_machines_identical(&scalar, &lane, "warmup");

    // Fork: the original continues on cont_a, the clone on cont_b.
    let mut scalar_fork = scalar.clone();
    let mut lane_fork = lane.clone();
    for (x, y) in &cont_a {
        rands.refill(&mut rng_a, &s);
        train_step_fast(&mut scalar, x, *y, &p, &rands);
    }
    let planes_a = BitPlanes::from_labelled(&s, &cont_a);
    train_rows_seq(&mut lane, &cont_a, &planes_a, &p, &mut rng_b, &mut scratch);
    assert_machines_identical(&scalar, &lane, "original after fork");

    for (x, y) in &cont_b {
        rands.refill(&mut rng_a, &s);
        train_step_fast(&mut scalar_fork, x, *y, &p, &rands);
    }
    let planes_b = BitPlanes::from_labelled(&s, &cont_b);
    train_rows_seq(&mut lane_fork, &cont_b, &planes_b, &p, &mut rng_b, &mut scratch);
    assert_machines_identical(&scalar_fork, &lane_fork, "clone after fork");
}

/// The lazy lane twin consumes the generator exactly like the per-step
/// lazy loop, across shapes and a flip-heavy low-T config.
#[test]
fn lazy_parity_across_shapes() {
    for (i, s) in [
        TmShape::iris(),
        TmShape { classes: 2, max_clauses: 4, features: 40, states: 8 },
    ]
    .into_iter()
    .enumerate()
    {
        for t in [1i32, 15] {
            let mut p = TmParams::paper_offline(&s);
            p.t = t;
            let plan = FeedbackPlan::new(&p);
            let mut data_rng = Xoshiro256::new(0x200 + i as u64);
            let rows = random_rows(&s, 130, &mut data_rng);
            let mut scalar = MultiTm::new(&s).unwrap();
            let mut rng_a = Xoshiro256::new(5);
            for (x, y) in &rows {
                train_step_lazy(&mut scalar, x, *y, &p, &plan, &mut rng_a);
            }
            let mut lane = MultiTm::new(&s).unwrap();
            let mut rng_b = Xoshiro256::new(5);
            let planes = BitPlanes::from_labelled(&s, &rows);
            let mut scratch = TrainScratch::new();
            lane.train_plane_batch_lazy(&rows, &planes, &p, &plan, &mut rng_b, &mut scratch);
            assert_machines_identical(&scalar, &lane, &format!("shape {i}, T = {t}"));
            assert_eq!(
                rng_a.next_u64(),
                rng_b.next_u64(),
                "generator positions diverged (shape {i}, T = {t})"
            );
        }
    }
}

/// train_epoch (now lane-backed) stays bit-identical to the historical
/// per-step lazy loop on a machine carrying TA faults.
#[test]
fn train_epoch_parity_under_faults() {
    let s = TmShape::iris();
    let p = TmParams::paper_offline(&s);
    let map = FaultMap::even_spread(&s, 0.2, Fault::StuckAt0, 9).unwrap();
    let mut data_rng = Xoshiro256::new(0x300);
    let rows = random_rows(&s, 100, &mut data_rng);

    let mut by_epoch = MultiTm::new(&s).unwrap();
    by_epoch.set_fault_map(map.clone());
    let mut rng_a = Xoshiro256::new(31);
    let stats = by_epoch.train_epoch(&rows, &p, &mut rng_a);
    assert_eq!(stats.steps, rows.len());

    let plan = FeedbackPlan::new(&p);
    let mut by_step = MultiTm::new(&s).unwrap();
    by_step.set_fault_map(map);
    let mut rng_b = Xoshiro256::new(31);
    for (x, y) in &rows {
        train_step_lazy(&mut by_step, x, *y, &p, &plan, &mut rng_b);
    }
    assert_machines_identical(&by_epoch, &by_step, "train_epoch vs lazy loop");
}

/// Serve-style keyed randomness: any partition of a Learn log into
/// coalesced runs trains to the same replica as applying the updates
/// one at a time — run boundaries cannot leak into state.
#[test]
fn keyed_learn_runs_are_partition_independent() {
    let s = TmShape::iris();
    let p = TmParams::paper_offline(&s);
    let base_seed = 0xF00D;
    let mut data_rng = Xoshiro256::new(0x400);
    let log: Vec<ShardUpdate> = (0..150)
        .map(|i| ShardUpdate {
            seq: (i + 1) as u64,
            kind: UpdateKind::Learn {
                input: gen::input(&mut data_rng, &s),
                label: i % s.classes,
            },
        })
        .collect();

    // Reference: one update at a time.
    let mut reference = MultiTm::new(&s).unwrap();
    let mut rands = None;
    for u in &log {
        reference.apply_update_with(u, &p, base_seed, &mut rands);
    }

    fn learn_input(u: &ShardUpdate) -> &Input {
        match &u.kind {
            UpdateKind::Learn { input, .. } => input,
            UpdateKind::ClauseFault { .. } => unreachable!(),
        }
    }
    fn learn_label(u: &ShardUpdate) -> usize {
        match &u.kind {
            UpdateKind::Learn { label, .. } => *label,
            UpdateKind::ClauseFault { .. } => unreachable!(),
        }
    }

    for (pi, partition) in
        [vec![150usize], vec![64, 64, 22], vec![1, 63, 64, 20, 2], vec![5; 30]]
            .into_iter()
            .enumerate()
    {
        assert_eq!(partition.iter().sum::<usize>(), log.len());
        let mut lane = MultiTm::new(&s).unwrap();
        let mut scratch = TrainScratch::new();
        let mut off = 0usize;
        for run_len in partition {
            let run = &log[off..off + run_len];
            off += run_len;
            let rows: Vec<(Input, usize)> =
                run.iter().map(|u| (learn_input(u).clone(), learn_label(u))).collect();
            let planes = BitPlanes::from_labelled(&s, &rows);
            lane.train_plane_batch(
                &rows,
                &planes,
                &p,
                |i, r| update_rands_into(r, &s, base_seed, run[i].seq),
                &mut scratch,
            );
        }
        assert_machines_identical(&reference, &lane, &format!("partition {pi}"));
    }
}

/// Flip accounting: the observability counters move under a flip-heavy
/// config and stay near zero on a converged machine — the regime the
/// speculative engine bets on.
#[test]
fn flip_counters_reflect_convergence() {
    let s = TmShape::iris();
    let p = TmParams::paper_offline(&s);
    // Learnable workload (per-class prototypes + noise): the machine
    // must actually converge for the flip rate to decay.
    let rows = tm_fpga::data::synthetic::prototype_dataset(s.classes, 110, s.features, 0.03, 0x500)
        .unwrap()
        .pack(&s);
    let planes = BitPlanes::from_labelled(&s, &rows);

    // Fresh machine: learning means flips.
    let mut tm = MultiTm::new(&s).unwrap();
    let mut rng = Xoshiro256::new(1);
    let mut cold = TrainScratch::seeded(&mut rng, &s);
    train_rows_seq(&mut tm, &rows, &planes, &p, &mut rng, &mut cold);
    assert!(cold.lane_flips() > 0, "a fresh machine must flip while learning");

    // Many epochs later: the same pass flips far less.
    for _ in 0..20 {
        let mut warm_rng = Xoshiro256::new(2);
        let mut warm = TrainScratch::seeded(&mut warm_rng, &s);
        train_rows_seq(&mut tm, &rows, &planes, &p, &mut warm_rng, &mut warm);
        let _ = warm.mean_flips_per_lane();
    }
    let mut final_rng = Xoshiro256::new(3);
    let mut converged = TrainScratch::seeded(&mut final_rng, &s);
    train_rows_seq(&mut tm, &rows, &planes, &p, &mut final_rng, &mut converged);
    assert!(
        converged.mean_flips_per_lane() < cold.mean_flips_per_lane(),
        "converged flips/lane {:.2} must undercut fresh flips/lane {:.2}",
        converged.mean_flips_per_lane(),
        cold.mean_flips_per_lane()
    );
}
