//! Network front-end differential suite: the serving stack behind a
//! line-delimited wire protocol, driven by deterministic scripted
//! clients over [`tm_fpga::net::SimTransport`] and by a real loopback
//! socket. Every control decision — slow-client shedding, admission
//! rejection, deadline expiry — is a pure function of the scripts, so
//! the sharded server and the scalar oracle must make **bit-identical**
//! decisions and predictions; accounting is exact, never approximate.

use std::thread;
use tm_fpga::coordinator::{run_net_soak, NetSoakConfig};
use tm_fpga::hub::SingleModel;
use tm_fpga::net::{
    loopback_drill, run_sim, run_tcp, ClientOp, ClientScript, NetConfig, Outcome, Request,
    TcpTransport, PROTO_VERSION,
};
use tm_fpga::serve::{
    BatcherConfig, ChaosSpec, NetChaosSpec, ScalarOracle, ServeConfig, ShardServer,
};
use tm_fpga::tm::{MultiTm, TmParams, TmShape, Xoshiro256};

fn shape() -> TmShape {
    TmShape::iris()
}

/// Random machine with realistic include density (testkit seeding).
fn machine(seed: u64) -> MultiTm {
    let mut rng = Xoshiro256::new(seed);
    tm_fpga::testkit::gen::machine(&mut rng, &shape())
}

fn send(at: u64, req: Request) -> ClientOp {
    ClientOp::Send { at, bytes: req.encode().into_bytes() }
}

/// A deterministic feature row for request `salt`.
fn bit_row(salt: u64) -> Vec<bool> {
    let mut rng = Xoshiro256::new(salt ^ 0xB17_0F0E);
    (0..shape().features).map(|_| rng.next_f32() < 0.5).collect()
}

/// Every connection-fault kind, alone and combined, over both backend
/// arms: zero outcome mismatches, equal stats, equal replica digests,
/// exact per-arm accounting.
#[test]
fn connection_fault_matrix_agrees_with_oracle() {
    let zero = NetChaosSpec { torn: 0, half_open: 0, disconnects: 0, slow_loris: 0, floods: 0 };
    let cases = [
        ("torn", NetChaosSpec { torn: 2, ..zero }),
        ("half-open", NetChaosSpec { half_open: 2, ..zero }),
        ("disconnect", NetChaosSpec { disconnects: 2, ..zero }),
        ("slow-loris", NetChaosSpec { slow_loris: 2, ..zero }),
        ("flood", NetChaosSpec { floods: 2, ..zero }),
        ("full-matrix", NetChaosSpec::full_matrix()),
    ];
    for (name, spec) in cases {
        let cfg = NetSoakConfig {
            clients: 6,
            requests_per_client: 24,
            spec,
            ..NetSoakConfig::default()
        };
        let rep = run_net_soak(&cfg).unwrap();
        assert!(rep.plan.faulted() >= 1, "{name}: no fault was scheduled");
        assert!(rep.agrees(), "{name}: arms disagreed: {rep:?}");
        assert!(rep.server.infers > 0, "{name}: no infer survived: {:?}", rep.server);
    }
}

/// Shard kills/stalls/corruptions *underneath* the connection chaos:
/// explicit server-side overload sheds are the only excused outcome
/// difference, and they are counted exactly.
#[test]
fn shard_faults_under_connection_chaos_stay_accounted() {
    let cfg = NetSoakConfig {
        shard_spec: Some(ChaosSpec { kills: 2, stalls: 1, corrupts: 1 }),
        ..NetSoakConfig::default()
    };
    let rep = run_net_soak(&cfg).unwrap();
    assert!(rep.agrees(), "arms disagreed: {rep:?}");
    assert_eq!(rep.excused_server_shed as u64, rep.server.server_shed, "{rep:?}");
}

/// Scripts for `clients` sessions that each grant a tiny read window,
/// then fire twelve infers into it — the degraded-client shedding path.
fn flood_scripts(clients: usize, window: u64) -> Vec<ClientScript> {
    (0..clients)
        .map(|c| {
            let mut ops = vec![ClientOp::ReadAllow { at: 0, frames: window }];
            ops.push(send(1, Request::Hello { version: PROTO_VERSION, model: None }));
            for cid in 1..=12u64 {
                let bits = bit_row(c as u64 * 100 + cid);
                ops.push(send(1 + cid, Request::Infer { id: cid, ttl: None, model: None, bits }));
            }
            // The client recovers late: queued frames may now deliver,
            // but every shed decision has already been taken.
            ops.push(ClientOp::ReadAllow { at: 40, frames: 200 });
            ClientScript { connect_at: 0, ops }
        })
        .collect()
}

/// Satellite: concurrent slow clients flooding one shard. With a write
/// window of 3 and a debt cap of 3, each session admits exactly the
/// hello plus five infers (promised reaches the cap) and sheds the
/// other seven — no response id duplicated, none lost, and the sharded
/// server and scalar oracle agree bit-for-bit.
#[test]
fn concurrent_floods_shed_exactly_and_lose_nothing() {
    let tm = machine(0xF10D);
    let params = TmParams::paper_online(&shape());
    let scripts = flood_scripts(4, 3);
    let batch = BatcherConfig { max_batch: 4, latency_budget: 2, expect_literals: None };
    let ncfg = NetConfig { batch, write_buffer_cap: 3, max_in_flight: 64, ..NetConfig::default() };

    let scfg = ServeConfig::new(1, params.clone(), 77);
    let server = ShardServer::new(&tm, &scfg).unwrap();
    let (srep, tr) = run_sim(SingleModel(server), scripts.clone(), &shape(), ncfg.clone()).unwrap();
    let oracle = ScalarOracle::new(tm, params, 77);
    let (orep, _) = run_sim(SingleModel(oracle), scripts, &shape(), ncfg).unwrap();

    assert_eq!(srep.stats.infers, 20, "{:?}", srep.stats);
    assert_eq!(srep.stats.shed_requests, 28, "{:?}", srep.stats);
    assert_eq!(srep.stats.preds, 20, "{:?}", srep.stats);
    assert_eq!(srep.stats.admission_rejected, 0, "{:?}", srep.stats);
    // Every request id lands in the outcome map exactly once.
    assert_eq!(srep.outcomes.len(), 4 * 12);
    for c in 0..4usize {
        for cid in 1..=5u64 {
            assert!(matches!(srep.outcomes[&(c, cid)], Outcome::Pred(_)), "client {c} id {cid}");
        }
        for cid in 6..=12u64 {
            assert_eq!(srep.outcomes[&(c, cid)], Outcome::SlowShed, "client {c} id {cid}");
        }
        // Delivered frames: hello-ok, the five admitted preds in request
        // order, and the final bye — shed requests produce no frame.
        let frames = tr.delivered(c);
        assert_eq!(frames.len(), 7, "client {c}: {frames:?}");
        assert!(frames[0].starts_with("ok hello"), "{frames:?}");
        for (k, cid) in (1..=5u64).enumerate() {
            assert!(frames[1 + k].starts_with(&format!("pred id={cid} ")), "{frames:?}");
        }
        assert!(frames[6].starts_with("bye"), "{frames:?}");
    }
    assert_eq!(srep.stats, orep.stats);
    assert_eq!(srep.outcomes, orep.outcomes);
}

/// Admission control: with a global in-flight depth of 3 and a client
/// that never reads, exactly three infers are admitted and the rest get
/// typed `admission` errors — deterministic to the request.
#[test]
fn admission_control_rejects_beyond_depth_with_typed_errors() {
    let tm = machine(0xAD31);
    let params = TmParams::paper_online(&shape());
    let mut ops = vec![ClientOp::ReadAllow { at: 0, frames: 1 }];
    ops.push(send(1, Request::Hello { version: PROTO_VERSION, model: None }));
    for cid in 1..=8u64 {
        let req = Request::Infer { id: cid, ttl: None, model: None, bits: bit_row(cid) };
        ops.push(send(1 + cid, req));
    }
    ops.push(ClientOp::ReadAllow { at: 30, frames: 100 });
    let scripts = vec![ClientScript { connect_at: 0, ops }];
    let batch = BatcherConfig { max_batch: 4, latency_budget: 2, expect_literals: None };
    let ncfg =
        NetConfig { batch, write_buffer_cap: 100, max_in_flight: 3, ..NetConfig::default() };
    let oracle = ScalarOracle::new(tm, params, 9);
    let (rep, tr) = run_sim(SingleModel(oracle), scripts, &shape(), ncfg).unwrap();

    assert_eq!(rep.stats.infers, 3, "{:?}", rep.stats);
    assert_eq!(rep.stats.admission_rejected, 5, "{:?}", rep.stats);
    assert_eq!(rep.stats.preds, 3, "{:?}", rep.stats);
    let frames = tr.delivered(0);
    let rejected = frames.iter().filter(|f| f.contains("kind=admission")).count();
    assert_eq!(rejected, 5, "{frames:?}");
    // hello-ok + 3 preds + 5 admission errors + bye.
    assert_eq!(frames.len(), 10, "{frames:?}");
    for cid in 1..=3u64 {
        assert!(matches!(rep.outcomes[&(0, cid)], Outcome::Pred(_)), "id {cid}");
    }
    for cid in 4..=8u64 {
        assert_eq!(rep.outcomes[&(0, cid)], Outcome::AdmissionRejected, "id {cid}");
    }
}

/// End-to-end over a real socket: bind an ephemeral loopback port, run
/// the drill client against the front end, and account every frame.
#[test]
fn tcp_loopback_drill_round_trips() {
    let tm = machine(0x07C9);
    let params = TmParams::paper_online(&shape());
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let addr = transport.local_addr();
    let n = 32u64;
    let features = shape().features;
    let client = thread::spawn(move || loopback_drill(addr, n, features, 0xD811).unwrap());
    let ncfg = NetConfig { max_in_flight: 4096, write_buffer_cap: 1024, ..NetConfig::default() };
    let oracle = ScalarOracle::new(tm, params, 5);
    let rep = run_tcp(SingleModel(oracle), transport, &shape(), ncfg, Some(60_000)).unwrap();
    let drill = client.join().unwrap();

    assert_eq!(drill.preds, n, "{drill:?}");
    assert_eq!(drill.errs, 0, "{drill:?}");
    assert_eq!(drill.stats.infers, n, "{drill:?}");
    assert_eq!(drill.bye.preds, n, "{drill:?}");
    assert_eq!(rep.stats.infers, n, "{:?}", rep.stats);
    assert_eq!(rep.stats.preds, n, "{:?}", rep.stats);
    assert_eq!(rep.stats.frame_errors, 0, "{:?}", rep.stats);
}
