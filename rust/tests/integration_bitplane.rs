//! Sample-sliced (bitplane) inference ⇄ row-major differential suite.
//!
//! `MultiTm::evaluate_planes` must be **bit-identical** to
//! `MultiTm::evaluate_batch` (and therefore to per-row `evaluate`, which
//! machine.rs pins to the batch path) over every datapath corner:
//! single- and multi-word literal rows, injected TA fault gates,
//! clause-output force overrides, inactive clause/class tails, and batch
//! sizes that are not multiples of 64 — including batches large enough
//! to engage the class × sample-chunk thread fan-out.

use tm_fpga::data::{blocks::BlockPlan, iris, SetAllocation};
use tm_fpga::testkit::gen;
use tm_fpga::tm::*;

fn random_inputs(shape: &TmShape, n: usize, rng: &mut Xoshiro256) -> Vec<Input> {
    gen::inputs(rng, shape, n)
}

/// Machine with uniformly random TA states (random include patterns),
/// plus the continued RNG stream for dataset draws.
fn random_machine(shape: &TmShape, seed: u64) -> (MultiTm, Xoshiro256) {
    let mut rng = Xoshiro256::new(seed);
    let tm = gen::machine(&mut rng, shape);
    (tm, rng)
}

/// Assert plane and row-major evaluation agree bit-for-bit in both modes,
/// and that the prediction paths (shared argmax) agree row by row.
fn assert_planes_match(tm: &MultiTm, inputs: &[Input], params: &TmParams, ctx: &str) {
    let planes = BitPlanes::from_inputs(tm.shape(), inputs);
    for mode in [EvalMode::Train, EvalMode::Infer] {
        let row_major = tm.evaluate_batch(inputs, params, mode);
        let sliced = tm.evaluate_planes(&planes, params, mode);
        assert_eq!(row_major, sliced, "{ctx}: sums diverged (n={}, {mode:?})", inputs.len());
    }
    assert_eq!(
        tm.predict_batch(inputs, params),
        tm.predict_planes(&planes, params),
        "{ctx}: predictions diverged (n={})",
        inputs.len()
    );
}

#[test]
fn planes_match_row_major_across_shapes_and_batch_sizes() {
    for (si, shape) in [
        TmShape::iris(),                                                    // 1 word
        TmShape { classes: 4, max_clauses: 6, features: 40, states: 8 },    // 2 words, partial
        TmShape { classes: 2, max_clauses: 4, features: 64, states: 8 },    // 2 full words
    ]
    .iter()
    .enumerate()
    {
        let (tm, mut rng) = random_machine(shape, 0x91A0 + si as u64);
        let mut p = TmParams::paper_offline(shape);
        p.t = 7;
        // Non-multiple-of-64 batches on both sides of the lane boundary;
        // 1000 rows push the iris shape over the thread-spawn threshold.
        for n in [1usize, 63, 64, 65, 130, 1000] {
            let inputs = random_inputs(shape, n, &mut rng);
            assert_planes_match(&tm, &inputs, &p, &format!("shape {si}"));
        }
        // Inactive clause/class tails (the over-provisioning ports).
        p.active_clauses = shape.max_clauses - 2;
        p.active_classes = shape.classes - 1;
        let inputs = random_inputs(shape, 97, &mut rng);
        assert_planes_match(&tm, &inputs, &p, &format!("shape {si} gated"));
    }
}

#[test]
fn planes_match_threaded_multiword() {
    // Big enough that class × sample-chunk fan-out engages on a
    // multi-word shape (work = 2048 · 4 · 6 ≥ the spawn threshold).
    let shape = TmShape { classes: 4, max_clauses: 6, features: 40, states: 8 };
    let (tm, mut rng) = random_machine(&shape, 0x7EAD);
    let p = TmParams::paper_offline(&shape);
    let inputs = random_inputs(&shape, 2048, &mut rng);
    assert_planes_match(&tm, &inputs, &p, "threaded multiword");
}

#[test]
fn planes_match_under_fault_gates() {
    let shape = TmShape { classes: 3, max_clauses: 8, features: 40, states: 16 };
    let (mut tm, mut rng) = random_machine(&shape, 0xFA17);
    let p = TmParams::paper_offline(&shape);
    for (frac, kind) in [(0.20, Fault::StuckAt0), (0.10, Fault::StuckAt1)] {
        let map = FaultMap::even_spread(&shape, frac, kind, 11).unwrap();
        tm.set_fault_map(map);
        let inputs = random_inputs(&shape, 150, &mut rng);
        assert_planes_match(&tm, &inputs, &p, &format!("{kind:?}"));
    }
}

#[test]
fn planes_match_under_clause_force() {
    let shape = TmShape::iris();
    let (mut tm, mut rng) = random_machine(&shape, 0xC10F);
    let mut p = TmParams::paper_offline(&shape);
    p.active_clauses = 12;
    tm.set_clause_fault(0, 0, Some(true));
    tm.set_clause_fault(1, 3, Some(false));
    // Forced clause in the gated-off tail: both paths must ignore it.
    tm.set_clause_fault(2, 13, Some(true));
    let inputs = random_inputs(&shape, 70, &mut rng);
    assert_planes_match(&tm, &inputs, &p, "forced");
    tm.set_clause_fault(0, 0, None);
    tm.set_clause_fault(1, 3, None);
    assert_planes_match(&tm, &inputs, &p, "partially cleared");
    tm.set_clause_fault(2, 13, None);
    assert_eq!(tm.clause_fault_count(), 0);
    assert_planes_match(&tm, &inputs, &p, "cleared");
}

#[test]
fn trained_machine_accuracy_planes_matches_batch() {
    let shape = TmShape::iris();
    let params = TmParams::paper_offline(&shape);
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 33).unwrap();
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
    let train = sets.offline.pack(&shape);
    let val = sets.validation.pack(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(3);
    for _ in 0..10 {
        tm.train_epoch(&train, &params, &mut rng);
    }
    let batch = PlaneBatch::from_labelled(&shape, &val);
    let acc_planes = tm.accuracy_planes(&batch, &params);
    let acc_batch = tm.accuracy_batch(&val, &params);
    assert!(
        (acc_planes - acc_batch).abs() < 1e-12,
        "plane acc {acc_planes} vs batch acc {acc_batch}"
    );
    assert!(acc_planes > 0.5, "trained machine beats chance: {acc_planes:.3}");

    // Dataset-side cache constructors agree with the direct transpose.
    let cached = sets.validation.pack_planes(&shape);
    assert_eq!(cached.labels(), batch.labels());
    assert_eq!(
        tm.predict_planes(cached.planes(), &params),
        tm.predict_planes(batch.planes(), &params)
    );
    let packed = sets.pack_planes(&shape);
    assert_eq!(packed.validation_planes.labels(), batch.labels());
    assert_eq!(packed.validation.len(), val.len());
    assert!(
        (tm.accuracy_planes(&packed.validation_planes, &params) - acc_batch).abs() < 1e-12
    );
}

#[test]
fn transpose_roundtrip_and_tail_masks() {
    let shape = TmShape { classes: 2, max_clauses: 4, features: 40, states: 8 };
    let mut rng = Xoshiro256::new(9);
    let inputs = random_inputs(&shape, 70, &mut rng);
    let planes = BitPlanes::from_inputs(&shape, &inputs);
    assert_eq!(planes.len(), 70);
    assert_eq!(planes.lanes(), 2);
    assert_eq!(planes.literals(), 80);
    assert_eq!(planes.lane_mask(0), !0u64);
    assert_eq!(planes.lane_mask(1), (1u64 << 6) - 1);
    for (i, x) in inputs.iter().enumerate() {
        for k in 0..shape.literals() {
            assert_eq!(planes.literal(k, i), x.literal(k), "lit {k} row {i}");
        }
    }
}

#[test]
fn empty_batch_yields_empty_results() {
    let shape = TmShape::iris();
    let tm = MultiTm::new(&shape).unwrap();
    let p = TmParams::paper_offline(&shape);
    let planes = BitPlanes::from_inputs(&shape, &[]);
    assert!(planes.is_empty());
    assert!(tm.evaluate_planes(&planes, &p, EvalMode::Infer).is_empty());
    assert!(tm.predict_planes(&planes, &p).is_empty());
    let batch = PlaneBatch::from_labelled(&shape, &[]);
    assert_eq!(tm.accuracy_planes(&batch, &p), 0.0);
}
