//! Native ⇄ PJRT parity: the proof that all three layers compose.
//!
//! The native Rust TM (`tm::feedback`) and the AOT-lowered L2/L1 graph
//! (Pallas kernels under `interpret=True`, lowered to HLO text, executed
//! by the PJRT CPU client) are driven with the **same** input rows and the
//! **same** [`StepRands`] streams. TA states must stay bit-identical along
//! full training trajectories, and inference must agree per datapoint.
//!
//! Requires `make artifacts` (skips with a notice otherwise).

use tm_fpga::data::{iris, BlockPlan, SetAllocation};
use tm_fpga::runtime::{default_artifacts_dir, Client, TmExecutor};
use tm_fpga::tm::*;

fn load_executor() -> Option<(Client, TmExecutor)> {
    let dir = default_artifacts_dir();
    if !dir.join("meta.json").exists() {
        eprintln!(
            "SKIP: artifacts not found in {} — run `make artifacts`",
            dir.display()
        );
        return None;
    }
    let client = Client::cpu().expect("PJRT CPU client");
    let exe = TmExecutor::load(&client, &dir).expect("load artifacts");
    Some((client, exe))
}

fn paper_data(shape: &TmShape) -> Vec<(Input, usize)> {
    let plan = BlockPlan::stratified(iris::booleanised(), 5, 7).unwrap();
    let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
    sets.offline.pack(shape)
}

#[test]
fn train_trajectory_bit_identical() {
    let Some((_c, exe)) = load_executor() else { return };
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_offline(&shape);
    let data = paper_data(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(0xBEEF_CAFE);

    // 3 epochs over the 30-row offline set = 90 steps, checked at every
    // step: the PJRT path computes next-state from the same current state
    // and randomness the native path consumes.
    for epoch in 0..3 {
        for (i, (x, y)) in data.iter().enumerate() {
            let r = StepRands::draw(&mut rng, &shape);
            let pjrt_next = exe
                .train_step(&tm, x, *y, &params, &r)
                .expect("pjrt train step");
            train_step(&mut tm, x, *y, &params, &r);
            assert_eq!(
                tm.ta().states(),
                &pjrt_next[..],
                "state diverged at epoch {epoch} step {i}"
            );
        }
    }
}

#[test]
fn inference_agrees_on_trained_machine() {
    let Some((_c, exe)) = load_executor() else { return };
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_offline(&shape);
    let data = paper_data(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(0x1234);
    for _ in 0..5 {
        for (x, y) in &data {
            let r = StepRands::draw(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &r);
        }
    }
    for (x, _) in &data {
        let (native_sums, native_pred) = tm.infer(x, &params);
        let (pjrt_sums, pjrt_pred) = exe.infer(&tm, x, &params).expect("pjrt infer");
        assert_eq!(&pjrt_sums[..params.active_classes], &native_sums[..]);
        assert_eq!(pjrt_pred, native_pred);
    }
}

#[test]
fn parity_holds_under_faults_and_overprovisioning() {
    let Some((_c, exe)) = load_executor() else { return };
    let shape = exe.meta.shape.clone();
    let mut params = TmParams::paper_online(&shape); // s = 1 path
    params.active_clauses = 12; // clause-number port below max
    let data = paper_data(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    tm.set_fault_map(
        FaultMap::even_spread(&shape, 0.20, Fault::StuckAt0, 99).unwrap(),
    );
    let mut rng = Xoshiro256::new(0xFA57);
    for (i, (x, y)) in data.iter().enumerate().take(60) {
        let r = StepRands::draw(&mut rng, &shape);
        let pjrt_next = exe.train_step(&tm, x, *y, &params, &r).expect("pjrt");
        train_step(&mut tm, x, *y, &params, &r);
        assert_eq!(tm.ta().states(), &pjrt_next[..], "diverged at step {i}");
        if i % 10 == 0 {
            let (s_native, p_native) = tm.infer(x, &params);
            let (s_pjrt, p_pjrt) = exe.infer(&tm, x, &params).unwrap();
            assert_eq!(&s_pjrt[..params.active_classes], &s_native[..]);
            assert_eq!(p_pjrt, p_native);
        }
    }
}

#[test]
fn epoch_scan_matches_stepwise_native() {
    // The scan artifact (one dispatch per pass) must land on exactly the
    // same TA states as N native per-datapoint steps — including the
    // no-op padding rows.
    let Some((_c, exe)) = load_executor() else { return };
    if exe.meta.epoch_steps == 0 {
        eprintln!("SKIP: artifacts lack tm_train_epoch");
        return;
    }
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_online(&shape); // the online-pass config
    let data = paper_data(&shape); // 30 rows < epoch_steps=60 -> padding
    let mut rng = Xoshiro256::new(0xE90C);
    let steps: Vec<(Input, usize, StepRands)> = data
        .iter()
        .map(|(x, y)| (x.clone(), *y, StepRands::draw(&mut rng, &shape)))
        .collect();
    let mut tm = MultiTm::new(&shape).unwrap();
    // Pre-train a little so the pass starts from a non-trivial state.
    let mut rng2 = Xoshiro256::new(0xAAA);
    for (x, y) in &data {
        let r = StepRands::draw(&mut rng2, &shape);
        train_step(&mut tm, x, *y, &TmParams::paper_offline(&shape), &r);
    }
    let pjrt_final = exe.train_epoch(&tm, &steps, &params).expect("epoch");
    for (x, y, r) in &steps {
        train_step(&mut tm, x, *y, &params, r);
    }
    assert_eq!(tm.ta().states(), &pjrt_final[..], "scan diverged from stepwise");
}

#[test]
fn epoch_scan_rejects_oversized_pass() {
    let Some((_c, exe)) = load_executor() else { return };
    if exe.meta.epoch_steps == 0 {
        return;
    }
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_online(&shape);
    let data = paper_data(&shape);
    let mut rng = Xoshiro256::new(1);
    let steps: Vec<(Input, usize, StepRands)> = data
        .iter()
        .cycle()
        .take(exe.meta.epoch_steps + 1)
        .map(|(x, y)| (x.clone(), *y, StepRands::draw(&mut rng, &shape)))
        .collect();
    let tm = MultiTm::new(&shape).unwrap();
    assert!(exe.train_epoch(&tm, &steps, &params).is_err());
}

#[test]
fn runtime_failure_paths() {
    use tm_fpga::runtime::ArtifactMeta;
    // Missing directory.
    assert!(ArtifactMeta::load(std::path::Path::new("/nonexistent/dir")).is_err());
    // Corrupt meta.json.
    let dir = std::env::temp_dir().join("tmfpga_bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("meta.json"), "{not json").unwrap();
    assert!(ArtifactMeta::load(&dir).is_err());
    // Valid JSON, invalid shape.
    std::fs::write(
        dir.join("meta.json"),
        r#"{"shape": {"classes": 0, "clauses": 16, "features": 16, "states": 100}, "batch": 150, "artifacts": {}}"#,
    )
    .unwrap();
    assert!(ArtifactMeta::load(&dir).is_err(), "shape validation must fire");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn executor_rejects_mismatched_machine() {
    let Some((_c, exe)) = load_executor() else { return };
    // A machine with a different structural shape must be refused before
    // any PJRT call.
    let other = TmShape { classes: 2, max_clauses: 8, features: 8, states: 16 };
    let tm = MultiTm::new(&other).unwrap();
    let x = Input::pack(&other, &vec![false; 8]);
    let params = TmParams::paper_offline(&other);
    let err = exe.infer(&tm, &x, &params).unwrap_err().to_string();
    assert!(err.contains("does not match artifact shape"), "{err}");
}

#[test]
fn accuracy_chunks_through_batch_limit() {
    let Some((_c, exe)) = load_executor() else { return };
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_offline(&shape);
    // 240 rows > the 150-row padded batch: the accuracy path must chunk.
    let base = paper_data(&shape);
    let mut data = Vec::new();
    for _ in 0..8 {
        data.extend(base.iter().cloned());
    }
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(0xC0DE);
    for _ in 0..5 {
        for (x, y) in &base {
            let r = StepRands::draw(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &r);
        }
    }
    let native = tm.accuracy(&data, &params);
    let pjrt = exe.accuracy(&tm, &data, &params).unwrap();
    assert!((native - pjrt).abs() < 1e-9);
}

#[test]
fn eval_batch_matches_native_accuracy() {
    let Some((_c, exe)) = load_executor() else { return };
    let shape = exe.meta.shape.clone();
    let params = TmParams::paper_offline(&shape);
    let data = paper_data(&shape);
    let mut tm = MultiTm::new(&shape).unwrap();
    let mut rng = Xoshiro256::new(0xACC);
    for _ in 0..8 {
        for (x, y) in &data {
            let r = StepRands::draw(&mut rng, &shape);
            train_step(&mut tm, x, *y, &params, &r);
        }
    }
    let native_acc = tm.accuracy(&data, &params);
    let pjrt_acc = exe.accuracy(&tm, &data, &params).unwrap();
    assert!((native_acc - pjrt_acc).abs() < 1e-9, "{native_acc} vs {pjrt_acc}");
    // Per-row predictions agree too.
    let (preds, correct) = exe.eval_batch(&tm, &data, &params).unwrap();
    let native_correct = data
        .iter()
        .zip(preds.iter())
        .filter(|((x, _), &p)| {
            let mut tm2 = tm.clone();
            tm2.predict(x, &params) == p as usize
        })
        .count();
    assert_eq!(native_correct, data.len(), "every row's prediction matches");
    assert_eq!(correct, (native_acc * data.len() as f64).round() as usize);
}
