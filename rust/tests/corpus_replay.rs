//! Committed-corpus replay: every fixture under `rust/tests/corpus/`
//! must parse, round-trip through the text format, and replay through
//! all five engine lanes with bit-identity at every step. A divergence
//! here means an engine broke an equivalence the corpus pins — minimize
//! it with `tmfpga verify --grow` style shrinking and commit the
//! reproducer as a new fixture.

use std::fs;
use std::path::PathBuf;
use tm_fpga::tm::params::TmShape;
use tm_fpga::verify::corpus::{replay, Schedule};
use tm_fpga::verify::shrink::random_schedule;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/corpus")
}

fn fixture_paths() -> Vec<PathBuf> {
    let mut paths: Vec<PathBuf> = fs::read_dir(corpus_dir())
        .expect("corpus dir must exist")
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("ron"))
        .collect();
    paths.sort();
    paths
}

#[test]
fn committed_fixtures_replay_bit_identically() {
    let paths = fixture_paths();
    assert!(!paths.is_empty(), "the committed corpus must not be empty");
    for path in paths {
        let name = path.display();
        let text = fs::read_to_string(&path).expect("readable fixture");
        let s = Schedule::parse(&text).unwrap_or_else(|e| panic!("{name}: parse failed: {e:#}"));
        assert!(!s.steps.is_empty(), "{name}: fixture has no steps");

        // Round-trip stability: re-serialized text parses back to the
        // same schedule (comments are the only thing dropped).
        let back = Schedule::parse(&s.to_text())
            .unwrap_or_else(|e| panic!("{name}: round-trip parse failed: {e:#}"));
        assert_eq!(back, s, "{name}: round-trip changed the schedule");

        let rep = replay(&s).unwrap_or_else(|d| panic!("{name}: diverged at {d}"));
        assert_eq!(rep.steps, s.steps.len(), "{name}: replay stopped early");
        assert!(rep.checks > 0, "{name}: replay made no cross-lane checks");
    }
}

/// The corpus covers every step kind across the committed fixtures —
/// a fixture set that stopped exercising (say) checkpoints would
/// silently weaken the whole harness.
#[test]
fn committed_fixtures_cover_every_step_kind() {
    use tm_fpga::verify::corpus::Step;
    let mut seen = [false; 11];
    for path in fixture_paths() {
        let s = Schedule::parse(&fs::read_to_string(&path).unwrap()).unwrap();
        for step in &s.steps {
            let k = match step {
                Step::Train { .. } => 0,
                Step::Infer { .. } => 1,
                Step::Rescore { .. } => 2,
                Step::Fault { .. } => 3,
                Step::Force { .. } => 4,
                Step::Clone => 5,
                Step::Checkpoint => 6,
                Step::Serve { .. } => 7,
                Step::Params { .. } => 8,
                Step::Net { .. } => 9,
                Step::Hub { .. } => 10,
            };
            seen[k] = true;
        }
    }
    assert_eq!(seen, [true; 11], "corpus no longer covers every step kind");
}

/// Seeded generator schedules replay clean over both a single-word and a
/// multi-word shape: the growth path (`tmfpga verify --grow`) should only
/// ever find divergences caused by real engine bugs, never by the
/// generator emitting invalid schedules.
#[test]
fn seeded_schedules_replay_clean_across_shapes() {
    for (name, shape) in [
        ("iris", TmShape::iris()),
        ("wide", TmShape { classes: 2, max_clauses: 8, features: 80, states: 50 }),
    ] {
        for seed in 0..3u64 {
            let s = random_schedule(&shape, seed, 40);
            // Generated schedules also survive the text round-trip.
            assert_eq!(Schedule::parse(&s.to_text()).unwrap(), s);
            if let Err(d) = replay(&s) {
                panic!("{name} seed {seed} diverged at {d}\nschedule:\n{}", s.to_text());
            }
        }
    }
}
