//! Fault-tolerance differential suite for the serving stack: every
//! failure mode the supervisor handles — immediate kills at *every*
//! update seq, mid-batch kills, corrupted checkpoints, stall windows,
//! overload shedding, malformed requests — is driven against the scalar
//! `MultiTm` oracle, and the recovered run must be **bit-identical** to
//! the run that never failed: same responses, same final replica
//! states, and exact shed/quarantine accounting. There is no tolerance
//! band anywhere; a single diverging prediction is a real replay bug.

use std::path::Path;
use tm_fpga::coordinator::{run_chaos_soak, ChaosSoakConfig, SoakConfig};
use tm_fpga::serve::{
    load_snapshot, restore, run_trace, save_snapshot, snapshot_bytes, BatcherConfig, ChaosEvent,
    ChaosPlan, KillKind, ScalarOracle, ServeConfig, ServeEvent, ServeOutcome, ShardServer,
};
use tm_fpga::tm::{Input, MultiTm, TmParams, TmShape, UpdateKind, Xoshiro256};

fn shape() -> TmShape {
    TmShape::iris()
}

/// Random machine with realistic include density (testkit seeding).
fn machine(seed: u64) -> MultiTm {
    let mut rng = Xoshiro256::new(seed);
    tm_fpga::testkit::gen::machine(&mut rng, &shape())
}

/// Interleaved trace: every third event is a labelled (Learn) update.
fn trace(n: usize, seed: u64) -> Vec<ServeEvent> {
    let s = shape();
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|i| {
            let input = Input::pack(&s, &tm_fpga::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
            if i % 3 == 0 {
                ServeEvent::Update {
                    at_tick: i as u64,
                    kind: UpdateKind::Learn { input, label: i % s.classes },
                }
            } else {
                ServeEvent::Infer { at_tick: i as u64, input }
            }
        })
        .collect()
}

fn update_count(events: &[ServeEvent]) -> u64 {
    events.iter().filter(|e| matches!(e, ServeEvent::Update { .. })).count() as u64
}

const BASE_SEED: u64 = 0xBA5E;

fn bcfg() -> BatcherConfig {
    BatcherConfig { max_batch: 8, latency_budget: 2, ..Default::default() }
}

/// Drive one chaos-armed server and the never-failing oracle over the
/// same trace; returns `(outcome, oracle_responses, oracle_digest)`.
fn run_pair(
    tm: &MultiTm,
    params: &TmParams,
    events: &[ServeEvent],
    shards: usize,
    plan: ChaosPlan,
    tune: impl FnOnce(&mut ServeConfig),
) -> (ServeOutcome, Vec<(u64, usize)>, u64) {
    let bcfg = bcfg();
    let mut cfg = ServeConfig::new(shards, params.clone(), BASE_SEED);
    tune(&mut cfg);
    let mut server = ShardServer::with_chaos(tm, &cfg, plan).unwrap();
    run_trace(&mut server, events, &bcfg).unwrap();
    let out = server.finish().unwrap();

    let mut oracle = ScalarOracle::new(tm.clone(), params.clone(), BASE_SEED);
    run_trace(&mut oracle, events, &bcfg).unwrap();
    let digest = oracle.machine().state_digest();
    (out, oracle.into_responses(), digest)
}

/// Every oracle response is either answered bit-identically or listed
/// in `shed`; nothing extra exists on the server side.
fn assert_partition(out: &ServeOutcome, want: &[(u64, usize)], ctx: &str) {
    assert_eq!(
        out.responses.len() + out.shed.len(),
        want.len(),
        "{ctx}: responses + shed must cover every admitted request"
    );
    assert_eq!(
        out.recovery.shed_requests,
        out.shed.len() as u64,
        "{ctx}: shed counter vs shed id list"
    );
    let mut answered = out.responses.iter().peekable();
    for &(id, pred) in want {
        if out.shed.binary_search(&id).is_ok() {
            continue;
        }
        match answered.next() {
            Some(&(got_id, got_pred)) => {
                assert_eq!(got_id, id, "{ctx}: response id order");
                assert_eq!(got_pred, pred, "{ctx}: request {id} diverged from the oracle");
            }
            None => panic!("{ctx}: request {id} neither answered nor shed"),
        }
    }
    assert!(answered.next().is_none(), "{ctx}: server answered an id the oracle never saw");
}

/// The headline acceptance: an immediate kill after **every single
/// update seq**, across shard counts 1/2/4, recovers bit-identically —
/// same responses, same final replicas, nothing shed.
#[test]
fn kill_at_every_update_seq_recovers_bit_identically() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0x60D);
    let events = trace(75, 0x41);
    let updates = update_count(&events);
    assert!(updates >= 20, "trace too short to sweep");
    for shards in [1usize, 2, 4] {
        for kill_seq in 1..=updates {
            let plan = ChaosPlan {
                events: vec![ChaosEvent::Kill {
                    shard: kill_seq as usize % shards,
                    after_seq: kill_seq,
                    kind: KillKind::Immediate,
                }],
            };
            let ctx = format!("shards {shards}, kill@{kill_seq}");
            let (out, want, digest) = run_pair(&tm, &p, &events, shards, plan, |c| {
                c.fault.checkpoint_every = 4;
            });
            assert_eq!(out.recovery.worker_panics, 1, "{ctx}");
            assert_eq!(out.recovery.recoveries, 1, "{ctx}");
            assert!(out.shed.is_empty(), "{ctx}: nothing may shed under lag 0");
            assert_eq!(out.responses, want, "{ctx}: responses diverged");
            for r in &out.replicas {
                assert_eq!(r.state_digest(), digest, "{ctx}: replica diverged");
            }
        }
    }
}

/// A worker killed *mid-batch* (the armed `OnNextBatch` kill) takes the
/// batch down with it; the supervisor re-dispatches it to the respawned
/// incarnation at the original flush seq, so responses still match.
#[test]
fn killed_mid_batch_is_redispatched_exactly() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0x7A2);
    let events = trace(90, 0x52);
    let plan = ChaosPlan {
        events: vec![ChaosEvent::Kill { shard: 1, after_seq: 5, kind: KillKind::OnNextBatch }],
    };
    let (out, want, digest) =
        run_pair(&tm, &p, &events, 2, plan, |c| c.fault.checkpoint_every = 4);
    assert_eq!(out.recovery.worker_panics, 1);
    assert_eq!(out.recovery.recoveries, 1);
    assert!(
        out.recovery.redispatched_batches >= 1,
        "the batch that died with the worker must be re-dispatched"
    );
    assert!(out.shed.is_empty());
    assert_eq!(out.responses, want);
    for r in &out.replicas {
        assert_eq!(r.state_digest(), digest);
    }
}

/// A corrupted newest checkpoint is rejected at restore time and
/// recovery falls back to the older retained snapshot — a strictly
/// longer replay, never a silent load of damaged state.
#[test]
fn corrupted_checkpoint_falls_back_to_an_older_snapshot() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0x0C0);
    let events = trace(100, 0x63);
    assert!(update_count(&events) >= 14);
    // checkpoint_every = 5: shard 0 snapshots at seqs 5, 10, ... — its
    // 2nd snapshot (seq 10) is the newest retained one when the kill at
    // seq 12 is recovered.
    let kill = ChaosEvent::Kill { shard: 0, after_seq: 12, kind: KillKind::Immediate };
    let clean_plan = ChaosPlan { events: vec![kill.clone()] };
    let corrupt_plan = ChaosPlan {
        events: vec![ChaosEvent::CorruptSnapshot { shard: 0, nth: 2 }, kill],
    };
    let tune = |c: &mut ServeConfig| c.fault.checkpoint_every = 5;
    let (clean, want, digest) = run_pair(&tm, &p, &events, 2, clean_plan, tune);
    let (corr, want2, digest2) = run_pair(&tm, &p, &events, 2, corrupt_plan, tune);
    assert_eq!(want, want2, "same trace, same oracle");
    assert_eq!(digest, digest2);

    assert_eq!(clean.recovery.corrupt_snapshots_rejected, 0);
    assert_eq!(corr.recovery.corrupt_snapshots_rejected, 1, "the flipped byte must be caught");
    assert_eq!(corr.recovery.recoveries, 1);
    assert!(
        corr.recovery.replayed_updates > clean.recovery.replayed_updates,
        "fallback to the older snapshot must replay a longer suffix \
         ({} vs {} updates)",
        corr.recovery.replayed_updates,
        clean.recovery.replayed_updates
    );
    for (out, label) in [(&clean, "clean"), (&corr, "corrupt")] {
        assert_eq!(out.responses, want, "{label} run diverged");
        assert!(out.shed.is_empty(), "{label} run shed requests");
        for r in &out.replicas {
            assert_eq!(r.state_digest(), digest, "{label} replica diverged");
        }
    }
}

/// A stalled worker buffers its window and drains in order: no
/// recovery, no reordering, responses bit-identical.
#[test]
fn stall_then_resume_stays_bit_identical() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0x57A);
    let events = trace(80, 0x74);
    let plan = ChaosPlan {
        events: vec![ChaosEvent::Stall { shard: 1, after_seq: 6, items: 9 }],
    };
    let (out, want, digest) = run_pair(&tm, &p, &events, 2, plan, |c| {
        c.fault.checkpoint_every = 8;
    });
    assert_eq!(out.recovery.chaos_events_fired, 1);
    assert_eq!(out.recovery.worker_panics, 0, "a stall is not a death");
    assert_eq!(out.recovery.recoveries, 0);
    assert!(out.shed.is_empty());
    assert_eq!(out.responses, want);
    for r in &out.replicas {
        assert_eq!(r.state_digest(), digest);
    }
}

/// Single shard + a recovery lag: every batch flushed during the outage
/// is shed with exact, deterministic accounting — and everything that
/// *was* answered still matches the oracle.
#[test]
fn shed_requests_are_accounted_exactly_and_deterministically() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0x5ED);
    let events = trace(90, 0x85);
    let plan = ChaosPlan {
        events: vec![ChaosEvent::Kill { shard: 0, after_seq: 8, kind: KillKind::Immediate }],
    };
    let tune = |c: &mut ServeConfig| {
        c.fault.checkpoint_every = 4;
        c.fault.recovery_lag = 6;
    };
    let (a, want, digest) = run_pair(&tm, &p, &events, 1, plan.clone(), tune);
    let (b, _, _) = run_pair(&tm, &p, &events, 1, plan, tune);

    assert!(!a.shed.is_empty(), "a 1-shard outage under lag must shed");
    assert!(a.recovery.shed_batches > 0);
    assert_eq!(a.shed, b.shed, "shed decisions must be deterministic");
    assert_eq!(a.responses, b.responses);
    assert_eq!(a.recovery, b.recovery, "recovery counters must be deterministic");
    assert_partition(&a, &want, "1-shard outage");
    // The update log still reaches the recovered shard in full: its
    // final replica matches the oracle even though some *responses*
    // were shed.
    for r in &a.replicas {
        assert_eq!(r.state_digest(), digest);
    }
}

/// Degraded mode: while a shard is down, the survivor absorbs only
/// `degraded_depth` batches before further ones are shed.
#[test]
fn degraded_depth_caps_survivor_absorption() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0xDE6);
    let events = trace(100, 0x96);
    let plan = ChaosPlan {
        events: vec![ChaosEvent::Kill { shard: 0, after_seq: 5, kind: KillKind::Immediate }],
    };
    let (out, want, digest) = run_pair(&tm, &p, &events, 2, plan, |c| {
        c.fault.checkpoint_every = 4;
        c.fault.recovery_lag = 40;
        c.fault.degraded_depth = 2;
    });
    assert!(
        out.recovery.shed_batches > 0,
        "a long outage under depth 2 must overflow the survivor"
    );
    assert_partition(&out, &want, "degraded 2-shard outage");
    for r in &out.replicas {
        assert_eq!(r.state_digest(), digest);
    }
}

/// Checkpoint images round-trip bit-identically, and any single-byte
/// flip or truncation is rejected at restore time — corruption can
/// never load silently.
#[test]
fn checkpoint_roundtrip_and_corruption_rejection() {
    let tm = machine(0x7EA);
    let p = TmParams::paper_offline(&shape());
    let bytes = snapshot_bytes(&tm, &p, 1234);
    let snap = restore(&bytes).unwrap();
    assert_eq!(snap.seq, 1234);
    assert_eq!(snap.machine.state_digest(), tm.state_digest());

    let step = (bytes.len() / 13).max(1);
    for pos in (0..bytes.len()).step_by(step) {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x01;
        assert!(restore(&bad).is_err(), "bit-flip at byte {pos} must be rejected");
    }
    for cut in [0usize, 1, 3, bytes.len() / 2, bytes.len() - 1] {
        assert!(restore(&bytes[..cut]).is_err(), "truncation to {cut} bytes must be rejected");
    }

    let path = Path::new(env!("CARGO_TARGET_TMPDIR")).join("recovery_roundtrip.tmfs");
    save_snapshot(&tm, &p, 77, &path).unwrap();
    let loaded = load_snapshot(&path).unwrap();
    assert_eq!(loaded.seq, 77);
    assert_eq!(loaded.machine.state_digest(), tm.state_digest());
    std::fs::remove_file(&path).ok();
}

/// A kill landing on the very last update is recovered during
/// `finish`, so the outcome still covers every request and replica.
#[test]
fn kill_at_the_final_update_is_recovered_at_finish() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0xF1A);
    let events = trace(76, 0xA7); // event 75 is an Update: the last seq
    let updates = update_count(&events);
    assert!(matches!(events.last(), Some(ServeEvent::Update { .. })));
    let plan = ChaosPlan {
        events: vec![ChaosEvent::Kill {
            shard: 1,
            after_seq: updates,
            kind: KillKind::Immediate,
        }],
    };
    let (out, want, digest) =
        run_pair(&tm, &p, &events, 2, plan, |c| c.fault.checkpoint_every = 4);
    assert_eq!(out.recovery.recoveries, 1);
    assert!(out.shed.is_empty());
    assert_eq!(out.responses, want);
    for r in &out.replicas {
        assert_eq!(r.state_digest(), digest);
    }
}

/// Malformed requests are quarantined at admission with exact id
/// accounting: the survivors' responses are bit-identical to the
/// oracle's, and no quarantined id is ever answered.
#[test]
fn malformed_requests_never_reach_a_shard() {
    let s = shape();
    let p = TmParams::paper_online(&s);
    let tm = machine(0xBAD);
    let wrong = TmShape { features: s.features + 1, ..s.clone() };
    let mut events = trace(80, 0xB8);
    let mut malformed_ids = Vec::new();
    let mut id = 0u64;
    for ev in events.iter_mut() {
        if let ServeEvent::Infer { input, .. } = ev {
            if id % 7 == 3 {
                *input = Input::pack(&wrong, &vec![false; wrong.features]);
                malformed_ids.push(id);
            }
            id += 1;
        }
    }
    let bcfg = BatcherConfig {
        max_batch: 8,
        latency_budget: 2,
        expect_literals: Some(s.literals()),
    };
    let cfg = ServeConfig::new(2, p.clone(), BASE_SEED);
    let mut server = ShardServer::new(&tm, &cfg).unwrap();
    let drive = run_trace(&mut server, &events, &bcfg).unwrap();
    let out = server.finish().unwrap();

    let mut oracle = ScalarOracle::new(tm.clone(), p, BASE_SEED);
    let oracle_drive = run_trace(&mut oracle, &events, &bcfg).unwrap();
    let want = oracle.into_responses();

    assert_eq!(drive.quarantined, malformed_ids.len() as u64, "exact quarantine count");
    assert_eq!(drive, oracle_drive, "both arms quarantine identically");
    assert_eq!(drive.infer_requests + drive.quarantined, id, "ids partition");
    assert_eq!(out.responses, want);
    for bad in &malformed_ids {
        assert!(
            out.responses.binary_search_by_key(bad, |&(i, _)| i).is_err(),
            "quarantined id {bad} must never be answered"
        );
    }
}

/// Seeded chaos schedules across seeds × shard counts through the full
/// soak driver (kills + stalls + checkpoint corruption + malformed
/// requests): every combination recovers to bit-identity with exact
/// accounting.
#[test]
fn seeded_chaos_matrix_agrees_across_seeds_and_shard_counts() {
    for shards in [1usize, 2, 4] {
        for chaos_seed in [0xAA11u64, 0xBB22, 0xCC33] {
            let cfg = ChaosSoakConfig {
                soak: SoakConfig {
                    shards,
                    events: 260,
                    warmup_epochs: 1,
                    ..Default::default()
                },
                chaos_seed,
                kills: 2,
                stalls: 1,
                corrupts: 1,
                malformed_every: 29,
                checkpoint_every: 8,
                ..Default::default()
            };
            let rep = run_chaos_soak(&cfg).unwrap();
            assert!(!rep.plan.events.is_empty());
            assert!(
                rep.agrees(),
                "shards {shards} chaos_seed {chaos_seed:#x}: {} mismatches, \
                 replicas_match={}, accounting={}",
                rep.mismatches,
                rep.replicas_match_oracle,
                rep.accounting_exact
            );
            assert!(rep.drive.quarantined > 0, "malformed injection must fire");
        }
    }
}
