//! Contract-feature harness (runs only under `--features contracts`):
//! the full invariant audit rides every corpus replay via the per-step
//! cross-check, and the shrinker proves it can reduce a planted
//! divergence in a long random schedule to a ≤ 5-step reproducer.
#![cfg(feature = "contracts")]

use tm_fpga::tm::params::TmShape;
use tm_fpga::verify::corpus::{replay, replay_opts, ReplayOptions, Step};
use tm_fpga::verify::shrink::{random_schedule, shrink_failure};

/// With the feature on, every replay step audits all five lanes through
/// `check_invariants` — a clean seeded replay therefore certifies the
/// hooks and the invariants together.
#[test]
fn contract_audits_pass_on_clean_replays() {
    for (name, shape) in [
        ("iris", TmShape::iris()),
        ("wide", TmShape { classes: 2, max_clauses: 8, features: 80, states: 50 }),
    ] {
        for seed in 0..2u64 {
            let s = random_schedule(&shape, seed, 30);
            let rep = replay(&s)
                .unwrap_or_else(|d| panic!("{name} seed {seed}: contract/identity failure {d}"));
            // Train steps contribute 3 identity checks + 5 audits; every
            // step contributes 3 pair diffs + 5 audits — so the audit
            // count must dominate the step count.
            assert!(rep.checks >= 8 * rep.steps as u64, "{name}: audits did not run");
        }
    }
}

/// Shrinker self-test (ISSUE 7 satellite 4): plant the known off-by-one
/// divergence (`inject_train_offby1` nudges one TA on the `fast` lane
/// after eager training whenever a clause force gate is programmed),
/// find a 200-step random schedule that trips it, and prove the
/// minimizer cuts the schedule to a ≤ 5-step reproducer that still
/// fails with the injection and passes without it.
#[test]
fn shrinker_reduces_planted_divergence_to_minimal_reproducer() {
    let shape = TmShape::iris();
    let inject = ReplayOptions { inject_train_offby1: true };

    let mut found = None;
    for seed in 0..32u64 {
        let s = random_schedule(&shape, seed, 200);
        if replay_opts(&s, &inject).is_err() {
            found = Some((seed, s));
            break;
        }
    }
    let (seed, schedule) = found.expect(
        "no 200-step schedule in seeds 0..32 programs a force gate before a train step — \
         the generator mix must have regressed",
    );

    // The schedule is clean without the injection: the divergence is the
    // planted fault, not a real engine bug.
    replay(&schedule).unwrap_or_else(|d| panic!("seed {seed} dirty without injection: {d}"));

    let min = shrink_failure(&schedule, &inject).expect("failing schedule must shrink");
    assert!(
        min.steps.len() <= 5,
        "minimizer left {} steps (want <= 5): {:?}",
        min.steps.len(),
        min.steps
    );
    // The minimal reproducer needs a force gate armed when a train step
    // runs — two steps is the theoretical floor.
    assert!(min.steps.len() >= 2, "a lone step cannot arm and trip the injection");
    assert!(
        min.steps.iter().any(|s| matches!(s, Step::Force { code, .. } if *code >= 0)),
        "reproducer lost the arming force gate: {:?}",
        min.steps
    );
    assert!(
        min.steps.iter().any(|s| matches!(s, Step::Train { .. })),
        "reproducer lost the training step (the only kind that injects): {:?}",
        min.steps
    );

    // Minimized: still fails with the injection, still clean without.
    assert!(replay_opts(&min, &inject).is_err(), "minimized schedule no longer reproduces");
    replay(&min).unwrap_or_else(|d| panic!("minimized schedule dirty without injection: {d}"));
}
