//! The byte-transport boundary of the front end: one trait pair with a
//! real TCP implementation and (in `super::sim`) a deterministic
//! in-memory one, so the identical [`super::frontend::FrontEnd`] logic
//! serves sockets in production and replays scripted chaos in tests.
//!
//! The deliberately narrow [`Conn`] surface is what keeps the front
//! end's *control decisions* transport-independent: reads are
//! chunked and non-blocking ([`ReadOutcome`]), writes enqueue whole
//! frames, and the only flow-control signal is [`Conn::granted`] — the
//! cumulative count of response frames the peer has actually absorbed
//! (flushed to the socket for TCP, consumed under the scripted read
//! window for the simulator). The front end's backpressure arithmetic
//! (promised − granted) reads that one number; it never inspects
//! socket internals.

use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

/// What one non-blocking read produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadOutcome {
    /// `n` fresh bytes were appended to the buffer.
    Data(usize),
    /// Nothing available right now; the peer is still connected.
    WouldBlock,
    /// The peer's write side is closed (clean EOF) or the connection is
    /// gone — no further bytes will ever arrive.
    Eof,
}

/// One client connection, as seen by the front end.
pub trait NetConn {
    /// Non-blocking chunked read: append at most `max` bytes to `buf`.
    fn read_into(&mut self, buf: &mut Vec<u8>, max: usize) -> ReadOutcome;
    /// Enqueue one complete response frame for delivery. Delivery is
    /// best-effort once the peer misbehaves (aborted connections drop
    /// frames); the *accounting* of what was promised lives in the
    /// front end, not here.
    fn write_frame(&mut self, frame: &[u8]);
    /// Push queued frames toward the peer as far as its window allows.
    fn flush(&mut self);
    /// Cumulative response frames the peer has absorbed — the
    /// backpressure denominator.
    fn granted(&self) -> u64;
    /// The peer can still receive frames.
    fn writable(&self) -> bool;
    /// Hang up (idempotent).
    fn close(&mut self);
}

/// A listener producing connections.
pub trait Transport {
    type Conn: NetConn;
    /// Move simulated time forward / pump buffered IO. `now` is the
    /// front end's virtual tick.
    fn advance(&mut self, now: u64);
    /// Accept one pending connection, if any.
    fn poll_accept(&mut self) -> Option<Self::Conn>;
}

/// Real-socket transport over a non-blocking [`TcpListener`].
pub struct TcpTransport {
    listener: TcpListener,
    local_addr: SocketAddr,
}

impl TcpTransport {
    /// Bind (port 0 picks a free port; see [`TcpTransport::local_addr`]).
    pub fn bind(addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("net: binding {addr}"))?;
        listener.set_nonblocking(true).context("net: non-blocking listener")?;
        let local_addr = listener.local_addr().context("net: local addr")?;
        Ok(TcpTransport { listener, local_addr })
    }

    /// The actually-bound address (resolves `--listen 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }
}

impl Transport for TcpTransport {
    type Conn = TcpConn;

    fn advance(&mut self, _now: u64) {}

    fn poll_accept(&mut self) -> Option<TcpConn> {
        match self.listener.accept() {
            Ok((stream, _peer)) => TcpConn::new(stream).ok(),
            Err(_) => None,
        }
    }
}

/// One non-blocking TCP connection with an internal frame queue: a
/// frame is "granted" once every one of its bytes reached the socket,
/// so a slow reader stalls `granted()` exactly when its kernel window
/// fills — real backpressure feeding the same arithmetic the simulator
/// exercises deterministically.
pub struct TcpConn {
    stream: Option<TcpStream>,
    /// Queued frames; the front one may be partially written.
    queue: VecDeque<Vec<u8>>,
    /// Bytes of the front frame already written.
    front_written: usize,
    granted: u64,
}

impl TcpConn {
    fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nonblocking(true).context("net: non-blocking conn")?;
        stream.set_nodelay(true).ok();
        Ok(TcpConn { stream: Some(stream), queue: VecDeque::new(), front_written: 0, granted: 0 })
    }
}

impl NetConn for TcpConn {
    fn read_into(&mut self, buf: &mut Vec<u8>, max: usize) -> ReadOutcome {
        let Some(stream) = self.stream.as_mut() else { return ReadOutcome::Eof };
        let mut chunk = vec![0u8; max.max(1)];
        match stream.read(&mut chunk) {
            Ok(0) => ReadOutcome::Eof,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                ReadOutcome::Data(n)
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                ReadOutcome::WouldBlock
            }
            Err(_) => ReadOutcome::Eof,
        }
    }

    fn write_frame(&mut self, frame: &[u8]) {
        if self.stream.is_some() {
            self.queue.push_back(frame.to_vec());
        }
        self.flush();
    }

    fn flush(&mut self) {
        let Some(stream) = self.stream.as_mut() else { return };
        while let Some(front) = self.queue.front() {
            match stream.write(&front[self.front_written..]) {
                Ok(0) => {
                    self.close();
                    return;
                }
                Ok(n) => {
                    self.front_written += n;
                    if self.front_written == front.len() {
                        self.queue.pop_front();
                        self.front_written = 0;
                        self.granted += 1;
                    }
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::Interrupted =>
                {
                    return;
                }
                Err(_) => {
                    self.close();
                    return;
                }
            }
        }
        let _ = stream.flush();
    }

    fn granted(&self) -> u64 {
        self.granted
    }

    fn writable(&self) -> bool {
        self.stream.is_some()
    }

    fn close(&mut self) {
        if let Some(stream) = self.stream.take() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        self.queue.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Loopback smoke: bytes written through a TcpConn arrive at the
    /// client; granted() counts fully-flushed frames.
    #[test]
    fn tcp_conn_roundtrip_on_loopback() {
        let mut transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = transport.local_addr();
        let mut client = TcpStream::connect(addr).unwrap();
        let mut server = loop {
            if let Some(conn) = transport.poll_accept() {
                break conn;
            }
            std::thread::yield_now();
        };
        client.write_all(b"ping\n").unwrap();
        let mut buf = Vec::new();
        let mut spins = 0;
        while !buf.ends_with(b"ping\n") {
            match server.read_into(&mut buf, 64) {
                ReadOutcome::Eof => panic!("unexpected eof"),
                _ => {
                    spins += 1;
                    assert!(spins < 100_000, "ping never arrived");
                    std::thread::yield_now();
                }
            }
        }
        server.write_frame(b"pong\n");
        server.flush();
        assert_eq!(server.granted(), 1);
        let mut got = [0u8; 5];
        client.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"pong\n");
        server.close();
        assert!(!server.writable());
        // Reading from the closed server side reports EOF, not a hang.
        assert_eq!(server.read_into(&mut buf, 8), ReadOutcome::Eof);
    }
}
