//! The deterministic in-memory transport: scripted clients on the
//! virtual clock.
//!
//! Every connection-level failure mode the front end must survive —
//! torn frames, half-open peers, disconnects mid-response, slow-loris
//! readers, floods — is expressed as a [`ClientScript`]: a connect tick
//! plus a list of tick-stamped [`ClientOp`]s. [`SimTransport::advance`]
//! replays the scripts against the clock, so the byte stream the front
//! end sees (and the read window each client grants) is a pure function
//! of `(scripts, tick)` — which is what lets `run_net_soak` drive the
//! sharded server and the scalar oracle through *bit-identical*
//! connection chaos and compare outcomes exactly.
//!
//! The one determinism subtlety lives in [`NetConn::granted`]: the
//! simulator reports the *scripted* cumulative read window, not the
//! frames actually handed over. Actual delivery depends on when the
//! backend produced a response (arm-dependent under faults); the window
//! is script-only. Every backpressure and admission decision therefore
//! computes identically in both soak arms, while delivered-frame
//! assertions remain available per client for the tests that want them.

use crate::net::proto::Request;
use crate::net::transport::{NetConn, ReadOutcome, Transport};
use crate::serve::chaos::{NetChaosPlan, NetFault};
use crate::tm::rng::Xoshiro256;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

/// One scripted action of a simulated client.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClientOp {
    /// Put bytes on the wire at `at` — any fragmentation; a frame torn
    /// across several `Send`s (and ticks) arrives exactly that torn.
    Send { at: u64, bytes: Vec<u8> },
    /// Grant the server a window of `frames` further response frames.
    ReadAllow { at: u64, frames: u64 },
    /// Half-open from `at`: the client's write side goes silent (no
    /// more `Send`s take effect, EOF after the buffer drains) while its
    /// read side keeps consuming responses.
    CloseWrite { at: u64 },
    /// Hard disconnect at `at`: nothing further is sent, received or
    /// granted; frames queued toward this client are dropped.
    Abort { at: u64 },
}

impl ClientOp {
    pub fn at(&self) -> u64 {
        match self {
            ClientOp::Send { at, .. }
            | ClientOp::ReadAllow { at, .. }
            | ClientOp::CloseWrite { at }
            | ClientOp::Abort { at } => *at,
        }
    }
}

/// A simulated client: when it connects and everything it ever does.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientScript {
    pub connect_at: u64,
    pub ops: Vec<ClientOp>,
}

impl ClientScript {
    /// Last tick at which this script does anything.
    pub fn end(&self) -> u64 {
        self.ops.iter().map(ClientOp::at).max().unwrap_or(self.connect_at)
    }
}

/// Shared per-client endpoint state (transport and conn halves).
#[derive(Debug, Default)]
struct Endpoint {
    /// Bytes sent by the client, not yet read by the server.
    inbound: VecDeque<u8>,
    /// Frames queued by the server, not yet consumed by the client.
    outbound: VecDeque<Vec<u8>>,
    /// Cumulative scripted read window.
    allowance: u64,
    /// Frames the client has actually consumed (delivery record).
    delivered: Vec<String>,
    /// Frames dropped because the connection was gone.
    dropped: u64,
    write_closed: bool,
    aborted: bool,
    /// Server-side hangup.
    server_closed: bool,
}

impl Endpoint {
    /// Hand queued frames to the client as far as its window reaches.
    fn pump(&mut self) {
        while !self.aborted && (self.delivered.len() as u64) < self.allowance {
            let Some(frame) = self.outbound.pop_front() else { break };
            self.delivered.push(String::from_utf8_lossy(&frame).into_owned());
        }
    }
}

/// The scripted transport: replays [`ClientScript`]s on the virtual
/// clock. Clients are accepted in index order on their connect tick.
pub struct SimTransport {
    scripts: Vec<ClientScript>,
    endpoints: Vec<Rc<RefCell<Endpoint>>>,
    /// Per client, how many ops have been replayed.
    cursor: Vec<usize>,
    /// Clients whose connect tick has arrived but which were not yet
    /// accepted.
    pending_accept: VecDeque<usize>,
    connected: Vec<bool>,
    now: u64,
}

impl SimTransport {
    pub fn new(scripts: Vec<ClientScript>) -> Self {
        let n = scripts.len();
        SimTransport {
            scripts,
            endpoints: (0..n).map(|_| Rc::new(RefCell::new(Endpoint::default()))).collect(),
            cursor: vec![0; n],
            pending_accept: VecDeque::new(),
            connected: vec![false; n],
            now: 0,
        }
    }

    /// Delivery record of client `i` — the frames it consumed, in
    /// order.
    pub fn delivered(&self, i: usize) -> Vec<String> {
        self.endpoints[i].borrow().delivered.clone()
    }

    /// Frames dropped toward client `i` (aborted connection).
    pub fn dropped(&self, i: usize) -> u64 {
        self.endpoints[i].borrow().dropped
    }
}

impl Transport for SimTransport {
    type Conn = SimConn;

    fn advance(&mut self, now: u64) {
        self.now = now;
        for i in 0..self.scripts.len() {
            if !self.connected[i] && self.scripts[i].connect_at <= now {
                self.connected[i] = true;
                self.pending_accept.push_back(i);
            }
            let mut ep = self.endpoints[i].borrow_mut();
            while self.cursor[i] < self.scripts[i].ops.len() {
                let op = &self.scripts[i].ops[self.cursor[i]];
                if op.at() > now || ep.aborted {
                    break;
                }
                self.cursor[i] += 1;
                match op {
                    ClientOp::Send { bytes, .. } => {
                        if !ep.write_closed {
                            ep.inbound.extend(bytes.iter().copied());
                        }
                    }
                    ClientOp::ReadAllow { frames, .. } => {
                        ep.allowance = ep.allowance.saturating_add(*frames);
                    }
                    ClientOp::CloseWrite { .. } => ep.write_closed = true,
                    ClientOp::Abort { .. } => {
                        ep.aborted = true;
                        ep.dropped += ep.outbound.len() as u64;
                        ep.outbound.clear();
                        ep.inbound.clear();
                    }
                }
            }
            ep.pump();
        }
    }

    fn poll_accept(&mut self) -> Option<SimConn> {
        let i = self.pending_accept.pop_front()?;
        Some(SimConn { client: i, ep: Rc::clone(&self.endpoints[i]) })
    }
}

/// The server's handle on one simulated connection.
pub struct SimConn {
    client: usize,
    ep: Rc<RefCell<Endpoint>>,
}

impl SimConn {
    /// Which script this connection belongs to (accept order equals
    /// client order, but tests may want it explicit).
    pub fn client(&self) -> usize {
        self.client
    }
}

impl NetConn for SimConn {
    fn read_into(&mut self, buf: &mut Vec<u8>, max: usize) -> ReadOutcome {
        let mut ep = self.ep.borrow_mut();
        if ep.aborted || ep.server_closed {
            return ReadOutcome::Eof;
        }
        if ep.inbound.is_empty() {
            return if ep.write_closed { ReadOutcome::Eof } else { ReadOutcome::WouldBlock };
        }
        let n = max.min(ep.inbound.len());
        buf.extend(ep.inbound.drain(..n));
        ReadOutcome::Data(n)
    }

    fn write_frame(&mut self, frame: &[u8]) {
        let mut ep = self.ep.borrow_mut();
        if ep.aborted || ep.server_closed {
            ep.dropped += 1;
            return;
        }
        ep.outbound.push_back(frame.to_vec());
        ep.pump();
    }

    fn flush(&mut self) {
        self.ep.borrow_mut().pump();
    }

    fn granted(&self) -> u64 {
        // Scripted window, NOT delivered count: identical in both soak
        // arms regardless of backend response timing.
        self.ep.borrow().allowance
    }

    fn writable(&self) -> bool {
        let ep = self.ep.borrow();
        !ep.aborted && !ep.server_closed
    }

    fn close(&mut self) {
        let mut ep = self.ep.borrow_mut();
        ep.server_closed = true;
        ep.dropped += ep.outbound.len() as u64;
        ep.outbound.clear();
    }
}

/// Workload shape for [`seeded_scripts`].
#[derive(Debug, Clone)]
pub struct ScriptConfig {
    pub clients: usize,
    pub requests_per_client: u64,
    /// Fraction of requests that are `learn` (the rest are `infer`).
    pub labelled_fraction: f32,
    /// Feature bits per sample (the served model's width).
    pub features: usize,
    pub classes: usize,
    /// Per-request deadline budget stamped on infer requests.
    pub ttl: Option<u64>,
    /// Protocol version every client negotiates at `hello` (1 pins the
    /// legacy single-model wire surface; 2 enables model binding).
    pub hello_version: u32,
    /// Model name bound at `hello` (v2 only; `None` = server default).
    pub model: Option<String>,
}

/// An effectively-unbounded read window for healthy clients.
const OPEN_WINDOW: u64 = 1 << 40;

/// Generate one deterministic script per client from `(seed, cfg)`,
/// with `plan.faults[i]` shaping client `i`'s misbehaviour. Healthy
/// clients connect, grant an open read window, and stream well-formed
/// requests; faulted ones tear frames, half-open, abort, dribble their
/// read window, or flood — all on fixed ticks, so two transports built
/// from the same inputs replay byte-identically.
pub fn seeded_scripts(seed: u64, cfg: &ScriptConfig, plan: &NetChaosPlan) -> Vec<ClientScript> {
    let mut scripts = Vec::with_capacity(cfg.clients);
    for client in 0..cfg.clients {
        let fault = plan.faults.get(client).copied().flatten();
        let mut rng =
            Xoshiro256::new(seed ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let connect_at = client as u64;
        let mut t = connect_at;
        let mut ops = Vec::new();

        // Read-window schedule: slow-loris dribbles, everyone else
        // grants openly at connect.
        match fault {
            Some(NetFault::SlowLoris { window, every }) => {
                // Enough grant events to (slowly) cover the whole
                // script; debt stays high while requests outpace them.
                let grants = cfg.requests_per_client * 2 + 8;
                for k in 0..grants {
                    ops.push(ClientOp::ReadAllow { at: connect_at + k * every, frames: window });
                }
            }
            _ => ops.push(ClientOp::ReadAllow { at: connect_at, frames: OPEN_WINDOW }),
        }

        let hello = Request::Hello { version: cfg.hello_version, model: cfg.model.clone() }
            .encode()
            .into_bytes();
        ops.push(ClientOp::Send { at: t, bytes: hello });
        t += 1;

        let mut in_tick = 0usize;
        for cid in 1..=cfg.requests_per_client {
            match fault {
                Some(NetFault::HalfOpen { after_requests }) if cid > after_requests => {
                    ops.push(ClientOp::CloseWrite { at: t });
                    break;
                }
                Some(NetFault::Disconnect { after_requests }) if cid > after_requests => {
                    ops.push(ClientOp::Abort { at: t });
                    break;
                }
                _ => {}
            }
            let bits: Vec<bool> = (0..cfg.features).map(|_| rng.next_f32() < 0.5).collect();
            let req = if rng.next_f32() < cfg.labelled_fraction {
                Request::Learn { id: cid, label: rng.next_below(cfg.classes), model: None, bits }
            } else {
                Request::Infer { id: cid, ttl: cfg.ttl, model: None, bits }
            };
            let bytes = req.encode().into_bytes();
            match fault {
                Some(NetFault::TornFrames { fragment }) => {
                    // One sliver per tick: the frame completes several
                    // ticks after it started.
                    for chunk in bytes.chunks(fragment.max(1)) {
                        ops.push(ClientOp::Send { at: t, bytes: chunk.to_vec() });
                        t += 1;
                    }
                }
                Some(NetFault::Flood { burst }) => {
                    ops.push(ClientOp::Send { at: t, bytes });
                    in_tick += 1;
                    if in_tick >= burst {
                        in_tick = 0;
                        t += 1;
                    }
                }
                _ => {
                    ops.push(ClientOp::Send { at: t, bytes });
                    t += 1 + rng.next_below(3) as u64;
                }
            }
        }
        scripts.push(ClientScript { connect_at, ops });
    }
    scripts
}

/// Last active tick across a set of scripts.
pub fn scripts_end(scripts: &[ClientScript]) -> u64 {
    scripts.iter().map(ClientScript::end).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::chaos::NetChaosSpec;

    fn cfg() -> ScriptConfig {
        ScriptConfig {
            clients: 4,
            requests_per_client: 10,
            labelled_fraction: 0.3,
            features: 8,
            classes: 3,
            ttl: Some(6),
            hello_version: 1,
            model: None,
        }
    }

    #[test]
    fn scripts_are_deterministic() {
        let plan = NetChaosPlan::seeded(3, 4, 10, &NetChaosSpec::full_matrix());
        let a = seeded_scripts(42, &cfg(), &plan);
        let b = seeded_scripts(42, &cfg(), &plan);
        assert_eq!(a, b);
        assert_ne!(a, seeded_scripts(43, &cfg(), &plan));
        assert_eq!(a.len(), 4);
        assert!(scripts_end(&a) > 0);
    }

    #[test]
    fn transport_replays_sends_and_windows_on_the_clock() {
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::ReadAllow { at: 0, frames: 1 },
                ClientOp::Send { at: 0, bytes: b"hel".to_vec() },
                ClientOp::Send { at: 2, bytes: b"lo v=1\n".to_vec() },
                ClientOp::ReadAllow { at: 4, frames: 1 },
            ],
        }];
        let mut tr = SimTransport::new(scripts);
        tr.advance(0);
        let mut conn = tr.poll_accept().expect("client connects at tick 0");
        assert!(tr.poll_accept().is_none());
        let mut buf = Vec::new();
        assert_eq!(conn.read_into(&mut buf, 64), ReadOutcome::Data(3));
        assert_eq!(conn.read_into(&mut buf, 64), ReadOutcome::WouldBlock);
        tr.advance(1);
        assert_eq!(conn.read_into(&mut buf, 64), ReadOutcome::WouldBlock, "sliver not due yet");
        tr.advance(2);
        assert_eq!(conn.read_into(&mut buf, 64), ReadOutcome::Data(7));
        assert_eq!(buf, b"hello v=1\n");
        // Window of 1: first frame delivered, second waits for tick 4.
        conn.write_frame(b"ok hello v=1\n");
        conn.write_frame(b"pred id=1 class=0\n");
        assert_eq!(conn.granted(), 1);
        assert_eq!(tr.delivered(0), vec!["ok hello v=1\n".to_string()]);
        tr.advance(4);
        assert_eq!(conn.granted(), 2);
        assert_eq!(tr.delivered(0).len(), 2);
    }

    #[test]
    fn abort_drops_queued_frames_and_reads_eof() {
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::Send { at: 0, bytes: b"x".to_vec() },
                ClientOp::Abort { at: 1 },
                // Post-abort ops are dead: neither send nor grant lands.
                ClientOp::Send { at: 2, bytes: b"y".to_vec() },
                ClientOp::ReadAllow { at: 2, frames: 5 },
            ],
        }];
        let mut tr = SimTransport::new(scripts);
        tr.advance(0);
        let mut conn = tr.poll_accept().unwrap();
        conn.write_frame(b"late\n");
        tr.advance(1);
        tr.advance(2);
        let mut buf = Vec::new();
        assert_eq!(conn.read_into(&mut buf, 8), ReadOutcome::Eof);
        assert!(!conn.writable());
        assert_eq!(conn.granted(), 0, "no grant lands after the abort");
        assert_eq!(tr.dropped(0), 1);
        conn.write_frame(b"later\n");
        assert_eq!(tr.dropped(0), 2);
        assert!(tr.delivered(0).is_empty());
    }

    #[test]
    fn half_open_reads_eof_after_drain_but_still_consumes() {
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::ReadAllow { at: 0, frames: 10 },
                ClientOp::Send { at: 0, bytes: b"stats id=1\n".to_vec() },
                ClientOp::CloseWrite { at: 1 },
                ClientOp::Send { at: 2, bytes: b"stats id=2\n".to_vec() },
            ],
        }];
        let mut tr = SimTransport::new(scripts);
        tr.advance(0);
        let mut conn = tr.poll_accept().unwrap();
        let mut buf = Vec::new();
        assert_eq!(conn.read_into(&mut buf, 64), ReadOutcome::Data(11));
        tr.advance(1);
        tr.advance(2);
        assert_eq!(conn.read_into(&mut buf, 64), ReadOutcome::Eof, "write side is closed");
        conn.write_frame(b"stats id=1 ...\n");
        assert_eq!(tr.delivered(0).len(), 1, "read side still consumes");
        assert!(conn.writable());
    }
}
