//! The network front end: per-client sessions over a [`HubNetBackend`].
//!
//! One single-threaded control loop owns everything nondeterministic a
//! network creates — accepts, torn reads, slow readers, disconnects —
//! and reduces it to the deterministic serving core the rest of the
//! crate already trusts: admitted inference requests flow through the
//! same [`MicroBatcher`] and the same sequenced update log as the
//! in-process drivers. Robustness is the design driver:
//!
//! - **Deadlines.** Every infer request carries a budget (its `ttl` or
//!   the configured default) that becomes an absolute [`Deadline`] on
//!   the virtual clock. Expiry is decided exactly once, at flush
//!   ([`split_expired`]): expired requests are answered with a typed
//!   `err kind=deadline`, dispatched ones are always scored — never a
//!   silent drop, and never an arm-dependent race.
//! - **Backpressure.** The only flow-control quantity is *frame debt*:
//!   `promised − granted` per session, where every request promises
//!   exactly one response frame and [`NetConn::granted`] counts what
//!   the peer absorbed. A session past [`NetConfig::write_buffer_cap`]
//!   is a slow client: further requests are shed (counted in
//!   `shed_requests`, no frame queued — the client is not reading
//!   anyway), which is also what bounds the per-connection write queue.
//!   Past [`NetConfig::max_in_flight`] of *global* debt the admission
//!   controller answers `err kind=admission`. Both quantities are pure
//!   functions of the scripted transport, so the sharded server and the
//!   scalar oracle make bit-identical control decisions under chaos.
//! - **Bounded reads.** [`FrameBuffer`] caps the bytes a connection may
//!   hold without a newline and reads are chunked, so a hostile frame
//!   costs at most `max_frame_bytes + read_chunk` — never an unbounded
//!   allocation. Unparseable input is a typed `err kind=frame` and a
//!   close.
//! - **In-order release.** Shards answer out of order; clients must
//!   not. Each admitted infer holds a slot in its session's queue and
//!   responses release strictly in request order, whatever order the
//!   backend produces them.
//! - **Model routing (v2).** Sessions negotiate a protocol version at
//!   `hello`; v2 sessions may bind a default model and route individual
//!   `infer`/`learn` frames with `model=`. Each model gets its *own*
//!   [`MicroBatcher`] (batches never mix tenants), its own seq clock,
//!   and its own telemetry row (flush causes, batch-width histogram,
//!   backend lifecycle counters, queue depths). A request naming an
//!   unknown model is answered `err kind=unknown-model` **before** it
//!   can reach any batcher; a model mid-eviction answers
//!   `err kind=evicting`. Legacy v1 sessions carry no model dimension,
//!   route to the backend's default model (id 0) and receive
//!   byte-identical frames to the pre-hub build.
//! - **Graceful drain.** `drain`: stop accepting → flush every model's
//!   batcher (deadline-checking the tails) → finalize the backend (join
//!   workers, verify the exactly-once audit, checkpoint replicas) →
//!   answer everything still routed → final `bye` stats frame → close.
//!
//! On a real socket ([`run_tcp`]) `granted` is frames flushed into the
//! kernel, so debt conflates response-production lag with client
//! slowness — honest backpressure, sized by generous default caps. The
//! deterministic contract is exercised through [`SimTransport`].

use crate::hub::{HubNetBackend, RouteError};
use crate::net::proto::{
    self, ErrKind, FrameBuffer, ModelTelemetry, Request, Response, WireStats, PROTO_CAPS,
    PROTO_MIN_VERSION, PROTO_VERSION, WIDTH_BUCKETS,
};
use crate::net::sim::{scripts_end, ClientScript, SimTransport};
use crate::net::transport::{NetConn, ReadOutcome, TcpTransport, Transport};
use crate::serve::batcher::{split_expired, BatcherConfig, MicroBatcher, PendingRequest};
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmShape;
use crate::tm::rng::Xoshiro256;
use crate::tm::update::{Deadline, UpdateKind};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashSet, VecDeque};

/// Front-end policy knobs.
#[derive(Debug, Clone)]
pub struct NetConfig {
    pub batch: BatcherConfig,
    /// Global frame-debt ceiling: admission rejects past this.
    pub max_in_flight: u64,
    /// Per-session frame-debt ceiling: slow-client shed past this.
    pub write_buffer_cap: u64,
    /// Longest legal frame; also bounds unterminated read buffers.
    pub max_frame_bytes: usize,
    /// Bytes per non-blocking read.
    pub read_chunk: usize,
    /// Deadline budget for infer requests that carry no `ttl`.
    pub default_ttl: Option<u64>,
    /// Record every applied update (the corpus-replay hook).
    pub record_updates: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            batch: BatcherConfig::default(),
            max_in_flight: 256,
            write_buffer_cap: 32,
            max_frame_bytes: 4096,
            read_chunk: 1024,
            default_ttl: None,
            record_updates: false,
        }
    }
}

impl NetConfig {
    pub fn validate(&self) -> Result<()> {
        self.batch.validate()?;
        if self.max_in_flight == 0 || self.write_buffer_cap == 0 {
            bail!("net: max_in_flight and write_buffer_cap must be >= 1");
        }
        if self.max_frame_bytes < 64 || self.read_chunk == 0 {
            bail!("net: max_frame_bytes must be >= 64 and read_chunk >= 1");
        }
        Ok(())
    }
}

/// Exact front-end accounting. Every request that reaches a parse ends
/// in exactly one of these counters' stories; the chaos soak asserts
/// them equal across backends and consistent with the outcome map.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    pub connections: u64,
    pub frames_in: u64,
    /// Infer requests admitted to a batcher.
    pub infers: u64,
    /// Learn requests applied as sequenced updates.
    pub learns: u64,
    /// Pred frames produced (admitted − expired − server-shed).
    pub preds: u64,
    /// Admitted requests answered `err kind=deadline` at flush.
    pub deadline_expired: u64,
    /// Requests answered `err kind=admission` (global debt ceiling).
    pub admission_rejected: u64,
    /// Requests shed without a frame (per-session debt ceiling).
    pub shed_requests: u64,
    /// Dispatched requests shed by the degraded backend.
    pub server_shed: u64,
    /// Semantically invalid requests (width, label, duplicate id,
    /// model field on a v1 session).
    pub quarantined: u64,
    /// Connections killed for unparseable/oversized frames.
    pub frame_errors: u64,
    /// Requests refused because the server was draining.
    pub draining_rejected: u64,
    /// Requests answered `err kind=unknown-model` (v2 routing misses —
    /// these never reach a batcher).
    pub unknown_model: u64,
    /// Requests answered `err kind=evicting` (model mid-eviction).
    pub evicting_rejected: u64,
    pub stats_served: u64,
    pub drains: u64,
}

impl NetStats {
    /// The wire-counter projection. The eight v1 scalars keep their
    /// exact legacy meaning; unknown-model and evicting refusals fold
    /// into `shed` (server-side refusals of otherwise-valid requests),
    /// which is zero on every legacy path.
    fn wire(&self, telemetry: Vec<ModelTelemetry>) -> WireStats {
        WireStats {
            infers: self.infers,
            learns: self.learns,
            preds: self.preds,
            shed: self.shed_requests + self.server_shed + self.unknown_model
                + self.evicting_rejected,
            deadline: self.deadline_expired,
            admission: self.admission_rejected,
            quarantined: self.quarantined,
            frame_errors: self.frame_errors,
            telemetry,
        }
    }
}

/// How one infer/learn request ended, keyed `(session, client id)` in
/// the report — the cross-arm comparison unit of the net soak.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Pred(usize),
    LearnAck(u64),
    DeadlineExpired,
    AdmissionRejected,
    SlowShed,
    ServerShed,
    BadRequest,
    Draining,
    /// Routed to a model name the backend does not host.
    UnknownModel,
    /// The target model was mid-eviction.
    Evicting,
}

/// What a finished front-end run produced.
#[derive(Debug)]
pub struct NetReport {
    pub stats: NetStats,
    /// `(session index, client request id)` → outcome.
    pub outcomes: BTreeMap<(usize, u64), Outcome>,
    /// Final replica state(s) from the backend's drain checkpoint, in
    /// [`HubNetBackend::models`] order.
    pub replicas: Vec<MultiTm>,
    /// The applied update log (when [`NetConfig::record_updates`]).
    pub updates: Vec<UpdateKind>,
    /// Per-model telemetry rows as of the drain barrier.
    pub telemetry: Vec<ModelTelemetry>,
}

enum SlotFill {
    Pred(usize),
    Deadline,
    Overload,
    /// A dispatch-time routing failure (the whole batch was refused).
    Route(ErrKind),
}

/// Per-model flush accounting (the front-end half of a telemetry row).
#[derive(Debug, Clone, Copy, Default)]
struct FlushCounters {
    full: u64,
    deadline: u64,
    fin: u64,
    width_hist: [u64; WIDTH_BUCKETS],
}

#[derive(Debug, Clone, Copy)]
enum FlushCause {
    Full,
    Deadline,
    Final,
}

struct Session<C> {
    conn: C,
    fb: FrameBuffer,
    hello_done: bool,
    /// Negotiated protocol version (0 until hello).
    version: u32,
    /// The session's default model id (bound at hello).
    model: u64,
    /// Response frames promised to this client.
    promised: u64,
    /// Read side exhausted (EOF seen).
    eof: bool,
    /// Hard-closed (frame error / version reject); no further parsing.
    dead: bool,
    /// Admitted infer global-ids, in request order (release order).
    slots: VecDeque<u64>,
    /// Filled but not yet releasable (an earlier slot is still open).
    ready: BTreeMap<u64, Response>,
    /// Client ids seen on this connection (duplicates are rejected).
    used_ids: HashSet<u64>,
}

impl<C> Session<C> {
    fn new(conn: C, max_frame_bytes: usize) -> Self {
        Session {
            conn,
            fb: FrameBuffer::new(max_frame_bytes),
            hello_done: false,
            version: 0,
            model: 0,
            promised: 0,
            eof: false,
            dead: false,
            slots: VecDeque::new(),
            ready: BTreeMap::new(),
            used_ids: HashSet::new(),
        }
    }
}

/// The wire error a routing failure answers with.
fn route_err_kind(e: RouteError) -> ErrKind {
    match e {
        RouteError::UnknownModel => ErrKind::UnknownModel,
        RouteError::Evicting => ErrKind::Evicting,
        RouteError::Budget | RouteError::Internal => ErrKind::Overload,
    }
}

/// The front end proper. Generic over transport (TCP or scripted sim)
/// and backend (model hub, sharded server or scalar oracle — the latter
/// two served as the anonymous default model through the
/// [`crate::hub::SingleModel`] adapter) — all pairings run the
/// identical control loop.
pub struct FrontEnd<B: HubNetBackend, T: Transport> {
    backend: B,
    transport: T,
    cfg: NetConfig,
    shape: TmShape,
    sessions: Vec<Session<T::Conn>>,
    /// One batcher per model: micro-batches never mix tenants.
    batchers: BTreeMap<u64, MicroBatcher>,
    /// Per-model flush/width accounting.
    counters: BTreeMap<u64, FlushCounters>,
    /// Outstanding global id → (session, client id).
    routes: BTreeMap<u64, (usize, u64)>,
    next_global: u64,
    /// Per-model applied-update clocks (mirror the backend's seqs).
    seqs: BTreeMap<u64, u64>,
    stats: NetStats,
    outcomes: BTreeMap<(usize, u64), Outcome>,
    draining: bool,
    updates: Vec<UpdateKind>,
}

impl<B: HubNetBackend, T: Transport> FrontEnd<B, T> {
    pub fn new(backend: B, transport: T, shape: TmShape, cfg: NetConfig) -> Result<Self> {
        cfg.validate().context("net front end")?;
        Ok(FrontEnd {
            backend,
            transport,
            cfg,
            shape,
            sessions: Vec::new(),
            batchers: BTreeMap::new(),
            counters: BTreeMap::new(),
            routes: BTreeMap::new(),
            next_global: 0,
            seqs: BTreeMap::new(),
            stats: NetStats::default(),
            outcomes: BTreeMap::new(),
            draining: false,
            updates: Vec::new(),
        })
    }

    pub fn stats(&self) -> NetStats {
        self.stats
    }

    /// A client requested drain (or the owner set it): the loop should
    /// stop ticking and call [`FrontEnd::drain`].
    pub fn is_draining(&self) -> bool {
        self.draining
    }

    fn session_debt(sess: &Session<T::Conn>) -> u64 {
        sess.promised.saturating_sub(sess.conn.granted())
    }

    fn global_debt(&self) -> u64 {
        self.sessions.iter().map(|s| Self::session_debt(s)).sum()
    }

    /// Promise and immediately write one response frame.
    fn immediate(&mut self, s: usize, resp: Response) {
        let sess = &mut self.sessions[s];
        sess.promised += 1;
        sess.conn.write_frame(resp.encode().as_bytes());
    }

    /// Release the session's in-order response queue as far as it is
    /// filled.
    fn release(&mut self, s: usize) {
        let sess = &mut self.sessions[s];
        while let Some(&gid) = sess.slots.front() {
            let Some(resp) = sess.ready.remove(&gid) else { break };
            sess.slots.pop_front();
            sess.conn.write_frame(resp.encode().as_bytes());
        }
    }

    /// Fill an admitted request's slot; true if the id was still
    /// routed.
    fn fill_slot(&mut self, gid: u64, fill: SlotFill) -> bool {
        let Some((s, cid)) = self.routes.remove(&gid) else { return false };
        let (resp, outcome) = match fill {
            SlotFill::Pred(class) => (Response::Pred { id: cid, class }, Outcome::Pred(class)),
            SlotFill::Deadline => {
                (Response::Err { id: Some(cid), kind: ErrKind::Deadline }, Outcome::DeadlineExpired)
            }
            SlotFill::Overload => {
                (Response::Err { id: Some(cid), kind: ErrKind::Overload }, Outcome::ServerShed)
            }
            SlotFill::Route(kind) => {
                let outcome = match kind {
                    ErrKind::UnknownModel => Outcome::UnknownModel,
                    ErrKind::Evicting => Outcome::Evicting,
                    _ => Outcome::ServerShed,
                };
                (Response::Err { id: Some(cid), kind }, outcome)
            }
        };
        self.outcomes.insert((s, cid), outcome);
        self.sessions[s].ready.insert(gid, resp);
        self.release(s);
        true
    }

    /// Record one flushed batch in the model's telemetry row.
    fn note_flush(&mut self, model: u64, width: usize, cause: FlushCause) {
        let c = self.counters.entry(model).or_default();
        match cause {
            FlushCause::Full => c.full += 1,
            FlushCause::Deadline => c.deadline += 1,
            FlushCause::Final => c.fin += 1,
        }
        c.width_hist[proto::width_bucket(width)] += 1;
    }

    /// Deadline-check and dispatch a flushed batch against its model.
    fn dispatch(&mut self, model: u64, batch: Vec<PendingRequest>, now: u64) {
        let (live, expired) = split_expired(batch, now);
        for gid in expired {
            if self.fill_slot(gid, SlotFill::Deadline) {
                self.stats.deadline_expired += 1;
            }
        }
        if live.is_empty() {
            return;
        }
        let gids: Vec<u64> = live.iter().map(|p| p.id).collect();
        if let Err(e) = self.backend.model_infer(model, live) {
            // The whole batch was refused at the routing layer: answer
            // every member typed, never a silent drop.
            let kind = route_err_kind(e);
            for gid in gids {
                if self.fill_slot(gid, SlotFill::Route(kind)) {
                    match kind {
                        ErrKind::UnknownModel => self.stats.unknown_model += 1,
                        ErrKind::Evicting => self.stats.evicting_rejected += 1,
                        _ => self.stats.server_shed += 1,
                    }
                }
            }
        }
    }

    /// Pull whatever the backend has produced and route it.
    fn route_backend(&mut self) {
        for (gid, class) in self.backend.poll_responses() {
            if self.fill_slot(gid, SlotFill::Pred(class)) {
                self.stats.preds += 1;
            }
        }
        for gid in self.backend.poll_shed() {
            if self.fill_slot(gid, SlotFill::Overload) {
                self.stats.server_shed += 1;
            }
        }
    }

    /// Resolve a request's target model: the session default, or a
    /// per-request `model=` override (v2 only — on a v1 session the
    /// field is an unnegotiated capability and quarantines).
    fn resolve_model(&self, s: usize, model: Option<&str>) -> Result<u64, ErrKind> {
        match model {
            None => Ok(self.sessions[s].model),
            Some(_) if self.sessions[s].version < 2 => Err(ErrKind::BadRequest),
            Some(name) => self.backend.bind(Some(name)).map_err(route_err_kind),
        }
    }

    /// Answer a pre-admission routing refusal and account it.
    fn refuse(&mut self, s: usize, cid: u64, kind: ErrKind) {
        let outcome = match kind {
            ErrKind::UnknownModel => {
                self.stats.unknown_model += 1;
                Outcome::UnknownModel
            }
            ErrKind::Evicting => {
                self.stats.evicting_rejected += 1;
                Outcome::Evicting
            }
            ErrKind::BadRequest => {
                self.stats.quarantined += 1;
                Outcome::BadRequest
            }
            _ => {
                self.stats.server_shed += 1;
                Outcome::ServerShed
            }
        };
        self.outcomes.insert((s, cid), outcome);
        self.immediate(s, Response::Err { id: Some(cid), kind });
    }

    /// The feature width requests against `model` must match.
    fn model_features(&self, model: u64) -> usize {
        self.backend.model_shape(model).map(|sh| sh.features).unwrap_or(self.shape.features)
    }

    fn handle_infer(
        &mut self,
        s: usize,
        cid: u64,
        ttl: Option<u64>,
        model: Option<&str>,
        bits: &[bool],
        now: u64,
    ) {
        let debt = Self::session_debt(&self.sessions[s]);
        if debt >= self.cfg.write_buffer_cap {
            // The client is not consuming responses; queueing another
            // frame would grow an unread buffer. Shed with accounting,
            // no frame.
            self.stats.shed_requests += 1;
            self.outcomes.insert((s, cid), Outcome::SlowShed);
            return;
        }
        if self.draining {
            self.stats.draining_rejected += 1;
            self.outcomes.insert((s, cid), Outcome::Draining);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::Draining });
            return;
        }
        if !self.sessions[s].used_ids.insert(cid) {
            self.stats.quarantined += 1;
            self.outcomes.insert((s, cid), Outcome::BadRequest);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::BadRequest });
            return;
        }
        // Routing precedes admission: an unknown-model request must be
        // refused before it can touch any batcher or debt ceiling.
        let mid = match self.resolve_model(s, model) {
            Ok(mid) => mid,
            Err(kind) => {
                self.refuse(s, cid, kind);
                return;
            }
        };
        if bits.len() != self.model_features(mid) {
            self.stats.quarantined += 1;
            self.outcomes.insert((s, cid), Outcome::BadRequest);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::BadRequest });
            return;
        }
        if self.global_debt() >= self.cfg.max_in_flight {
            self.stats.admission_rejected += 1;
            self.outcomes.insert((s, cid), Outcome::AdmissionRejected);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::Admission });
            return;
        }
        let gid = self.next_global;
        self.next_global += 1;
        self.sessions[s].promised += 1;
        self.sessions[s].slots.push_back(gid);
        self.routes.insert(gid, (s, cid));
        self.stats.infers += 1;
        let deadline = ttl.or(self.cfg.default_ttl).map(|t| Deadline::after(now, t));
        let shape = self.backend.model_shape(mid).unwrap_or_else(|| self.shape.clone());
        let input = Input::pack(&shape, bits);
        let batch_cfg = self.cfg.batch.clone();
        let batcher = self
            .batchers
            .entry(mid)
            .or_insert_with(|| MicroBatcher::new(batch_cfg).expect("validated batcher config"));
        if let Some(batch) = batcher.push(PendingRequest { id: gid, input, deadline }, now) {
            self.note_flush(mid, batch.len(), FlushCause::Full);
            self.dispatch(mid, batch, now);
        }
    }

    fn handle_learn(
        &mut self,
        s: usize,
        cid: u64,
        label: usize,
        model: Option<&str>,
        bits: &[bool],
    ) {
        let debt = Self::session_debt(&self.sessions[s]);
        if debt >= self.cfg.write_buffer_cap {
            self.stats.shed_requests += 1;
            self.outcomes.insert((s, cid), Outcome::SlowShed);
            return;
        }
        if self.draining {
            self.stats.draining_rejected += 1;
            self.outcomes.insert((s, cid), Outcome::Draining);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::Draining });
            return;
        }
        if !self.sessions[s].used_ids.insert(cid) {
            self.stats.quarantined += 1;
            self.outcomes.insert((s, cid), Outcome::BadRequest);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::BadRequest });
            return;
        }
        let mid = match self.resolve_model(s, model) {
            Ok(mid) => mid,
            Err(kind) => {
                self.refuse(s, cid, kind);
                return;
            }
        };
        let shape = self.backend.model_shape(mid).unwrap_or_else(|| self.shape.clone());
        if bits.len() != shape.features || label >= shape.classes {
            self.stats.quarantined += 1;
            self.outcomes.insert((s, cid), Outcome::BadRequest);
            self.immediate(s, Response::Err { id: Some(cid), kind: ErrKind::BadRequest });
            return;
        }
        let input = Input::pack(&shape, bits);
        let kind = UpdateKind::Learn { input, label };
        if self.cfg.record_updates {
            self.updates.push(kind.clone());
        }
        match self.backend.model_update(mid, kind) {
            Ok(()) => {
                let seq = self.seqs.entry(mid).or_insert(0);
                *seq += 1;
                let seq = *seq;
                self.stats.learns += 1;
                self.outcomes.insert((s, cid), Outcome::LearnAck(seq));
                self.immediate(s, Response::LearnOk { id: cid, seq });
            }
            Err(e) => self.refuse(s, cid, route_err_kind(e)),
        }
    }

    /// Assemble the per-model telemetry rows (v2 stats/bye surface).
    fn telemetry(&self) -> Vec<ModelTelemetry> {
        self.backend
            .models()
            .into_iter()
            .map(|mid| {
                let c = self.counters.get(&mid).copied().unwrap_or_default();
                let (evictions, rehydrations) = self.backend.lifecycle(mid);
                ModelTelemetry {
                    model: self.backend.model_label(mid),
                    evictions,
                    rehydrations,
                    full_flushes: c.full,
                    deadline_flushes: c.deadline,
                    final_flushes: c.fin,
                    width_hist: c.width_hist,
                    queue_depths: self.backend.queue_depths(mid),
                }
            })
            .collect()
    }

    fn handle_request(&mut self, s: usize, req: Request, now: u64) {
        if !self.sessions[s].hello_done {
            match req {
                Request::Hello { version, model }
                    if (PROTO_MIN_VERSION..=PROTO_VERSION).contains(&version) =>
                {
                    match self.backend.bind(model.as_deref()) {
                        Ok(mid) => {
                            let sess = &mut self.sessions[s];
                            sess.hello_done = true;
                            sess.version = version;
                            sess.model = mid;
                            let caps = (version >= 2).then(|| PROTO_CAPS.to_string());
                            self.immediate(s, Response::HelloOk { version, caps });
                        }
                        Err(e) => {
                            let kind = route_err_kind(e);
                            match kind {
                                ErrKind::UnknownModel => self.stats.unknown_model += 1,
                                ErrKind::Evicting => self.stats.evicting_rejected += 1,
                                _ => self.stats.server_shed += 1,
                            }
                            self.immediate(s, Response::Err { id: None, kind });
                            self.sessions[s].conn.close();
                            self.sessions[s].dead = true;
                        }
                    }
                }
                Request::Hello { .. } => {
                    self.immediate(s, Response::Err { id: None, kind: ErrKind::Version });
                    self.sessions[s].conn.close();
                    self.sessions[s].dead = true;
                }
                _ => {
                    self.stats.quarantined += 1;
                    self.immediate(s, Response::Err { id: None, kind: ErrKind::BadRequest });
                    self.sessions[s].conn.close();
                    self.sessions[s].dead = true;
                }
            }
            return;
        }
        match req {
            Request::Hello { .. } => {
                self.stats.quarantined += 1;
                self.immediate(s, Response::Err { id: None, kind: ErrKind::BadRequest });
            }
            Request::Stats { id } => {
                self.stats.stats_served += 1;
                let telemetry =
                    if self.sessions[s].version >= 2 { self.telemetry() } else { Vec::new() };
                let wire = self.stats.wire(telemetry);
                self.immediate(s, Response::Stats { id, stats: wire });
            }
            Request::Drain { id } => {
                self.stats.drains += 1;
                self.draining = true;
                self.immediate(s, Response::DrainOk { id });
            }
            Request::Infer { id, ttl, model, bits } => {
                self.handle_infer(s, id, ttl, model.as_deref(), &bits, now)
            }
            Request::Learn { id, label, model, bits } => {
                self.handle_learn(s, id, label, model.as_deref(), &bits)
            }
        }
    }

    /// Read, reassemble and process everything session `s` has for us.
    fn pump_session(&mut self, s: usize, now: u64) {
        if self.sessions[s].dead {
            return;
        }
        let mut lines = Vec::new();
        let mut frame_err = false;
        {
            let read_chunk = self.cfg.read_chunk;
            let sess = &mut self.sessions[s];
            let mut chunk = Vec::with_capacity(read_chunk);
            while !sess.eof {
                chunk.clear();
                match sess.conn.read_into(&mut chunk, read_chunk) {
                    ReadOutcome::Data(_) => {
                        sess.fb.push(&chunk);
                        match sess.fb.frames() {
                            Ok(fs) => lines.extend(fs),
                            Err(_) => {
                                frame_err = true;
                                break;
                            }
                        }
                    }
                    ReadOutcome::WouldBlock => break,
                    ReadOutcome::Eof => sess.eof = true,
                }
            }
        }
        for line in lines {
            if self.sessions[s].dead {
                break;
            }
            self.stats.frames_in += 1;
            match proto::parse_request(&line) {
                Ok(req) => self.handle_request(s, req, now),
                Err(_) => {
                    frame_err = true;
                    break;
                }
            }
        }
        if frame_err && !self.sessions[s].dead {
            self.stats.frame_errors += 1;
            self.immediate(s, Response::Err { id: None, kind: ErrKind::Frame });
            self.sessions[s].conn.close();
            self.sessions[s].dead = true;
        }
    }

    /// One turn of the control loop at virtual tick `now`.
    pub fn tick(&mut self, now: u64) {
        self.transport.advance(now);
        if !self.draining {
            while let Some(conn) = self.transport.poll_accept() {
                self.stats.connections += 1;
                self.sessions.push(Session::new(conn, self.cfg.max_frame_bytes));
            }
        }
        let due: Vec<u64> = self
            .batchers
            .iter()
            .filter(|(_, b)| b.due(now))
            .map(|(&mid, _)| mid)
            .collect();
        for mid in due {
            if let Some(batch) = self.batchers.get_mut(&mid).and_then(|b| b.flush()) {
                self.note_flush(mid, batch.len(), FlushCause::Deadline);
                self.dispatch(mid, batch, now);
            }
        }
        for s in 0..self.sessions.len() {
            self.pump_session(s, now);
        }
        self.route_backend();
        for sess in &mut self.sessions {
            sess.conn.flush();
        }
    }

    /// Graceful drain: flush every model's batcher tail
    /// (deadline-checked), finalize the backend (joins workers,
    /// verifies the exactly-once audit, checkpoints replicas), answer
    /// everything still in flight, send every live client a final `bye`
    /// stats frame, and close. Errors if any admitted request would
    /// finish unanswered.
    pub fn drain(mut self, now: u64) -> Result<(NetReport, T)> {
        self.draining = true;
        let mids: Vec<u64> = self.batchers.keys().copied().collect();
        for mid in mids {
            if let Some(batch) = self.batchers.get_mut(&mid).and_then(|b| b.flush()) {
                self.note_flush(mid, batch.len(), FlushCause::Final);
                self.dispatch(mid, batch, now);
            }
        }
        // Telemetry is snapshotted before finalize consumes the
        // backend (queue depths post-flush, pre-join).
        let telemetry = self.telemetry();
        // Deferred durable writes reach stable storage before the
        // workers join: a drained run survives power loss whole.
        self.backend.sync_durable()?;
        let fin = self.backend.finalize()?;
        for (gid, class) in fin.responses {
            if self.fill_slot(gid, SlotFill::Pred(class)) {
                self.stats.preds += 1;
            }
        }
        for gid in fin.shed {
            if self.fill_slot(gid, SlotFill::Overload) {
                self.stats.server_shed += 1;
            }
        }
        if !self.routes.is_empty() {
            bail!("net: {} admitted requests finished unanswered", self.routes.len());
        }
        let bye_v1 = Response::Bye { stats: self.stats.wire(Vec::new()) };
        let bye_v2 = Response::Bye { stats: self.stats.wire(telemetry.clone()) };
        for sess in &mut self.sessions {
            if sess.conn.writable() {
                let bye = if sess.version >= 2 { &bye_v2 } else { &bye_v1 };
                sess.promised += 1;
                sess.conn.write_frame(bye.encode().as_bytes());
                sess.conn.flush();
            }
            sess.conn.close();
        }
        self.transport.advance(now);
        let report = NetReport {
            stats: self.stats,
            outcomes: self.outcomes,
            replicas: fin.replicas,
            updates: self.updates,
            telemetry,
        };
        Ok((report, self.transport))
    }
}

/// Drive scripted clients to completion against `backend`: tick from 0
/// past the last scripted action plus the batcher's budget, then drain.
/// Fully deterministic in `(backend determinism, scripts, cfg)`.
pub fn run_sim<B: HubNetBackend>(
    backend: B,
    scripts: Vec<ClientScript>,
    shape: &TmShape,
    cfg: NetConfig,
) -> Result<(NetReport, SimTransport)> {
    let horizon = scripts_end(&scripts) + cfg.batch.latency_budget + 2;
    let transport = SimTransport::new(scripts);
    let mut fe = FrontEnd::new(backend, transport, shape.clone(), cfg)?;
    let mut now = 0;
    while now <= horizon {
        fe.tick(now);
        if fe.is_draining() {
            break;
        }
        now += 1;
    }
    fe.drain(now)
}

/// Serve real sockets: tick the front end roughly every millisecond
/// until a client requests drain (or `max_idle_ticks` elapse with no
/// inbound frames and no open work — the CI drill's safety net).
pub fn run_tcp<B: HubNetBackend>(
    backend: B,
    transport: TcpTransport,
    shape: &TmShape,
    cfg: NetConfig,
    max_idle_ticks: Option<u64>,
) -> Result<NetReport> {
    let mut fe = FrontEnd::new(backend, transport, shape.clone(), cfg)?;
    let mut now = 0u64;
    let mut idle = 0u64;
    loop {
        let before = fe.stats().frames_in;
        fe.tick(now);
        if fe.is_draining() {
            // A few settle ticks so in-flight shard replies land before
            // the drain barrier does the final collection.
            for _ in 0..3 {
                now += 1;
                fe.tick(now);
            }
            return Ok(fe.drain(now)?.0);
        }
        if fe.stats().frames_in == before {
            idle += 1;
            if let Some(cap) = max_idle_ticks {
                if idle > cap {
                    return Ok(fe.drain(now)?.0);
                }
            }
        } else {
            idle = 0;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
        now += 1;
    }
}

/// What the loopback drill observed, client-side.
#[derive(Debug)]
pub struct DrillReport {
    pub preds: u64,
    pub errs: u64,
    pub stats: WireStats,
    pub bye: WireStats,
}

/// The CI loopback drill client: speak the real protocol over a real
/// socket — hello, `requests` infers, a stats probe, then drain — and
/// account every response frame until the server's final `bye`.
pub fn loopback_drill(
    addr: std::net::SocketAddr,
    requests: u64,
    features: usize,
    seed: u64,
) -> Result<DrillReport> {
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("drill: connecting {addr}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10))).ok();
    let mut reader = BufReader::new(stream.try_clone().context("drill: cloning stream")?);
    let mut rng = Xoshiro256::new(seed);

    let mut expect = |reader: &mut BufReader<std::net::TcpStream>| -> Result<Response> {
        let mut line = String::new();
        let n = reader.read_line(&mut line).context("drill: reading response")?;
        if n == 0 {
            bail!("drill: server hung up early");
        }
        proto::parse_response(line.trim_end())
    };

    stream.write_all(Request::Hello { version: PROTO_VERSION, model: None }.encode().as_bytes())?;
    match expect(&mut reader)? {
        Response::HelloOk { version, .. } if version == PROTO_VERSION => {}
        other => bail!("drill: expected ok hello, got {other:?}"),
    }

    for cid in 1..=requests {
        let bits: Vec<bool> = (0..features).map(|_| rng.next_f32() < 0.5).collect();
        let req = Request::Infer { id: cid, ttl: None, model: None, bits };
        stream.write_all(req.encode().as_bytes())?;
    }
    stream.write_all(Request::Stats { id: requests + 1 }.encode().as_bytes())?;
    stream.write_all(Request::Drain { id: requests + 2 }.encode().as_bytes())?;

    let mut preds = 0u64;
    let mut errs = 0u64;
    let mut stats = None;
    let mut bye = None;
    while bye.is_none() {
        match expect(&mut reader)? {
            Response::Pred { .. } => preds += 1,
            Response::Err { .. } => errs += 1,
            Response::Stats { stats: s, .. } => stats = Some(s),
            Response::DrainOk { .. } => {}
            Response::Bye { stats: s } => bye = Some(s),
            other => bail!("drill: unexpected frame {other:?}"),
        }
    }
    Ok(DrillReport {
        preds,
        errs,
        stats: stats.context("drill: no stats frame seen")?,
        bye: bye.expect("loop exits only with bye"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hub::SingleModel;
    use crate::net::sim::ClientOp;
    use crate::serve::ScalarOracle;
    use crate::tm::params::TmParams;

    fn oracle() -> (SingleModel<ScalarOracle>, TmShape) {
        let s = TmShape::iris();
        let p = TmParams::paper_online(&s);
        let mut rng = Xoshiro256::new(0x0E0E);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        (SingleModel(ScalarOracle::new(tm, p, 0xBA5E)), s)
    }

    fn send(at: u64, req: Request) -> ClientOp {
        ClientOp::Send { at, bytes: req.encode().into_bytes() }
    }

    fn hello_v1(at: u64) -> ClientOp {
        send(at, Request::Hello { version: 1, model: None })
    }

    fn infer(id: u64, ttl: Option<u64>, bits: Vec<bool>) -> Request {
        Request::Infer { id, ttl, model: None, bits }
    }

    fn bits(s: &TmShape, seed: u64) -> Vec<bool> {
        let mut rng = Xoshiro256::new(seed);
        (0..s.features).map(|_| rng.next_f32() < 0.5).collect()
    }

    #[test]
    fn healthy_session_end_to_end() {
        let (oracle, s) = oracle();
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::ReadAllow { at: 0, frames: 100 },
                hello_v1(0),
                send(1, infer(1, None, bits(&s, 1))),
                send(2, Request::Learn { id: 2, label: 1, model: None, bits: bits(&s, 2) }),
                send(3, infer(3, None, bits(&s, 3))),
                send(4, Request::Stats { id: 4 }),
            ],
        }];
        let cfg = NetConfig {
            batch: BatcherConfig { max_batch: 8, latency_budget: 2, ..Default::default() },
            ..Default::default()
        };
        let (report, tr) = run_sim(oracle, scripts, &s, cfg).unwrap();
        assert_eq!(report.stats.infers, 2);
        assert_eq!(report.stats.learns, 1);
        assert_eq!(report.stats.preds, 2);
        assert_eq!(report.stats.quarantined, 0);
        assert_eq!(report.stats.frame_errors, 0);
        assert!(matches!(report.outcomes[&(0, 1)], Outcome::Pred(_)));
        assert_eq!(report.outcomes[&(0, 2)], Outcome::LearnAck(1));
        assert!(matches!(report.outcomes[&(0, 3)], Outcome::Pred(_)));
        let delivered = tr.delivered(0);
        // A v1 session's frames are byte-identical to the pre-hub
        // build: no caps, no telemetry, "ok hello v=1".
        assert_eq!(delivered[0], "ok hello v=1\n");
        // Responses: hello-ok, learn-ok (immediate), two preds in
        // request order, stats, bye.
        assert_eq!(delivered.len(), 6);
        assert!(delivered[1].starts_with("ok id=2 seq=1"));
        assert!(delivered.last().unwrap().starts_with("bye "));
        assert!(
            !delivered.iter().any(|l| l.contains("tv=")),
            "v1 session must not see telemetry: {delivered:?}"
        );
        let pred_lines: Vec<&String> =
            delivered.iter().filter(|l| l.starts_with("pred")).collect();
        assert!(pred_lines[0].starts_with("pred id=1 "));
        assert!(pred_lines[1].starts_with("pred id=3 "));
    }

    #[test]
    fn v2_session_negotiates_caps_and_routing_is_typed() {
        let (oracle, s) = oracle();
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::ReadAllow { at: 0, frames: 100 },
                send(0, Request::Hello { version: 2, model: None }),
                send(1, infer(1, None, bits(&s, 1))),
                // Routed at a model this single-model backend does not
                // host: typed unknown-model, never batched.
                send(2, Request::Infer {
                    id: 2,
                    ttl: None,
                    model: Some("ghost".into()),
                    bits: bits(&s, 2),
                }),
                send(3, Request::Stats { id: 3 }),
            ],
        }];
        let cfg = NetConfig {
            batch: BatcherConfig { max_batch: 8, latency_budget: 2, ..Default::default() },
            ..Default::default()
        };
        let (report, tr) = run_sim(oracle, scripts, &s, cfg).unwrap();
        let delivered = tr.delivered(0);
        assert_eq!(delivered[0], format!("ok hello v=2 caps={PROTO_CAPS}\n"));
        assert_eq!(report.stats.unknown_model, 1);
        assert_eq!(report.stats.infers, 1, "the unknown-model request never reached a batcher");
        assert_eq!(report.outcomes[&(0, 2)], Outcome::UnknownModel);
        assert!(delivered.iter().any(|l| l.starts_with("err id=2 kind=unknown-model")));
        // v2 stats and bye carry the versioned telemetry map for the
        // anonymous default model.
        let stats_line = delivered.iter().find(|l| l.starts_with("stats id=3")).unwrap();
        assert!(stats_line.contains(" tv=1 models=default:"), "{stats_line:?}");
        let bye = delivered.last().unwrap();
        assert!(bye.starts_with("bye ") && bye.contains(" tv=1 models=default:"), "{bye:?}");
        assert_eq!(report.telemetry.len(), 1);
        assert_eq!(report.telemetry[0].model, "default");
        let flushes = report.telemetry[0].full_flushes
            + report.telemetry[0].deadline_flushes
            + report.telemetry[0].final_flushes;
        assert!(flushes >= 1, "the admitted infer must appear as a flush: {report:?}");
    }

    #[test]
    fn deadline_budget_expires_with_typed_response() {
        let (oracle, s) = oracle();
        // Budget 2 but the batch sits for 6 ticks (latency budget), so
        // the first request expires; the second (ttl 100) survives.
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::ReadAllow { at: 0, frames: 100 },
                hello_v1(0),
                send(1, infer(1, Some(2), bits(&s, 1))),
                send(1, infer(2, Some(100), bits(&s, 2))),
            ],
        }];
        let cfg = NetConfig {
            batch: BatcherConfig { max_batch: 8, latency_budget: 6, ..Default::default() },
            ..Default::default()
        };
        let (report, tr) = run_sim(oracle, scripts, &s, cfg).unwrap();
        assert_eq!(report.stats.deadline_expired, 1);
        assert_eq!(report.stats.preds, 1);
        assert_eq!(report.outcomes[&(0, 1)], Outcome::DeadlineExpired);
        assert!(matches!(report.outcomes[&(0, 2)], Outcome::Pred(_)));
        // In-order release: the deadline err for id 1 precedes the pred
        // for id 2.
        let delivered = tr.delivered(0);
        let i_err = delivered.iter().position(|l| l.starts_with("err id=1")).unwrap();
        let i_pred = delivered.iter().position(|l| l.starts_with("pred id=2")).unwrap();
        assert!(i_err < i_pred);
        assert!(delivered[i_err].contains("kind=deadline"));
    }

    #[test]
    fn version_negotiation_and_missing_hello() {
        let (oracle, s) = oracle();
        let scripts = vec![
            ClientScript {
                connect_at: 0,
                ops: vec![
                    ClientOp::ReadAllow { at: 0, frames: 10 },
                    send(0, Request::Hello { version: 9, model: None }),
                ],
            },
            ClientScript {
                connect_at: 1,
                ops: vec![
                    ClientOp::ReadAllow { at: 1, frames: 10 },
                    send(1, Request::Stats { id: 1 }),
                ],
            },
        ];
        let (report, tr) = run_sim(oracle, scripts, &s, NetConfig::default()).unwrap();
        assert_eq!(report.stats.connections, 2);
        assert!(tr.delivered(0)[0].starts_with("err kind=version"));
        assert!(tr.delivered(1)[0].starts_with("err kind=bad-request"));
        assert_eq!(report.stats.quarantined, 1);
    }

    #[test]
    fn hostile_frames_are_capped_and_typed() {
        let (oracle, s) = oracle();
        let scripts = vec![
            // A 200-byte line against a 128-byte cap, no newline.
            ClientScript {
                connect_at: 0,
                ops: vec![
                    ClientOp::ReadAllow { at: 0, frames: 10 },
                    hello_v1(0),
                    ClientOp::Send { at: 1, bytes: vec![b'x'; 200] },
                ],
            },
            // Unparseable verb.
            ClientScript {
                connect_at: 0,
                ops: vec![
                    ClientOp::ReadAllow { at: 0, frames: 10 },
                    hello_v1(0),
                    ClientOp::Send { at: 1, bytes: b"explode id=1\n".to_vec() },
                ],
            },
        ];
        let cfg = NetConfig { max_frame_bytes: 128, ..Default::default() };
        let (report, tr) = run_sim(oracle, scripts, &s, cfg).unwrap();
        assert_eq!(report.stats.frame_errors, 2);
        for c in 0..2 {
            let delivered = tr.delivered(c);
            assert!(
                delivered.iter().any(|l| l.starts_with("err kind=frame")),
                "client {c} got {delivered:?}"
            );
        }
    }

    #[test]
    fn slow_client_is_shed_and_admission_rejects() {
        let (oracle, s) = oracle();
        // Client grants only 2 frames ever; hello-ok consumes part of
        // the window, then debt builds until the cap (3) sheds.
        let mut ops = vec![ClientOp::ReadAllow { at: 0, frames: 2 }, hello_v1(0)];
        for cid in 1..=8 {
            ops.push(send(1 + cid, infer(cid, None, bits(&s, cid))));
        }
        let scripts = vec![ClientScript { connect_at: 0, ops }];
        let cfg = NetConfig {
            batch: BatcherConfig { max_batch: 1, latency_budget: 0, ..Default::default() },
            write_buffer_cap: 3,
            max_in_flight: 100,
            ..Default::default()
        };
        let (report, _tr) = run_sim(oracle, scripts, &s, cfg).unwrap();
        // Debt: promised rises with hello + preds while granted stays
        // at 2 → once debt hits 3, every later request is shed.
        assert!(report.stats.shed_requests > 0, "slow client never shed: {:?}", report.stats);
        assert_eq!(
            report.stats.infers + report.stats.shed_requests,
            8,
            "every request accounted exactly once: {:?}",
            report.stats
        );
        let sheds = report
            .outcomes
            .values()
            .filter(|o| matches!(o, Outcome::SlowShed))
            .count() as u64;
        assert_eq!(sheds, report.stats.shed_requests);

        // Same shape, but a tiny global ceiling: admission rejects with
        // a typed answer instead of silence.
        let (oracle2, _) = oracle_pair();
        let mut ops = vec![
            ClientOp::ReadAllow { at: 0, frames: 1 }, // hello consumes it
            hello_v1(0),
        ];
        for cid in 1..=5 {
            ops.push(send(1 + cid, infer(cid, None, bits(&s, cid))));
        }
        ops.push(ClientOp::ReadAllow { at: 20, frames: 100 });
        let scripts = vec![ClientScript { connect_at: 0, ops }];
        let cfg = NetConfig {
            batch: BatcherConfig { max_batch: 1, latency_budget: 0, ..Default::default() },
            write_buffer_cap: 100,
            max_in_flight: 2,
            ..Default::default()
        };
        let (report, tr) = run_sim(oracle2, scripts, &s, cfg).unwrap();
        assert!(report.stats.admission_rejected > 0, "{:?}", report.stats);
        assert!(tr.delivered(0).iter().any(|l| l.contains("kind=admission")));
    }

    fn oracle_pair() -> (ScalarOracle, TmShape) {
        oracle()
    }

    #[test]
    fn drain_request_stops_intake_and_says_bye() {
        let (oracle, s) = oracle();
        let scripts = vec![ClientScript {
            connect_at: 0,
            ops: vec![
                ClientOp::ReadAllow { at: 0, frames: 100 },
                hello_v1(0),
                send(1, infer(1, None, bits(&s, 1))),
                send(2, Request::Drain { id: 2 }),
            ],
        }];
        let (report, tr) = run_sim(oracle, scripts, &s, NetConfig::default()).unwrap();
        assert_eq!(report.stats.drains, 1);
        assert_eq!(report.stats.preds, 1, "in-flight work is answered before close");
        let delivered = tr.delivered(0);
        assert!(delivered.iter().any(|l| l.starts_with("ok drain id=2")));
        let bye = delivered.last().unwrap();
        assert!(bye.starts_with("bye "), "final frame is the stats bye, got {bye:?}");
        let parsed = proto::parse_response(bye.trim_end()).unwrap();
        match parsed {
            Response::Bye { stats } => {
                assert_eq!(stats.infers, 1);
                assert_eq!(stats.preds, 1);
            }
            other => panic!("expected bye, got {other:?}"),
        }
    }
}
