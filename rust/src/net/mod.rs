//! Network-facing serving front end.
//!
//! Layers, bottom up:
//!
//! - [`proto`] — the line-delimited, versioned request/response wire
//!   protocol and the allocation-bounded [`proto::FrameBuffer`].
//! - [`transport`] — the [`transport::Transport`] / byte-connection
//!   boundary, with the real non-blocking TCP implementation.
//! - [`sim`] — the deterministic in-memory transport: scripted clients
//!   on the virtual clock, including connection-level chaos (torn
//!   frames, half-open peers, hard disconnects, slow-loris readers,
//!   floods) generated from a seeded [`crate::serve::NetChaosPlan`].
//! - [`frontend`] — the control loop tying a transport to a
//!   [`crate::serve::NetBackend`]: sessions, admission control,
//!   deadline budgets, debt-based backpressure and graceful drain.
//!
//! The same [`frontend::FrontEnd`] drives all transport × backend
//! pairings, which is what lets the network chaos soak
//! (`coordinator::soak::run_net_soak`) demand bit-identical behaviour
//! from the sharded server and the scalar oracle under identical
//! scripted abuse.

pub mod frontend;
pub mod proto;
pub mod sim;
pub mod transport;

pub use frontend::{
    loopback_drill, run_sim, run_tcp, DrillReport, FrontEnd, NetConfig, NetReport, NetStats,
    Outcome,
};
pub use proto::{ErrKind, FrameBuffer, Request, Response, WireStats, PROTO_VERSION};
pub use sim::{seeded_scripts, ClientOp, ClientScript, ScriptConfig, SimTransport};
pub use transport::{NetConn, ReadOutcome, TcpTransport, Transport};
