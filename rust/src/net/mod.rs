//! Network-facing serving front end.
//!
//! Layers, bottom up:
//!
//! - [`proto`] — the line-delimited, versioned request/response wire
//!   protocol and the allocation-bounded [`proto::FrameBuffer`].
//! - [`transport`] — the [`transport::Transport`] / byte-connection
//!   boundary, with the real non-blocking TCP implementation.
//! - [`sim`] — the deterministic in-memory transport: scripted clients
//!   on the virtual clock, including connection-level chaos (torn
//!   frames, half-open peers, hard disconnects, slow-loris readers,
//!   floods) generated from a seeded [`crate::serve::NetChaosPlan`].
//! - [`frontend`] — the control loop tying a transport to a
//!   [`crate::hub::HubNetBackend`]: sessions (with per-session
//!   protocol-version and default-model negotiation), model routing,
//!   per-model micro-batchers, admission control, deadline budgets,
//!   debt-based backpressure, per-model telemetry and graceful drain.
//!
//! The same [`frontend::FrontEnd`] drives all transport × backend
//! pairings — model hub, sharded server or scalar oracle (the latter
//! two as the anonymous default model via the
//! [`crate::hub::SingleModel`] adapter) — which is what lets the network
//! chaos soak (`coordinator::soak::run_net_soak`) demand bit-identical
//! behaviour from the sharded server and the scalar oracle under
//! identical scripted abuse, and the hub soak do the same per tenant.

pub mod frontend;
pub mod proto;
pub mod sim;
pub mod transport;

pub use frontend::{
    loopback_drill, run_sim, run_tcp, DrillReport, FrontEnd, NetConfig, NetReport, NetStats,
    Outcome,
};
pub use proto::{
    ErrKind, FrameBuffer, ModelTelemetry, Request, Response, WireStats, PROTO_CAPS,
    PROTO_MIN_VERSION, PROTO_VERSION, TELEMETRY_VERSION,
};
pub use sim::{seeded_scripts, ClientOp, ClientScript, ScriptConfig, SimTransport};
pub use transport::{NetConn, ReadOutcome, TcpTransport, Transport};
