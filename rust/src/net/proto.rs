//! The line-delimited, versioned wire protocol of the serving front
//! end.
//!
//! One frame per `\n`-terminated ASCII line, `verb key=value ...`. The
//! first frame on every connection must be `hello v=1`; the server
//! answers `ok hello v=1` (or a typed `err kind=version` and a close —
//! version negotiation is explicit, never silent). Requests carry a
//! client-chosen per-connection id echoed on the response, so a client
//! can pipeline freely; the front end releases `infer` responses in
//! request order per connection regardless of shard completion order.
//!
//! ```text
//! -> hello v=1                          <- ok hello v=1
//! -> infer id=7 ttl=5 bits=0110...      <- pred id=7 class=2
//! -> learn id=8 label=1 bits=0011...    <- ok id=8 seq=42
//! -> stats id=9                         <- stats id=9 infers=.. ...
//! -> drain id=10                        <- ok drain id=10 … bye infers=.. ...
//! any rejected request                  <- err id=N kind=<reason>
//! ```
//!
//! Parsing is **paranoid by design**: [`FrameBuffer`] bounds how many
//! bytes a connection may accumulate without producing a newline, so a
//! hostile peer can never force an unbounded allocation; every line is
//! tokenized strictly (unknown verbs, unknown keys, duplicate or
//! missing fields, non-digit values and non-ASCII bytes are all typed
//! errors). Field *semantics* (bit-width vs the served model, label
//! range, admission) are the front end's job — this module only
//! guarantees that what comes out of a parse is structurally sound and
//! cost-bounded.

use anyhow::{anyhow, bail, Result};

/// The one protocol version this build speaks.
pub const PROTO_VERSION: u32 = 1;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Mandatory first frame: version negotiation.
    Hello { version: u32 },
    /// Score one sample. `ttl` is a per-request deadline budget in
    /// virtual ticks (absent = the front end's default).
    Infer { id: u64, ttl: Option<u64>, bits: Vec<bool> },
    /// One online training step.
    Learn { id: u64, label: usize, bits: Vec<bool> },
    /// Counter snapshot.
    Stats { id: u64 },
    /// Begin graceful drain: stop accepting, flush, checkpoint, close.
    Drain { id: u64 },
}

/// Why a request was rejected — every rejection is typed and answered,
/// never a silent drop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrKind {
    /// The request's deadline budget expired before dispatch.
    Deadline,
    /// The admission controller's in-flight depth is exhausted.
    Admission,
    /// Structurally valid frame, semantically unusable (wrong bit
    /// width, label out of range, duplicate id, missing hello).
    BadRequest,
    /// Unsupported protocol version in `hello`.
    Version,
    /// Unparseable or oversized frame (connection is closed after).
    Frame,
    /// The server is draining and accepts no new work.
    Draining,
    /// Dispatched but shed by the degraded backend under overload.
    Overload,
}

impl ErrKind {
    fn as_str(self) -> &'static str {
        match self {
            ErrKind::Deadline => "deadline",
            ErrKind::Admission => "admission",
            ErrKind::BadRequest => "bad-request",
            ErrKind::Version => "version",
            ErrKind::Frame => "frame",
            ErrKind::Draining => "draining",
            ErrKind::Overload => "overload",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "deadline" => ErrKind::Deadline,
            "admission" => ErrKind::Admission,
            "bad-request" => ErrKind::BadRequest,
            "version" => ErrKind::Version,
            "frame" => ErrKind::Frame,
            "draining" => ErrKind::Draining,
            "overload" => ErrKind::Overload,
            other => bail!("proto: unknown err kind {other:?}"),
        })
    }
}

/// The counters a `stats` response and the final `bye` frame carry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    pub infers: u64,
    pub learns: u64,
    pub preds: u64,
    pub shed: u64,
    pub deadline: u64,
    pub admission: u64,
    pub quarantined: u64,
    pub frame_errors: u64,
}

impl WireStats {
    fn encode_fields(&self, out: &mut String) {
        use std::fmt::Write;
        let _ = write!(
            out,
            "infers={} learns={} preds={} shed={} deadline={} admission={} quarantined={} \
             frame_errors={}",
            self.infers,
            self.learns,
            self.preds,
            self.shed,
            self.deadline,
            self.admission,
            self.quarantined,
            self.frame_errors
        );
    }
}

/// A server response frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    HelloOk { version: u32 },
    Pred { id: u64, class: usize },
    LearnOk { id: u64, seq: u64 },
    DrainOk { id: u64 },
    Stats { id: u64, stats: WireStats },
    Err { id: Option<u64>, kind: ErrKind },
    /// The final frame of a graceful drain, after which the connection
    /// closes.
    Bye { stats: WireStats },
}

impl Request {
    /// Wire form, newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = match self {
            Request::Hello { version } => format!("hello v={version}"),
            Request::Infer { id, ttl, bits } => {
                let mut s = format!("infer id={id}");
                if let Some(t) = ttl {
                    s.push_str(&format!(" ttl={t}"));
                }
                s.push_str(" bits=");
                push_bits(&mut s, bits);
                s
            }
            Request::Learn { id, label, bits } => {
                let mut s = format!("learn id={id} label={label} bits=");
                push_bits(&mut s, bits);
                s
            }
            Request::Stats { id } => format!("stats id={id}"),
            Request::Drain { id } => format!("drain id={id}"),
        };
        s.push('\n');
        s
    }
}

impl Response {
    /// Wire form, newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = match self {
            Response::HelloOk { version } => format!("ok hello v={version}"),
            Response::Pred { id, class } => format!("pred id={id} class={class}"),
            Response::LearnOk { id, seq } => format!("ok id={id} seq={seq}"),
            Response::DrainOk { id } => format!("ok drain id={id}"),
            Response::Stats { id, stats } => {
                let mut s = format!("stats id={id} ");
                stats.encode_fields(&mut s);
                s
            }
            Response::Err { id, kind } => match id {
                Some(id) => format!("err id={id} kind={}", kind.as_str()),
                None => format!("err kind={}", kind.as_str()),
            },
            Response::Bye { stats } => {
                let mut s = "bye ".to_string();
                stats.encode_fields(&mut s);
                s
            }
        };
        s.push('\n');
        s
    }
}

fn push_bits(s: &mut String, bits: &[bool]) {
    s.reserve(bits.len());
    for &b in bits {
        s.push(if b { '1' } else { '0' });
    }
}

/// Strict key=value field collector: every key consumed at most once,
/// unknown keys rejected, leftovers rejected.
struct Fields<'a> {
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> Fields<'a> {
    fn parse(tokens: std::str::SplitAsciiWhitespace<'a>) -> Result<Self> {
        let mut pairs = Vec::new();
        for tok in tokens {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow!("proto: token {tok:?} is not key=value"))?;
            if v.is_empty() {
                bail!("proto: empty value for key {k:?}");
            }
            if pairs.iter().any(|&(pk, _)| pk == k) {
                bail!("proto: duplicate key {k:?}");
            }
            pairs.push((k, v));
        }
        Ok(Fields { pairs })
    }

    fn take(&mut self, key: &str) -> Option<&'a str> {
        let i = self.pairs.iter().position(|&(k, _)| k == key)?;
        Some(self.pairs.remove(i).1)
    }

    fn want(&mut self, key: &str) -> Result<&'a str> {
        self.take(key).ok_or_else(|| anyhow!("proto: missing key {key:?}"))
    }

    fn finish(self) -> Result<()> {
        if let Some((k, _)) = self.pairs.first() {
            bail!("proto: unknown key {k:?}");
        }
        Ok(())
    }
}

fn parse_u64(v: &str) -> Result<u64> {
    if v.len() > 20 || !v.bytes().all(|b| b.is_ascii_digit()) {
        bail!("proto: {v:?} is not an unsigned integer");
    }
    v.parse::<u64>().map_err(|_| anyhow!("proto: integer {v:?} out of range"))
}

fn parse_bits(v: &str) -> Result<Vec<bool>> {
    v.bytes()
        .map(|b| match b {
            b'0' => Ok(false),
            b'1' => Ok(true),
            _ => bail!("proto: bits must be 0/1, got byte {b:#04x}"),
        })
        .collect()
}

/// Parse one request line (no trailing newline). Errors are frame-level
/// (`err kind=frame` territory): the caller decides whether to answer
/// or hang up, but a failed parse never partially applies.
pub fn parse_request(line: &str) -> Result<Request> {
    let mut tokens = line.split_ascii_whitespace();
    let verb = tokens.next().ok_or_else(|| anyhow!("proto: empty frame"))?;
    let mut f = Fields::parse(tokens)?;
    let req = match verb {
        "hello" => Request::Hello { version: parse_u64(f.want("v")?)? as u32 },
        "infer" => Request::Infer {
            id: parse_u64(f.want("id")?)?,
            ttl: f.take("ttl").map(parse_u64).transpose()?,
            bits: parse_bits(f.want("bits")?)?,
        },
        "learn" => Request::Learn {
            id: parse_u64(f.want("id")?)?,
            label: parse_u64(f.want("label")?)? as usize,
            bits: parse_bits(f.want("bits")?)?,
        },
        "stats" => Request::Stats { id: parse_u64(f.want("id")?)? },
        "drain" => Request::Drain { id: parse_u64(f.want("id")?)? },
        other => bail!("proto: unknown verb {other:?}"),
    };
    f.finish()?;
    Ok(req)
}

/// Parse one response line (no trailing newline) — the client half,
/// used by the loopback drill and the tests.
pub fn parse_response(line: &str) -> Result<Response> {
    let mut tokens = line.split_ascii_whitespace();
    let verb = tokens.next().ok_or_else(|| anyhow!("proto: empty frame"))?;
    let sub = match verb {
        "ok" => {
            let mut peek = tokens.clone();
            match peek.next() {
                Some("hello") => {
                    tokens.next();
                    Some("hello")
                }
                Some("drain") => {
                    tokens.next();
                    Some("drain")
                }
                _ => None,
            }
        }
        _ => None,
    };
    let mut f = Fields::parse(tokens)?;
    let parse_stats = |f: &mut Fields| -> Result<WireStats> {
        Ok(WireStats {
            infers: parse_u64(f.want("infers")?)?,
            learns: parse_u64(f.want("learns")?)?,
            preds: parse_u64(f.want("preds")?)?,
            shed: parse_u64(f.want("shed")?)?,
            deadline: parse_u64(f.want("deadline")?)?,
            admission: parse_u64(f.want("admission")?)?,
            quarantined: parse_u64(f.want("quarantined")?)?,
            frame_errors: parse_u64(f.want("frame_errors")?)?,
        })
    };
    let resp = match (verb, sub) {
        ("ok", Some("hello")) => Response::HelloOk { version: parse_u64(f.want("v")?)? as u32 },
        ("ok", Some("drain")) => Response::DrainOk { id: parse_u64(f.want("id")?)? },
        ("ok", None) => Response::LearnOk {
            id: parse_u64(f.want("id")?)?,
            seq: parse_u64(f.want("seq")?)?,
        },
        ("pred", _) => Response::Pred {
            id: parse_u64(f.want("id")?)?,
            class: parse_u64(f.want("class")?)? as usize,
        },
        ("stats", _) => {
            Response::Stats { id: parse_u64(f.want("id")?)?, stats: parse_stats(&mut f)? }
        }
        ("err", _) => Response::Err {
            id: f.take("id").map(parse_u64).transpose()?,
            kind: ErrKind::parse(f.want("kind")?)?,
        },
        ("bye", _) => Response::Bye { stats: parse_stats(&mut f)? },
        (other, _) => bail!("proto: unknown verb {other:?}"),
    };
    f.finish()?;
    Ok(resp)
}

/// Reassembles newline-delimited frames from arbitrarily torn byte
/// slivers, under a hard per-line byte cap: the moment the unterminated
/// tail exceeds `max_frame_bytes`, the buffer errors — a hostile peer
/// streaming garbage without newlines costs at most one cap's worth of
/// memory, never an unbounded allocation.
#[derive(Debug)]
pub struct FrameBuffer {
    buf: Vec<u8>,
    max_frame_bytes: usize,
}

impl FrameBuffer {
    pub fn new(max_frame_bytes: usize) -> Self {
        FrameBuffer { buf: Vec::new(), max_frame_bytes }
    }

    /// Append raw bytes (any fragmentation).
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Drain every complete line, then enforce the cap on what remains:
    /// an unterminated tail longer than the cap (or a non-UTF-8 line)
    /// is a frame error. Call after every `push` so the buffer can
    /// never hold more than one cap plus one read chunk.
    pub fn frames(&mut self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        while let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = self.buf.drain(..=pos).collect();
            let line = &line[..line.len() - 1];
            if line.len() > self.max_frame_bytes {
                bail!(
                    "proto: frame of {} bytes exceeds the {}-byte cap",
                    line.len(),
                    self.max_frame_bytes
                );
            }
            let line = std::str::from_utf8(line)
                .map_err(|_| anyhow!("proto: frame is not valid UTF-8"))?;
            out.push(line.to_string());
        }
        if self.buf.len() > self.max_frame_bytes {
            bail!(
                "proto: unterminated frame already {} bytes, cap is {}",
                self.buf.len(),
                self.max_frame_bytes
            );
        }
        Ok(out)
    }

    /// Bytes currently buffered without a terminating newline.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let wire = req.encode();
        assert!(wire.ends_with('\n'));
        assert_eq!(parse_request(wire.trim_end()).unwrap(), req, "wire: {wire:?}");
    }

    fn roundtrip_resp(resp: Response) {
        let wire = resp.encode();
        assert!(wire.ends_with('\n'));
        assert_eq!(parse_response(wire.trim_end()).unwrap(), resp, "wire: {wire:?}");
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::Hello { version: 1 });
        roundtrip_req(Request::Infer { id: 7, ttl: Some(5), bits: vec![true, false, true] });
        roundtrip_req(Request::Infer { id: 8, ttl: None, bits: vec![false; 16] });
        roundtrip_req(Request::Learn { id: 9, label: 2, bits: vec![true; 4] });
        roundtrip_req(Request::Stats { id: 10 });
        roundtrip_req(Request::Drain { id: u64::MAX });
    }

    #[test]
    fn responses_roundtrip() {
        let stats = WireStats {
            infers: 1,
            learns: 2,
            preds: 3,
            shed: 4,
            deadline: 5,
            admission: 6,
            quarantined: 7,
            frame_errors: 8,
        };
        roundtrip_resp(Response::HelloOk { version: 1 });
        roundtrip_resp(Response::Pred { id: 3, class: 2 });
        roundtrip_resp(Response::LearnOk { id: 4, seq: 17 });
        roundtrip_resp(Response::DrainOk { id: 11 });
        roundtrip_resp(Response::Stats { id: 9, stats });
        for kind in [
            ErrKind::Deadline,
            ErrKind::Admission,
            ErrKind::BadRequest,
            ErrKind::Version,
            ErrKind::Frame,
            ErrKind::Draining,
            ErrKind::Overload,
        ] {
            roundtrip_resp(Response::Err { id: Some(5), kind });
            roundtrip_resp(Response::Err { id: None, kind });
        }
        roundtrip_resp(Response::Bye { stats });
    }

    #[test]
    fn hostile_lines_are_typed_errors() {
        for bad in [
            "",
            "zap id=1",
            "infer id=1",                        // missing bits
            "infer id=1 bits=01 bits=10",        // duplicate key
            "infer id=1 bits=01 color=red",      // unknown key
            "infer id=x bits=01",                // non-numeric id
            "infer id=1 bits=012",               // non-binary bit
            "infer id=99999999999999999999999999 bits=0", // overlong integer
            "infer id= bits=01",                 // empty value
            "learn id=1 bits=01",                // missing label
            "hello",                             // missing version
        ] {
            assert!(parse_request(bad).is_err(), "parsed hostile line {bad:?}");
        }
        assert!(parse_response("ok id=1").is_err(), "missing seq");
        assert!(parse_response("err id=1 kind=sideways").is_err());
        assert!(parse_response("bye infers=1").is_err(), "truncated stats");
    }

    #[test]
    fn frame_buffer_reassembles_torn_frames() {
        let mut fb = FrameBuffer::new(64);
        let wire = Request::Infer { id: 3, ttl: None, bits: vec![true, false] }.encode();
        // One byte per push: the torn-frame worst case.
        let mut got = Vec::new();
        for b in wire.as_bytes() {
            fb.push(std::slice::from_ref(b));
            got.extend(fb.frames().unwrap());
        }
        assert_eq!(got.len(), 1);
        assert_eq!(
            parse_request(&got[0]).unwrap(),
            Request::Infer { id: 3, ttl: None, bits: vec![true, false] }
        );
        assert_eq!(fb.pending(), 0);
        // Two frames in one sliver.
        fb.push(b"stats id=1\nstats id=2\nsta");
        let two = fb.frames().unwrap();
        assert_eq!(two, vec!["stats id=1".to_string(), "stats id=2".to_string()]);
        assert_eq!(fb.pending(), 3);
    }

    #[test]
    fn frame_buffer_caps_hostile_streams() {
        // No newline at all: errors as soon as the tail passes the cap.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[b'a'; 16]);
        assert!(fb.frames().is_ok(), "at the cap is still legal");
        fb.push(b"a");
        assert!(fb.frames().is_err(), "one past the cap errors");
        // A terminated line past the cap errors too.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[b'b'; 30]);
        fb.push(b"\n");
        assert!(fb.frames().is_err());
        // Non-UTF-8 is a frame error, not a panic.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[0xFF, 0xFE, b'\n']);
        assert!(fb.frames().is_err());
    }
}
