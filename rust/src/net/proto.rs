//! The line-delimited, versioned wire protocol of the serving front
//! end.
//!
//! One frame per `\n`-terminated ASCII line, `verb key=value ...`. The
//! first frame on every connection must be a `hello`; the server
//! answers `ok hello v=N` with the *negotiated* version (or a typed
//! `err kind=version` and a close — version negotiation is explicit,
//! never silent). Requests carry a client-chosen per-connection id
//! echoed on the response, so a client can pipeline freely; the front
//! end releases `infer` responses in request order per connection
//! regardless of shard completion order.
//!
//! Two protocol versions are spoken by this build:
//!
//! - **v1** (legacy, single-model): exactly the PR 8 wire format. A v1
//!   session's frames carry no model dimension, route to the server's
//!   default model, and receive byte-identical responses to the pre-hub
//!   build — pinned by tests and by the committed session transcript in
//!   `rust/tests/proto/`.
//! - **v2** (model hub): `hello v=2 [model=NAME]` negotiates
//!   capabilities (the reply carries `caps=`) and binds the session's
//!   default model; `infer`/`learn` may carry `model=NAME` to route
//!   per-request; `stats`/`bye` gain a versioned per-model telemetry
//!   map (`tv=`/`models=`); two err kinds are added (`unknown-model`,
//!   `evicting`).
//!
//! ```text
//! -> hello v=2 model=tenant0            <- ok hello v=2 caps=models,telemetry
//! -> infer id=7 ttl=5 bits=0110...      <- pred id=7 class=2
//! -> infer id=8 model=b bits=0110...    <- pred id=8 class=0
//! -> learn id=9 label=1 bits=0011...    <- ok id=9 seq=42
//! -> stats id=10                        <- stats id=10 infers=.. tv=1 models=..
//! -> drain id=11                        <- ok drain id=11 … bye infers=.. ...
//! any rejected request                  <- err id=N kind=<reason>
//! ```
//!
//! Parsing is **paranoid by design**: [`FrameBuffer`] bounds how many
//! bytes a connection may accumulate without producing a newline, so a
//! hostile peer can never force an unbounded allocation; every line is
//! tokenized strictly (unknown verbs, unknown keys, duplicate or
//! missing fields, non-digit values, malformed model names and
//! non-ASCII bytes are all typed errors). Field *semantics* (bit-width
//! vs the served model, label range, admission, whether a named model
//! exists) are the front end's job — this module only guarantees that
//! what comes out of a parse is structurally sound and cost-bounded.

use crate::hub::model::valid_model_name;
use anyhow::{anyhow, bail, Result};

/// The newest protocol version this build speaks.
pub const PROTO_VERSION: u32 = 2;

/// The oldest version still accepted (legacy single-model sessions).
pub const PROTO_MIN_VERSION: u32 = 1;

/// Capability list advertised to v2 clients in `ok hello caps=`.
pub const PROTO_CAPS: &str = "models,telemetry";

/// Version tag of the per-model telemetry encoding (`tv=` field).
pub const TELEMETRY_VERSION: u32 = 1;

/// Number of buckets in the batch-width histogram: widths 1, 2–3, 4–7,
/// 8–15, 16–31, 32–63, 64+.
pub const WIDTH_BUCKETS: usize = 7;

/// Histogram bucket index for a flushed batch width (width ≥ 1).
pub fn width_bucket(width: usize) -> usize {
    match width {
        0..=1 => 0,
        2..=3 => 1,
        4..=7 => 2,
        8..=15 => 3,
        16..=31 => 4,
        32..=63 => 5,
        _ => 6,
    }
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Mandatory first frame: version negotiation. v2 may bind the
    /// session's default model by name.
    Hello { version: u32, model: Option<String> },
    /// Score one sample. `ttl` is a per-request deadline budget in
    /// virtual ticks (absent = the front end's default); `model` routes
    /// the request (absent = the session's default model).
    Infer { id: u64, ttl: Option<u64>, model: Option<String>, bits: Vec<bool> },
    /// One online training step against `model` (absent = default).
    Learn { id: u64, label: usize, model: Option<String>, bits: Vec<bool> },
    /// Counter snapshot.
    Stats { id: u64 },
    /// Begin graceful drain: stop accepting, flush, checkpoint, close.
    Drain { id: u64 },
}

/// Why a request was rejected — every rejection is typed and answered,
/// never a silent drop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrKind {
    /// The request's deadline budget expired before dispatch.
    Deadline,
    /// The admission controller's in-flight depth is exhausted.
    Admission,
    /// Structurally valid frame, semantically unusable (wrong bit
    /// width, label out of range, duplicate id, missing hello).
    BadRequest,
    /// Unsupported protocol version in `hello`.
    Version,
    /// Unparseable or oversized frame (connection is closed after).
    Frame,
    /// The server is draining and accepts no new work.
    Draining,
    /// Dispatched but shed by the degraded backend under overload.
    Overload,
    /// The named model is not hosted by this hub.
    UnknownModel,
    /// The target model is mid-eviction; retry after the barrier.
    Evicting,
}

impl ErrKind {
    fn as_str(self) -> &'static str {
        match self {
            ErrKind::Deadline => "deadline",
            ErrKind::Admission => "admission",
            ErrKind::BadRequest => "bad-request",
            ErrKind::Version => "version",
            ErrKind::Frame => "frame",
            ErrKind::Draining => "draining",
            ErrKind::Overload => "overload",
            ErrKind::UnknownModel => "unknown-model",
            ErrKind::Evicting => "evicting",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "deadline" => ErrKind::Deadline,
            "admission" => ErrKind::Admission,
            "bad-request" => ErrKind::BadRequest,
            "version" => ErrKind::Version,
            "frame" => ErrKind::Frame,
            "draining" => ErrKind::Draining,
            "overload" => ErrKind::Overload,
            "unknown-model" => ErrKind::UnknownModel,
            "evicting" => ErrKind::Evicting,
            other => bail!("proto: unknown err kind {other:?}"),
        })
    }
}

/// One model's row in the versioned telemetry map: lifecycle counters,
/// flush causes, the batch-width histogram and a per-shard queue-depth
/// snapshot.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ModelTelemetry {
    /// The model's wire name.
    pub model: String,
    pub evictions: u64,
    pub rehydrations: u64,
    pub full_flushes: u64,
    pub deadline_flushes: u64,
    pub final_flushes: u64,
    /// Flushed-batch width histogram (see [`width_bucket`]).
    pub width_hist: [u64; WIDTH_BUCKETS],
    /// Outstanding batches per shard at snapshot time (empty when the
    /// backend has no internal queues).
    pub queue_depths: Vec<u64>,
}

impl ModelTelemetry {
    fn encode(&self, out: &mut String) {
        use std::fmt::Write;
        let _ = write!(
            out,
            "{}:{}:{}:{}:{}:{}:",
            self.model,
            self.evictions,
            self.rehydrations,
            self.full_flushes,
            self.deadline_flushes,
            self.final_flushes
        );
        for (i, h) in self.width_hist.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{h}");
        }
        out.push(':');
        if self.queue_depths.is_empty() {
            out.push('-');
        } else {
            for (i, q) in self.queue_depths.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{q}");
            }
        }
    }

    fn parse(entry: &str) -> Result<Self> {
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() != 8 {
            bail!("proto: telemetry entry {entry:?} has {} fields, want 8", parts.len());
        }
        if !valid_model_name(parts[0]) {
            bail!("proto: bad model name {:?} in telemetry", parts[0]);
        }
        let hist: Vec<u64> = parts[6].split(',').map(parse_u64).collect::<Result<_>>()?;
        let width_hist: [u64; WIDTH_BUCKETS] = hist
            .try_into()
            .map_err(|_| anyhow!("proto: width histogram must have {WIDTH_BUCKETS} buckets"))?;
        let queue_depths = if parts[7] == "-" {
            Vec::new()
        } else {
            parts[7].split(',').map(parse_u64).collect::<Result<_>>()?
        };
        Ok(ModelTelemetry {
            model: parts[0].to_string(),
            evictions: parse_u64(parts[1])?,
            rehydrations: parse_u64(parts[2])?,
            full_flushes: parse_u64(parts[3])?,
            deadline_flushes: parse_u64(parts[4])?,
            final_flushes: parse_u64(parts[5])?,
            width_hist,
            queue_depths,
        })
    }
}

/// The counters a `stats` response and the final `bye` frame carry.
/// The eight scalar counters are the v1 surface, encoded identically
/// forever; `telemetry` is the v2 per-model map, appended as
/// `tv=<version> models=<entries>` only when non-empty — so every v1
/// frame stays byte-identical.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WireStats {
    pub infers: u64,
    pub learns: u64,
    pub preds: u64,
    pub shed: u64,
    pub deadline: u64,
    pub admission: u64,
    pub quarantined: u64,
    pub frame_errors: u64,
    /// Per-model telemetry rows (v2 sessions; empty on v1).
    pub telemetry: Vec<ModelTelemetry>,
}

impl WireStats {
    fn encode_fields(&self, out: &mut String) {
        use std::fmt::Write;
        let _ = write!(
            out,
            "infers={} learns={} preds={} shed={} deadline={} admission={} quarantined={} \
             frame_errors={}",
            self.infers,
            self.learns,
            self.preds,
            self.shed,
            self.deadline,
            self.admission,
            self.quarantined,
            self.frame_errors
        );
        if !self.telemetry.is_empty() {
            let _ = write!(out, " tv={TELEMETRY_VERSION} models=");
            for (i, row) in self.telemetry.iter().enumerate() {
                if i > 0 {
                    out.push(';');
                }
                row.encode(out);
            }
        }
    }
}

/// A server response frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// Negotiated version; `caps` is present from v2 on.
    HelloOk { version: u32, caps: Option<String> },
    Pred { id: u64, class: usize },
    LearnOk { id: u64, seq: u64 },
    DrainOk { id: u64 },
    Stats { id: u64, stats: WireStats },
    Err { id: Option<u64>, kind: ErrKind },
    /// The final frame of a graceful drain, after which the connection
    /// closes.
    Bye { stats: WireStats },
}

impl Request {
    /// Wire form, newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = match self {
            Request::Hello { version, model } => {
                let mut s = format!("hello v={version}");
                if let Some(m) = model {
                    s.push_str(&format!(" model={m}"));
                }
                s
            }
            Request::Infer { id, ttl, model, bits } => {
                let mut s = format!("infer id={id}");
                if let Some(m) = model {
                    s.push_str(&format!(" model={m}"));
                }
                if let Some(t) = ttl {
                    s.push_str(&format!(" ttl={t}"));
                }
                s.push_str(" bits=");
                push_bits(&mut s, bits);
                s
            }
            Request::Learn { id, label, model, bits } => {
                let mut s = format!("learn id={id}");
                if let Some(m) = model {
                    s.push_str(&format!(" model={m}"));
                }
                s.push_str(&format!(" label={label} bits="));
                push_bits(&mut s, bits);
                s
            }
            Request::Stats { id } => format!("stats id={id}"),
            Request::Drain { id } => format!("drain id={id}"),
        };
        s.push('\n');
        s
    }
}

impl Response {
    /// Wire form, newline-terminated.
    pub fn encode(&self) -> String {
        let mut s = match self {
            Response::HelloOk { version, caps } => {
                let mut s = format!("ok hello v={version}");
                if let Some(c) = caps {
                    s.push_str(&format!(" caps={c}"));
                }
                s
            }
            Response::Pred { id, class } => format!("pred id={id} class={class}"),
            Response::LearnOk { id, seq } => format!("ok id={id} seq={seq}"),
            Response::DrainOk { id } => format!("ok drain id={id}"),
            Response::Stats { id, stats } => {
                let mut s = format!("stats id={id} ");
                stats.encode_fields(&mut s);
                s
            }
            Response::Err { id, kind } => match id {
                Some(id) => format!("err id={id} kind={}", kind.as_str()),
                None => format!("err kind={}", kind.as_str()),
            },
            Response::Bye { stats } => {
                let mut s = "bye ".to_string();
                stats.encode_fields(&mut s);
                s
            }
        };
        s.push('\n');
        s
    }
}

fn push_bits(s: &mut String, bits: &[bool]) {
    s.reserve(bits.len());
    for &b in bits {
        s.push(if b { '1' } else { '0' });
    }
}

/// Strict key=value field collector: every key consumed at most once,
/// unknown keys rejected, leftovers rejected.
struct Fields<'a> {
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> Fields<'a> {
    fn parse(tokens: std::str::SplitAsciiWhitespace<'a>) -> Result<Self> {
        let mut pairs = Vec::new();
        for tok in tokens {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| anyhow!("proto: token {tok:?} is not key=value"))?;
            if v.is_empty() {
                bail!("proto: empty value for key {k:?}");
            }
            if pairs.iter().any(|&(pk, _)| pk == k) {
                bail!("proto: duplicate key {k:?}");
            }
            pairs.push((k, v));
        }
        Ok(Fields { pairs })
    }

    fn take(&mut self, key: &str) -> Option<&'a str> {
        let i = self.pairs.iter().position(|&(k, _)| k == key)?;
        Some(self.pairs.remove(i).1)
    }

    fn want(&mut self, key: &str) -> Result<&'a str> {
        self.take(key).ok_or_else(|| anyhow!("proto: missing key {key:?}"))
    }

    fn finish(self) -> Result<()> {
        if let Some((k, _)) = self.pairs.first() {
            bail!("proto: unknown key {k:?}");
        }
        Ok(())
    }
}

fn parse_u64(v: &str) -> Result<u64> {
    if v.len() > 20 || !v.bytes().all(|b| b.is_ascii_digit()) {
        bail!("proto: {v:?} is not an unsigned integer");
    }
    v.parse::<u64>().map_err(|_| anyhow!("proto: integer {v:?} out of range"))
}

fn parse_bits(v: &str) -> Result<Vec<bool>> {
    v.bytes()
        .map(|b| match b {
            b'0' => Ok(false),
            b'1' => Ok(true),
            _ => bail!("proto: bits must be 0/1, got byte {b:#04x}"),
        })
        .collect()
}

/// A `model=` value: the hub's name grammar, enforced at parse time so
/// a malformed name is a frame error, not a routing miss.
fn parse_model(v: &str) -> Result<String> {
    if !valid_model_name(v) {
        bail!("proto: bad model name {v:?} (want 1..=32 of [A-Za-z0-9_-])");
    }
    Ok(v.to_string())
}

/// Parse one request line (no trailing newline). Errors are frame-level
/// (`err kind=frame` territory): the caller decides whether to answer
/// or hang up, but a failed parse never partially applies.
pub fn parse_request(line: &str) -> Result<Request> {
    let mut tokens = line.split_ascii_whitespace();
    let verb = tokens.next().ok_or_else(|| anyhow!("proto: empty frame"))?;
    let mut f = Fields::parse(tokens)?;
    let req = match verb {
        "hello" => {
            let version = parse_u64(f.want("v")?)? as u32;
            let model = f.take("model").map(parse_model).transpose()?;
            if model.is_some() && version < 2 {
                bail!("proto: hello model= requires v>=2, got v={version}");
            }
            Request::Hello { version, model }
        }
        "infer" => Request::Infer {
            id: parse_u64(f.want("id")?)?,
            ttl: f.take("ttl").map(parse_u64).transpose()?,
            model: f.take("model").map(parse_model).transpose()?,
            bits: parse_bits(f.want("bits")?)?,
        },
        "learn" => Request::Learn {
            id: parse_u64(f.want("id")?)?,
            label: parse_u64(f.want("label")?)? as usize,
            model: f.take("model").map(parse_model).transpose()?,
            bits: parse_bits(f.want("bits")?)?,
        },
        "stats" => Request::Stats { id: parse_u64(f.want("id")?)? },
        "drain" => Request::Drain { id: parse_u64(f.want("id")?)? },
        other => bail!("proto: unknown verb {other:?}"),
    };
    f.finish()?;
    Ok(req)
}

/// Parse one response line (no trailing newline) — the client half,
/// used by the loopback drill and the tests.
pub fn parse_response(line: &str) -> Result<Response> {
    let mut tokens = line.split_ascii_whitespace();
    let verb = tokens.next().ok_or_else(|| anyhow!("proto: empty frame"))?;
    let sub = match verb {
        "ok" => {
            let mut peek = tokens.clone();
            match peek.next() {
                Some("hello") => {
                    tokens.next();
                    Some("hello")
                }
                Some("drain") => {
                    tokens.next();
                    Some("drain")
                }
                _ => None,
            }
        }
        _ => None,
    };
    let mut f = Fields::parse(tokens)?;
    let parse_stats = |f: &mut Fields| -> Result<WireStats> {
        let mut stats = WireStats {
            infers: parse_u64(f.want("infers")?)?,
            learns: parse_u64(f.want("learns")?)?,
            preds: parse_u64(f.want("preds")?)?,
            shed: parse_u64(f.want("shed")?)?,
            deadline: parse_u64(f.want("deadline")?)?,
            admission: parse_u64(f.want("admission")?)?,
            quarantined: parse_u64(f.want("quarantined")?)?,
            frame_errors: parse_u64(f.want("frame_errors")?)?,
            telemetry: Vec::new(),
        };
        if let Some(tv) = f.take("tv") {
            let tv = parse_u64(tv)? as u32;
            if tv != TELEMETRY_VERSION {
                bail!("proto: telemetry version {tv} unsupported (want {TELEMETRY_VERSION})");
            }
            stats.telemetry = f
                .want("models")?
                .split(';')
                .map(ModelTelemetry::parse)
                .collect::<Result<_>>()?;
        }
        Ok(stats)
    };
    let resp = match (verb, sub) {
        ("ok", Some("hello")) => Response::HelloOk {
            version: parse_u64(f.want("v")?)? as u32,
            caps: f.take("caps").map(str::to_string),
        },
        ("ok", Some("drain")) => Response::DrainOk { id: parse_u64(f.want("id")?)? },
        ("ok", None) => Response::LearnOk {
            id: parse_u64(f.want("id")?)?,
            seq: parse_u64(f.want("seq")?)?,
        },
        ("pred", _) => Response::Pred {
            id: parse_u64(f.want("id")?)?,
            class: parse_u64(f.want("class")?)? as usize,
        },
        ("stats", _) => {
            Response::Stats { id: parse_u64(f.want("id")?)?, stats: parse_stats(&mut f)? }
        }
        ("err", _) => Response::Err {
            id: f.take("id").map(parse_u64).transpose()?,
            kind: ErrKind::parse(f.want("kind")?)?,
        },
        ("bye", _) => Response::Bye { stats: parse_stats(&mut f)? },
        (other, _) => bail!("proto: unknown verb {other:?}"),
    };
    f.finish()?;
    Ok(resp)
}

/// Reassembles newline-delimited frames from arbitrarily torn byte
/// slivers, under a hard per-line byte cap: the moment the unterminated
/// tail exceeds `max_frame_bytes`, the buffer errors — a hostile peer
/// streaming garbage without newlines costs at most one cap's worth of
/// memory, never an unbounded allocation.
#[derive(Debug)]
pub struct FrameBuffer {
    buf: Vec<u8>,
    max_frame_bytes: usize,
}

impl FrameBuffer {
    pub fn new(max_frame_bytes: usize) -> Self {
        FrameBuffer { buf: Vec::new(), max_frame_bytes }
    }

    /// Append raw bytes (any fragmentation).
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Drain every complete line, then enforce the cap on what remains:
    /// an unterminated tail longer than the cap (or a non-UTF-8 line)
    /// is a frame error. Call after every `push` so the buffer can
    /// never hold more than one cap plus one read chunk.
    pub fn frames(&mut self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        while let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = self.buf.drain(..=pos).collect();
            let line = &line[..line.len() - 1];
            if line.len() > self.max_frame_bytes {
                bail!(
                    "proto: frame of {} bytes exceeds the {}-byte cap",
                    line.len(),
                    self.max_frame_bytes
                );
            }
            let line = std::str::from_utf8(line)
                .map_err(|_| anyhow!("proto: frame is not valid UTF-8"))?;
            out.push(line.to_string());
        }
        if self.buf.len() > self.max_frame_bytes {
            bail!(
                "proto: unterminated frame already {} bytes, cap is {}",
                self.buf.len(),
                self.max_frame_bytes
            );
        }
        Ok(out)
    }

    /// Bytes currently buffered without a terminating newline.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let wire = req.encode();
        assert!(wire.ends_with('\n'));
        assert_eq!(parse_request(wire.trim_end()).unwrap(), req, "wire: {wire:?}");
    }

    fn roundtrip_resp(resp: Response) {
        let wire = resp.encode();
        assert!(wire.ends_with('\n'));
        assert_eq!(parse_response(wire.trim_end()).unwrap(), resp, "wire: {wire:?}");
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::Hello { version: 1, model: None });
        roundtrip_req(Request::Hello { version: 2, model: Some("tenant-0".into()) });
        roundtrip_req(Request::Infer {
            id: 7,
            ttl: Some(5),
            model: None,
            bits: vec![true, false, true],
        });
        roundtrip_req(Request::Infer {
            id: 8,
            ttl: None,
            model: Some("b".into()),
            bits: vec![false; 16],
        });
        roundtrip_req(Request::Learn {
            id: 9,
            label: 2,
            model: Some("tenant_1".into()),
            bits: vec![true; 4],
        });
        roundtrip_req(Request::Stats { id: 10 });
        roundtrip_req(Request::Drain { id: u64::MAX });
    }

    /// The v1 byte-forms are frozen: every model-less frame encodes to
    /// exactly the pre-hub wire bytes, and the pre-hub lines parse to
    /// the model-less requests. This is the compatibility contract the
    /// committed session transcript replays end to end.
    #[test]
    fn v1_wire_forms_are_byte_identical() {
        assert_eq!(Request::Hello { version: 1, model: None }.encode(), "hello v=1\n");
        assert_eq!(
            Request::Infer { id: 7, ttl: Some(5), model: None, bits: vec![true, false] }.encode(),
            "infer id=7 ttl=5 bits=10\n"
        );
        assert_eq!(
            Request::Learn { id: 8, label: 1, model: None, bits: vec![false, true] }.encode(),
            "learn id=8 label=1 bits=01\n"
        );
        assert_eq!(Response::HelloOk { version: 1, caps: None }.encode(), "ok hello v=1\n");
        assert_eq!(
            parse_request("infer id=7 ttl=5 bits=10").unwrap(),
            Request::Infer { id: 7, ttl: Some(5), model: None, bits: vec![true, false] }
        );
        let legacy_stats = WireStats {
            infers: 1,
            learns: 2,
            preds: 3,
            shed: 4,
            deadline: 5,
            admission: 6,
            quarantined: 7,
            frame_errors: 8,
            telemetry: Vec::new(),
        };
        assert_eq!(
            Response::Stats { id: 9, stats: legacy_stats }.encode(),
            "stats id=9 infers=1 learns=2 preds=3 shed=4 deadline=5 admission=6 quarantined=7 \
             frame_errors=8\n"
        );
    }

    #[test]
    fn responses_roundtrip() {
        let stats = WireStats {
            infers: 1,
            learns: 2,
            preds: 3,
            shed: 4,
            deadline: 5,
            admission: 6,
            quarantined: 7,
            frame_errors: 8,
            telemetry: Vec::new(),
        };
        roundtrip_resp(Response::HelloOk { version: 1, caps: None });
        roundtrip_resp(Response::HelloOk { version: 2, caps: Some(PROTO_CAPS.to_string()) });
        roundtrip_resp(Response::Pred { id: 3, class: 2 });
        roundtrip_resp(Response::LearnOk { id: 4, seq: 17 });
        roundtrip_resp(Response::DrainOk { id: 11 });
        roundtrip_resp(Response::Stats { id: 9, stats: stats.clone() });
        for kind in [
            ErrKind::Deadline,
            ErrKind::Admission,
            ErrKind::BadRequest,
            ErrKind::Version,
            ErrKind::Frame,
            ErrKind::Draining,
            ErrKind::Overload,
            ErrKind::UnknownModel,
            ErrKind::Evicting,
        ] {
            roundtrip_resp(Response::Err { id: Some(5), kind });
            roundtrip_resp(Response::Err { id: None, kind });
        }
        roundtrip_resp(Response::Bye { stats });
    }

    #[test]
    fn telemetry_roundtrips_and_is_versioned() {
        let stats = WireStats {
            infers: 40,
            learns: 12,
            preds: 38,
            shed: 2,
            deadline: 1,
            admission: 0,
            quarantined: 3,
            frame_errors: 0,
            telemetry: vec![
                ModelTelemetry {
                    model: "tenant-0".into(),
                    evictions: 2,
                    rehydrations: 2,
                    full_flushes: 5,
                    deadline_flushes: 3,
                    final_flushes: 1,
                    width_hist: [4, 3, 2, 0, 0, 0, 0],
                    queue_depths: vec![1, 0, 2],
                },
                ModelTelemetry {
                    model: "b".into(),
                    width_hist: [0; WIDTH_BUCKETS],
                    ..Default::default()
                },
            ],
        };
        let wire = Response::Bye { stats: stats.clone() }.encode();
        assert!(wire.contains(" tv=1 models="), "telemetry must carry its version: {wire:?}");
        assert!(wire.contains("tenant-0:2:2:5:3:1:4,3,2,0,0,0,0:1,0,2"), "wire: {wire:?}");
        assert!(wire.contains(";b:0:0:0:0:0:0,0,0,0,0,0,0:-"), "empty depths encode -: {wire:?}");
        assert_eq!(parse_response(wire.trim_end()).unwrap(), Response::Bye { stats });
        // A future telemetry version is a typed parse error, not a
        // silent misread.
        let bumped = wire.replace(" tv=1 ", " tv=9 ");
        assert!(parse_response(bumped.trim_end()).is_err());
    }

    #[test]
    fn width_buckets_partition_the_lane() {
        assert_eq!(width_bucket(1), 0);
        assert_eq!(width_bucket(2), 1);
        assert_eq!(width_bucket(3), 1);
        assert_eq!(width_bucket(4), 2);
        assert_eq!(width_bucket(15), 3);
        assert_eq!(width_bucket(16), 4);
        assert_eq!(width_bucket(63), 5);
        assert_eq!(width_bucket(64), 6);
        assert_eq!(width_bucket(1000), 6);
    }

    #[test]
    fn hostile_lines_are_typed_errors() {
        for bad in [
            "",
            "zap id=1",
            "infer id=1",                        // missing bits
            "infer id=1 bits=01 bits=10",        // duplicate key
            "infer id=1 bits=01 color=red",      // unknown key
            "infer id=x bits=01",                // non-numeric id
            "infer id=1 bits=012",               // non-binary bit
            "infer id=99999999999999999999999999 bits=0", // overlong integer
            "infer id= bits=01",                 // empty value
            "learn id=1 bits=01",                // missing label
            "hello",                             // missing version
            "hello v=1 model=a",                 // model binding needs v2
            "infer id=1 model=a/b bits=01",      // model name grammar
            "infer id=1 model=way-too-long-a-name-for-any-model-here bits=01",
        ] {
            assert!(parse_request(bad).is_err(), "parsed hostile line {bad:?}");
        }
        assert!(parse_response("ok id=1").is_err(), "missing seq");
        assert!(parse_response("err id=1 kind=sideways").is_err());
        assert!(parse_response("bye infers=1").is_err(), "truncated stats");
        // tv without models, and a malformed telemetry entry.
        assert!(parse_response(
            "bye infers=0 learns=0 preds=0 shed=0 deadline=0 admission=0 quarantined=0 \
             frame_errors=0 tv=1"
        )
        .is_err());
        assert!(parse_response(
            "bye infers=0 learns=0 preds=0 shed=0 deadline=0 admission=0 quarantined=0 \
             frame_errors=0 tv=1 models=a:1:2"
        )
        .is_err());
    }

    #[test]
    fn frame_buffer_reassembles_torn_frames() {
        let mut fb = FrameBuffer::new(64);
        let wire =
            Request::Infer { id: 3, ttl: None, model: None, bits: vec![true, false] }.encode();
        // One byte per push: the torn-frame worst case.
        let mut got = Vec::new();
        for b in wire.as_bytes() {
            fb.push(std::slice::from_ref(b));
            got.extend(fb.frames().unwrap());
        }
        assert_eq!(got.len(), 1);
        assert_eq!(
            parse_request(&got[0]).unwrap(),
            Request::Infer { id: 3, ttl: None, model: None, bits: vec![true, false] }
        );
        assert_eq!(fb.pending(), 0);
        // Two frames in one sliver.
        fb.push(b"stats id=1\nstats id=2\nsta");
        let two = fb.frames().unwrap();
        assert_eq!(two, vec!["stats id=1".to_string(), "stats id=2".to_string()]);
        assert_eq!(fb.pending(), 3);
    }

    #[test]
    fn frame_buffer_caps_hostile_streams() {
        // No newline at all: errors as soon as the tail passes the cap.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[b'a'; 16]);
        assert!(fb.frames().is_ok(), "at the cap is still legal");
        fb.push(b"a");
        assert!(fb.frames().is_err(), "one past the cap errors");
        // A terminated line past the cap errors too.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[b'b'; 30]);
        fb.push(b"\n");
        assert!(fb.frames().is_err());
        // Non-UTF-8 is a frame error, not a panic.
        let mut fb = FrameBuffer::new(16);
        fb.push(&[0xFF, 0xFE, b'\n']);
        assert!(fb.frames().is_err());
    }
}
