//! Corpus growth: seeded randomized schedule generation plus a
//! delta-debugging minimizer (ROADMAP item 5, part 3).
//!
//! [`random_schedule`] draws adversarial but *valid* schedules — the
//! step-kind mix leans on training (where bit-identity is hardest) and
//! sprinkles fault/force/clone/checkpoint/serve/net/param churn between
//! steps. [`grow`] replays a seeded batch of them; any divergence is
//! handed to [`shrink_failure`], which first truncates the schedule at
//! the failing step (the replayer reports where it stopped) and then
//! runs [`minimize`] — classic ddmin chunk removal followed by per-step
//! payload halving — until the reproducer is minimal. `tmfpga verify
//! --grow` writes each minimized reproducer as a committed-style fixture
//! and exits nonzero, so CI turns every discovered divergence into a
//! permanent regression test.
//!
//! Everything here is seeded — no wall-clock, no global randomness — so
//! a CI failure replays exactly on a laptop.

use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::Xoshiro256;
use crate::verify::corpus::{replay, replay_opts, Divergence, ReplayOptions, Schedule, Step};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Draw a `len`-step schedule over `shape`. The mix is roughly half
/// training; every payload and seed comes from `seed`, so the same
/// arguments always yield the same schedule.
pub fn random_schedule(shape: &TmShape, seed: u64, len: usize) -> Schedule {
    let mut rng = Xoshiro256::new(seed ^ 0x5C8E_D01E);
    let mut s = Schedule::new(shape, seed);
    s.params = TmParams::paper_offline(shape);
    for _ in 0..len {
        let roll = rng.next_f32();
        let step = if roll < 0.45 {
            Step::Train { rows: 1 + rng.next_below(48) as u32, seed: rng.next_u64() }
        } else if roll < 0.60 {
            Step::Infer { rows: 1 + rng.next_below(64) as u32, seed: rng.next_u64() }
        } else if roll < 0.70 {
            Step::Rescore { seed: rng.next_u64() }
        } else if roll < 0.78 {
            Step::Force {
                class: rng.next_below(shape.classes) as u32,
                clause: rng.next_below(shape.max_clauses) as u32,
                code: [-1, 0, 1][rng.next_below(3)],
            }
        } else if roll < 0.84 {
            Step::Fault {
                bp: [0, 500, 1000, 2000][rng.next_below(4)],
                kind: rng.next_below(3) as u8,
                seed: rng.next_u64(),
            }
        } else if roll < 0.88 {
            Step::Serve { updates: 1 + rng.next_below(20) as u32, seed: rng.next_u64() }
        } else if roll < 0.90 {
            Step::Net {
                clients: (2 + rng.next_below(3)) as u32,
                requests: (2 + rng.next_below(6)) as u32,
                seed: rng.next_u64(),
            }
        } else if roll < 0.94 {
            Step::Clone
        } else if roll < 0.98 {
            Step::Checkpoint
        } else {
            let half = (shape.max_clauses / 2).max(1);
            Step::Params {
                t: [1, 5, 15][rng.next_below(3)],
                s_bits: [1.0f32, 1.375, 2.0][rng.next_below(3)].to_bits(),
                active_clauses: (2 * (1 + rng.next_below(half))) as u32,
                active_classes: (1 + rng.next_below(shape.classes)) as u32,
            }
        };
        s.steps.push(step);
    }
    s
}

/// Delta-debugging minimization: remove ever-smaller chunks of the step
/// list while `fails` keeps returning true, then halve the payloads
/// (train/infer/serve/net row counts) of the surviving steps. Returns the
/// smallest failing schedule found; `fails(&result)` is guaranteed true.
pub fn minimize(s: &Schedule, fails: &mut dyn FnMut(&Schedule) -> bool) -> Schedule {
    let mut best = s.clone();
    // ddmin over the step list: try dropping chunks at granularity n,
    // doubling n when nothing can be dropped, until single-step removal
    // is exhausted.
    let mut n = 2usize;
    while best.steps.len() >= 2 {
        let chunk = best.steps.len().div_ceil(n);
        let mut reduced = false;
        let mut start = 0usize;
        while start < best.steps.len() {
            let end = (start + chunk).min(best.steps.len());
            let mut cand = best.clone();
            cand.steps.drain(start..end);
            if !cand.steps.is_empty() && fails(&cand) {
                best = cand;
                n = n.saturating_sub(1).max(2);
                reduced = true;
                break;
            }
            start = end;
        }
        if !reduced {
            if chunk == 1 {
                break;
            }
            n = (n * 2).min(best.steps.len());
        }
    }
    // Payload shrink: repeatedly halve row/update counts while the
    // schedule still fails.
    loop {
        let mut moved = false;
        for idx in 0..best.steps.len() {
            while let Some(smaller) = halve_payload(&best.steps[idx]) {
                let mut cand = best.clone();
                cand.steps[idx] = smaller;
                if fails(&cand) {
                    best = cand;
                    moved = true;
                } else {
                    break;
                }
            }
        }
        if !moved {
            break;
        }
    }
    best
}

/// One halving of a step's payload, if it has one above 1.
fn halve_payload(step: &Step) -> Option<Step> {
    match *step {
        Step::Train { rows, seed } if rows > 1 => Some(Step::Train { rows: rows / 2, seed }),
        Step::Infer { rows, seed } if rows > 1 => Some(Step::Infer { rows: rows / 2, seed }),
        Step::Serve { updates, seed } if updates > 1 => {
            Some(Step::Serve { updates: updates / 2, seed })
        }
        Step::Net { clients, requests, seed } if requests > 1 => {
            Some(Step::Net { clients, requests: requests / 2, seed })
        }
        Step::Net { clients, requests, seed } if clients > 1 => {
            Some(Step::Net { clients: clients / 2, requests, seed })
        }
        _ => None,
    }
}

/// Shrink a failing schedule to a minimal reproducer under `opts`:
/// truncate at the reported divergence step, then [`minimize`]. Returns
/// `None` if `s` does not actually fail.
pub fn shrink_failure(s: &Schedule, opts: &ReplayOptions) -> Option<Schedule> {
    let d = replay_opts(s, opts).err()?;
    let mut fails = |cand: &Schedule| replay_opts(cand, opts).is_err();
    let mut seed_sched = s.clone();
    // The replayer stops at the first divergence, so everything after
    // that step is dead weight — drop it before ddmin even starts.
    seed_sched.steps.truncate((d.step + 1).min(seed_sched.steps.len()));
    if !fails(&seed_sched) {
        seed_sched = s.clone();
    }
    Some(minimize(&seed_sched, &mut fails))
}

/// One discovered divergence: the minimized schedule and what it trips.
#[derive(Debug, Clone)]
pub struct Reproducer {
    pub schedule: Schedule,
    pub divergence: Divergence,
    /// Index of the generated schedule that exposed it.
    pub found_at: usize,
}

/// Outcome of one bounded growth run.
#[derive(Debug, Clone, Default)]
pub struct GrowOutcome {
    /// Schedules generated and replayed.
    pub schedules: usize,
    /// Steps replayed across all clean schedules.
    pub clean_steps: usize,
    /// Minimized reproducers for every divergence found.
    pub found: Vec<Reproducer>,
}

/// Generate and replay `schedules` seeded random schedules of
/// `steps_per` steps over `shape`; shrink every divergence to a minimal
/// reproducer. Deterministic in `(shape, base_seed, schedules,
/// steps_per)`.
pub fn grow(shape: &TmShape, base_seed: u64, schedules: usize, steps_per: usize) -> GrowOutcome {
    let mut out = GrowOutcome { schedules, ..GrowOutcome::default() };
    for i in 0..schedules {
        let s = random_schedule(shape, base_seed.wrapping_add(i as u64), steps_per);
        match replay(&s) {
            Ok(rep) => out.clean_steps += rep.steps,
            Err(_) => {
                if let Some(min) = shrink_failure(&s, &ReplayOptions::default()) {
                    // Re-replay the minimized schedule for its divergence
                    // message; minimize() guarantees it still fails.
                    if let Err(divergence) = replay(&min) {
                        out.found.push(Reproducer { schedule: min, divergence, found_at: i });
                    }
                }
            }
        }
    }
    out
}

/// Write a schedule as a corpus fixture `<dir>/<name>.ron`, creating the
/// directory if needed. Returns the path written.
pub fn write_fixture(dir: &Path, name: &str, s: &Schedule) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating corpus dir {}", dir.display()))?;
    let path = dir.join(format!("{name}.ron"));
    std::fs::write(&path, s.to_text())
        .with_context(|| format!("writing fixture {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_schedules_are_deterministic_and_valid() {
        let shape = TmShape::iris();
        let a = random_schedule(&shape, 42, 60);
        let b = random_schedule(&shape, 42, 60);
        assert_eq!(a, b);
        let c = random_schedule(&shape, 43, 60);
        assert_ne!(a.steps, c.steps);
        // Generated schedules serialize to parseable fixtures (clean
        // replay is asserted by the corpus tests and tier-1 suite).
        let back = Schedule::parse(&a.to_text()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn minimize_keeps_only_what_fails() {
        // Synthetic failure predicate: "fails" iff the schedule still
        // contains a Force step AND a later Train step — the minimizer
        // must cut 40 steps down to exactly those two.
        let shape = TmShape::iris();
        let mut s = random_schedule(&shape, 9, 40);
        s.steps.retain(|st| !matches!(st, Step::Force { .. }));
        s.steps.insert(7, Step::Force { class: 0, clause: 0, code: 1 });
        let mut fails = |cand: &Schedule| {
            let force = cand.steps.iter().position(|st| matches!(st, Step::Force { .. }));
            let train = cand.steps.iter().rposition(|st| matches!(st, Step::Train { .. }));
            matches!((force, train), (Some(f), Some(t)) if f < t)
        };
        assert!(fails(&s), "seed schedule must fail the predicate");
        let min = minimize(&s, &mut fails);
        assert!(fails(&min));
        assert_eq!(min.steps.len(), 2, "got {:?}", min.steps);
        assert!(matches!(min.steps[0], Step::Force { .. }));
        assert!(matches!(min.steps[1], Step::Train { rows: 1, .. }));
    }

    #[test]
    fn shrink_failure_returns_none_on_clean_schedule() {
        let shape = TmShape::iris();
        let s = random_schedule(&shape, 5, 10);
        assert!(shrink_failure(&s, &ReplayOptions::default()).is_none());
    }
}
