//! Machine-level invariant contracts (ROADMAP item 5).
//!
//! Every engine in this repo — scalar oracle, word-parallel eager, lazy,
//! lane-speculative, plane inference, rescore cache, serve replicas —
//! mutates or reads the same [`MultiTm`] representation. The invariants
//! they all rely on are written down **once** here and audited by
//! [`check_invariants`]:
//!
//! 1. **TA states in range** — exactly `num_tas()` states, each within
//!    `0..=max_state()` (the repo's saturating-counter convention; see
//!    `tm::automaton`).
//! 2. **Action-cache coherence** — every packed action bit equals
//!    `state >= include_threshold()` for its TA, so the word engines and
//!    the scalar oracle can never disagree about an include.
//! 3. **Tail bits clear** — action words and both fault gate planes carry
//!    no bits beyond the literal width ([`word_mask`]); padding must
//!    never leak into a clause AND.
//! 4. **Fault-gate consistency** — the OR plane is a subset of the AND
//!    plane (`FaultMap::set` never writes `(and=0, or=1)`), and the O(1)
//!    faulty counter matches a recount from the gate words.
//! 5. **Clause-force gates** — force codes are `{-1, 0, 1}` and
//!    `clause_fault_count()` equals the number of non-clear codes.
//! 6. **Mutation-clock monotonicity** — the master revision counter is
//!    never behind the global stamp or any per-row stamp (the rescore
//!    cache's incremental-rebuild correctness hangs on this ordering).
//! 7. **Clone/restore uid freshness** — the machine uid is nonzero
//!    (allocator starts at 1; uid 0 would alias "no machine" in caches).
//! 8. **Scratch geometry** — the evaluation scratch holds one clause
//!    output per clause row and one sum per class.
//!
//! Vote-total and fingerprint *stability* (evaluation must not move the
//! state digest) are schedule-level properties and are asserted by the
//! corpus replayer (`crate::verify::corpus`) around every inference step.
//!
//! The `contracts` cargo feature wires these checks into the mutation hot
//! paths — `apply_word_feedback` and the scalar TA transitions (localized
//! O(1)/O(word) checks), `apply_update`, checkpoint restore, rebuild and
//! clone (full audits). Without the feature the hooks below compile to
//! empty inline functions: the release path pays nothing.

use crate::tm::machine::MultiTm;
use crate::tm::params::word_mask;

/// Audit every structural invariant of `tm`. Returns the first violation
/// rendered for humans, or `Ok(())` if the machine is internally
/// consistent. Always compiled (the corpus replayer and tests call it
/// directly); only the *hooks* are feature-gated.
pub fn check_invariants(tm: &MultiTm) -> Result<(), String> {
    let s = tm.shape();
    if let Err(e) = s.validate() {
        return Err(format!("shape invalid: {e:#}"));
    }
    let rows = s.classes * s.max_clauses;
    let words = s.words();

    // 1. TA state vector geometry + range.
    let states = tm.ta().states();
    if states.len() != s.num_tas() {
        return Err(format!(
            "TA block holds {} states, shape wants {}",
            states.len(),
            s.num_tas()
        ));
    }
    let max = s.max_state();
    for (i, &st) in states.iter().enumerate() {
        if st > max {
            return Err(format!("TA {i} state {st} escapes 0..={max}"));
        }
    }

    // 2 + 3 (action side). Per-word coherence and tail bits.
    if tm.actions.len() != rows * words {
        return Err(format!(
            "action cache holds {} words, want {}",
            tm.actions.len(),
            rows * words
        ));
    }
    for c in 0..s.classes {
        for j in 0..s.max_clauses {
            for w in 0..words {
                check_word(tm, c, j, w)?;
            }
        }
    }

    // 3 (gate side) + 4. Fault planes within width, OR ⊆ AND, counter
    // exact.
    let (and_words, or_words) = tm.fault().words();
    if and_words.len() != rows * words || or_words.len() != rows * words {
        return Err(format!(
            "fault planes hold {}/{} words, want {}",
            and_words.len(),
            or_words.len(),
            rows * words
        ));
    }
    for row in 0..rows {
        for w in 0..words {
            let i = row * words + w;
            let width = word_mask(s.literals(), w);
            let (a, o) = (and_words[i], or_words[i]);
            if a & !width != 0 || o & !width != 0 {
                return Err(format!(
                    "fault gate bits escape the literal width at row {row} word {w}"
                ));
            }
            if o & !a != 0 {
                return Err(format!(
                    "unreachable (and=0, or=1) fault encoding at row {row} word {w}"
                ));
            }
        }
    }
    if tm.fault().count() != tm.fault().recount() {
        return Err(format!(
            "fault counter {} disagrees with recount {}",
            tm.fault().count(),
            tm.fault().recount()
        ));
    }

    // 5. Clause-force gate codes and their counter.
    let codes = tm.clause_force_codes();
    if codes.len() != rows {
        return Err(format!("clause force table holds {} codes, want {rows}", codes.len()));
    }
    let mut forced = 0usize;
    for (row, &code) in codes.iter().enumerate() {
        match code {
            -1 | 0 | 1 => {}
            other => return Err(format!("clause force code {other} at row {row}")),
        }
        if code >= 0 {
            forced += 1;
        }
    }
    if forced != tm.clause_fault_count() {
        return Err(format!(
            "clause fault counter {} disagrees with {forced} programmed gates",
            tm.clause_fault_count()
        ));
    }

    // 6. Mutation-clock ordering.
    let (rev, clause_rev, global_rev) = tm.rev_counters();
    if global_rev > rev {
        return Err(format!("global revision {global_rev} runs ahead of master {rev}"));
    }
    if clause_rev.len() != rows {
        return Err(format!("clause clock holds {} stamps, want {rows}", clause_rev.len()));
    }
    for (row, &cr) in clause_rev.iter().enumerate() {
        if cr > rev {
            return Err(format!("row {row} revision {cr} runs ahead of master {rev}"));
        }
    }

    // 7. Uid freshness.
    if tm.uid() == 0 {
        return Err("machine uid is 0 (allocator starts at 1)".into());
    }

    // 8. Scratch geometry.
    if tm.clause_out.len() != rows {
        return Err(format!(
            "clause-output scratch holds {} slots, want {rows}",
            tm.clause_out.len()
        ));
    }
    if tm.sums.len() != s.classes {
        return Err(format!(
            "vote scratch holds {} slots, want {}",
            tm.sums.len(),
            s.classes
        ));
    }
    Ok(())
}

/// Localized coherence check for one packed action word: tail bits clear
/// and every bit equal to its TA's include decision. O(64) — cheap enough
/// to run after every `apply_word_feedback` under the `contracts`
/// feature.
pub fn check_word(tm: &MultiTm, class: usize, clause: usize, word: usize) -> Result<(), String> {
    let s = tm.shape();
    let lits = s.literals();
    let mask = word_mask(lits, word);
    let got = tm.action_words(class, clause)[word];
    if got & !mask != 0 {
        return Err(format!(
            "action word ({class},{clause},{word}) has tail bits set: {got:#018x} outside {mask:#018x}"
        ));
    }
    let mut want = 0u64;
    for k in 0..64 {
        let lit = word * 64 + k;
        if lit >= lits {
            break;
        }
        let st = tm.ta().state(class, clause, lit);
        if st > s.max_state() {
            return Err(format!(
                "TA ({class},{clause},{lit}) state {st} escapes 0..={}",
                s.max_state()
            ));
        }
        if st >= s.include_threshold() {
            want |= 1u64 << k;
        }
    }
    if got != want {
        return Err(format!(
            "action word ({class},{clause},{word}) incoherent: cached {got:#018x}, states say {want:#018x}"
        ));
    }
    Ok(())
}

/// Localized coherence check for one TA: state in range and its cached
/// action bit equal to the include decision. O(1) — runs after every
/// scalar `ta_increment`/`ta_decrement` under the `contracts` feature.
pub fn check_ta(tm: &MultiTm, class: usize, clause: usize, lit: usize) -> Result<(), String> {
    let s = tm.shape();
    let st = tm.ta().state(class, clause, lit);
    if st > s.max_state() {
        return Err(format!(
            "TA ({class},{clause},{lit}) state {st} escapes 0..={}",
            s.max_state()
        ));
    }
    let cached = tm.action_words(class, clause)[lit / 64] >> (lit % 64) & 1 != 0;
    let want = st >= s.include_threshold();
    if cached != want {
        return Err(format!(
            "TA ({class},{clause},{lit}) action bit cached {cached}, state {st} says {want}"
        ));
    }
    Ok(())
}

/// Full-audit hook. `site` names the mutation for the panic message.
/// Compiled to nothing without the `contracts` feature.
#[cfg(feature = "contracts")]
pub fn enforce(tm: &MultiTm, site: &str) {
    if let Err(e) = check_invariants(tm) {
        panic!("contract violation after {site}: {e}");
    }
}

/// Full-audit hook (release stub: the `contracts` feature is off, so
/// this inlines to nothing and the hot paths carry zero overhead).
#[cfg(not(feature = "contracts"))]
#[inline(always)]
pub fn enforce(_tm: &MultiTm, _site: &str) {}

/// Word-local hook for `apply_word_feedback`.
#[cfg(feature = "contracts")]
pub fn enforce_word(tm: &MultiTm, class: usize, clause: usize, word: usize) {
    if let Err(e) = check_word(tm, class, clause, word) {
        panic!("contract violation after apply_word_feedback: {e}");
    }
}

/// Word-local hook (release stub; see [`enforce`]).
#[cfg(not(feature = "contracts"))]
#[inline(always)]
pub fn enforce_word(_tm: &MultiTm, _class: usize, _clause: usize, _word: usize) {}

/// TA-local hook for the scalar transitions.
#[cfg(feature = "contracts")]
pub fn enforce_ta(tm: &MultiTm, class: usize, clause: usize, lit: usize) {
    if let Err(e) = check_ta(tm, class, clause, lit) {
        panic!("contract violation after scalar TA transition: {e}");
    }
}

/// TA-local hook (release stub; see [`enforce`]).
#[cfg(not(feature = "contracts"))]
#[inline(always)]
pub fn enforce_ta(_tm: &MultiTm, _class: usize, _clause: usize, _lit: usize) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::{TmParams, TmShape};
    use crate::tm::rng::{StepRands, Xoshiro256};

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn trained(seed: u64) -> MultiTm {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(seed);
        let mut tm = crate::testkit::gen::machine(&mut rng, &s);
        for i in 0..40 {
            let bits = crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5);
            let x = crate::tm::clause::Input::pack(&s, &bits);
            let rands = StepRands::draw(&mut rng, &s);
            crate::tm::feedback::train_step(&mut tm, &x, i % s.classes, &p, &rands);
        }
        tm
    }

    #[test]
    fn fresh_and_trained_machines_are_consistent() {
        let fresh = MultiTm::new(&shape()).unwrap();
        check_invariants(&fresh).unwrap();
        let tm = trained(11);
        check_invariants(&tm).unwrap();
        check_invariants(&tm.clone()).unwrap();
    }

    #[test]
    fn corrupted_action_cache_is_caught() {
        let mut tm = trained(12);
        tm.actions[0] ^= 1;
        let err = check_invariants(&tm).unwrap_err();
        assert!(err.contains("incoherent"), "got: {err}");
        assert!(check_word(&tm, 0, 0, 0).is_err());
        assert!(check_ta(&tm, 0, 0, 0).is_err());
    }

    #[test]
    fn action_tail_bits_are_caught() {
        // iris rows are 32 literals wide; bit 40 is padding.
        let mut tm = trained(13);
        tm.actions[0] |= 1u64 << 40;
        let err = check_invariants(&tm).unwrap_err();
        assert!(err.contains("tail bits"), "got: {err}");
    }

    #[test]
    fn corrupted_force_code_is_caught() {
        let mut tm = trained(14);
        tm.clause_force[3] = 5;
        let err = check_invariants(&tm).unwrap_err();
        assert!(err.contains("force code"), "got: {err}");
    }

    #[test]
    fn force_counter_drift_is_caught() {
        let mut tm = trained(15);
        // Program a gate behind the counter's back.
        tm.clause_force[0] = 1;
        let err = check_invariants(&tm).unwrap_err();
        assert!(err.contains("clause fault counter"), "got: {err}");
        // Programming through the API keeps the counter exact.
        let mut tm = trained(15);
        tm.set_clause_fault(0, 0, Some(true));
        check_invariants(&tm).unwrap();
    }

    #[test]
    fn faulted_and_forced_machines_stay_consistent() {
        use crate::tm::fault::{Fault, FaultMap};
        let s = shape();
        let mut tm = trained(16);
        let map = FaultMap::even_spread(&s, 0.2, Fault::StuckAt1, 77).unwrap();
        tm.set_fault_map(map);
        tm.set_clause_fault(1, 2, Some(false));
        check_invariants(&tm).unwrap();
    }
}
