//! Scenario corpus: one serializable schedule language replayed through
//! **every** engine pair with bit-identity asserted after every step
//! (ROADMAP item 5). This replaces the hand-rolled schedule loops the
//! five differential suites each grew independently — a fixture under
//! `rust/tests/corpus/*.ron` exercises all of them at once, and any new
//! engine plugs in by joining a replay lane here (see EXPERIMENTS.md
//! §Verification).
//!
//! ## Lanes
//!
//! A schedule drives five machines forked from one seeded start:
//!
//! | lane | engine | identity class |
//! |------|--------|----------------|
//! | `oracle` | scalar `train_step` | eager |
//! | `fast` | word-parallel `train_step_fast` | eager |
//! | `lane` | lane-speculative `train_plane_batch` | eager |
//! | `lazy` | per-step `train_step_lazy` | lazy |
//! | `lazy-lane` | `train_plane_batch_lazy` | lazy |
//!
//! The three eager lanes consume identical per-sample [`StepRands`] and
//! must stay **bit-identical to each other**; the two lazy lanes share a
//! same-seeded generator and must stay bit-identical to each other (plus
//! generator-position equality, checked by draining one draw from both
//! after every training step). Serve-update steps apply the same
//! sequenced log to every lane through its own path (scalar keyed
//! replay, `apply_update`, coalesced `train_plane_batch` runs), so the
//! serving layer's replica-convergence contract rides the same fixture.
//! Inference steps assert tri-parity (row-major, batch, bit-plane, and
//! rescore-cache sums) and digest stability; checkpoint steps round-trip
//! every lane through the TMFS snapshot codec and assert uid freshness.
//!
//! ## Fixture format
//!
//! The offline image carries no serde, so fixtures are a line-oriented
//! text format under the `.ron` extension (one value per `key=value`
//! token, `#` comments, order fixed):
//!
//! ```text
//! tmfpga-corpus v1
//! shape classes=3 clauses=16 features=16 states=100
//! params s_bits=1068876431 t=15 active_clauses=16 active_classes=3 boost=0 style=1
//! base_seed 99
//! step train rows=20 seed=7
//! step force class=0 clause=3 code=1
//! step checkpoint
//! end
//! ```
//!
//! `s_bits` is the IEEE-754 bit pattern of `s` (`f32::to_bits`) so the
//! round-trip is exact. Schedules are grown and minimized by
//! [`crate::verify::shrink`].
//!
//! Format `v2` adds the `net` step kind (a scripted client fleet driven
//! through two network front ends with connection-level chaos, see
//! [`crate::net`]); schedules without net steps keep serializing as
//! `v1`, and a `v1` header containing a net step is rejected. Format
//! `v3` adds the `hub` step kind (a multi-tenant [`crate::hub::ModelHub`]
//! under a one-replica budget, round-robin updates with forced
//! evictions, checked against never-evicted mirrors); the same
//! downgrade/rejection rules apply. Format `v4` adds the `restart` step
//! kind (a durable-hub round trip through [`crate::store`]: updates
//! written ahead to a WAL + checkpoint store in a scratch directory,
//! the hub dropped, and a second hub rebuilt from the on-disk bytes
//! alone, checked against never-persisted mirrors).

use crate::hub::{HubConfig, ModelHub, SingleModel};
use crate::net::{run_sim, seeded_scripts, NetConfig, ScriptConfig};
use crate::serve::{
    restore, snapshot_bytes, BatcherConfig, NetChaosPlan, NetChaosSpec, ScalarOracle,
    ServeConfig, ShardServer,
};
use crate::tm::bitplane::{BitPlanes, PlaneBatch};
use crate::tm::clause::{EvalMode, Input};
use crate::tm::engine::{train_step_fast, train_step_lazy, EpochStats, FeedbackPlan};
use crate::tm::fault::{Fault, FaultMap};
use crate::tm::feedback::train_step;
use crate::tm::machine::MultiTm;
use crate::tm::params::{SStyle, TmParams, TmShape};
use crate::tm::rescore::RescoreCache;
use crate::store::{RealDisk, Store, StoreConfig};
use crate::tm::rng::{StepRands, Xoshiro256};
use crate::tm::train_planes::TrainScratch;
use crate::tm::update::{update_rands, update_rands_into, ShardUpdate, UpdateKind};
use anyhow::{bail, Context, Result};
use std::fmt;

/// One step of a replayable schedule. Payload sizes are `u32` and seeds
/// are explicit so fixtures are self-contained and text-stable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Step {
    /// Train `rows` seeded samples through all five lanes.
    Train { rows: u32, seed: u64 },
    /// Score `rows` seeded samples; assert row/batch/plane/digest parity.
    Infer { rows: u32, seed: u64 },
    /// Re-score the persistent monitor batch through the rescore cache
    /// against a cold plane evaluation.
    Rescore { seed: u64 },
    /// Program a seeded even-spread stuck-at fault map on every lane
    /// (`bp` = basis points of TAs faulted; kind 0 clears, 1 = stuck-at-0,
    /// 2 = stuck-at-1).
    Fault { bp: u32, kind: u8, seed: u64 },
    /// Program one clause-output force gate (code -1 clears, 0/1 force).
    Force { class: u32, clause: u32, code: i8 },
    /// Fork the fast lane; assert fresh uid + bit-identical state.
    Clone,
    /// Snapshot/restore every lane through the TMFS codec; lanes continue
    /// on the restored machines (fresh uids).
    Checkpoint,
    /// Apply `updates` sequenced shard updates (Learn + ClauseFault mix)
    /// to every lane through its own application path.
    Serve { updates: u32, seed: u64 },
    /// Drive a scripted client fleet (full connection-fault matrix)
    /// through two network front ends forked from the fast lane —
    /// scalar oracle vs sharded server — assert identical outcomes,
    /// stats, admitted-update logs and replica digests, then fold the
    /// admitted log into every lane (needs fixture format v2).
    Net { clients: u32, requests: u32, seed: u64 },
    /// Fork `tenants` hub models from the fast lane under a ONE-replica
    /// memory budget, apply `updates` seeded Learns round-robin with
    /// forced evictions interleaved, and assert every tenant's final
    /// digest bit-identical to a never-evicted mirror replaying the
    /// same `(base_seed, seq)` log (needs fixture format v3).
    Hub { tenants: u32, updates: u32, seed: u64 },
    /// Fork `tenants` models from the fast lane into a *durable* hub
    /// (write-ahead log + checkpoint store in a scratch directory),
    /// apply `updates` seeded Learns round-robin with forced evictions
    /// interleaved, sync and drop the hub, then rebuild a second hub
    /// from the on-disk bytes alone and assert every tenant's
    /// rehydrated seq and digest bit-identical to a never-persisted
    /// mirror replaying the same `(base_seed, seq)` log (needs fixture
    /// format v4).
    Restart { tenants: u32, updates: u32, seed: u64 },
    /// Swap the training hyper-parameters mid-schedule.
    Params { t: i32, s_bits: u32, active_clauses: u32, active_classes: u32 },
}

impl Step {
    fn to_line(&self) -> String {
        match self {
            Step::Train { rows, seed } => format!("step train rows={rows} seed={seed}"),
            Step::Infer { rows, seed } => format!("step infer rows={rows} seed={seed}"),
            Step::Rescore { seed } => format!("step rescore seed={seed}"),
            Step::Fault { bp, kind, seed } => {
                format!("step fault bp={bp} kind={kind} seed={seed}")
            }
            Step::Force { class, clause, code } => {
                format!("step force class={class} clause={clause} code={code}")
            }
            Step::Clone => "step clone".into(),
            Step::Checkpoint => "step checkpoint".into(),
            Step::Serve { updates, seed } => {
                format!("step serve updates={updates} seed={seed}")
            }
            Step::Net { clients, requests, seed } => {
                format!("step net clients={clients} requests={requests} seed={seed}")
            }
            Step::Hub { tenants, updates, seed } => {
                format!("step hub tenants={tenants} updates={updates} seed={seed}")
            }
            Step::Restart { tenants, updates, seed } => {
                format!("step restart tenants={tenants} updates={updates} seed={seed}")
            }
            Step::Params { t, s_bits, active_clauses, active_classes } => format!(
                "step params t={t} s_bits={s_bits} active_clauses={active_clauses} active_classes={active_classes}"
            ),
        }
    }
}

/// A complete replayable scenario: machine geometry, starting
/// hyper-parameters, the seed every lane forks from, and the step list.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub shape: TmShape,
    pub params: TmParams,
    pub base_seed: u64,
    pub steps: Vec<Step>,
}

impl Schedule {
    /// A schedule over `shape` with the paper's offline hyper-parameters
    /// and no steps yet.
    pub fn new(shape: &TmShape, base_seed: u64) -> Self {
        Schedule {
            shape: shape.clone(),
            params: TmParams::paper_offline(shape),
            base_seed,
            steps: Vec::new(),
        }
    }

    /// Serialize to the fixture text format (see the module docs).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let has_net = self.steps.iter().any(|s| matches!(s, Step::Net { .. }));
        let has_hub = self.steps.iter().any(|s| matches!(s, Step::Hub { .. }));
        let has_restart = self.steps.iter().any(|s| matches!(s, Step::Restart { .. }));
        out.push_str(if has_restart {
            "tmfpga-corpus v4\n"
        } else if has_hub {
            "tmfpga-corpus v3\n"
        } else if has_net {
            "tmfpga-corpus v2\n"
        } else {
            "tmfpga-corpus v1\n"
        });
        out.push_str(&format!(
            "shape classes={} clauses={} features={} states={}\n",
            self.shape.classes, self.shape.max_clauses, self.shape.features, self.shape.states
        ));
        let style = match self.params.s_style {
            SStyle::Canonical => 0,
            SStyle::InactionBiased => 1,
        };
        out.push_str(&format!(
            "params s_bits={} t={} active_clauses={} active_classes={} boost={} style={style}\n",
            self.params.s.to_bits(),
            self.params.t,
            self.params.active_clauses,
            self.params.active_classes,
            u8::from(self.params.boost_true_positive),
        ));
        out.push_str(&format!("base_seed {}\n", self.base_seed));
        for step in &self.steps {
            out.push_str(&step.to_line());
            out.push('\n');
        }
        out.push_str("end\n");
        out
    }

    /// Parse the fixture text format. Strict: unknown step kinds, missing
    /// keys and trailing garbage are errors, so a corrupted fixture fails
    /// loudly instead of silently replaying a different scenario.
    pub fn parse(text: &str) -> Result<Schedule> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header = lines.next().context("empty fixture")?;
        let version = match header {
            "tmfpga-corpus v1" => 1u32,
            "tmfpga-corpus v2" => 2,
            "tmfpga-corpus v3" => 3,
            "tmfpga-corpus v4" => 4,
            other => bail!("bad fixture header {other:?} (want \"tmfpga-corpus v1\"..\"v4\")"),
        };

        let shape_line = lines.next().context("missing shape line")?;
        let toks: Vec<&str> = shape_line.split_whitespace().collect();
        if toks.first() != Some(&"shape") {
            bail!("expected shape line, got {shape_line:?}");
        }
        let shape = TmShape {
            classes: get(&toks, "classes")?,
            max_clauses: get(&toks, "clauses")?,
            features: get(&toks, "features")?,
            states: get(&toks, "states")?,
        };

        let params_line = lines.next().context("missing params line")?;
        let toks: Vec<&str> = params_line.split_whitespace().collect();
        if toks.first() != Some(&"params") {
            bail!("expected params line, got {params_line:?}");
        }
        let style: u8 = get(&toks, "style")?;
        let boost: u8 = get(&toks, "boost")?;
        let params = TmParams {
            s: f32::from_bits(get(&toks, "s_bits")?),
            t: get(&toks, "t")?,
            active_clauses: get(&toks, "active_clauses")?,
            active_classes: get(&toks, "active_classes")?,
            boost_true_positive: boost != 0,
            s_style: match style {
                0 => SStyle::Canonical,
                1 => SStyle::InactionBiased,
                other => bail!("unknown s style code {other}"),
            },
        };

        let seed_line = lines.next().context("missing base_seed line")?;
        let mut seed_toks = seed_line.split_whitespace();
        let base_seed = match (seed_toks.next(), seed_toks.next(), seed_toks.next()) {
            (Some("base_seed"), Some(v), None) => {
                v.parse::<u64>().map_err(|e| anyhow::Error::msg(format!("bad base_seed {v:?} ({e})")))?
            }
            _ => bail!("expected base_seed line, got {seed_line:?}"),
        };

        let mut steps = Vec::new();
        let mut ended = false;
        for line in lines {
            if ended {
                bail!("trailing content after end: {line:?}");
            }
            if line == "end" {
                ended = true;
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.first() != Some(&"step") || toks.len() < 2 {
                bail!("expected step line, got {line:?}");
            }
            let step = match toks[1] {
                "train" => Step::Train { rows: get(&toks, "rows")?, seed: get(&toks, "seed")? },
                "infer" => Step::Infer { rows: get(&toks, "rows")?, seed: get(&toks, "seed")? },
                "rescore" => Step::Rescore { seed: get(&toks, "seed")? },
                "fault" => Step::Fault {
                    bp: get(&toks, "bp")?,
                    kind: get(&toks, "kind")?,
                    seed: get(&toks, "seed")?,
                },
                "force" => Step::Force {
                    class: get(&toks, "class")?,
                    clause: get(&toks, "clause")?,
                    code: get(&toks, "code")?,
                },
                "clone" => Step::Clone,
                "checkpoint" => Step::Checkpoint,
                "serve" => {
                    Step::Serve { updates: get(&toks, "updates")?, seed: get(&toks, "seed")? }
                }
                "net" => {
                    if version < 2 {
                        bail!("net steps need a \"tmfpga-corpus v2\" fixture header");
                    }
                    Step::Net {
                        clients: get(&toks, "clients")?,
                        requests: get(&toks, "requests")?,
                        seed: get(&toks, "seed")?,
                    }
                }
                "hub" => {
                    if version < 3 {
                        bail!("hub steps need a \"tmfpga-corpus v3\" fixture header");
                    }
                    Step::Hub {
                        tenants: get(&toks, "tenants")?,
                        updates: get(&toks, "updates")?,
                        seed: get(&toks, "seed")?,
                    }
                }
                "restart" => {
                    if version < 4 {
                        bail!("restart steps need a \"tmfpga-corpus v4\" fixture header");
                    }
                    Step::Restart {
                        tenants: get(&toks, "tenants")?,
                        updates: get(&toks, "updates")?,
                        seed: get(&toks, "seed")?,
                    }
                }
                "params" => Step::Params {
                    t: get(&toks, "t")?,
                    s_bits: get(&toks, "s_bits")?,
                    active_clauses: get(&toks, "active_clauses")?,
                    active_classes: get(&toks, "active_classes")?,
                },
                other => bail!("unknown step kind {other:?}"),
            };
            steps.push(step);
        }
        if !ended {
            bail!("fixture missing end line");
        }
        Ok(Schedule { shape, params, base_seed, steps })
    }
}

/// Find `key=value` among `toks` and parse the value.
fn get<T: std::str::FromStr>(toks: &[&str], key: &str) -> Result<T>
where
    T::Err: fmt::Display,
{
    for tok in toks {
        if let Some(v) = tok.strip_prefix(key).and_then(|r| r.strip_prefix('=')) {
            return v
                .parse::<T>()
                .map_err(|e| anyhow::Error::msg(format!("bad value for {key}: {v:?} ({e})")));
        }
    }
    bail!("missing key {key} in {toks:?}")
}

/// Replay knobs. The injection flag exists solely so the shrinker's own
/// test suite can plant a known divergence and prove the minimizer finds
/// it — never set it outside tests.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplayOptions {
    /// After each eager training step, if any clause-output force gate is
    /// programmed, nudge one TA of the `fast` lane by one state — a
    /// deliberate off-by-one divergence.
    pub inject_train_offby1: bool,
}

/// First bit-identity or contract failure of a replay: the step index it
/// surfaced after (== `steps.len()` for end-of-schedule checks) and what
/// disagreed.
#[derive(Debug, Clone)]
pub struct Divergence {
    pub step: usize,
    pub what: String,
}

impl fmt::Display for Divergence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "step {}: {}", self.step, self.what)
    }
}

/// Replay accounting for reporting (`tmfpga verify`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Report {
    /// Steps executed.
    pub steps: usize,
    /// Cross-lane identity comparisons + contract audits that passed.
    pub checks: u64,
}

/// Replay `s` through every lane with default options.
pub fn replay(s: &Schedule) -> Result<Report, Divergence> {
    replay_opts(s, &ReplayOptions::default())
}

/// Replay `s` through every lane. Returns the first [`Divergence`], or a
/// [`Report`] when the whole schedule holds.
pub fn replay_opts(s: &Schedule, opts: &ReplayOptions) -> Result<Report, Divergence> {
    let shape = &s.shape;
    if let Err(e) = shape.validate() {
        return Err(Divergence { step: 0, what: format!("invalid shape: {e}") });
    }
    let mut params = s.params.clone();
    if let Err(e) = params.validate(shape) {
        return Err(Divergence { step: 0, what: format!("invalid params: {e}") });
    }

    // All five lanes fork from one seeded machine.
    let mut init_rng = Xoshiro256::new(s.base_seed);
    let oracle_init = crate::testkit::gen::machine(&mut init_rng, shape);
    let mut a = oracle_init.clone(); // scalar oracle
    let mut b = oracle_init.clone(); // word-parallel eager
    let mut c = oracle_init.clone(); // lane-speculative eager
    let mut d = oracle_init.clone(); // lazy per-step
    let mut e = oracle_init; // lazy lane-speculative

    // The lazy pair shares a generator seed; position equality is checked
    // by draining one draw from both after every training step.
    let mut rng_d = Xoshiro256::new(mix(s.base_seed, 0x1A2B));
    let mut rng_e = Xoshiro256::new(mix(s.base_seed, 0x1A2B));
    let mut scratch_c = TrainScratch::new();
    let mut scratch_e = TrainScratch::new();

    // Rescore-cache lane state: a persistent monitor batch (stable
    // fingerprint across Rescore steps) so incremental revalidation — and
    // its forced cold rebuild after checkpoint restore — is actually
    // exercised.
    let mut cache = RescoreCache::new();
    let monitor_rows = rows_from_seed(shape, 24, mix(s.base_seed, 0x4E5C));
    let monitor = PlaneBatch::from_labelled(shape, &monitor_rows);
    let mut expect_cold = true; // nothing cached yet

    let mut serve_scratch: Option<StepRands> = None;
    let mut next_seq: u64 = 1;
    let mut checks: u64 = 0;

    for (i, step) in s.steps.iter().enumerate() {
        match step {
            Step::Train { rows, seed } => {
                let data = rows_from_seed(shape, *rows as usize, mix(s.base_seed, *seed));
                let mut rec_rng = Xoshiro256::new(mix(s.base_seed, seed ^ 0x7EA1));
                let recs: Vec<StepRands> =
                    data.iter().map(|_| StepRands::draw(&mut rec_rng, shape)).collect();

                let mut act_a = EpochStats::default();
                let mut act_b = EpochStats::default();
                for ((x, y), r) in data.iter().zip(&recs) {
                    act_a.absorb(train_step(&mut a, x, *y, &params, r));
                    act_b.absorb(train_step_fast(&mut b, x, *y, &params, r));
                }
                let planes = BitPlanes::from_labelled(shape, &data);
                let act_c = c.train_plane_batch(
                    &data,
                    &planes,
                    &params,
                    |j, r| r.clone_from(&recs[j]),
                    &mut scratch_c,
                );
                if act_a != act_b || act_a != act_c {
                    return Err(Divergence {
                        step: i,
                        what: format!(
                            "eager activity diverged: oracle {act_a:?} fast {act_b:?} lane {act_c:?}"
                        ),
                    });
                }

                let plan = FeedbackPlan::new(&params);
                let mut act_d = EpochStats::default();
                for (x, y) in &data {
                    act_d.absorb(train_step_lazy(&mut d, x, *y, &params, &plan, &mut rng_d));
                }
                let act_e =
                    e.train_plane_batch_lazy(&data, &planes, &params, &plan, &mut rng_e, &mut scratch_e);
                if act_d != act_e {
                    return Err(Divergence {
                        step: i,
                        what: format!("lazy activity diverged: step {act_d:?} lane {act_e:?}"),
                    });
                }
                if rng_d.next_u64() != rng_e.next_u64() {
                    return Err(Divergence {
                        step: i,
                        what: "lazy generator positions diverged".into(),
                    });
                }
                checks += 3;

                if opts.inject_train_offby1 && b.clause_fault_count() > 0 {
                    inject_offby1(&mut b);
                }
            }
            Step::Infer { rows, seed } => {
                let data = rows_from_seed(shape, *rows as usize, mix(s.base_seed, *seed));
                if !data.is_empty() {
                    let inputs: Vec<Input> = data.iter().map(|(x, _)| x.clone()).collect();
                    let digest = b.state_digest();
                    let batch = b.evaluate_batch(&inputs, &params, EvalMode::Infer);
                    let planes = BitPlanes::from_inputs(shape, &inputs);
                    let sliced = b.evaluate_planes(&planes, &params, EvalMode::Infer);
                    if batch != sliced {
                        return Err(Divergence {
                            step: i,
                            what: "row-major vs bit-plane sums diverged".into(),
                        });
                    }
                    for (row, x) in inputs.iter().enumerate() {
                        let sums = a.evaluate(x, &params, EvalMode::Infer).to_vec();
                        for cls in 0..params.active_classes {
                            if batch[cls * inputs.len() + row] != sums[cls] {
                                return Err(Divergence {
                                    step: i,
                                    what: format!(
                                        "scalar vs batch sum diverged at row {row} class {cls}"
                                    ),
                                });
                            }
                        }
                    }
                    if b.predict_batch(&inputs, &params) != b.predict_planes(&planes, &params) {
                        return Err(Divergence {
                            step: i,
                            what: "batch vs plane predictions diverged".into(),
                        });
                    }
                    if b.state_digest() != digest {
                        return Err(Divergence {
                            step: i,
                            what: "inference moved the state digest".into(),
                        });
                    }
                    checks += 4;
                }
            }
            Step::Rescore { seed } => {
                let cold_before = cache.stats().cold_builds;
                let inc = cache.evaluate(&b, monitor.planes(), &params, EvalMode::Infer);
                let cold = b.evaluate_planes(monitor.planes(), &params, EvalMode::Infer);
                if inc != cold {
                    return Err(Divergence {
                        step: i,
                        what: "rescore cache sums diverged from cold evaluation".into(),
                    });
                }
                if expect_cold && cache.stats().cold_builds == cold_before {
                    return Err(Divergence {
                        step: i,
                        what: "stale rescore entry validated against a fresh machine uid".into(),
                    });
                }
                expect_cold = false;
                // A seeded throwaway batch churns the cache's entry ring.
                let extra = rows_from_seed(shape, 8, mix(s.base_seed, *seed));
                if !extra.is_empty() {
                    let batch = PlaneBatch::from_labelled(shape, &extra);
                    let inc = cache.evaluate(&b, batch.planes(), &params, EvalMode::Infer);
                    let cold = b.evaluate_planes(batch.planes(), &params, EvalMode::Infer);
                    if inc != cold {
                        return Err(Divergence {
                            step: i,
                            what: "rescore cache sums diverged on throwaway batch".into(),
                        });
                    }
                }
                checks += 2;
            }
            Step::Fault { bp, kind, seed } => {
                let map = match kind {
                    0 => FaultMap::none(shape),
                    k => {
                        let fault = if *k == 1 { Fault::StuckAt0 } else { Fault::StuckAt1 };
                        let fraction = f64::from((*bp).min(10_000)) / 10_000.0;
                        match FaultMap::even_spread(shape, fraction, fault, mix(s.base_seed, *seed))
                        {
                            Ok(m) => m,
                            Err(e2) => {
                                return Err(Divergence {
                                    step: i,
                                    what: format!("even_spread failed: {e2}"),
                                })
                            }
                        }
                    }
                };
                for m in [&mut a, &mut b, &mut c, &mut d, &mut e] {
                    m.set_fault_map(map.clone());
                }
            }
            Step::Force { class, clause, code } => {
                let cls = *class as usize % shape.classes;
                let j = *clause as usize % shape.max_clauses;
                let force = match code {
                    0 => Some(false),
                    1 => Some(true),
                    _ => None,
                };
                for m in [&mut a, &mut b, &mut c, &mut d, &mut e] {
                    m.set_clause_fault(cls, j, force);
                }
            }
            Step::Clone => {
                let fork = b.clone();
                if fork.uid() == b.uid() {
                    return Err(Divergence { step: i, what: "clone kept the original uid".into() });
                }
                if let Err(what) = diff(&fork, &b, "clone/original") {
                    return Err(Divergence { step: i, what });
                }
                checks += 1;
            }
            Step::Checkpoint => {
                let lanes = [
                    (&mut a, "oracle"),
                    (&mut b, "fast"),
                    (&mut c, "lane"),
                    (&mut d, "lazy"),
                    (&mut e, "lazy-lane"),
                ];
                for (m, name) in lanes {
                    let bytes = snapshot_bytes(m, &params, next_seq);
                    let snap = match restore(&bytes) {
                        Ok(snap) => snap,
                        Err(e2) => {
                            return Err(Divergence {
                                step: i,
                                what: format!("{name}: snapshot restore failed: {e2:#}"),
                            })
                        }
                    };
                    if snap.seq != next_seq {
                        return Err(Divergence {
                            step: i,
                            what: format!("{name}: snapshot seq {} != {next_seq}", snap.seq),
                        });
                    }
                    if snap.machine.state_digest() != m.state_digest() {
                        return Err(Divergence {
                            step: i,
                            what: format!("{name}: restore moved the state digest"),
                        });
                    }
                    if snap.machine.uid() == m.uid() {
                        return Err(Divergence {
                            step: i,
                            what: format!("{name}: restored machine kept the snapshot uid"),
                        });
                    }
                    *m = snap.machine;
                    checks += 1;
                }
                // Every lane now carries a fresh uid: the rescore cache
                // must cold-rebuild at the next Rescore step even though
                // the monitor fingerprint is unchanged (the
                // load_snapshot/uid contract, see ISSUE 7 satellite 3).
                expect_cold = true;
            }
            Step::Serve { updates, seed } => {
                let log = gen_updates(shape, *updates as usize, mix(s.base_seed, *seed), &mut next_seq);
                apply_shard_log(
                    &log,
                    &params,
                    s.base_seed,
                    [&mut a, &mut b, &mut c, &mut d, &mut e],
                    &mut serve_scratch,
                    &mut scratch_c,
                );
            }
            Step::Net { clients, requests, seed } => {
                // Two front ends forked from the fast lane serve the same
                // scripted fleet (every connection-fault kind armed): the
                // scalar oracle vs the sharded server. Everything
                // observable must be bit-identical — outcome per request,
                // shed/deadline/admission accounting, the admitted-update
                // log, and the replica state the arms end with.
                let plan = NetChaosPlan::seeded(
                    mix(s.base_seed, seed ^ 0x4EC5),
                    *clients as usize,
                    u64::from(*requests),
                    &NetChaosSpec::full_matrix(),
                );
                let script_cfg = ScriptConfig {
                    clients: *clients as usize,
                    requests_per_client: u64::from(*requests),
                    labelled_fraction: 0.35,
                    features: shape.features,
                    classes: shape.classes,
                    ttl: Some(3),
                    // Corpus net steps are pinned to the v1 wire surface:
                    // their fixtures predate the model dimension and must
                    // replay byte-identically forever.
                    hello_version: 1,
                    model: None,
                };
                let scripts = seeded_scripts(mix(s.base_seed, *seed), &script_cfg, &plan);
                let batch =
                    BatcherConfig { max_batch: 8, latency_budget: 4, expect_literals: None };
                let ncfg = NetConfig { batch, record_updates: true, ..NetConfig::default() };
                let serve_seed = mix(s.base_seed, seed ^ 0x5E4E);
                let oracle = ScalarOracle::new(b.clone(), params.clone(), serve_seed);
                let orep = match run_sim(SingleModel(oracle), scripts.clone(), shape, ncfg.clone())
                {
                    Ok((rep, _)) => rep,
                    Err(e2) => {
                        return Err(Divergence {
                            step: i,
                            what: format!("net oracle arm failed: {e2:#}"),
                        })
                    }
                };
                let scfg = ServeConfig::new(2, params.clone(), serve_seed);
                let server = match ShardServer::new(&b, &scfg) {
                    Ok(sv) => sv,
                    Err(e2) => {
                        return Err(Divergence {
                            step: i,
                            what: format!("net shard spawn failed: {e2:#}"),
                        })
                    }
                };
                let srep = match run_sim(SingleModel(server), scripts, shape, ncfg) {
                    Ok((rep, _)) => rep,
                    Err(e2) => {
                        return Err(Divergence {
                            step: i,
                            what: format!("net server arm failed: {e2:#}"),
                        })
                    }
                };
                if srep.stats != orep.stats {
                    return Err(Divergence {
                        step: i,
                        what: format!(
                            "net stats diverged: server {:?} oracle {:?}",
                            srep.stats, orep.stats
                        ),
                    });
                }
                if srep.outcomes != orep.outcomes {
                    return Err(Divergence { step: i, what: "net outcome maps diverged".into() });
                }
                if srep.updates != orep.updates {
                    return Err(Divergence {
                        step: i,
                        what: "net admitted-update logs diverged".into(),
                    });
                }
                let od = orep.replicas[0].state_digest();
                if srep.replicas.iter().any(|r| r.state_digest() != od) {
                    return Err(Divergence {
                        step: i,
                        what: "net replica digests diverged from oracle".into(),
                    });
                }
                checks += 4;
                // Fold the admitted log into every lane through the
                // shard-update paths, continuing the replay's own
                // sequence stream.
                let log: Vec<ShardUpdate> = orep
                    .updates
                    .into_iter()
                    .map(|kind| {
                        let seq = next_seq;
                        next_seq += 1;
                        ShardUpdate { seq, kind }
                    })
                    .collect();
                apply_shard_log(
                    &log,
                    &params,
                    s.base_seed,
                    [&mut a, &mut b, &mut c, &mut d, &mut e],
                    &mut serve_scratch,
                    &mut scratch_c,
                );
            }
            Step::Hub { tenants, updates, seed } => {
                // Fork hub tenants from the fast lane under a budget of
                // ONE resident replica, so round-robin updates force an
                // eviction/rehydration cycle on nearly every touch. Each
                // tenant's never-evicted mirror applies the identical
                // `(base_seed, seq)` log; digests must match exactly —
                // the hub's residency machinery is contractually
                // invisible.
                let n = (*tenants as usize).clamp(1, 8);
                let hub_seed = mix(s.base_seed, *seed);
                let cost = snapshot_bytes(&b, &params, 0).len();
                let mut hub = ModelHub::new(HubConfig {
                    memory_budget: cost,
                    checkpoint_every: 4,
                    plane_cache_batches: 8,
                });
                let mut handles = Vec::with_capacity(n);
                let mut mirrors: Vec<(MultiTm, u64, u64)> = Vec::with_capacity(n);
                for t in 0..n {
                    let tseed = mix(hub_seed, t as u64 + 1);
                    match hub.create(&format!("lane-{t}"), b.clone(), params.clone(), tseed) {
                        Ok(h) => handles.push(h),
                        Err(e2) => {
                            return Err(Divergence {
                                step: i,
                                what: format!("hub create lane-{t} failed: {e2}"),
                            })
                        }
                    }
                    mirrors.push((b.clone(), tseed, 0));
                }
                let mut rng = Xoshiro256::new(mix(hub_seed, 0xB0B));
                for k in 0..*updates {
                    let t = k as usize % n;
                    let bits = crate::testkit::gen::bool_vec(&mut rng, shape.features, 0.5);
                    let kind = UpdateKind::Learn {
                        input: Input::pack(shape, &bits),
                        label: rng.next_below(shape.classes),
                    };
                    let seq = match hub.update(handles[t], kind.clone()) {
                        Ok(seq) => seq,
                        Err(e2) => {
                            return Err(Divergence {
                                step: i,
                                what: format!("hub update on lane-{t} failed: {e2}"),
                            })
                        }
                    };
                    let (mirror, tseed, mseq) = &mut mirrors[t];
                    *mseq += 1;
                    if seq != *mseq {
                        return Err(Divergence {
                            step: i,
                            what: format!("hub seq {seq} != mirror seq {mseq} on lane-{t}"),
                        });
                    }
                    mirror.apply_update(&ShardUpdate { seq, kind }, &params, *tseed);
                    if k % 3 == 2 {
                        if let Err(e2) = hub.evict(handles[t]) {
                            return Err(Divergence {
                                step: i,
                                what: format!("hub forced evict lane-{t} failed: {e2}"),
                            });
                        }
                    }
                }
                for t in 0..n {
                    let digest = match hub.digest(handles[t]) {
                        Ok(dg) => dg,
                        Err(e2) => {
                            return Err(Divergence {
                                step: i,
                                what: format!("hub digest lane-{t} failed: {e2}"),
                            })
                        }
                    };
                    if digest != mirrors[t].0.state_digest() {
                        return Err(Divergence {
                            step: i,
                            what: format!(
                                "hub lane-{t} digest diverged from its never-evicted mirror"
                            ),
                        });
                    }
                    checks += 1;
                }
            }
            Step::Restart { tenants, updates, seed } => {
                // Fork durable-hub tenants from the fast lane: every
                // create/update/evict is written ahead to a WAL +
                // checkpoint store in a scratch directory, the hub is
                // synced and dropped, and a second hub is rebuilt from
                // the on-disk bytes alone. Each tenant must come back at
                // its exact durable seq with a digest bit-identical to a
                // never-persisted mirror replaying the identical
                // `(base_seed, seq)` log — the durable round trip, like
                // eviction, is contractually invisible.
                let n = (*tenants as usize).clamp(1, 8);
                let hub_seed = mix(s.base_seed, *seed);
                let fail = |what: String| Divergence { step: i, what };
                let dir = restart_scratch_dir(s.base_seed, i);
                std::fs::remove_dir_all(&dir).ok();
                // Tiny segments so even short fixtures cross a rotation.
                let store_cfg = StoreConfig { segment_bytes: 1024, ..StoreConfig::default() };
                let cost = snapshot_bytes(&b, &params, 0).len();
                let hub_cfg = HubConfig {
                    memory_budget: cost,
                    checkpoint_every: 4,
                    plane_cache_batches: 8,
                };
                let (store, recovered) = match Store::open(Box::new(RealDisk), &dir, store_cfg) {
                    Ok(ok) => ok,
                    Err(e2) => return Err(fail(format!("restart: store open failed: {e2}"))),
                };
                if !recovered.is_empty() {
                    return Err(fail("restart: fresh scratch store recovered models".into()));
                }
                let mut hub = match ModelHub::open_durable(hub_cfg.clone(), store, recovered) {
                    Ok(h) => h,
                    Err(e2) => return Err(fail(format!("restart: durable hub failed: {e2}"))),
                };
                let mut handles = Vec::with_capacity(n);
                let mut mirrors: Vec<(MultiTm, u64, u64)> = Vec::with_capacity(n);
                for t in 0..n {
                    let tseed = mix(hub_seed, t as u64 + 1);
                    match hub.create(&format!("lane-{t}"), b.clone(), params.clone(), tseed) {
                        Ok(h) => handles.push(h),
                        Err(e2) => {
                            return Err(fail(format!("restart: create lane-{t} failed: {e2}")))
                        }
                    }
                    mirrors.push((b.clone(), tseed, 0));
                }
                let mut rng = Xoshiro256::new(mix(hub_seed, 0xD15C));
                for k in 0..*updates {
                    let t = k as usize % n;
                    let bits = crate::testkit::gen::bool_vec(&mut rng, shape.features, 0.5);
                    let kind = UpdateKind::Learn {
                        input: Input::pack(shape, &bits),
                        label: rng.next_below(shape.classes),
                    };
                    let seq = match hub.update(handles[t], kind.clone()) {
                        Ok(seq) => seq,
                        Err(e2) => {
                            return Err(fail(format!("restart: update lane-{t} failed: {e2}")))
                        }
                    };
                    let (mirror, tseed, mseq) = &mut mirrors[t];
                    *mseq += 1;
                    if seq != *mseq {
                        return Err(fail(format!(
                            "restart: seq {seq} != mirror seq {mseq} on lane-{t}"
                        )));
                    }
                    mirror.apply_update(&ShardUpdate { seq, kind }, &params, *tseed);
                    if k % 3 == 2 {
                        if let Err(e2) = hub.evict(handles[t]) {
                            return Err(fail(format!(
                                "restart: forced evict lane-{t} failed: {e2}"
                            )));
                        }
                    }
                }
                if let Err(e2) = hub.sync_durable() {
                    return Err(fail(format!("restart: sync failed: {e2}")));
                }
                drop(hub);
                // Rebuild from disk alone and compare against the mirrors.
                let (store, recovered) = match Store::open(Box::new(RealDisk), &dir, store_cfg) {
                    Ok(ok) => ok,
                    Err(e2) => return Err(fail(format!("restart: reopen failed: {e2}"))),
                };
                if recovered.len() != n {
                    return Err(fail(format!(
                        "restart: recovered {} of {n} models",
                        recovered.len()
                    )));
                }
                let mut hub2 = match ModelHub::open_durable(hub_cfg, store, recovered) {
                    Ok(h) => h,
                    Err(e2) => return Err(fail(format!("restart: rebuild failed: {e2}"))),
                };
                for (t, (mirror, _, mseq)) in mirrors.iter().enumerate() {
                    let Some(h) = hub2.resolve(&format!("lane-{t}")) else {
                        return Err(fail(format!("restart: lane-{t} missing after rebuild")));
                    };
                    if hub2.model_seq(h) != Some(*mseq) {
                        return Err(fail(format!(
                            "restart: lane-{t} resumed at seq {:?}, want {mseq}",
                            hub2.model_seq(h)
                        )));
                    }
                    let digest = match hub2.digest(h) {
                        Ok(dg) => dg,
                        Err(e2) => {
                            return Err(fail(format!("restart: digest lane-{t} failed: {e2}")))
                        }
                    };
                    if digest != mirror.state_digest() {
                        return Err(fail(format!(
                            "restart: lane-{t} rehydrated digest diverged from its \
                             never-persisted mirror"
                        )));
                    }
                    checks += 2;
                }
                std::fs::remove_dir_all(&dir).ok();
            }
            Step::Params { t, s_bits, active_clauses, active_classes } => {
                let mut np = params.clone();
                np.t = *t;
                np.s = f32::from_bits(*s_bits);
                np.active_clauses = (*active_clauses as usize).clamp(1, shape.max_clauses);
                np.active_classes = (*active_classes as usize).clamp(1, shape.classes);
                if let Err(e2) = np.validate(shape) {
                    return Err(Divergence { step: i, what: format!("params step invalid: {e2}") });
                }
                params = np;
            }
        }
        checks += cross_check(i, &a, &b, &c, &d, &e)?;
    }
    Ok(Report { steps: s.steps.len(), checks })
}

/// Golden-ratio seed mixing so per-step seeds never collide with the
/// base seed's other derivations.
#[inline]
fn mix(base: u64, salt: u64) -> u64 {
    base ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Scratch store directory for one `Restart` step — unique per process
/// and call, so parallel replays (the test harness) never collide.
fn restart_scratch_dir(base_seed: u64, step: usize) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static CALLS: AtomicU64 = AtomicU64::new(0);
    let k = CALLS.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "tmfpga_corpus_restart_{}_{base_seed:016x}_{step}_{k}",
        std::process::id()
    ))
}

/// Seeded labelled rows for one step.
fn rows_from_seed(shape: &TmShape, n: usize, seed: u64) -> Vec<(Input, usize)> {
    let mut rng = Xoshiro256::new(seed);
    crate::testkit::gen::rows(&mut rng, shape, n)
}

/// The planted off-by-one: bump the first non-saturated TA of clause
/// (0,0) on one lane, guaranteeing a state delta the cross-check sees.
fn inject_offby1(tm: &mut MultiTm) {
    let shape = tm.shape().clone();
    for lit in 0..shape.literals() {
        if tm.ta().state(0, 0, lit) < shape.max_state() {
            tm.ta_increment(0, 0, lit);
            return;
        }
    }
}

/// One coalesced Learn run through the keyed lane trainer.
fn flush_learn_run(
    tm: &mut MultiTm,
    run: &[(Input, usize, u64)],
    params: &TmParams,
    base_seed: u64,
    scratch: &mut TrainScratch,
) {
    if run.is_empty() {
        return;
    }
    let shape = tm.shape().clone();
    let rows: Vec<(Input, usize)> = run.iter().map(|(x, y, _)| (x.clone(), *y)).collect();
    let planes = BitPlanes::from_labelled(&shape, &rows);
    tm.train_plane_batch(
        &rows,
        &planes,
        params,
        |i, r| update_rands_into(r, &shape, base_seed, run[i].2),
        scratch,
    );
}

/// Apply one sequenced shard-update log to the five lanes `[a, b, c, d,
/// e]`, each through its own application path: scalar keyed replay,
/// allocating `apply_update_with`, coalesced lane runs, and the plain
/// `apply_update` pair — the same discipline the shard workers use.
fn apply_shard_log(
    log: &[ShardUpdate],
    params: &TmParams,
    base_seed: u64,
    lanes: [&mut MultiTm; 5],
    serve_scratch: &mut Option<StepRands>,
    scratch_c: &mut TrainScratch,
) {
    let [a, b, c, d, e] = lanes;
    let shape = a.shape().clone();
    // Scalar oracle: keyed replay of the log.
    for u in log {
        match &u.kind {
            UpdateKind::Learn { input, label } => {
                let r = update_rands(&shape, base_seed, u.seq);
                train_step(a, input, *label, params, &r);
            }
            UpdateKind::ClauseFault { class, clause, force } => {
                a.set_clause_fault(*class, *clause, *force);
            }
        }
    }
    // Replica paths: allocating, scratch-carrying, and plain.
    for u in log {
        b.apply_update_with(u, params, base_seed, serve_scratch);
        d.apply_update(u, params, base_seed);
        e.apply_update(u, params, base_seed);
    }
    // Lane path: coalesced Learn runs through the keyed bit-plane
    // trainer, fault edits applied at run breaks — exactly the shard
    // workers' batching discipline.
    let mut run: Vec<(Input, usize, u64)> = Vec::new();
    for u in log {
        match &u.kind {
            UpdateKind::Learn { input, label } => {
                run.push((input.clone(), *label, u.seq));
            }
            UpdateKind::ClauseFault { class, clause, force } => {
                flush_learn_run(c, &run, params, base_seed, scratch_c);
                run.clear();
                c.set_clause_fault(*class, *clause, *force);
            }
        }
    }
    flush_learn_run(c, &run, params, base_seed, scratch_c);
}

/// Seeded shard-update log (≈85% Learn, 15% clause-fault edits),
/// consuming sequence numbers from the replayer's log head.
fn gen_updates(shape: &TmShape, n: usize, seed: u64, next_seq: &mut u64) -> Vec<ShardUpdate> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let seq = *next_seq;
            *next_seq += 1;
            let kind = if rng.next_f32() < 0.85 {
                let bits = crate::testkit::gen::bool_vec(&mut rng, shape.features, 0.5);
                UpdateKind::Learn {
                    input: Input::pack(shape, &bits),
                    label: rng.next_below(shape.classes),
                }
            } else {
                UpdateKind::ClauseFault {
                    class: rng.next_below(shape.classes),
                    clause: rng.next_below(shape.max_clauses),
                    force: [None, Some(false), Some(true)][rng.next_below(3)],
                }
            };
            ShardUpdate { seq, kind }
        })
        .collect()
}

/// Full bit-identity comparison of two machines (states, action caches,
/// force gates, fault planes, digest).
fn diff(x: &MultiTm, y: &MultiTm, pair: &str) -> Result<(), String> {
    if x.ta().states() != y.ta().states() {
        return Err(format!("{pair}: TA states diverged"));
    }
    let s = x.shape();
    for c in 0..s.classes {
        for j in 0..s.max_clauses {
            if x.action_words(c, j) != y.action_words(c, j) {
                return Err(format!("{pair}: action cache diverged at ({c},{j})"));
            }
        }
    }
    if x.clause_force_codes() != y.clause_force_codes() {
        return Err(format!("{pair}: clause force gates diverged"));
    }
    if x.clause_fault_count() != y.clause_fault_count() {
        return Err(format!("{pair}: clause fault counters diverged"));
    }
    if x.fault().words() != y.fault().words() {
        return Err(format!("{pair}: fault gate planes diverged"));
    }
    if x.state_digest() != y.state_digest() {
        return Err(format!("{pair}: state digests diverged"));
    }
    Ok(())
}

/// Post-step identity + contract sweep: the three eager lanes against the
/// oracle, the lazy pair against each other, and (under the `contracts`
/// feature) a full invariant audit of every lane.
fn cross_check(
    step: usize,
    a: &MultiTm,
    b: &MultiTm,
    c: &MultiTm,
    d: &MultiTm,
    e: &MultiTm,
) -> Result<u64, Divergence> {
    let mut checks = 0u64;
    for (x, y, pair) in [(a, b, "oracle/fast"), (a, c, "oracle/lane"), (d, e, "lazy/lazy-lane")] {
        diff(x, y, pair).map_err(|what| Divergence { step, what })?;
        checks += 1;
    }
    #[cfg(feature = "contracts")]
    for (m, name) in [(a, "oracle"), (b, "fast"), (c, "lane"), (d, "lazy"), (e, "lazy-lane")] {
        super::contracts::check_invariants(m).map_err(|e2| Divergence {
            step,
            what: format!("contract violation on {name} lane: {e2}"),
        })?;
        checks += 1;
    }
    Ok(checks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Schedule {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0xBEEF);
        s.steps = vec![
            Step::Train { rows: 12, seed: 1 },
            Step::Infer { rows: 8, seed: 2 },
            Step::Force { class: 0, clause: 3, code: 1 },
            Step::Rescore { seed: 3 },
            Step::Fault { bp: 800, kind: 1, seed: 4 },
            Step::Train { rows: 6, seed: 5 },
            Step::Clone,
            Step::Serve { updates: 9, seed: 6 },
            Step::Checkpoint,
            Step::Rescore { seed: 7 },
            Step::Params { t: 5, s_bits: 1.0f32.to_bits(), active_clauses: 8, active_classes: 2 },
            Step::Train { rows: 5, seed: 8 },
        ];
        s
    }

    #[test]
    fn text_round_trips_exactly() {
        let s = demo();
        let text = s.to_text();
        let back = Schedule::parse(&text).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_text(), text);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Schedule::parse("").is_err());
        assert!(Schedule::parse("tmfpga-corpus v2\n").is_err());
        let mut text = demo().to_text();
        text.push_str("step train rows=1 seed=1\n");
        assert!(Schedule::parse(&text).is_err(), "content after end must be rejected");
        let text = demo().to_text().replace("step train", "step banana");
        assert!(Schedule::parse(&text).is_err());
        let text = demo().to_text().replace("rows=12", "rows=x");
        assert!(Schedule::parse(&text).is_err());
    }

    #[test]
    fn net_steps_round_trip_as_v2() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0xBEEF);
        s.steps = vec![
            Step::Train { rows: 6, seed: 1 },
            Step::Net { clients: 3, requests: 5, seed: 2 },
        ];
        let text = s.to_text();
        assert!(text.starts_with("tmfpga-corpus v2\n"), "net step must bump the header");
        let back = Schedule::parse(&text).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_text(), text);
        // The same step list under a v1 header must be rejected.
        let v1 = text.replace("tmfpga-corpus v2", "tmfpga-corpus v1");
        assert!(Schedule::parse(&v1).is_err(), "net step in a v1 fixture must fail");
        // A v2 header without net steps still parses (and re-emits v1).
        let plain = demo().to_text().replace("tmfpga-corpus v1", "tmfpga-corpus v2");
        let back = Schedule::parse(&plain).unwrap();
        assert_eq!(back, demo());
    }

    #[test]
    fn hub_steps_round_trip_as_v3() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0xBEEF);
        s.steps = vec![
            Step::Train { rows: 6, seed: 1 },
            Step::Hub { tenants: 3, updates: 10, seed: 2 },
        ];
        let text = s.to_text();
        assert!(text.starts_with("tmfpga-corpus v3\n"), "hub step must bump the header");
        let back = Schedule::parse(&text).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_text(), text);
        // The same step list under a v2 header must be rejected.
        let v2 = text.replace("tmfpga-corpus v3", "tmfpga-corpus v2");
        assert!(Schedule::parse(&v2).is_err(), "hub step in a v2 fixture must fail");
        // A v3 header without hub steps still parses (and re-emits v1).
        let plain = demo().to_text().replace("tmfpga-corpus v1", "tmfpga-corpus v3");
        let back = Schedule::parse(&plain).unwrap();
        assert_eq!(back, demo());
    }

    #[test]
    fn restart_steps_round_trip_as_v4() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0xBEEF);
        s.steps = vec![
            Step::Train { rows: 6, seed: 1 },
            Step::Restart { tenants: 2, updates: 9, seed: 2 },
        ];
        let text = s.to_text();
        assert!(text.starts_with("tmfpga-corpus v4\n"), "restart step must bump the header");
        let back = Schedule::parse(&text).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.to_text(), text);
        // The same step list under a v3 header must be rejected.
        let v3 = text.replace("tmfpga-corpus v4", "tmfpga-corpus v3");
        assert!(Schedule::parse(&v3).is_err(), "restart step in a v3 fixture must fail");
        // A v4 header without restart steps still parses (and re-emits v1).
        let plain = demo().to_text().replace("tmfpga-corpus v1", "tmfpga-corpus v4");
        let back = Schedule::parse(&plain).unwrap();
        assert_eq!(back, demo());
    }

    #[test]
    fn restart_step_replays_clean() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0x0D15);
        s.steps = vec![
            Step::Train { rows: 8, seed: 1 },
            Step::Restart { tenants: 2, updates: 10, seed: 2 },
            Step::Train { rows: 4, seed: 3 },
        ];
        let rep = replay(&s).unwrap();
        assert_eq!(rep.steps, 3);
        assert!(rep.checks > 0);
    }

    #[test]
    fn hub_step_replays_clean() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0x1B1B);
        s.steps = vec![
            Step::Train { rows: 8, seed: 1 },
            Step::Hub { tenants: 3, updates: 12, seed: 2 },
            Step::Train { rows: 4, seed: 3 },
        ];
        let rep = replay(&s).unwrap();
        assert_eq!(rep.steps, 3);
        assert!(rep.checks > 0);
    }

    #[test]
    fn net_step_replays_clean() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 0x5EED);
        s.steps = vec![
            Step::Train { rows: 8, seed: 1 },
            Step::Net { clients: 4, requests: 6, seed: 2 },
            Step::Train { rows: 4, seed: 3 },
        ];
        let rep = replay(&s).unwrap();
        assert_eq!(rep.steps, 3);
        assert!(rep.checks > 0);
    }

    #[test]
    fn demo_schedule_replays_clean() {
        let rep = replay(&demo()).unwrap();
        assert_eq!(rep.steps, demo().steps.len());
        assert!(rep.checks > 0);
    }

    #[test]
    fn injection_without_force_gate_is_inert() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 7);
        s.steps = vec![Step::Train { rows: 10, seed: 1 }, Step::Train { rows: 10, seed: 2 }];
        let opts = ReplayOptions { inject_train_offby1: true };
        assert!(replay_opts(&s, &opts).is_ok(), "no force gate -> no injection");
    }

    #[test]
    fn injection_with_force_gate_diverges() {
        let shape = TmShape::iris();
        let mut s = Schedule::new(&shape, 7);
        s.steps = vec![
            Step::Force { class: 1, clause: 2, code: 0 },
            Step::Train { rows: 4, seed: 1 },
        ];
        assert!(replay(&s).is_ok(), "clean replay must pass");
        let opts = ReplayOptions { inject_train_offby1: true };
        let d = replay_opts(&s, &opts).unwrap_err();
        assert_eq!(d.step, 1, "divergence surfaces at the train step");
    }
}
