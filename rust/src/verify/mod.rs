//! Unified verification harness (ROADMAP item 5): machine-level
//! invariant **contracts**, a replayable scenario **corpus** asserting
//! bit-identity across every engine pair, and seeded corpus **growth**
//! with delta-debugging shrink of any divergence to a minimal committed
//! fixture.
//!
//! - [`contracts`] — `check_invariants(&MultiTm)` plus feature-gated
//!   hooks (`--features contracts`) wired into the mutation hot paths;
//!   zero release-path cost when the feature is off.
//! - [`corpus`] — the schedule language (`rust/tests/corpus/*.ron`), the
//!   five-lane replayer, and the divergence report.
//! - [`shrink`] — seeded schedule generation, ddmin minimization, and
//!   fixture writing; driven by `tmfpga verify --grow` in CI.
//!
//! EXPERIMENTS.md §Verification documents the contract list, the fixture
//! format, and how a new engine joins the replay matrix.

pub mod contracts;
pub mod corpus;
pub mod shrink;
