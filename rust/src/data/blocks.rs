//! Block memory manager — cross-validation infrastructure (paper §3.6.1).
//!
//! The full dataset is split into *blocks* whose length divides every set
//! size; blocks are combined in different *orderings* to form the three
//! sets (offline training / validation / online training), the experiment
//! is re-run per ordering and results averaged. For iris: 150 rows → 5
//! blocks of 30; sets of 30/60/60 rows; 5! = 120 orderings.
//!
//! Blocks are **stratified**: each class is dealt round-robin so every
//! block carries an equal class mix — the paper's mitigation for "uneven
//! distributions of classes and patterns across these three sets".

use crate::data::dataset::BoolDataset;
use crate::tm::bitplane::PlaneBatch;
use crate::tm::clause::Input;
use crate::tm::params::TmShape;
use crate::tm::rng::Xoshiro256;
use anyhow::{bail, Result};

/// A dataset divided into equal, class-stratified blocks.
#[derive(Debug, Clone)]
pub struct BlockPlan {
    blocks: Vec<BoolDataset>,
}

/// How many blocks each set receives, in order
/// (offline training, validation, online training).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SetAllocation {
    pub offline: usize,
    pub validation: usize,
    pub online: usize,
}

impl SetAllocation {
    /// The paper's iris allocation: 30/60/60 rows = 1/2/2 blocks of 30.
    pub fn paper() -> Self {
        SetAllocation { offline: 1, validation: 2, online: 2 }
    }

    pub fn total(&self) -> usize {
        self.offline + self.validation + self.online
    }
}

/// The three data sets (§3.6.1) produced by one block ordering.
#[derive(Debug, Clone)]
pub struct Sets {
    pub offline: BoolDataset,
    pub validation: BoolDataset,
    pub online: BoolDataset,
}

/// One ordering's three sets packed for a machine shape, with the
/// literal-major bitplane transpose of each set cached alongside
/// ([`crate::tm::bitplane`]): cross-validation drivers that rescore the
/// same fold at many analysis points (sweep grids, figure sweeps) pay
/// the pack + transpose exactly once per ordering.
#[derive(Debug, Clone)]
pub struct PackedSets {
    pub offline: Vec<(Input, usize)>,
    pub validation: Vec<(Input, usize)>,
    pub online: Vec<(Input, usize)>,
    pub offline_planes: PlaneBatch,
    pub validation_planes: PlaneBatch,
    pub online_planes: PlaneBatch,
}

impl Sets {
    /// Pack all three sets and transpose each into cached bitplanes.
    pub fn pack_planes(&self, shape: &TmShape) -> PackedSets {
        let offline = self.offline.pack(shape);
        let validation = self.validation.pack(shape);
        let online = self.online.pack(shape);
        PackedSets {
            offline_planes: PlaneBatch::from_labelled(shape, &offline),
            validation_planes: PlaneBatch::from_labelled(shape, &validation),
            online_planes: PlaneBatch::from_labelled(shape, &online),
            offline,
            validation,
            online,
        }
    }
}

impl BlockPlan {
    /// Split `data` into `n_blocks` stratified blocks. Every class count
    /// must be divisible by `n_blocks` (iris: 50 per class / 5 = 10).
    /// `seed` shuffles within each class before dealing.
    pub fn stratified(data: &BoolDataset, n_blocks: usize, seed: u64) -> Result<Self> {
        if n_blocks == 0 || data.len() % n_blocks != 0 {
            bail!("{} rows not divisible into {n_blocks} blocks", data.len());
        }
        let counts = data.class_counts();
        for (c, &n) in counts.iter().enumerate() {
            if n % n_blocks != 0 {
                bail!("class {c} has {n} rows, not divisible by {n_blocks}");
            }
        }
        // Per-class index pools, shuffled.
        let mut rng = Xoshiro256::new(seed);
        let mut pools: Vec<Vec<usize>> = vec![Vec::new(); data.n_classes];
        for (i, &l) in data.labels.iter().enumerate() {
            pools[l].push(i);
        }
        for p in pools.iter_mut() {
            rng.shuffle(p);
        }
        // Deal round-robin into blocks; then shuffle each block's row
        // order so class runs don't align inside a block.
        let mut block_idx: Vec<Vec<usize>> = vec![Vec::new(); n_blocks];
        for pool in &pools {
            for (i, &row) in pool.iter().enumerate() {
                block_idx[i % n_blocks].push(row);
            }
        }
        for b in block_idx.iter_mut() {
            rng.shuffle(b);
        }
        Ok(BlockPlan { blocks: block_idx.iter().map(|idx| data.subset(idx)).collect() })
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn block_len(&self) -> usize {
        self.blocks[0].len()
    }

    pub fn block(&self, i: usize) -> &BoolDataset {
        &self.blocks[i]
    }

    /// Assemble the three sets from an ordering of block ids.
    pub fn sets(&self, ordering: &[usize], alloc: SetAllocation) -> Result<Sets> {
        if ordering.len() != self.n_blocks() || alloc.total() != self.n_blocks() {
            bail!(
                "ordering ({}) and allocation ({}) must cover all {} blocks",
                ordering.len(),
                alloc.total(),
                self.n_blocks()
            );
        }
        let mut seen = vec![false; self.n_blocks()];
        for &b in ordering {
            if b >= self.n_blocks() || seen[b] {
                bail!("ordering is not a permutation of block ids");
            }
            seen[b] = true;
        }
        let gather = |ids: &[usize]| {
            let parts: Vec<&BoolDataset> = ids.iter().map(|&b| &self.blocks[b]).collect();
            BoolDataset::concat(&parts)
        };
        let (off, rest) = ordering.split_at(alloc.offline);
        let (val, onl) = rest.split_at(alloc.validation);
        Ok(Sets { offline: gather(off), validation: gather(val), online: gather(onl) })
    }
}

/// All `n!` orderings of `0..n` (Heap's algorithm). For the paper's 5
/// blocks this is the full 120-ordering sweep.
pub fn all_orderings(n: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut a: Vec<usize> = (0..n).collect();
    fn heap(k: usize, a: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if k <= 1 {
            out.push(a.clone());
            return;
        }
        for i in 0..k {
            heap(k - 1, a, out);
            if k % 2 == 0 {
                a.swap(i, k - 1);
            } else {
                a.swap(0, k - 1);
            }
        }
    }
    heap(n, &mut a, &mut out);
    out
}

/// The paper's §3.6.1 mechanism: a small set of *starting orderings*
/// "easily manipulated to produce the full number of orderings". We use
/// cyclic rotation as the manipulation: [`rotation_representatives`]
/// yields the `n!/n` lexicographically-minimal representatives, and
/// [`expand_rotations`] rotates each `n` times to regenerate all `n!`.
pub fn rotation_representatives(n: usize) -> Vec<Vec<usize>> {
    let mut reps = Vec::new();
    for p in all_orderings(n) {
        let mut min_rot = p.clone();
        for r in 1..n {
            let rot: Vec<usize> = p[r..].iter().chain(p[..r].iter()).copied().collect();
            if rot < min_rot {
                min_rot = rot;
            }
        }
        if min_rot == p {
            reps.push(p);
        }
    }
    reps
}

/// Expand starting orderings by all cyclic rotations.
pub fn expand_rotations(starting: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for p in starting {
        let n = p.len();
        for r in 0..n {
            out.push(p[r..].iter().chain(p[..r].iter()).copied().collect());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::iris;

    #[test]
    fn iris_splits_into_5_stratified_blocks() {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 1).unwrap();
        assert_eq!(plan.n_blocks(), 5);
        assert_eq!(plan.block_len(), 30);
        for b in 0..5 {
            assert_eq!(plan.block(b).class_counts(), vec![10, 10, 10]);
        }
    }

    #[test]
    fn blocks_partition_the_dataset() {
        let data = iris::booleanised();
        let plan = BlockPlan::stratified(data, 5, 2).unwrap();
        let mut all_rows: Vec<Vec<bool>> = Vec::new();
        for b in 0..5 {
            all_rows.extend(plan.block(b).rows.iter().cloned());
        }
        assert_eq!(all_rows.len(), 150);
        // Row multiset must match (iris has duplicate rows, so compare
        // sorted encodings).
        let key = |r: &Vec<bool>| r.iter().fold(0u32, |a, &b| a << 1 | b as u32);
        let mut got: Vec<u32> = all_rows.iter().map(key).collect();
        let mut want: Vec<u32> = data.rows.iter().map(key).collect();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn indivisible_counts_rejected() {
        let data = iris::booleanised();
        assert!(BlockPlan::stratified(data, 7, 0).is_err());
        let mut odd = data.clone();
        odd.rows.pop();
        odd.labels.pop();
        assert!(BlockPlan::stratified(&odd, 5, 0).is_err());
    }

    #[test]
    fn paper_set_sizes() {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 3).unwrap();
        let sets = plan.sets(&[0, 1, 2, 3, 4], SetAllocation::paper()).unwrap();
        assert_eq!(sets.offline.len(), 30);
        assert_eq!(sets.validation.len(), 60);
        assert_eq!(sets.online.len(), 60);
        // Stratification carries through.
        assert_eq!(sets.offline.class_counts(), vec![10, 10, 10]);
        assert_eq!(sets.online.class_counts(), vec![20, 20, 20]);
    }

    #[test]
    fn bad_orderings_rejected() {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 3).unwrap();
        let alloc = SetAllocation::paper();
        assert!(plan.sets(&[0, 1, 2, 3], alloc).is_err(), "too short");
        assert!(plan.sets(&[0, 1, 2, 3, 3], alloc).is_err(), "repeat");
        assert!(plan.sets(&[0, 1, 2, 3, 9], alloc).is_err(), "out of range");
    }

    #[test]
    fn all_orderings_is_full_permutation_set() {
        let perms = all_orderings(5);
        assert_eq!(perms.len(), 120, "the paper's 120 cross-correlated orderings");
        let mut uniq = perms.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 120);
    }

    #[test]
    fn rotation_machinery_regenerates_all() {
        let reps = rotation_representatives(5);
        assert_eq!(reps.len(), 24, "120 / 5 rotation classes");
        let mut expanded = expand_rotations(&reps);
        assert_eq!(expanded.len(), 120);
        expanded.sort();
        expanded.dedup();
        assert_eq!(expanded.len(), 120, "rotations regenerate all orderings");
    }

    #[test]
    fn different_orderings_give_different_offline_sets() {
        let plan = BlockPlan::stratified(iris::booleanised(), 5, 3).unwrap();
        let alloc = SetAllocation::paper();
        let a = plan.sets(&[0, 1, 2, 3, 4], alloc).unwrap();
        let b = plan.sets(&[4, 1, 2, 3, 0], alloc).unwrap();
        assert_ne!(a.offline.rows, b.offline.rows);
    }
}
