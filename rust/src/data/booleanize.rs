//! Thermometer booleanisation.
//!
//! The paper evaluates on "the iris dataset (16 booleanised inputs, 3
//! classifications, 150 unique datapoints)" — 4 real features × 4 bits.
//! We use quantile-threshold (thermometer) encoding, the standard TM
//! booleanisation: for each feature, `bits` thresholds at the
//! `q/(bits+1)` quantiles of the training distribution; bit `b` is
//! `x > threshold_b`. Thresholds are fitted once at design time (they
//! would be baked into the FPGA input path) and stored in [`Booleanizer`].

use crate::data::dataset::{BoolDataset, RawDataset};
use anyhow::{bail, Result};

/// Fitted thermometer encoder.
#[derive(Debug, Clone, PartialEq)]
pub struct Booleanizer {
    /// `thresholds[f]` = ascending thresholds for feature `f`.
    thresholds: Vec<Vec<f32>>,
    bits_per_feature: usize,
}

impl Booleanizer {
    /// Fit thresholds on a dataset: for each feature, the
    /// `q/(bits+1)`-quantiles (q = 1..=bits) of the empirical
    /// distribution (linear interpolation between order statistics).
    pub fn fit(data: &RawDataset, bits_per_feature: usize) -> Result<Self> {
        if bits_per_feature == 0 {
            bail!("bits_per_feature must be > 0");
        }
        let nf = data.n_features();
        let mut thresholds = Vec::with_capacity(nf);
        for f in 0..nf {
            let mut col: Vec<f32> = data.rows.iter().map(|r| r[f]).collect();
            col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut th = Vec::with_capacity(bits_per_feature);
            for q in 1..=bits_per_feature {
                let p = q as f64 / (bits_per_feature + 1) as f64;
                th.push(quantile_sorted(&col, p));
            }
            thresholds.push(th);
        }
        Ok(Booleanizer { thresholds, bits_per_feature })
    }

    pub fn bits_per_feature(&self) -> usize {
        self.bits_per_feature
    }

    pub fn n_features(&self) -> usize {
        self.thresholds.len()
    }

    /// Output width in Boolean inputs.
    pub fn width(&self) -> usize {
        self.n_features() * self.bits_per_feature
    }

    pub fn thresholds(&self) -> &[Vec<f32>] {
        &self.thresholds
    }

    /// Encode one raw row.
    pub fn encode_row(&self, row: &[f32]) -> Result<Vec<bool>> {
        if row.len() != self.n_features() {
            bail!("row width {} != fitted {}", row.len(), self.n_features());
        }
        let mut out = Vec::with_capacity(self.width());
        for (f, &x) in row.iter().enumerate() {
            for &t in &self.thresholds[f] {
                out.push(x > t);
            }
        }
        Ok(out)
    }

    /// Encode a whole dataset.
    pub fn encode(&self, data: &RawDataset) -> Result<BoolDataset> {
        let rows: Result<Vec<Vec<bool>>> =
            data.rows.iter().map(|r| self.encode_row(r)).collect();
        Ok(BoolDataset { rows: rows?, labels: data.labels.clone(), n_classes: data.n_classes })
    }
}

/// Binary-code booleanisation: each feature is min-max normalised,
/// quantised to `2^bits - 1` levels and emitted as a plain binary code
/// (MSB first).
///
/// This is the encoding used by the TM-FPGA hardware line (each iris
/// feature as a 4-bit binary value → 16 Boolean inputs) and is what
/// reproduces the paper's starting accuracies; thermometer encoding
/// ([`Booleanizer`]) makes iris markedly easier (~+8% accuracy) — the
/// ablation bench `benches/ablations.rs` quantifies the gap.
#[derive(Debug, Clone, PartialEq)]
pub struct BinaryBooleanizer {
    mins: Vec<f32>,
    maxs: Vec<f32>,
    bits_per_feature: usize,
}

impl BinaryBooleanizer {
    /// Fit per-feature min/max on a dataset.
    pub fn fit(data: &RawDataset, bits_per_feature: usize) -> Result<Self> {
        if bits_per_feature == 0 || bits_per_feature > 16 {
            bail!("bits_per_feature must be in 1..=16");
        }
        let nf = data.n_features();
        let mut mins = vec![f32::MAX; nf];
        let mut maxs = vec![f32::MIN; nf];
        for row in &data.rows {
            for (f, &x) in row.iter().enumerate() {
                mins[f] = mins[f].min(x);
                maxs[f] = maxs[f].max(x);
            }
        }
        Ok(BinaryBooleanizer { mins, maxs, bits_per_feature })
    }

    pub fn bits_per_feature(&self) -> usize {
        self.bits_per_feature
    }

    pub fn n_features(&self) -> usize {
        self.mins.len()
    }

    pub fn width(&self) -> usize {
        self.n_features() * self.bits_per_feature
    }

    /// Quantisation level of one value (clamped to the fitted range).
    pub fn level(&self, feature: usize, x: f32) -> u32 {
        let (lo, hi) = (self.mins[feature], self.maxs[feature]);
        let max_level = (1u32 << self.bits_per_feature) - 1;
        if hi <= lo {
            return 0; // constant feature
        }
        let t = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
        (t * max_level as f32).round() as u32
    }

    /// Encode one raw row (MSB-first binary code per feature).
    pub fn encode_row(&self, row: &[f32]) -> Result<Vec<bool>> {
        if row.len() != self.n_features() {
            bail!("row width {} != fitted {}", row.len(), self.n_features());
        }
        let mut out = Vec::with_capacity(self.width());
        for (f, &x) in row.iter().enumerate() {
            let q = self.level(f, x);
            for b in (0..self.bits_per_feature).rev() {
                out.push(q >> b & 1 == 1);
            }
        }
        Ok(out)
    }

    /// Encode a whole dataset.
    pub fn encode(&self, data: &RawDataset) -> Result<BoolDataset> {
        let rows: Result<Vec<Vec<bool>>> =
            data.rows.iter().map(|r| self.encode_row(r)).collect();
        Ok(BoolDataset { rows: rows?, labels: data.labels.clone(), n_classes: data.n_classes })
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice.
fn quantile_sorted(sorted: &[f32], p: f64) -> f32 {
    assert!(!sorted.is_empty());
    let h = p * (sorted.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = (h - lo as f64) as f32;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp_dataset() -> RawDataset {
        // Feature 0: 0..100; feature 1: constant 5.0.
        let rows: Vec<Vec<f32>> = (0..100).map(|i| vec![i as f32, 5.0]).collect();
        RawDataset::new(rows, vec![0; 100], 1).unwrap()
    }

    #[test]
    fn quantiles_of_ramp() {
        let b = Booleanizer::fit(&ramp_dataset(), 4).unwrap();
        let th = &b.thresholds()[0];
        assert_eq!(th.len(), 4);
        // Quantiles at 0.2/0.4/0.6/0.8 of 0..99.
        for (i, expect) in [19.8f32, 39.6, 59.4, 79.2].iter().enumerate() {
            assert!((th[i] - expect).abs() < 0.5, "th[{i}]={} want≈{expect}", th[i]);
        }
    }

    #[test]
    fn thermometer_monotone() {
        let b = Booleanizer::fit(&ramp_dataset(), 4).unwrap();
        // Thermometer property: bits are a prefix of 1s (descending with
        // threshold index).
        for x in [0.0f32, 25.0, 50.0, 75.0, 99.0] {
            let bits = b.encode_row(&[x, 5.0]).unwrap();
            let f0 = &bits[0..4];
            let mut seen_false = false;
            for &bit in f0 {
                if seen_false {
                    assert!(!bit, "thermometer code must be monotone for x={x}");
                }
                seen_false |= !bit;
            }
        }
        // Extremes.
        assert_eq!(b.encode_row(&[-1.0, 5.0]).unwrap()[0..4], [false; 4]);
        assert_eq!(b.encode_row(&[1000.0, 5.0]).unwrap()[0..4], [true; 4]);
    }

    #[test]
    fn constant_feature_encodes_all_false() {
        let b = Booleanizer::fit(&ramp_dataset(), 4).unwrap();
        // Feature 1 constant 5.0: thresholds all 5.0; 5.0 > 5.0 is false.
        let bits = b.encode_row(&[50.0, 5.0]).unwrap();
        assert_eq!(&bits[4..8], &[false; 4]);
    }

    #[test]
    fn width_and_errors() {
        let b = Booleanizer::fit(&ramp_dataset(), 4).unwrap();
        assert_eq!(b.width(), 8);
        assert!(b.encode_row(&[1.0]).is_err());
        assert!(Booleanizer::fit(&ramp_dataset(), 0).is_err());
    }

    #[test]
    fn binary_levels_span_range() {
        let d = ramp_dataset();
        let b = BinaryBooleanizer::fit(&d, 4).unwrap();
        assert_eq!(b.level(0, 0.0), 0);
        assert_eq!(b.level(0, 99.0), 15);
        assert_eq!(b.level(0, 49.5), 8, "midpoint rounds to 8");
        // Clamping outside the fitted range.
        assert_eq!(b.level(0, -10.0), 0);
        assert_eq!(b.level(0, 1000.0), 15);
        // Constant feature collapses to level 0.
        assert_eq!(b.level(1, 5.0), 0);
    }

    #[test]
    fn binary_code_msb_first() {
        let d = ramp_dataset();
        let b = BinaryBooleanizer::fit(&d, 4).unwrap();
        // x = 99 -> level 15 -> 1111; x = 0 -> 0000.
        assert_eq!(b.encode_row(&[99.0, 5.0]).unwrap()[0..4], [true; 4]);
        assert_eq!(b.encode_row(&[0.0, 5.0]).unwrap()[0..4], [false; 4]);
        // level 8 -> 1000 (MSB first).
        let bits = b.encode_row(&[49.5, 5.0]).unwrap();
        assert_eq!(&bits[0..4], &[true, false, false, false]);
    }

    #[test]
    fn binary_encode_dataset() {
        let d = ramp_dataset();
        let b = BinaryBooleanizer::fit(&d, 4).unwrap();
        let e = b.encode(&d).unwrap();
        assert_eq!(e.n_features(), 8);
        assert_eq!(e.len(), 100);
        assert!(BinaryBooleanizer::fit(&d, 0).is_err());
        assert!(BinaryBooleanizer::fit(&d, 17).is_err());
        assert!(b.encode_row(&[1.0]).is_err());
    }

    #[test]
    fn encode_dataset_preserves_labels() {
        let d = ramp_dataset();
        let b = Booleanizer::fit(&d, 2).unwrap();
        let e = b.encode(&d).unwrap();
        assert_eq!(e.len(), 100);
        assert_eq!(e.n_features(), 4);
        assert_eq!(e.labels, d.labels);
    }
}
