//! Online data input subsystem (paper §3.5) — behavioural layer.
//!
//! The online data source is application dependent; the paper abstracts it
//! into: an *input parser* producing rows from the concrete source (here
//! on-chip ROM, as in the paper's experiments), a *cyclic buffer* that
//! holds rows while the TM is busy with accuracy analysis (so "datapoints
//! [are not] ignored by the system"), and the *online data manager* that
//! serves rows to TM management on request.
//!
//! The cycle-level twins of these live in `fpga::online`; this module
//! carries the source/buffer semantics shared by both paths.

use crate::data::dataset::BoolDataset;
use crate::data::filter::ClassFilter;
use crate::tm::rng::Xoshiro256;
use anyhow::{bail, ensure, Result};

/// Anything that can produce online datapoints (the paper's replaceable
/// input-parser IP: ROM today, UART/Ethernet via the MCU tomorrow).
pub trait OnlineSource {
    /// Produce the next row, or `None` if the source is (currently) dry.
    fn next_row(&mut self) -> Option<(Vec<bool>, usize)>;
    /// Rows produced so far.
    fn produced(&self) -> usize;
}

/// ROM-backed source: cycles through a stored set row by row, applying the
/// class-filter IP on the way out (§3.5: "This also included the filter IP
/// discussed for the Offline Data Input subsystem").
#[derive(Debug, Clone)]
pub struct RomSource {
    data: BoolDataset,
    pos: usize,
    produced: usize,
    pub filter: ClassFilter,
}

impl RomSource {
    pub fn new(data: BoolDataset, filter: ClassFilter) -> Result<Self> {
        if data.is_empty() {
            bail!("RomSource: empty dataset");
        }
        Ok(RomSource { data, pos: 0, produced: 0, filter })
    }

    /// Length of one full pass over the stored set (unfiltered).
    pub fn rom_len(&self) -> usize {
        self.data.len()
    }
}

impl OnlineSource for RomSource {
    fn next_row(&mut self) -> Option<(Vec<bool>, usize)> {
        // Skip filtered rows; guaranteed to terminate unless the filter
        // rejects everything — then report dry after one full scan.
        for _ in 0..self.data.len() {
            let i = self.pos;
            self.pos = (self.pos + 1) % self.data.len();
            if self.filter.passes(self.data.labels[i]) {
                self.produced += 1;
                return Some((self.data.rows[i].clone(), self.data.labels[i]));
            }
        }
        None
    }

    fn produced(&self) -> usize {
        self.produced
    }
}

/// Fixed-capacity cyclic (ring) buffer (§3.5.2). Overflow drops the
/// **newest** arrival (the RTL cannot stall an external sensor) and counts
/// it, so experiments can report data loss.
#[derive(Debug, Clone)]
pub struct CyclicBuffer<T> {
    slots: Vec<Option<T>>,
    head: usize, // next pop
    len: usize,
    dropped: usize,
}

impl<T> CyclicBuffer<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "buffer needs capacity");
        CyclicBuffer {
            slots: (0..capacity).map(|_| None).collect(),
            head: 0,
            len: 0,
            dropped: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.capacity()
    }

    /// Datapoints lost to overflow so far.
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Push a row; returns `false` (and counts a drop) when full.
    pub fn push(&mut self, item: T) -> bool {
        if self.is_full() {
            self.dropped += 1;
            return false;
        }
        let tail = (self.head + self.len) % self.capacity();
        self.slots[tail] = Some(item);
        self.len += 1;
        true
    }

    /// Pop the oldest row.
    pub fn pop(&mut self) -> Option<T> {
        if self.is_empty() {
            return None;
        }
        let item = self.slots[self.head].take();
        self.head = (self.head + 1) % self.capacity();
        self.len -= 1;
        item
    }
}

/// One event of a synthetic request-arrival trace: a row from the
/// modular input interface stamped with a virtual arrival tick.
/// `label: Some(_)` means the sample arrived labelled (an online-learning
/// update for the serving layer); `None` means it is a pure inference
/// request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub at_tick: u64,
    pub bits: Vec<bool>,
    pub label: Option<usize>,
}

/// Shape of a synthetic arrival trace.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Events to generate (fewer if the source runs dry).
    pub events: usize,
    /// Probability that a row arrives labelled (0 ⇒ pure inference
    /// traffic, 1 ⇒ pure online-training traffic).
    pub labelled_fraction: f32,
    /// Mean inter-arrival gap in virtual ticks. Gaps are geometric
    /// (the discrete memoryless distribution — Poisson-ish arrivals on
    /// a tick clock); 0 pins every event to tick 0 (a burst).
    pub mean_gap: f64,
    /// Seed of the trace's own generator (arrival times and labelling
    /// are independent of the data source).
    pub seed: u64,
}

/// A generated arrival trace: events with non-decreasing ticks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalTrace {
    pub events: Vec<TraceEvent>,
}

/// Largest geometric gap the sampler will emit (keeps a pathological
/// `mean_gap` from spinning; the tail beyond this is astronomically
/// unlikely for any sane mean).
const MAX_GAP: u64 = 1 << 20;

/// Generate a synthetic arrival trace by pulling rows from any
/// [`OnlineSource`] (the paper's replaceable input-parser IP) and
/// stamping them with seeded geometric inter-arrival gaps and a seeded
/// labelled/unlabelled coin. Fully deterministic in
/// `(source state, cfg)` — gap sampling counts Bernoulli failures
/// instead of taking logarithms, so the trace is bit-reproducible across
/// platforms. Stops early (without error) if the source runs dry.
pub fn arrival_trace<S: OnlineSource>(source: &mut S, cfg: &TraceConfig) -> Result<ArrivalTrace> {
    ensure!(
        (0.0..=1.0).contains(&cfg.labelled_fraction),
        "TraceConfig: labelled_fraction must be in [0, 1], got {}",
        cfg.labelled_fraction
    );
    ensure!(
        cfg.mean_gap >= 0.0 && cfg.mean_gap.is_finite(),
        "TraceConfig: mean_gap must be finite and >= 0, got {}",
        cfg.mean_gap
    );
    let mut rng = Xoshiro256::new(cfg.seed);
    // Geometric success probability with the requested mean gap.
    let p = (1.0 / (1.0 + cfg.mean_gap)) as f32;
    let mut tick = 0u64;
    let mut events = Vec::with_capacity(cfg.events);
    while events.len() < cfg.events {
        let Some((bits, label)) = source.next_row() else { break };
        let labelled = rng.next_f32() < cfg.labelled_fraction;
        events.push(TraceEvent { at_tick: tick, bits, label: labelled.then_some(label) });
        let mut gap = 0u64;
        while gap < MAX_GAP && rng.next_f32() >= p {
            gap += 1;
        }
        tick += gap;
    }
    Ok(ArrivalTrace { events })
}

/// The online data manager (§3.5.1): pulls from the source into the
/// buffer, serves TM-management requests from the buffer.
pub struct OnlineDataManager<S: OnlineSource> {
    source: S,
    pub buffer: CyclicBuffer<(Vec<bool>, usize)>,
}

impl<S: OnlineSource> OnlineDataManager<S> {
    pub fn new(source: S, buffer_capacity: usize) -> Self {
        OnlineDataManager { source, buffer: CyclicBuffer::new(buffer_capacity) }
    }

    /// Model the source producing `n` rows while the TM is busy (e.g.
    /// during accuracy analysis). Rows land in the buffer; overflow is
    /// counted there.
    pub fn produce(&mut self, n: usize) {
        for _ in 0..n {
            match self.source.next_row() {
                Some(row) => {
                    self.buffer.push(row);
                }
                None => break,
            }
        }
    }

    /// TM management requests one row: serve buffered data first, else
    /// pull from the source directly.
    pub fn request_row(&mut self) -> Option<(Vec<bool>, usize)> {
        self.buffer.pop().or_else(|| self.source.next_row())
    }

    pub fn source(&self) -> &S {
        &self.source
    }

    pub fn source_mut(&mut self) -> &mut S {
        &mut self.source
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::iris;

    fn tiny() -> BoolDataset {
        BoolDataset {
            rows: vec![vec![true], vec![false], vec![true]],
            labels: vec![0, 1, 2],
            n_classes: 3,
        }
    }

    #[test]
    fn rom_source_cycles() {
        let mut s = RomSource::new(tiny(), ClassFilter::disabled()).unwrap();
        let labels: Vec<usize> = (0..7).map(|_| s.next_row().unwrap().1).collect();
        assert_eq!(labels, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(s.produced(), 7);
    }

    #[test]
    fn rom_source_filters() {
        let mut s = RomSource::new(tiny(), ClassFilter::removing(1)).unwrap();
        let labels: Vec<usize> = (0..4).map(|_| s.next_row().unwrap().1).collect();
        assert_eq!(labels, vec![0, 2, 0, 2]);
    }

    #[test]
    fn rom_source_filter_liftable_midstream() {
        let mut s = RomSource::new(tiny(), ClassFilter::removing(1)).unwrap();
        assert_eq!(s.next_row().unwrap().1, 0);
        s.filter.set_enabled(false); // the new class appears (§5.2)
        assert_eq!(s.next_row().unwrap().1, 1);
    }

    #[test]
    fn rom_source_all_filtered_is_dry() {
        let one = BoolDataset { rows: vec![vec![true]], labels: vec![0], n_classes: 1 };
        let mut s = RomSource::new(one, ClassFilter::removing(0)).unwrap();
        assert!(s.next_row().is_none());
        assert!(RomSource::new(
            BoolDataset { rows: vec![], labels: vec![], n_classes: 1 },
            ClassFilter::disabled()
        )
        .is_err());
    }

    #[test]
    fn cyclic_buffer_fifo() {
        let mut b = CyclicBuffer::new(3);
        assert!(b.is_empty());
        assert!(b.push(1) && b.push(2) && b.push(3));
        assert!(b.is_full());
        assert!(!b.push(4), "overflow rejected");
        assert_eq!(b.dropped(), 1);
        assert_eq!(b.pop(), Some(1));
        assert!(b.push(5));
        assert_eq!(b.pop(), Some(2));
        assert_eq!(b.pop(), Some(3));
        assert_eq!(b.pop(), Some(5));
        assert_eq!(b.pop(), None);
    }

    #[test]
    fn cyclic_buffer_wraps_many_times() {
        let mut b = CyclicBuffer::new(4);
        for i in 0..100 {
            assert!(b.push(i));
            assert_eq!(b.pop(), Some(i));
        }
        assert_eq!(b.dropped(), 0);
    }

    #[test]
    fn manager_buffers_during_analysis() {
        let d = iris::booleanised().clone();
        let src = RomSource::new(d, ClassFilter::disabled()).unwrap();
        let mut mgr = OnlineDataManager::new(src, 8);
        // TM busy: source produces 5 rows into the buffer.
        mgr.produce(5);
        assert_eq!(mgr.buffer.len(), 5);
        // TM management drains buffered rows first (arrival order kept).
        let first = mgr.request_row().unwrap();
        assert_eq!(first.1, iris::booleanised().labels[0]);
        for _ in 0..4 {
            mgr.request_row().unwrap();
        }
        assert!(mgr.buffer.is_empty());
        // Next request pulls straight from the source.
        assert!(mgr.request_row().is_some());
        assert_eq!(mgr.source().produced(), 6 + 0 + 0 + 5 - 5 + 0); // 5 produced + 1 direct
    }

    #[test]
    fn arrival_trace_is_deterministic_and_monotone() {
        let cfg = TraceConfig {
            events: 200,
            labelled_fraction: 0.3,
            mean_gap: 2.0,
            seed: 0xACE,
        };
        let mut s1 = RomSource::new(iris::booleanised().clone(), ClassFilter::disabled())
            .unwrap();
        let mut s2 = s1.clone();
        let a = arrival_trace(&mut s1, &cfg).unwrap();
        let b = arrival_trace(&mut s2, &cfg).unwrap();
        assert_eq!(a, b, "same seed + source state => same trace");
        assert_eq!(a.events.len(), 200);
        for w in a.events.windows(2) {
            assert!(w[0].at_tick <= w[1].at_tick, "ticks must be non-decreasing");
        }
        let labelled = a.events.iter().filter(|e| e.label.is_some()).count();
        assert!(
            (30..=90).contains(&labelled),
            "labelled fraction way off: {labelled}/200"
        );
        // Mean gap in the right ballpark (geometric with mean 2).
        let span = a.events.last().unwrap().at_tick;
        let mean = span as f64 / 199.0;
        assert!((1.0..=3.5).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn arrival_trace_edge_fractions_and_burst() {
        let mut src =
            RomSource::new(iris::booleanised().clone(), ClassFilter::disabled()).unwrap();
        let burst = arrival_trace(
            &mut src,
            &TraceConfig { events: 50, labelled_fraction: 0.0, mean_gap: 0.0, seed: 1 },
        )
        .unwrap();
        assert!(burst.events.iter().all(|e| e.at_tick == 0), "mean_gap 0 is a burst");
        assert!(burst.events.iter().all(|e| e.label.is_none()));
        let all_labelled = arrival_trace(
            &mut src,
            &TraceConfig { events: 50, labelled_fraction: 1.0, mean_gap: 1.0, seed: 1 },
        )
        .unwrap();
        assert!(all_labelled.events.iter().all(|e| e.label.is_some()));
        // Invalid configs are rejected.
        let bad = TraceConfig { events: 1, labelled_fraction: 1.5, mean_gap: 1.0, seed: 1 };
        assert!(arrival_trace(&mut src, &bad).is_err());
        let bad = TraceConfig { events: 1, labelled_fraction: 0.5, mean_gap: -1.0, seed: 1 };
        assert!(arrival_trace(&mut src, &bad).is_err());
    }

    #[test]
    fn arrival_trace_stops_when_source_dries() {
        let one = BoolDataset { rows: vec![vec![true]], labels: vec![0], n_classes: 1 };
        let mut src = RomSource::new(one, ClassFilter::removing(0)).unwrap();
        let t = arrival_trace(
            &mut src,
            &TraceConfig { events: 10, labelled_fraction: 0.5, mean_gap: 1.0, seed: 2 },
        )
        .unwrap();
        assert!(t.events.is_empty(), "dry source => empty trace, no error");
    }

    #[test]
    fn manager_overflow_counts_lost_datapoints() {
        let d = iris::booleanised().clone();
        let src = RomSource::new(d, ClassFilter::disabled()).unwrap();
        let mut mgr = OnlineDataManager::new(src, 4);
        mgr.produce(10);
        assert_eq!(mgr.buffer.len(), 4);
        assert_eq!(mgr.buffer.dropped(), 6);
    }
}
