//! Synthetic Boolean pattern datasets.
//!
//! The paper's architecture is dataset-agnostic (the input parser and
//! booleaniser are swappable IPs); these generators give the test suite
//! and benches learnable workloads with *known* structure, independent of
//! iris:
//!
//! - [`prototype_dataset`]: each class is a random prototype bit-pattern;
//!   rows are prototypes with per-bit noise. Linearly separable-ish,
//!   learnable by a TM with few clauses.
//! - [`xor_dataset`]: class = XOR of two designated feature bits, the
//!   classic non-linearly-separable case that needs negative-polarity
//!   clauses (inhibition, §2).

use crate::data::dataset::BoolDataset;
use crate::tm::rng::Xoshiro256;
use anyhow::{bail, Result};

/// Per-class random prototypes + bit-flip noise.
///
/// `rows_per_class` rows per class, `features` wide, each bit flipped
/// with probability `noise`.
pub fn prototype_dataset(
    classes: usize,
    rows_per_class: usize,
    features: usize,
    noise: f32,
    seed: u64,
) -> Result<BoolDataset> {
    if classes < 2 || rows_per_class == 0 || features == 0 {
        bail!("degenerate prototype dataset");
    }
    if !(0.0..=0.5).contains(&noise) {
        bail!("noise must be in [0, 0.5], got {noise}");
    }
    let mut rng = Xoshiro256::new(seed);
    // Distinct prototypes: resample any duplicate.
    let mut prototypes: Vec<Vec<bool>> = Vec::with_capacity(classes);
    while prototypes.len() < classes {
        let p: Vec<bool> = (0..features).map(|_| rng.next_f32() < 0.5).collect();
        if !prototypes.contains(&p) {
            prototypes.push(p);
        }
    }
    let mut rows: Vec<Vec<bool>> = Vec::with_capacity(classes * rows_per_class);
    let mut labels = Vec::with_capacity(classes * rows_per_class);
    for (c, proto) in prototypes.iter().enumerate() {
        for _ in 0..rows_per_class {
            rows.push(
                proto
                    .iter()
                    .map(|&b| if rng.next_f32() < noise { !b } else { b })
                    .collect(),
            );
            labels.push(c);
        }
    }
    // Interleave classes so truncated prefixes stay balanced.
    let mut idx: Vec<usize> = (0..rows.len()).collect();
    rng.shuffle(&mut idx);
    Ok(BoolDataset {
        rows: idx.iter().map(|&i| rows[i].clone()).collect(),
        labels: idx.iter().map(|&i| labels[i]).collect(),
        n_classes: classes,
    })
}

/// Two-class XOR over feature bits `a` and `b`; remaining features are
/// uniform distractors.
pub fn xor_dataset(
    rows: usize,
    features: usize,
    a: usize,
    b: usize,
    seed: u64,
) -> Result<BoolDataset> {
    if a >= features || b >= features || a == b {
        bail!("xor bits must be distinct and in range");
    }
    let mut rng = Xoshiro256::new(seed);
    let mut data_rows = Vec::with_capacity(rows);
    let mut labels = Vec::with_capacity(rows);
    for _ in 0..rows {
        let row: Vec<bool> = (0..features).map(|_| rng.next_f32() < 0.5).collect();
        labels.push((row[a] ^ row[b]) as usize);
        data_rows.push(row);
    }
    Ok(BoolDataset { rows: data_rows, labels, n_classes: 2 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::*;

    #[test]
    fn prototype_shapes_and_balance() {
        let d = prototype_dataset(3, 40, 16, 0.05, 1).unwrap();
        assert_eq!(d.len(), 120);
        assert_eq!(d.n_features(), 16);
        assert_eq!(d.class_counts(), vec![40, 40, 40]);
        // Prefixes are roughly balanced thanks to the shuffle.
        let head = d.truncate(30).class_counts();
        assert!(head.iter().all(|&n| n >= 3), "head counts {head:?}");
    }

    #[test]
    fn degenerate_configs_rejected() {
        assert!(prototype_dataset(1, 10, 8, 0.1, 0).is_err());
        assert!(prototype_dataset(2, 0, 8, 0.1, 0).is_err());
        assert!(prototype_dataset(2, 10, 8, 0.9, 0).is_err());
        assert!(xor_dataset(10, 8, 3, 3, 0).is_err());
        assert!(xor_dataset(10, 8, 9, 1, 0).is_err());
    }

    #[test]
    fn xor_labels_consistent() {
        let d = xor_dataset(200, 8, 1, 4, 9).unwrap();
        for (row, &label) in d.rows.iter().zip(d.labels.iter()) {
            assert_eq!(label, (row[1] ^ row[4]) as usize);
        }
        // Both labels occur.
        let counts = d.class_counts();
        assert!(counts[0] > 50 && counts[1] > 50, "{counts:?}");
    }

    /// The TM must learn the prototype task to high accuracy — a
    /// dataset-independent learnability check of the whole training
    /// pipeline.
    #[test]
    fn tm_learns_prototypes() {
        let shape = TmShape { classes: 3, max_clauses: 8, features: 16, states: 100 };
        let d = prototype_dataset(3, 40, 16, 0.05, 3).unwrap();
        let train = d.truncate(90).pack(&shape);
        let test = d.subset(&(90..120).collect::<Vec<_>>()).pack(&shape);
        let params = TmParams::paper_offline(&shape);
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(5);
        let mut rands = StepRands::draw(&mut rng, &shape);
        for _ in 0..20 {
            for (x, y) in &train {
                rands.refill(&mut rng, &shape);
                train_step(&mut tm, x, *y, &params, &rands);
            }
        }
        let acc = tm.accuracy(&test, &params);
        assert!(acc > 0.85, "prototype task should be easy, got {acc:.3}");
    }

    /// XOR requires inhibition (negative-polarity clauses): the TM's
    /// majority vote with both polarities must crack it where a single
    /// positive-clause vote could not.
    #[test]
    fn tm_learns_xor() {
        let shape = TmShape { classes: 2, max_clauses: 8, features: 8, states: 100 };
        let d = xor_dataset(400, 8, 0, 1, 11).unwrap();
        let train = d.truncate(300).pack(&shape);
        let test = d.subset(&(300..400).collect::<Vec<_>>()).pack(&shape);
        let mut params = TmParams::paper_offline(&shape);
        params.s = 3.0; // XOR needs more specific clauses than iris
        params.t = 4;
        let mut tm = MultiTm::new(&shape).unwrap();
        let mut rng = Xoshiro256::new(13);
        let mut rands = StepRands::draw(&mut rng, &shape);
        for _ in 0..60 {
            for (x, y) in &train {
                rands.refill(&mut rng, &shape);
                train_step(&mut tm, x, *y, &params, &rands);
            }
        }
        let acc = tm.accuracy(&test, &params);
        assert!(acc > 0.85, "XOR accuracy {acc:.3}");
    }
}
