//! Data subsystems: datasets, booleanisation, the cross-validation block
//! memory manager, the class-filter IP and the online input path
//! (paper §3.4–§3.6).

pub mod blocks;
pub mod booleanize;
pub mod dataset;
pub mod filter;
pub mod iris;
pub mod online;
pub mod synthetic;

pub use blocks::{all_orderings, BlockPlan, PackedSets, SetAllocation, Sets};
pub use booleanize::Booleanizer;
pub use dataset::{BoolDataset, RawDataset};
pub use filter::ClassFilter;
pub use online::{
    arrival_trace, ArrivalTrace, CyclicBuffer, OnlineDataManager, OnlineSource, RomSource,
    TraceConfig, TraceEvent,
};
