//! Dataset containers: raw (real-valued) and booleanised views.
//!
//! The TM consumes Boolean features (§2); [`BoolDataset`] is what every
//! other subsystem (blocks, filter, ROM model, TM) operates on.

use crate::tm::bitplane::PlaneBatch;
use crate::tm::clause::Input;
use crate::tm::params::TmShape;
use anyhow::{bail, Result};

/// A raw real-valued dataset.
#[derive(Debug, Clone)]
pub struct RawDataset {
    /// `rows[i]` = feature vector of datapoint `i`.
    pub rows: Vec<Vec<f32>>,
    /// `labels[i]` in `0..n_classes`.
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

impl RawDataset {
    pub fn new(rows: Vec<Vec<f32>>, labels: Vec<usize>, n_classes: usize) -> Result<Self> {
        if rows.len() != labels.len() {
            bail!("rows/labels length mismatch: {} vs {}", rows.len(), labels.len());
        }
        if rows.is_empty() {
            bail!("empty dataset");
        }
        let width = rows[0].len();
        if rows.iter().any(|r| r.len() != width) {
            bail!("ragged rows");
        }
        if let Some(&bad) = labels.iter().find(|&&l| l >= n_classes) {
            bail!("label {bad} out of range (n_classes = {n_classes})");
        }
        Ok(RawDataset { rows, labels, n_classes })
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.rows[0].len()
    }

    /// Parse a simple CSV with a header row; last column is the integer
    /// class label, all other columns are f32 features.
    pub fn from_csv(text: &str) -> Result<Self> {
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        let mut n_classes = 0;
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header / blanks
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() < 2 {
                bail!("csv line {i}: need at least one feature + label");
            }
            let (feat_cols, label_col) = cols.split_at(cols.len() - 1);
            let feats: Result<Vec<f32>, _> =
                feat_cols.iter().map(|c| c.trim().parse::<f32>()).collect();
            let feats = feats.map_err(|e| anyhow::anyhow!("csv line {i}: {e}"))?;
            let label: usize = label_col[0]
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("csv line {i} label: {e}"))?;
            n_classes = n_classes.max(label + 1);
            rows.push(feats);
            labels.push(label);
        }
        RawDataset::new(rows, labels, n_classes)
    }
}

/// A booleanised dataset: one `Vec<bool>` feature row per datapoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BoolDataset {
    pub rows: Vec<Vec<bool>>,
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

impl BoolDataset {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.rows.first().map_or(0, |r| r.len())
    }

    /// Pack every row for a machine of `shape` (shape.features must match).
    pub fn pack(&self, shape: &TmShape) -> Vec<(Input, usize)> {
        assert_eq!(shape.features, self.n_features(), "shape/feature width mismatch");
        self.rows
            .iter()
            .zip(self.labels.iter())
            .map(|(r, &l)| (Input::pack(shape, r), l))
            .collect()
    }

    /// Pack every row and transpose the batch into literal-major
    /// bitplanes (see [`crate::tm::bitplane`]) — the dataset-level
    /// convenience for callers that score one set many times; drivers
    /// working per cross-validation fold use `Sets::pack_planes` in
    /// [`crate::data::blocks`] instead.
    pub fn pack_planes(&self, shape: &TmShape) -> PlaneBatch {
        PlaneBatch::from_labelled(shape, &self.pack(shape))
    }

    /// Per-class datapoint counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l] += 1;
        }
        counts
    }

    /// Select a subset of rows by index.
    pub fn subset(&self, idx: &[usize]) -> BoolDataset {
        BoolDataset {
            rows: idx.iter().map(|&i| self.rows[i].clone()).collect(),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
            n_classes: self.n_classes,
        }
    }

    /// Concatenate datasets (same width / class count).
    pub fn concat(parts: &[&BoolDataset]) -> BoolDataset {
        assert!(!parts.is_empty());
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for p in parts {
            assert_eq!(p.n_classes, parts[0].n_classes);
            rows.extend(p.rows.iter().cloned());
            labels.extend(p.labels.iter().cloned());
        }
        BoolDataset { rows, labels, n_classes: parts[0].n_classes }
    }

    /// Truncate to the first `n` rows (paper §5.1 uses the first 20 of the
    /// 30-row offline block).
    pub fn truncate(&self, n: usize) -> BoolDataset {
        let n = n.min(self.len());
        BoolDataset {
            rows: self.rows[..n].to_vec(),
            labels: self.labels[..n].to_vec(),
            n_classes: self.n_classes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let csv = "a,b,class\n1.0,2.0,0\n3.5,-1.0,1\n0.0,0.0,2\n";
        let d = RawDataset::from_csv(csv).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.n_features(), 2);
        assert_eq!(d.n_classes, 3);
        assert_eq!(d.rows[1], vec![3.5, -1.0]);
        assert_eq!(d.labels, vec![0, 1, 2]);
    }

    #[test]
    fn csv_errors() {
        assert!(RawDataset::from_csv("h\n").is_err(), "empty");
        assert!(RawDataset::from_csv("a,c\nx,0\n").is_err(), "non-numeric");
        assert!(RawDataset::from_csv("a,c\n1.0\n").is_err(), "too few cols");
    }

    #[test]
    fn ragged_and_bad_labels_rejected() {
        assert!(RawDataset::new(vec![vec![1.0], vec![1.0, 2.0]], vec![0, 0], 1).is_err());
        assert!(RawDataset::new(vec![vec![1.0]], vec![5], 3).is_err());
    }

    fn tiny_bool() -> BoolDataset {
        BoolDataset {
            rows: vec![
                vec![true, false, true],
                vec![false, false, true],
                vec![true, true, true],
                vec![false, true, false],
            ],
            labels: vec![0, 1, 0, 2],
            n_classes: 3,
        }
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny_bool().class_counts(), vec![2, 1, 1]);
    }

    #[test]
    fn subset_concat_truncate() {
        let d = tiny_bool();
        let s = d.subset(&[3, 0]);
        assert_eq!(s.labels, vec![2, 0]);
        let c = BoolDataset::concat(&[&s, &d]);
        assert_eq!(c.len(), 6);
        assert_eq!(c.labels[0], 2);
        let t = d.truncate(2);
        assert_eq!(t.len(), 2);
        assert_eq!(d.truncate(99).len(), 4, "truncate clamps");
    }

    #[test]
    fn pack_width_matches() {
        let d = tiny_bool();
        let shape = TmShape { classes: 3, max_clauses: 4, features: 3, states: 8 };
        let packed = d.pack(&shape);
        assert_eq!(packed.len(), 4);
        assert_eq!(packed[0].1, 0);
        assert!(packed[0].0.literal(0));
        assert!(!packed[0].0.literal(1));
        assert!(packed[0].0.literal(3 + 1), "complement of false feature");
    }
}
