//! Class filter IP (paper §3.4.1 / §5.2).
//!
//! "A filtering subsystem was created, controlled by an external enable
//! signal, to remove a certain class if desired." Used to withhold one
//! classification during offline training and early online operation, then
//! lift the filter mid-run to study unseen-class introduction (Figs 5–7).

use crate::data::dataset::BoolDataset;

/// The class-filter IP: when enabled, datapoints of `class` are dropped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassFilter {
    pub enabled: bool,
    pub class: usize,
}

impl ClassFilter {
    pub fn disabled() -> Self {
        ClassFilter { enabled: false, class: 0 }
    }

    pub fn removing(class: usize) -> Self {
        ClassFilter { enabled: true, class }
    }

    /// The external enable signal.
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    /// Does a datapoint with this label pass the filter?
    #[inline]
    pub fn passes(&self, label: usize) -> bool {
        !(self.enabled && label == self.class)
    }

    /// Filter a whole set (the offline-input path applies this when
    /// streaming rows out of ROM).
    pub fn apply(&self, data: &BoolDataset) -> BoolDataset {
        if !self.enabled {
            return data.clone();
        }
        let idx: Vec<usize> = data
            .labels
            .iter()
            .enumerate()
            .filter(|(_, &l)| self.passes(l))
            .map(|(i, _)| i)
            .collect();
        data.subset(&idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::iris;

    #[test]
    fn disabled_filter_passes_everything() {
        let f = ClassFilter::disabled();
        let d = iris::booleanised();
        let out = f.apply(d);
        assert_eq!(out.len(), 150);
        assert!((0..3).all(|c| f.passes(c)));
    }

    #[test]
    fn removes_exactly_one_class() {
        let f = ClassFilter::removing(0);
        let d = iris::booleanised();
        let out = f.apply(d);
        assert_eq!(out.len(), 100, "class 0's 50 rows removed");
        assert!(out.labels.iter().all(|&l| l != 0));
        assert_eq!(out.class_counts(), vec![0, 50, 50]);
    }

    #[test]
    fn enable_signal_toggles_at_runtime() {
        let mut f = ClassFilter::removing(2);
        assert!(!f.passes(2));
        f.set_enabled(false);
        assert!(f.passes(2), "lifting the filter re-admits the class");
        f.set_enabled(true);
        assert!(!f.passes(2));
    }

    #[test]
    fn paper_set_sizes_after_filtering() {
        // §5.2: "the validation and online training sets ... were each
        // reduced to approximately 40 in size when one of three
        // [classes] was filtered out"; offline 30 -> 20.
        let plan = crate::data::blocks::BlockPlan::stratified(iris::booleanised(), 5, 1)
            .unwrap();
        let sets = plan
            .sets(&[0, 1, 2, 3, 4], crate::data::blocks::SetAllocation::paper())
            .unwrap();
        let f = ClassFilter::removing(0);
        assert_eq!(f.apply(&sets.offline).len(), 20);
        assert_eq!(f.apply(&sets.validation).len(), 40);
        assert_eq!(f.apply(&sets.online).len(), 40);
    }
}
