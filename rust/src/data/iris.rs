//! The iris dataset, embedded (UCI / Fisher, 150 rows, 4 features,
//! 3 classes), plus the paper's encoding pipeline: 4 bits/feature binary
//! code → 16 Boolean inputs (§5).
//!
//! Encoding choice: the paper only states "16 booleanised inputs". We use
//! the TM-FPGA hardware line's 4-bit **binary** code per min-max-quantised
//! feature — it reproduces the paper's starting accuracies (offline
//! training set ≈83%) where thermometer encoding overshoots them by ~8%
//! (see `benches/ablations.rs`). Thermometer remains available via
//! [`booleanised_thermometer`].

use crate::data::booleanize::{BinaryBooleanizer, Booleanizer};
use crate::data::dataset::{BoolDataset, RawDataset};
use anyhow::Result;
use once_cell::sync::Lazy;

/// Raw CSV, compiled into the binary so the launcher needs no data files.
pub const IRIS_CSV: &str = include_str!("../../../data/iris.csv");

/// Bits per feature used throughout the paper's evaluation
/// (4 features × 4 bits = 16 booleanised inputs).
pub const BITS_PER_FEATURE: usize = 4;

static RAW: Lazy<RawDataset> =
    Lazy::new(|| RawDataset::from_csv(IRIS_CSV).expect("embedded iris parses"));

/// The raw iris dataset.
pub fn raw() -> &'static RawDataset {
    &RAW
}

/// The paper-default booleaniser: 4-bit binary code per feature, fitted on
/// the full dataset (design-time fit — the quantiser would be baked into
/// the FPGA input path).
pub fn booleanizer() -> Result<BinaryBooleanizer> {
    BinaryBooleanizer::fit(raw(), BITS_PER_FEATURE)
}

/// Alternative thermometer booleaniser (same width) for ablations.
pub fn booleanizer_thermometer() -> Result<Booleanizer> {
    Booleanizer::fit(raw(), BITS_PER_FEATURE)
}

static BOOL: Lazy<BoolDataset> = Lazy::new(|| {
    booleanizer()
        .and_then(|b| b.encode(raw()))
        .expect("embedded iris booleanises")
});

static BOOL_THERMO: Lazy<BoolDataset> = Lazy::new(|| {
    booleanizer_thermometer()
        .and_then(|b| b.encode(raw()))
        .expect("embedded iris booleanises (thermometer)")
});

/// The booleanised iris dataset (150 × 16 bits, labels 0..3) — paper
/// encoding (binary code).
pub fn booleanised() -> &'static BoolDataset {
    &BOOL
}

/// Thermometer-encoded variant (ablation).
pub fn booleanised_thermometer() -> &'static BoolDataset {
    &BOOL_THERMO
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_matches_paper_description() {
        let d = raw();
        assert_eq!(d.len(), 150, "150 unique datapoints");
        assert_eq!(d.n_features(), 4);
        assert_eq!(d.n_classes, 3, "3 classifications");
        // 50 per class, contiguous (setosa, versicolor, virginica).
        for c in 0..3 {
            assert!(d.labels[c * 50..(c + 1) * 50].iter().all(|&l| l == c));
        }
    }

    #[test]
    fn known_first_and_last_rows() {
        let d = raw();
        assert_eq!(d.rows[0], vec![5.1, 3.5, 1.4, 0.2]);
        assert_eq!(d.rows[149], vec![5.9, 3.0, 5.1, 1.8]);
    }

    #[test]
    fn booleanised_is_16_wide() {
        let b = booleanised();
        assert_eq!(b.len(), 150);
        assert_eq!(b.n_features(), 16, "16 booleanised inputs");
        assert_eq!(b.n_classes, 3);
    }

    #[test]
    fn encoding_separates_classes_reasonably() {
        // Sanity: setosa has small petals — the petal-length MSB (feature
        // 2 → bit 8) should be 0 for every setosa and 1 for most
        // virginica rows.
        let b = booleanised();
        let msb_ones = |range: std::ops::Range<usize>| -> usize {
            range.filter(|&i| b.rows[i][8]).count()
        };
        assert_eq!(msb_ones(0..50), 0, "setosa petal MSB all 0");
        assert!(msb_ones(100..150) > 35, "virginica petal MSB mostly 1");
    }

    #[test]
    fn binary_levels_cover_full_scale() {
        let bz = booleanizer().unwrap();
        // Min and max of each feature map to levels 0 and 15.
        let d = raw();
        for f in 0..4 {
            let lo = d.rows.iter().map(|r| r[f]).fold(f32::MAX, f32::min);
            let hi = d.rows.iter().map(|r| r[f]).fold(f32::MIN, f32::max);
            assert_eq!(bz.level(f, lo), 0);
            assert_eq!(bz.level(f, hi), 15);
        }
    }

    #[test]
    fn thermometer_variant_available() {
        let t = booleanised_thermometer();
        assert_eq!(t.len(), 150);
        assert_eq!(t.n_features(), 16);
        assert_ne!(t.rows, booleanised().rows, "encodings differ");
    }
}
