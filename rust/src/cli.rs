//! Hand-rolled CLI (the offline image has no `clap`): subcommand +
//! `--flag value` parsing with typed accessors and good error messages.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and flags.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Cli {
    /// Parse `args` (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut cli = Cli { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("empty flag name");
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Value = next token unless it's another flag / absent
                    // (then it's a boolean).
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            cli.flags.insert(name.to_string(), v);
                        }
                        _ => {
                            cli.flags.insert(name.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn flag_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a float, got {v:?}")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a float, got {v:?}")),
        }
    }

    pub fn flag_bool(&self, name: &str, default: bool) -> Result<bool> {
        match self.flag(name) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{name} expects a boolean, got {v:?}"),
        }
    }

    /// Comma-separated usize list (e.g. `--ordering 0,1,2,3,4`).
    pub fn flag_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => {
                let list: Result<Vec<usize>, _> =
                    v.split(',').map(|x| x.trim().parse::<usize>()).collect();
                Ok(Some(list.with_context(|| format!("--{name} expects n,n,..."))?))
            }
        }
    }
}

pub const USAGE: &str = "\
tmfpga — FPGA online-learning Tsetlin machine (Prescott et al., 2023) reproduction

USAGE: tmfpga <command> [flags]

COMMANDS
  fig <4|5|6|7|8|9|all>   regenerate a paper figure over the cross-validation
                          sweep   [--orderings N=120] [--threads N=auto]
                          [--seed N=42] [--out DIR=results]
  run                     one full system run (Fig-3 flow), prints the UART
                          log     [--ordering 0,1,2,3,4] [--iterations N=16]
                          [--online-learning BOOL=true] [--filter CLASS]
                          [--seed N]
  serve                   deterministic serving soak: sharded micro-batched
                          online inference vs the scalar oracle
                          [--shards N=2] [--events N=1000] [--batch N=64]
                          [--deadline TICKS=8] [--labelled F=0.2]
                          [--gap TICKS=1.0] [--seed N=42] [--warmup N=4]
                          with --chaos-seed N: seeded fault drill (kills,
                          stalls, checkpoint corruption) asserting
                          post-recovery bit-identity   [--kills N=2]
                          [--stalls N=1] [--corrupts N=1]
                          [--malformed-every N=97] [--checkpoint-every N=32]
                          [--recovery-lag OPS=0] [--degraded-depth N]
  perf                    §6 performance table (FPGA model vs software paths)
                          [--iters N=20] [--pjrt-steps N=60]
  power                   §6 power table (gating / over-provisioning)
  sweep                   hyper-parameter grid search  [--orderings N=12]
                          [--epochs N=10] [--out DIR]
  replay                  catastrophic-forgetting replay ablation
                          [--interval K=5] [--orderings N=8]
  explain                 dump trained clause compositions + a vote
                          attribution    [--seed N] [--row N]
  parity                  verify native vs PJRT bit-parity on a trajectory
                          [--steps N=60]
  verify                  replay the committed scenario corpus through every
                          engine pair (bit-identity), exit nonzero on any
                          divergence   [--fixtures DIR=rust/tests/corpus]
                          with --grow N: also generate and replay N seeded
                          random schedules, shrinking any divergence to a
                          minimal fixture    [--steps N=100] [--seed N=42]
                          [--out DIR=rust/tests/corpus]
  help                    this text

The binary is self-contained after `make artifacts` (PJRT paths need the
artifacts directory; override with TMFPGA_ARTIFACTS).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_subcommand_and_flags() {
        let c = parse("fig 4 --orderings 12 --threads 4");
        assert_eq!(c.command, "fig");
        assert_eq!(c.positional, vec!["4"]);
        assert_eq!(c.flag_usize("orderings", 120).unwrap(), 12);
        assert_eq!(c.flag_usize("threads", 0).unwrap(), 4);
        assert_eq!(c.flag_usize("seed", 42).unwrap(), 42, "default");
    }

    #[test]
    fn equals_form_and_bools() {
        let c = parse("run --online-learning=false --filter 0 --verbose");
        assert!(!c.flag_bool("online-learning", true).unwrap());
        assert_eq!(c.flag_usize("filter", 99).unwrap(), 0);
        assert!(c.flag_bool("verbose", false).unwrap());
    }

    #[test]
    fn f64_flags_keep_precision() {
        let c = parse("serve --gap 0.125");
        assert_eq!(c.flag_f64("gap", 1.0).unwrap(), 0.125);
        assert_eq!(parse("serve").flag_f64("gap", 1.5).unwrap(), 1.5, "default");
        assert!(parse("serve --gap wide").flag_f64("gap", 1.0).is_err());
    }

    #[test]
    fn usize_list() {
        let c = parse("run --ordering 4,3,2,1,0");
        assert_eq!(c.flag_usize_list("ordering").unwrap().unwrap(), vec![4, 3, 2, 1, 0]);
        assert!(parse("run").flag_usize_list("ordering").unwrap().is_none());
        assert!(parse("run --ordering a,b").flag_usize_list("ordering").is_err());
    }

    #[test]
    fn bad_values_error() {
        let c = parse("fig 4 --orderings twelve");
        assert!(c.flag_usize("orderings", 1).is_err());
        let c = parse("run --online-learning maybe");
        assert!(c.flag_bool("online-learning", true).is_err());
    }

    #[test]
    fn empty_args_is_help() {
        let c = Cli::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(c.command, "help");
    }
}
