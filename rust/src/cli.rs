//! Hand-rolled CLI (the offline image has no `clap`): subcommand +
//! `--flag value` parsing with typed accessors and good error messages.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and flags.
/// `flags` keeps the last occurrence of each flag (the common case);
/// every occurrence is also retained in order so repeatable flags like
/// `serve --model NAME=SPEC` can accumulate.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub repeated: BTreeMap<String, Vec<String>>,
}

impl Cli {
    /// Parse `args` (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut cli = Cli { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("empty flag name");
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.push_flag(k, v);
                } else {
                    // Value = next token unless it's another flag / absent
                    // (then it's a boolean).
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            cli.push_flag(name, &v);
                        }
                        _ => {
                            cli.push_flag(name, "true");
                        }
                    }
                }
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    fn push_flag(&mut self, name: &str, value: &str) {
        self.repeated.entry(name.to_string()).or_default().push(value.to_string());
        self.flags.insert(name.to_string(), value.to_string());
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    pub fn flag_all(&self, name: &str) -> &[String] {
        self.repeated.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn flag_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a float, got {v:?}")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} expects a float, got {v:?}")),
        }
    }

    pub fn flag_bool(&self, name: &str, default: bool) -> Result<bool> {
        match self.flag(name) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{name} expects a boolean, got {v:?}"),
        }
    }

    /// Comma-separated usize list (e.g. `--ordering 0,1,2,3,4`).
    pub fn flag_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => {
                let list: Result<Vec<usize>, _> =
                    v.split(',').map(|x| x.trim().parse::<usize>()).collect();
                Ok(Some(list.with_context(|| format!("--{name} expects n,n,..."))?))
            }
        }
    }
}

/// A bad flag *combination* (as opposed to a malformed value): the
/// caller gets usage text and exit code 2, not a stack trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UsageError(pub String);

impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "usage: {}", self.0)
    }
}

impl std::error::Error for UsageError {}

fn usage(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg.into()))
}

/// One `--model NAME=SPEC` occurrence, parsed. `SPEC` is
/// `DATASET[:seed=N]`; the only built-in dataset geometry is `iris`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub dataset: String,
    pub seed: Option<u64>,
}

/// Parse one `--model` value. Names obey the wire grammar
/// ([`crate::hub::model::valid_model_name`]) so every model registered
/// from the command line is addressable in `model=` protocol fields.
pub fn parse_model_spec(s: &str) -> Result<ModelSpec> {
    let (name, spec) = s
        .split_once('=')
        .ok_or_else(|| usage(format!("--model expects NAME=SPEC, got {s:?}")))?;
    if !crate::hub::model::valid_model_name(name) {
        return Err(usage(format!(
            "--model name {name:?} must be 1..=32 chars of [A-Za-z0-9_-]"
        )));
    }
    let mut parts = spec.split(':');
    let dataset = parts.next().unwrap_or_default().to_string();
    if dataset != "iris" {
        return Err(usage(format!(
            "--model {name}: unknown dataset {dataset:?} (only `iris` is built in)"
        )));
    }
    let mut seed = None;
    for opt in parts {
        match opt.split_once('=') {
            Some(("seed", v)) => {
                seed = Some(
                    v.parse()
                        .with_context(|| format!("--model {name}: seed expects an integer"))?,
                );
            }
            _ => {
                return Err(usage(format!(
                    "--model {name}: unknown option {opt:?} (expected seed=N)"
                )))
            }
        }
    }
    Ok(ModelSpec { name: name.to_string(), dataset, seed })
}

/// All `--model` occurrences parsed, with duplicate names rejected.
pub fn model_specs(cli: &Cli) -> Result<Vec<ModelSpec>> {
    let mut out: Vec<ModelSpec> = Vec::new();
    for raw in cli.flag_all("model") {
        let spec = parse_model_spec(raw)?;
        if out.iter().any(|m| m.name == spec.name) {
            return Err(usage(format!("--model {} given more than once", spec.name)));
        }
        out.push(spec);
    }
    Ok(out)
}

/// The explicit `serve` subcommand mode, if one was given. Legacy
/// invocations (no positional mode) select behaviour from flags alone
/// and stay valid forever; `run`/`soak`/`drill` are the redesigned
/// spellings.
pub fn serve_mode(cli: &Cli) -> Result<Option<&str>> {
    match cli.positional.first().map(String::as_str) {
        None => Ok(None),
        Some(m @ ("run" | "soak" | "drill")) => Ok(Some(m)),
        Some(other) => Err(usage(format!(
            "unknown serve mode {other:?}; expected run, soak or drill"
        ))),
    }
}

/// Reject invalid `serve` flag combinations before any work starts.
/// The legacy mode flags are mutually exclusive: `--chaos-seed` (shard
/// fault drill), `--net-chaos-seed` (network chaos soak) and `--listen`
/// (real sockets); the subcommand modes `run`/`soak`/`drill` layer on
/// top (exclusive with the chaos flags). Mode-specific knobs without
/// their mode are usage errors, as are out-of-range values with no
/// sane meaning.
pub fn validate_serve(cli: &Cli) -> Result<()> {
    let has = |n: &str| cli.flag(n).is_some();
    let mode = serve_mode(cli)?;
    let chaos = has("chaos-seed");
    let net_chaos = has("net-chaos-seed");
    let listen = has("listen");
    // Real-socket serving: the legacy --listen spelling, or the
    // run/drill subcommands (which default the listen address).
    let sockets = listen || matches!(mode, Some("run") | Some("drill"));
    if chaos && net_chaos {
        return Err(usage(
            "--chaos-seed and --net-chaos-seed are exclusive; run one drill at a time",
        ));
    }
    if mode.is_some() && (chaos || net_chaos) {
        return Err(usage(
            "serve run/soak/drill are socket/hub modes; chaos drills use the legacy flags",
        ));
    }
    if listen && (chaos || net_chaos) {
        return Err(usage(
            "--listen serves real sockets; chaos drills use the simulated transport",
        ));
    }
    if mode == Some("soak") && listen {
        return Err(usage("serve soak drives the simulated clock; drop --listen"));
    }
    if has("drill") && !listen && mode != Some("drill") {
        return Err(usage("--drill runs a loopback client against --listen; add --listen ADDR"));
    }
    let specs = model_specs(cli)?;
    if !specs.is_empty() && mode.is_none() {
        return Err(usage("--model needs a serve mode; try serve run/soak/drill"));
    }
    for knob in ["tenants", "budget-models", "evict-every", "rounds"] {
        if has(knob) && mode != Some("soak") {
            return Err(usage(format!("--{knob} is a hub-soak knob; use serve soak")));
        }
    }
    if mode == Some("soak") {
        let tenants = cli.flag_usize("tenants", 4)?;
        if tenants == 0 {
            return Err(usage("--tenants must be >= 1"));
        }
        if !specs.is_empty() && has("tenants") && specs.len() != tenants {
            return Err(usage(format!(
                "--tenants {} disagrees with {} --model spec(s); drop one of them",
                tenants,
                specs.len()
            )));
        }
        if cli.flag_usize("rounds", 4)? == 0 {
            return Err(usage("--rounds must be >= 1"));
        }
    }
    if has("data-dir") && mode != Some("soak") {
        return Err(usage("--data-dir selects the durable restart drill; use serve soak"));
    }
    if has("crash-after") && !has("data-dir") {
        return Err(usage("--crash-after needs --data-dir DIR (the durable restart drill)"));
    }
    if has("crash-after") && cli.flag_u64("crash-after", 1)? == 0 {
        return Err(usage("--crash-after must be >= 1 (durable writes are counted from 1)"));
    }
    if has("data-dir") {
        for knob in ["rounds", "budget-models"] {
            if has(knob) {
                return Err(usage(format!(
                    "--{knob} is an in-memory hub-soak knob; drop it with --data-dir"
                )));
            }
        }
    }
    const DRILL_KNOBS: [&str; 6] =
        ["kills", "stalls", "corrupts", "malformed-every", "recovery-lag", "degraded-depth"];
    for knob in DRILL_KNOBS {
        if has(knob) && !chaos {
            return Err(usage(format!("--{knob} is a fault-drill knob; add --chaos-seed N")));
        }
    }
    if has("checkpoint-every") && !chaos && !net_chaos && mode != Some("soak") {
        return Err(usage("--checkpoint-every needs --chaos-seed N, --net-chaos-seed N or serve soak"));
    }
    for knob in ["clients", "net-requests", "write-cap", "max-in-flight"] {
        if has(knob) && !net_chaos && !sockets {
            return Err(usage(format!(
                "--{knob} is a network-serving knob; add --net-chaos-seed N or --listen ADDR"
            )));
        }
    }
    if cli.flag_usize("shards", 2)? == 0 {
        return Err(usage("--shards must be >= 1"));
    }
    if cli.flag_usize("events", 1000)? == 0 {
        return Err(usage("--events must be >= 1"));
    }
    let batch = cli.flag_usize("batch", 64)?;
    if !(1..=64).contains(&batch) {
        return Err(usage("--batch must be in 1..=64 (one bitplane lane)"));
    }
    let labelled = cli.flag_f32("labelled", 0.2)?;
    if !(0.0..=1.0).contains(&labelled) {
        return Err(usage("--labelled is a fraction in [0, 1]"));
    }
    if chaos && cli.flag_u64("degraded-depth", 1)? == 0 {
        return Err(usage("--degraded-depth 0 would shed every batch; omit it for unbounded"));
    }
    if (net_chaos || sockets)
        && (cli.flag_usize("clients", 8)? == 0
            || cli.flag_u64("net-requests", 40)? == 0
            || cli.flag_u64("write-cap", 8)? == 0
            || cli.flag_u64("max-in-flight", 256)? == 0
            || cli.flag_u64("drill", 64)? == 0
            || cli.flag_u64("requests", 64)? == 0)
    {
        return Err(usage(
            "--clients/--net-requests/--write-cap/--max-in-flight/--drill/--requests must be >= 1",
        ));
    }
    Ok(())
}

pub const USAGE: &str = "\
tmfpga — FPGA online-learning Tsetlin machine (Prescott et al., 2023) reproduction

USAGE: tmfpga <command> [flags]

COMMANDS
  fig <4|5|6|7|8|9|all>   regenerate a paper figure over the cross-validation
                          sweep   [--orderings N=120] [--threads N=auto]
                          [--seed N=42] [--out DIR=results]
  run                     one full system run (Fig-3 flow), prints the UART
                          log     [--ordering 0,1,2,3,4] [--iterations N=16]
                          [--online-learning BOOL=true] [--filter CLASS]
                          [--seed N]
  serve [run|soak|drill]  model serving; bare `serve` keeps the legacy
                          single-model soak and flag spellings
    serve run             serve the line protocol on a real TCP socket
                          [--listen ADDR=127.0.0.1:0] [--shards N=2]
                          [--model NAME=iris[:seed=N]]... (repeatable;
                          registers hub models addressable via the wire
                          `model=` field; none = one anonymous model)
    serve soak            multi-tenant model-hub soak: N tenants interleave
                          on one hub under a replica memory budget with
                          forced eviction/rehydration mid-trace; every
                          tenant must stay bit-identical to its private
                          scalar oracle   [--tenants N=4] [--events N=200]
                          [--rounds N=4] [--budget-models N=2]
                          [--evict-every N=2] [--checkpoint-every N=16]
                          [--model NAME=iris[:seed=N]]... (names tenants)
                          with --data-dir DIR: durable-hub restart drill —
                          recover DIR (WAL + checkpoints), drive the traces
                          to completion, verify answers and final digests
                          bit-identical to the never-crashed oracle;
                          --crash-after N fail-stops at the Nth durable
                          write and exits 86 with DIR intact (relaunch
                          without it to resume where the crash hit)
    serve drill           loopback drill: serve on a socket and run an
                          in-process client, then drain
                          [--listen ADDR=127.0.0.1:0] [--requests N=64]
                          legacy spellings (no subcommand):
                          [--shards N=2] [--events N=1000] [--batch N=64]
                          [--deadline TICKS=8] [--labelled F=0.2]
                          [--gap TICKS=1.0] [--seed N=42] [--warmup N=4]
                          with --chaos-seed N: seeded fault drill (kills,
                          stalls, checkpoint corruption) asserting
                          post-recovery bit-identity   [--kills N=2]
                          [--stalls N=1] [--corrupts N=1]
                          [--malformed-every N=97] [--checkpoint-every N=32]
                          [--recovery-lag OPS=0] [--degraded-depth N]
                          with --net-chaos-seed N: deterministic network
                          chaos soak (torn frames, half-open peers,
                          disconnects, slow-loris readers, floods) through
                          the simulated transport, asserting per-request
                          bit-identity vs the oracle   [--clients N=8]
                          [--net-requests N=40] [--write-cap N=8]
                          [--max-in-flight N=256]
                          with --listen ADDR: serve the line protocol on a
                          real TCP socket (port 0 picks a free port);
                          --drill N runs an in-process loopback client with
                          N requests, then drains
  perf                    §6 performance table (FPGA model vs software paths)
                          [--iters N=20] [--pjrt-steps N=60]
  power                   §6 power table (gating / over-provisioning)
  sweep                   hyper-parameter grid search  [--orderings N=12]
                          [--epochs N=10] [--out DIR]
  replay                  catastrophic-forgetting replay ablation
                          [--interval K=5] [--orderings N=8]
  explain                 dump trained clause compositions + a vote
                          attribution    [--seed N] [--row N]
  parity                  verify native vs PJRT bit-parity on a trajectory
                          [--steps N=60]
  verify                  replay the committed scenario corpus through every
                          engine pair (bit-identity), exit nonzero on any
                          divergence   [--fixtures DIR=rust/tests/corpus]
                          with --grow N: also generate and replay N seeded
                          random schedules, shrinking any divergence to a
                          minimal fixture    [--steps N=100] [--seed N=42]
                          [--out DIR=rust/tests/corpus]
  help                    this text

The binary is self-contained after `make artifacts` (PJRT paths need the
artifacts directory; override with TMFPGA_ARTIFACTS).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_subcommand_and_flags() {
        let c = parse("fig 4 --orderings 12 --threads 4");
        assert_eq!(c.command, "fig");
        assert_eq!(c.positional, vec!["4"]);
        assert_eq!(c.flag_usize("orderings", 120).unwrap(), 12);
        assert_eq!(c.flag_usize("threads", 0).unwrap(), 4);
        assert_eq!(c.flag_usize("seed", 42).unwrap(), 42, "default");
    }

    #[test]
    fn equals_form_and_bools() {
        let c = parse("run --online-learning=false --filter 0 --verbose");
        assert!(!c.flag_bool("online-learning", true).unwrap());
        assert_eq!(c.flag_usize("filter", 99).unwrap(), 0);
        assert!(c.flag_bool("verbose", false).unwrap());
    }

    #[test]
    fn f64_flags_keep_precision() {
        let c = parse("serve --gap 0.125");
        assert_eq!(c.flag_f64("gap", 1.0).unwrap(), 0.125);
        assert_eq!(parse("serve").flag_f64("gap", 1.5).unwrap(), 1.5, "default");
        assert!(parse("serve --gap wide").flag_f64("gap", 1.0).is_err());
    }

    #[test]
    fn usize_list() {
        let c = parse("run --ordering 4,3,2,1,0");
        assert_eq!(c.flag_usize_list("ordering").unwrap().unwrap(), vec![4, 3, 2, 1, 0]);
        assert!(parse("run").flag_usize_list("ordering").unwrap().is_none());
        assert!(parse("run --ordering a,b").flag_usize_list("ordering").is_err());
    }

    #[test]
    fn bad_values_error() {
        let c = parse("fig 4 --orderings twelve");
        assert!(c.flag_usize("orderings", 1).is_err());
        let c = parse("run --online-learning maybe");
        assert!(c.flag_bool("online-learning", true).is_err());
    }

    #[test]
    fn empty_args_is_help() {
        let c = Cli::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(c.command, "help");
    }

    fn usage_err(s: &str) -> UsageError {
        let err = validate_serve(&parse(s)).expect_err(s);
        err.downcast_ref::<UsageError>().unwrap_or_else(|| panic!("untyped error for {s}")).clone()
    }

    #[test]
    fn serve_mode_flags_are_exclusive() {
        assert!(validate_serve(&parse("serve")).is_ok());
        assert!(validate_serve(&parse("serve --chaos-seed 1 --kills 2 --recovery-lag 0")).is_ok());
        assert!(validate_serve(&parse("serve --net-chaos-seed 7 --clients 4")).is_ok());
        assert!(validate_serve(&parse("serve --listen 127.0.0.1:0 --drill 64")).is_ok());
        usage_err("serve --chaos-seed 1 --net-chaos-seed 2");
        usage_err("serve --listen 127.0.0.1:0 --chaos-seed 1");
        usage_err("serve --listen 127.0.0.1:0 --net-chaos-seed 1");
        usage_err("serve --drill 64");
    }

    #[test]
    fn serve_mode_knobs_need_their_mode() {
        // The exact flag set the CI recovery drill passes must stay
        // valid, including an explicit --recovery-lag 0.
        let ci = "serve --events 600 --chaos-seed 3141592653 --checkpoint-every 16 \
                  --kills 2 --stalls 1 --corrupts 1";
        assert!(validate_serve(&parse(ci)).is_ok());
        usage_err("serve --kills 2");
        usage_err("serve --recovery-lag 0");
        usage_err("serve --checkpoint-every 16");
        usage_err("serve --clients 4");
        usage_err("serve --net-requests 40");
        assert!(validate_serve(&parse("serve --net-chaos-seed 1 --checkpoint-every 8")).is_ok());
    }

    #[test]
    fn repeated_flags_accumulate_in_order() {
        let c = parse("serve soak --model a=iris --model b=iris:seed=9 --seed 1 --seed 2");
        assert_eq!(c.flag_all("model"), ["a=iris".to_string(), "b=iris:seed=9".to_string()]);
        assert_eq!(c.flag("model"), Some("b=iris:seed=9"), "plain accessor keeps last");
        assert_eq!(c.flag_u64("seed", 0).unwrap(), 2, "non-repeatable flags keep last-wins");
        assert!(parse("serve").flag_all("model").is_empty());
    }

    #[test]
    fn model_specs_parse_and_validate() {
        let m = parse_model_spec("alpha=iris").unwrap();
        assert_eq!(m, ModelSpec { name: "alpha".into(), dataset: "iris".into(), seed: None });
        let m = parse_model_spec("b-2=iris:seed=77").unwrap();
        assert_eq!(m.seed, Some(77));
        for bad in ["nospec", "=iris", "bad name=iris", "a=mnist", "a=iris:depth=3"] {
            let err = parse_model_spec(bad).expect_err(bad);
            assert!(err.downcast_ref::<UsageError>().is_some(), "untyped error for {bad}");
        }
        // A malformed seed value is a plain parse error, not a usage error.
        assert!(parse_model_spec("a=iris:seed=lots")
            .unwrap_err()
            .downcast_ref::<UsageError>()
            .is_none());
        let dup = model_specs(&parse("serve soak --model a=iris --model a=iris"));
        assert!(dup.unwrap_err().downcast_ref::<UsageError>().is_some());
    }

    #[test]
    fn serve_subcommand_modes_validate() {
        assert!(validate_serve(&parse("serve soak")).is_ok());
        assert!(validate_serve(&parse(
            "serve soak --tenants 4 --budget-models 2 --evict-every 2 --checkpoint-every 8"
        ))
        .is_ok());
        assert!(validate_serve(&parse("serve soak --model a=iris --model b=iris")).is_ok());
        assert!(validate_serve(&parse("serve soak --tenants 2 --model a=iris --model b=iris"))
            .is_ok());
        assert!(validate_serve(&parse("serve run --model a=iris --clients 4")).is_ok());
        assert!(validate_serve(&parse("serve drill --requests 32")).is_ok());
        usage_err("serve bogus");
        usage_err("serve soak --tenants 0");
        usage_err("serve soak --rounds 0");
        usage_err("serve soak --listen 127.0.0.1:0");
        usage_err("serve soak --chaos-seed 1");
        usage_err("serve run --net-chaos-seed 1");
        usage_err("serve soak --tenants 3 --model a=iris");
        usage_err("serve --model a=iris");
        usage_err("serve --tenants 4");
        usage_err("serve run --budget-models 2");
        usage_err("serve drill --requests 0");
    }

    #[test]
    fn durable_restart_flags_validate() {
        assert!(validate_serve(&parse("serve soak --data-dir /tmp/d")).is_ok());
        assert!(validate_serve(&parse(
            "serve soak --model alpha=iris --data-dir /tmp/d --crash-after 25 --seed 7"
        ))
        .is_ok());
        assert!(validate_serve(&parse(
            "serve soak --data-dir /tmp/d --events 80 --evict-every 5 --checkpoint-every 8"
        ))
        .is_ok());
        usage_err("serve --data-dir /tmp/d");
        usage_err("serve run --data-dir /tmp/d");
        usage_err("serve soak --crash-after 25");
        usage_err("serve soak --data-dir /tmp/d --crash-after 0");
        usage_err("serve soak --data-dir /tmp/d --rounds 2");
        usage_err("serve soak --data-dir /tmp/d --budget-models 2");
    }

    #[test]
    fn serve_value_ranges_are_enforced() {
        usage_err("serve --shards 0");
        usage_err("serve --events 0");
        usage_err("serve --batch 0");
        usage_err("serve --batch 65");
        usage_err("serve --labelled 1.5");
        usage_err("serve --chaos-seed 1 --degraded-depth 0");
        usage_err("serve --net-chaos-seed 1 --clients 0");
        usage_err("serve --net-chaos-seed 1 --net-requests 0");
        usage_err("serve --listen 127.0.0.1:0 --drill 0");
        // Malformed values stay plain parse errors, not usage errors.
        assert!(validate_serve(&parse("serve --shards two"))
            .unwrap_err()
            .downcast_ref::<UsageError>()
            .is_none());
    }
}
