//! Design-time and run-time parameters of the Tsetlin machine.
//!
//! Mirrors the paper's split (§3.1): classes / clauses / TA states are
//! *pre-synthesis* parameters; `s`, `T`, the clause-number port and the
//! active-class count are *run-time* controllable (via the AXI register
//! file in the RTL model, or directly on [`TmParams`] here).

use anyhow::{bail, Result};

/// Pre-synthesis (structural) parameters: fixed when the machine is built,
/// analogous to what would require FPGA re-synthesis to change.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TmShape {
    /// Number of provisioned classes (paper: over-provisionable, §3.1.1).
    pub classes: usize,
    /// Maximum number of clauses per class (the "maximum clause number
    /// pre-synthesis parameter", §3.1.1). Must be even so the +/- polarity
    /// split is balanced.
    pub max_clauses: usize,
    /// Number of Boolean input features. Literals = `2 * features`
    /// (each feature and its complement).
    pub features: usize,
    /// TA states **per action side**: total states = `2 * states`, with
    /// `0 ..= states-1` ⇒ exclude and `states ..= 2*states-1` ⇒ include.
    pub states: u32,
}

impl TmShape {
    /// Shape used throughout the paper's evaluation: iris booleanised to 16
    /// inputs, 3 classes, 16 clauses per class.
    pub fn iris() -> Self {
        TmShape { classes: 3, max_clauses: 16, features: 16, states: 100 }
    }

    /// Number of literals (features and their complements).
    pub fn literals(&self) -> usize {
        2 * self.features
    }

    /// Total TAs in the machine (one per class/clause/literal).
    pub fn num_tas(&self) -> usize {
        self.classes * self.max_clauses * self.literals()
    }

    /// Number of `u64` words needed to hold one literal row bit-packed.
    pub fn words(&self) -> usize {
        self.literals().div_ceil(64)
    }

    /// State index of the exclude/include decision boundary: actions with
    /// state `>= include_threshold()` are *include*.
    pub fn include_threshold(&self) -> u32 {
        self.states
    }

    /// Largest legal state value.
    pub fn max_state(&self) -> u32 {
        2 * self.states - 1
    }

    pub fn validate(&self) -> Result<()> {
        if self.classes == 0 {
            bail!("TmShape: classes must be > 0");
        }
        if self.max_clauses == 0 || self.max_clauses % 2 != 0 {
            bail!("TmShape: max_clauses must be positive and even, got {}", self.max_clauses);
        }
        if self.features == 0 {
            bail!("TmShape: features must be > 0");
        }
        if self.states < 2 {
            bail!("TmShape: need at least 2 states per side, got {}", self.states);
        }
        Ok(())
    }
}

/// How the specificity hyper-parameter `s` maps to the Type-I event
/// probabilities.
///
/// - [`SStyle::Canonical`] is Granmo 2018: reinforce w.p. `(s-1)/s`,
///   weaken w.p. `1/s`. At `s = 1` weakening always fires.
/// - [`SStyle::InactionBiased`] scales *both* events by `(s-1)/s` — the
///   reading consistent with the paper's §5.1 ("a lower s value increases
///   the likelihood of inaction, so overall there will be a bias away
///   from issuing feedback when a low s value is used, resulting in
///   reduced power consumption"): at `s = 1` Type I is fully inactive and
///   online learning is driven by Type-II discrimination alone, which is
///   also what reproduces the paper's *rising* offline-set curve (no
///   Type-I forgetting). The paper's LFSR-based hardware implements one
///   comparison threshold per event, making this a one-constant change in
///   RTL.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SStyle {
    Canonical,
    /// The paper's inaction-biased reading (our §5 default).
    #[default]
    InactionBiased,
}

/// Run-time parameters: controllable without re-synthesis (paper §3.1:
/// "sensitivity and threshold hyperparameters, s and T, are controllable
/// during runtime via I/O ports"; clause number via the clause-number port).
#[derive(Debug, Clone, PartialEq)]
pub struct TmParams {
    /// Specificity hyper-parameter `s >= 1`. The paper uses 1.375 for
    /// offline and 1.0 for online training.
    pub s: f32,
    /// Vote-clamp / feedback-probability threshold `T >= 1`. Paper: 15.
    /// "T can be thought of as a target for the number of clauses to
    /// activate" (§2).
    pub t: i32,
    /// Clause-number port (§3.1.1): number of clauses per class actually
    /// in use; `active_clauses <= max_clauses`, must be even. Clauses with
    /// index `>= active_clauses` are clock-gated: output 0, no feedback.
    pub active_clauses: usize,
    /// Over-provisioned class control: classes with index
    /// `>= active_classes` never vote and never receive feedback.
    pub active_classes: usize,
    /// Granmo's "boost true positive" option: when set, the Type-I
    /// include-reinforcement fires with probability 1 instead of (s-1)/s.
    pub boost_true_positive: bool,
    /// s → probability mapping (see [`SStyle`]).
    pub s_style: SStyle,
}

impl TmParams {
    /// Paper offline-training configuration (§5): s = 1.375, T = 15.
    pub fn paper_offline(shape: &TmShape) -> Self {
        TmParams {
            s: 1.375,
            t: 15,
            active_clauses: shape.max_clauses,
            active_classes: shape.classes,
            boost_true_positive: false,
            s_style: SStyle::InactionBiased,
        }
    }

    /// Paper online-training configuration (§5.1): s = 1.0 — "a lower s
    /// value increases the likelihood of inaction ... resulting in reduced
    /// power consumption".
    pub fn paper_online(shape: &TmShape) -> Self {
        TmParams { s: 1.0, ..Self::paper_offline(shape) }
    }

    pub fn validate(&self, shape: &TmShape) -> Result<()> {
        if !(self.s >= 1.0) {
            bail!("TmParams: s must be >= 1.0, got {}", self.s);
        }
        if self.t < 1 {
            bail!("TmParams: T must be >= 1, got {}", self.t);
        }
        if self.active_clauses == 0
            || self.active_clauses > shape.max_clauses
            || self.active_clauses % 2 != 0
        {
            bail!(
                "TmParams: active_clauses must be even in 2..=max_clauses ({}), got {}",
                shape.max_clauses,
                self.active_clauses
            );
        }
        if self.active_classes == 0 || self.active_classes > shape.classes {
            bail!(
                "TmParams: active_classes must be in 1..=classes ({}), got {}",
                shape.classes,
                self.active_classes
            );
        }
        Ok(())
    }

    /// Probability of the Type-I include-reinforcement event: `(s-1)/s`
    /// (or 1.0 with boost).
    pub fn p_reinforce(&self) -> f32 {
        if self.boost_true_positive {
            1.0
        } else {
            (self.s - 1.0) / self.s
        }
    }

    /// Probability of the Type-I weaken event: `1/s` (canonical) or
    /// `(s-1)/s` (inaction-biased, see [`SStyle`]).
    pub fn p_weaken(&self) -> f32 {
        match self.s_style {
            SStyle::Canonical => 1.0 / self.s,
            SStyle::InactionBiased => (self.s - 1.0) / self.s,
        }
    }
}

/// Clause polarity convention used across every layer of this repo
/// (native Rust, RTL model, JAX/Pallas): **even clause index ⇒ positive
/// vote, odd ⇒ negative vote**. Interleaving keeps the +/- split balanced
/// under any even `active_clauses` (the over-provisioning port).
#[inline]
pub fn polarity(clause: usize) -> i32 {
    if clause % 2 == 0 {
        1
    } else {
        -1
    }
}

/// THE tail mask of this repo: the valid bits of 64-bit word `word` of a
/// `len`-bit packed row — all-ones for full words, a low-bit partial mask
/// for the tail word of a non-multiple-of-64 row. Shared by the literal
/// tails of the word-parallel feedback engine (`tm::engine`), the sample
/// tails of the bitplane lanes (`tm::bitplane::BitPlanes::lane_mask`) and
/// the incremental re-scorer (`tm::rescore`), so the tail semantics
/// cannot drift between the packed domains.
#[inline]
pub fn word_mask(len: usize, word: usize) -> u64 {
    debug_assert!(word * 64 < len, "word {word} out of range for {len} bits");
    let n = len - word * 64;
    if n >= 64 {
        !0u64
    } else {
        (1u64 << n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iris_shape_is_papers() {
        let s = TmShape::iris();
        assert_eq!(s.classes, 3);
        assert_eq!(s.max_clauses, 16);
        assert_eq!(s.features, 16);
        assert_eq!(s.literals(), 32);
        assert_eq!(s.words(), 1);
        assert_eq!(s.num_tas(), 3 * 16 * 32);
        s.validate().unwrap();
    }

    #[test]
    fn include_threshold_splits_state_space() {
        let s = TmShape::iris();
        assert_eq!(s.include_threshold(), 100);
        assert_eq!(s.max_state(), 199);
    }

    #[test]
    fn invalid_shapes_rejected() {
        let mut s = TmShape::iris();
        s.max_clauses = 15; // odd
        assert!(s.validate().is_err());
        s.max_clauses = 0;
        assert!(s.validate().is_err());
        s = TmShape::iris();
        s.classes = 0;
        assert!(s.validate().is_err());
        s = TmShape::iris();
        s.states = 1;
        assert!(s.validate().is_err());
        s = TmShape::iris();
        s.features = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn paper_params_match_section5() {
        let shape = TmShape::iris();
        let off = TmParams::paper_offline(&shape);
        assert_eq!(off.s, 1.375);
        assert_eq!(off.t, 15);
        let on = TmParams::paper_online(&shape);
        assert_eq!(on.s, 1.0);
        assert_eq!(on.t, 15);
        off.validate(&shape).unwrap();
        on.validate(&shape).unwrap();
    }

    #[test]
    fn invalid_params_rejected() {
        let shape = TmShape::iris();
        let base = TmParams::paper_offline(&shape);
        let mut p = base.clone();
        p.s = 0.5;
        assert!(p.validate(&shape).is_err());
        p = base.clone();
        p.t = 0;
        assert!(p.validate(&shape).is_err());
        p = base.clone();
        p.active_clauses = 18; // > max
        assert!(p.validate(&shape).is_err());
        p = base.clone();
        p.active_clauses = 7; // odd
        assert!(p.validate(&shape).is_err());
        p = base.clone();
        p.active_classes = 4; // > classes
        assert!(p.validate(&shape).is_err());
    }

    #[test]
    fn probabilities() {
        let shape = TmShape::iris();
        let mut p = TmParams::paper_online(&shape); // s = 1, inaction-biased
        assert_eq!(p.p_reinforce(), 0.0);
        assert_eq!(p.p_weaken(), 0.0, "inaction-biased: s = 1 means full Type-I inaction");
        p.s_style = SStyle::Canonical;
        assert_eq!(p.p_weaken(), 1.0, "canonical: s = 1 always weakens");
        p.s = 2.0;
        assert!((p.p_reinforce() - 0.5).abs() < 1e-6);
        assert!((p.p_weaken() - 0.5).abs() < 1e-6);
        p.s_style = SStyle::InactionBiased;
        assert!((p.p_weaken() - 0.5).abs() < 1e-6, "styles agree at s = 2");
        p.boost_true_positive = true;
        assert_eq!(p.p_reinforce(), 1.0);
    }

    #[test]
    fn word_mask_covers_full_and_tail_words() {
        assert_eq!(word_mask(64, 0), !0u64);
        assert_eq!(word_mask(128, 1), !0u64);
        assert_eq!(word_mask(32, 0), (1u64 << 32) - 1);
        assert_eq!(word_mask(80, 1), (1u64 << 16) - 1);
        assert_eq!(word_mask(65, 1), 1);
        // One bit per valid position, none past the tail.
        for len in [1usize, 63, 64, 65, 100, 128] {
            let total: u32 = (0..len.div_ceil(64)).map(|w| word_mask(len, w).count_ones()).sum();
            assert_eq!(total as usize, len, "len {len}");
        }
    }

    #[test]
    fn polarity_interleaves() {
        assert_eq!(polarity(0), 1);
        assert_eq!(polarity(1), -1);
        assert_eq!(polarity(14), 1);
        assert_eq!(polarity(15), -1);
        // Any even prefix is balanced.
        for n in (2..=16).step_by(2) {
            let sum: i32 = (0..n).map(polarity).sum();
            assert_eq!(sum, 0, "prefix of {n} clauses must balance");
        }
    }
}
