//! Incremental dirty-clause re-scoring — the online-learning twin of the
//! sample-sliced kernel in `tm::bitplane`.
//!
//! The paper's headline scenario interleaves training with inference
//! while the accuracy monitor re-scores the model over the same stored
//! sets at every analysis point. Between two analysis points only the
//! clauses whose TA action caches actually *flipped* (exclude→include or
//! include→exclude) can change any fired-mask — and the T-threshold makes
//! feedback, and therefore flips, increasingly rare as the TM converges.
//! That is exactly the sparsity the runtime-tunable eFPGA TM
//! (arXiv 2502.07823) and MATADOR (arXiv 2403.10538) exploit in hardware
//! by touching only active clause banks; here it is mapped onto cached
//! per-(batch, class, clause) fired-masks.
//!
//! [`RescoreCache`] keeps, per scored [`BitPlanes`] batch, every active
//! clause's fired-mask (one `u64` per 64-sample lane) plus per-sample
//! vote tallies. [`MultiTm`]'s mutation clock (stamped by the existing
//! `TaBlock::update_word` flip masks on their way through
//! `MultiTm::apply_word_feedback`, by the scalar increment/decrement
//! transitions, and conservatively by clause-force edits, fault-map loads
//! and bulk state rebuilds) tells the cache *which* clauses moved; only
//! those clauses' masks are re-ANDed, and the tallies are patched by
//! delta (subtract the bits that stopped firing, add the ones that
//! started). A full re-score costs
//! O(classes × clauses × includes × lanes); the incremental pass costs
//! O(dirty clauses × includes × lanes) + an O(classes × samples)
//! clamp-extract — the dominant cost of the interleaved train/infer loop
//! collapses with the dirty fraction.
//!
//! Results are **bit-identical** to a cold [`MultiTm::evaluate_planes`]
//! pass: the per-clause semantics live in one shared helper
//! (`bitplane::clause_fired_mask`), staleness is decided conservatively
//! (any event that *could* change a clause re-scores it), batch identity
//! is content-fingerprinted, and machines are told apart by a
//! process-unique id so clones cannot replay a stale revision clock.
//! `rust/tests/integration_rescore.rs` is the differential proof across
//! randomized interleaved schedules, mid-run fault injection, clause
//! force overrides and fingerprint-invalidated batches.

use crate::tm::bitplane::{clause_fired_mask, BitPlanes, PlaneBatch};
use crate::tm::clause::EvalMode;
use crate::tm::machine::{argmax_rows, MultiTm};
use crate::tm::params::{polarity, TmParams};

/// Cumulative counters of a [`RescoreCache`]'s work — the observability
/// hook behind the bench's online-monitor row and the system report's
/// dirty-fraction column.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RescoreStats {
    /// Incremental evaluations served (cold builds excluded).
    pub evaluations: u64,
    /// Full builds: first sight of a batch, or a conservative rebuild
    /// (different machine, mode, active set, or fingerprint eviction).
    pub cold_builds: u64,
    /// Clauses re-scored because their revision stamp moved.
    pub dirty_clauses: u64,
    /// Clauses served straight from the cache.
    pub clean_clauses: u64,
}

impl RescoreStats {
    /// Fraction of per-evaluation clause visits that had to be re-scored
    /// (cold builds excluded — this is the steady-state incremental
    /// ratio; at convergence it approaches 0).
    pub fn dirty_fraction(&self) -> f64 {
        let total = self.dirty_clauses + self.clean_clauses;
        if total == 0 {
            0.0
        } else {
            self.dirty_clauses as f64 / total as f64
        }
    }
}

/// One cached batch: fired-masks + tallies, and everything that must
/// match for them to still be exact.
#[derive(Debug, Clone)]
struct Entry {
    /// Batch identity ([`BitPlanes::fingerprint`]).
    fingerprint: u64,
    /// Machine identity ([`MultiTm::uid`]).
    machine: u64,
    mode: EvalMode,
    active_clauses: usize,
    active_classes: usize,
    n: usize,
    lanes: usize,
    /// Fired-masks, `[(c * active_clauses + j) * lanes + l]`.
    fired: Vec<u64>,
    /// Machine revision stamp at which each clause slot was scored,
    /// `[c * active_clauses + j]`.
    seen_rev: Vec<u64>,
    /// Unclamped per-sample vote sums, `[c * n + i]` — patched by delta
    /// when a clause's masks change. `T` is applied at extraction, so
    /// run-time `T` changes never invalidate the cache.
    totals: Vec<i32>,
}

/// Incremental re-scoring cache over transposed plane batches. One cache
/// serves many batches (keyed by content fingerprint) and survives
/// machine swaps, parameter changes and batch edits by conservatively
/// rebuilding whatever stopped being provably exact.
#[derive(Debug, Clone, Default)]
pub struct RescoreCache {
    entries: Vec<Entry>,
    stats: RescoreStats,
    /// Scratch: effective literal indices of the clause being re-scored.
    lits: Vec<u32>,
    /// Scratch: freshly computed fired-masks of one clause.
    masks: Vec<u64>,
}

/// Cached batches kept before the oldest is evicted. The drivers score a
/// handful of fixed sets (the analyzer: three sets × filter configs);
/// the cap only bounds memory when a caller streams many one-shot
/// batches through a single cache.
const MAX_ENTRIES: usize = 8;

impl RescoreCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative work counters.
    pub fn stats(&self) -> RescoreStats {
        self.stats
    }

    /// Drop every cached batch (stats are kept).
    pub fn invalidate(&mut self) {
        self.entries.clear();
    }

    /// Clamped sums for every active class over a transposed batch,
    /// class-major (`result[c * planes.len() + i]`) — **bit-identical**
    /// to [`MultiTm::evaluate_planes`] on the same machine and batch,
    /// re-ANDing only the clauses whose revision stamp moved since this
    /// cache last scored them.
    pub fn evaluate(
        &mut self,
        tm: &MultiTm,
        planes: &BitPlanes,
        params: &TmParams,
        mode: EvalMode,
    ) -> Vec<i32> {
        assert_eq!(
            planes.literals(),
            tm.shape().literals(),
            "plane/machine literal width mismatch"
        );
        let n = planes.len();
        let nc = params.active_classes;
        if n == 0 || nc == 0 {
            return Vec::new();
        }
        let idx = self.entry_for(tm, planes, params, mode);
        self.refresh(idx, tm, planes, mode);
        let entry = &self.entries[idx];
        let t = params.t;
        entry.totals.iter().map(|&v| v.clamp(-t, t)).collect()
    }

    /// Batched prediction off the cache (row-identical to
    /// [`MultiTm::predict_planes`]).
    pub fn predict(
        &mut self,
        tm: &MultiTm,
        planes: &BitPlanes,
        params: &TmParams,
    ) -> Vec<usize> {
        let sums = self.evaluate(tm, planes, params, EvalMode::Infer);
        argmax_rows(&sums, planes.len(), params.active_classes)
    }

    /// Classification accuracy over a labelled plane batch — equal to
    /// [`MultiTm::accuracy_planes`] on the same batch.
    pub fn accuracy(&mut self, tm: &MultiTm, batch: &PlaneBatch, params: &TmParams) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        let preds = self.predict(tm, batch.planes(), params);
        let correct =
            preds.iter().zip(batch.labels().iter()).filter(|(p, y)| p == y).count();
        correct as f64 / batch.len() as f64
    }

    /// Find (or conservatively rebuild) the entry for this
    /// (batch, machine, mode, active-set) combination; returns its index
    /// with `seen_rev` zeroed when a full build is needed.
    fn entry_for(
        &mut self,
        tm: &MultiTm,
        planes: &BitPlanes,
        params: &TmParams,
        mode: EvalMode,
    ) -> usize {
        let fp = planes.fingerprint();
        let nc = params.active_classes;
        match self.entries.iter().position(|e| e.fingerprint == fp) {
            Some(i) => {
                let e = &self.entries[i];
                let exact = e.machine == tm.uid()
                    && e.mode == mode
                    && e.active_clauses == params.active_clauses
                    && e.active_classes == nc
                    && e.n == planes.len();
                if !exact {
                    self.entries[i] = Self::blank(tm, planes, params, mode);
                    self.stats.cold_builds += 1;
                } else {
                    self.stats.evaluations += 1;
                }
                i
            }
            None => {
                if self.entries.len() >= MAX_ENTRIES {
                    self.entries.remove(0); // oldest batch out
                }
                self.entries.push(Self::blank(tm, planes, params, mode));
                self.stats.cold_builds += 1;
                self.entries.len() - 1
            }
        }
    }

    /// A zeroed entry: every clause slot at revision 0 with empty masks,
    /// so the next [`RescoreCache::refresh`] scores everything. Revision
    /// stamps are ≥ 1 for any constructed machine ([`MultiTm::new`] ends
    /// with a bulk rebuild stamp), so stamp 0 can never read as fresh.
    fn blank(tm: &MultiTm, planes: &BitPlanes, params: &TmParams, mode: EvalMode) -> Entry {
        let nc = params.active_classes;
        let slots = nc * params.active_clauses;
        Entry {
            fingerprint: planes.fingerprint(),
            machine: tm.uid(),
            mode,
            active_clauses: params.active_clauses,
            active_classes: nc,
            n: planes.len(),
            lanes: planes.lanes(),
            fired: vec![0u64; slots * planes.lanes()],
            seen_rev: vec![0u64; slots],
            totals: vec![0i32; nc * planes.len()],
        }
    }

    /// Re-score every stale clause of one entry: recompute its
    /// fired-masks through the shared sliced-clause semantics and patch
    /// the vote tallies by delta.
    fn refresh(&mut self, idx: usize, tm: &MultiTm, planes: &BitPlanes, mode: EvalMode) {
        let entry = &mut self.entries[idx];
        let train = mode == EvalMode::Train;
        let max_clauses = tm.shape().max_clauses;
        let (n, lanes) = (entry.n, entry.lanes);
        for c in 0..entry.active_classes {
            for j in 0..entry.active_clauses {
                let slot = c * entry.active_clauses + j;
                let rev = tm.row_rev(c * max_clauses + j);
                if entry.seen_rev[slot] >= rev {
                    self.stats.clean_clauses += 1;
                    continue;
                }
                if entry.seen_rev[slot] > 0 {
                    self.stats.dirty_clauses += 1;
                }
                self.lits.clear();
                let force = tm.push_eff_lits(c, j, &mut self.lits);
                self.masks.clear();
                for l in 0..lanes {
                    let valid = planes.lane_mask(l);
                    self.masks.push(clause_fired_mask(planes, l, valid, train, force, &self.lits));
                }
                // Patch the tallies with the mask delta: bits that
                // stopped firing lose this clause's polarity, bits that
                // started firing gain it. Plane tails are zero and masks
                // are lane-masked, so every set bit is a real sample.
                // This scalar per-bit walk costs O(popcount of changed
                // bits) — tiny at the incremental fractions this engine
                // targets, but a constant factor worse than the cold
                // path's bit-sliced counters when everything changed
                // (cold builds, fault injections); those events are rare
                // and amortised across the incremental evaluations that
                // follow, so a second bit-sliced fill path isn't worth
                // its surface area.
                let pol = polarity(j);
                let totals = &mut entry.totals[c * n..(c + 1) * n];
                for (l, &new) in self.masks.iter().enumerate() {
                    let old = entry.fired[slot * lanes + l];
                    if new == old {
                        continue;
                    }
                    let mut gained = new & !old;
                    while gained != 0 {
                        let b = gained.trailing_zeros() as usize;
                        totals[l * 64 + b] += pol;
                        gained &= gained - 1;
                    }
                    let mut lost = old & !new;
                    while lost != 0 {
                        let b = lost.trailing_zeros() as usize;
                        totals[l * 64 + b] -= pol;
                        lost &= lost - 1;
                    }
                    entry.fired[slot * lanes + l] = new;
                }
                entry.seen_rev[slot] = rev;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::clause::Input;
    use crate::tm::engine::train_step_fast;
    use crate::tm::params::{TmParams, TmShape};
    use crate::tm::rng::{StepRands, Xoshiro256};

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn random_rows(s: &TmShape, n: usize, rng: &mut Xoshiro256) -> Vec<(Input, usize)> {
        (0..n)
            .map(|i| {
                let bits: Vec<bool> =
                    (0..s.features).map(|_| rng.next_f32() < 0.5).collect();
                (Input::pack(s, &bits), i % s.classes)
            })
            .collect()
    }

    #[test]
    fn matches_cold_pass_across_training() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut tm = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(0x1A);
        let rows = random_rows(&s, 70, &mut rng);
        let batch = PlaneBatch::from_labelled(&s, &rows);
        let mut cache = RescoreCache::new();
        let mut rands = StepRands::draw(&mut rng, &s);
        for step in 0..40 {
            let (x, y) = &rows[step % rows.len()];
            rands.refill(&mut rng, &s);
            train_step_fast(&mut tm, x, *y, &p, &rands);
            let inc = cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer);
            let cold = tm.evaluate_planes(batch.planes(), &p, EvalMode::Infer);
            assert_eq!(inc, cold, "step {step}");
        }
        assert_eq!(cache.stats().cold_builds, 1, "one batch, one cold build");
        assert!(cache.stats().evaluations >= 39);
    }

    #[test]
    fn second_evaluation_without_mutation_is_all_clean() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x2B);
        let states: Vec<u32> =
            (0..s.num_tas()).map(|_| rng.next_below(2 * s.states as usize) as u32).collect();
        let tm = MultiTm::from_states(&s, states).unwrap();
        let rows = random_rows(&s, 33, &mut rng);
        let batch = PlaneBatch::from_labelled(&s, &rows);
        let mut cache = RescoreCache::new();
        let a = cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer);
        let before = cache.stats();
        let b = cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer);
        assert_eq!(a, b);
        let after = cache.stats();
        assert_eq!(after.dirty_clauses, before.dirty_clauses, "no clause re-scored");
        assert_eq!(
            after.clean_clauses - before.clean_clauses,
            (p.active_classes * p.active_clauses) as u64
        );
    }

    #[test]
    fn t_change_needs_no_rebuild() {
        let s = shape();
        let mut p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x3C);
        let states: Vec<u32> =
            (0..s.num_tas()).map(|_| rng.next_below(2 * s.states as usize) as u32).collect();
        let tm = MultiTm::from_states(&s, states).unwrap();
        let rows = random_rows(&s, 100, &mut rng);
        let batch = PlaneBatch::from_labelled(&s, &rows);
        let mut cache = RescoreCache::new();
        cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer);
        for t in [1, 3, 15] {
            p.t = t;
            let inc = cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer);
            assert_eq!(inc, tm.evaluate_planes(batch.planes(), &p, EvalMode::Infer));
        }
        assert_eq!(cache.stats().cold_builds, 1, "T is applied at extraction");
    }

    #[test]
    fn clone_forces_full_rebuild() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut tm = MultiTm::new(&s).unwrap();
        let rows = random_rows(&s, 20, &mut Xoshiro256::new(0x4D));
        let batch = PlaneBatch::from_labelled(&s, &rows);
        let mut cache = RescoreCache::new();
        cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer);
        // Diverge a clone, then hand the *clone* to the same cache: the
        // uid mismatch must trigger a rebuild, not a stale-rev readout.
        let mut fork = tm.clone();
        fork.set_clause_fault(0, 0, Some(true));
        tm.set_clause_fault(0, 1, Some(true)); // original moves too
        let inc = cache.evaluate(&fork, batch.planes(), &p, EvalMode::Infer);
        assert_eq!(inc, fork.evaluate_planes(batch.planes(), &p, EvalMode::Infer));
        assert_eq!(cache.stats().cold_builds, 2);
    }

    #[test]
    fn eviction_keeps_results_correct() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let tm = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(0x5E);
        let batches: Vec<PlaneBatch> = (0..MAX_ENTRIES + 2)
            .map(|_| PlaneBatch::from_labelled(&s, &random_rows(&s, 10, &mut rng)))
            .collect();
        let mut cache = RescoreCache::new();
        for b in &batches {
            cache.evaluate(&tm, b.planes(), &p, EvalMode::Infer);
        }
        // The first batch was evicted; scoring it again cold-builds and
        // still matches.
        let inc = cache.evaluate(&tm, batches[0].planes(), &p, EvalMode::Infer);
        assert_eq!(inc, tm.evaluate_planes(batches[0].planes(), &p, EvalMode::Infer));
        assert_eq!(cache.stats().cold_builds as usize, MAX_ENTRIES + 2 + 1);
    }

    #[test]
    fn empty_inputs_short_circuit() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let tm = MultiTm::new(&s).unwrap();
        let batch = PlaneBatch::from_labelled(&s, &[]);
        let mut cache = RescoreCache::new();
        assert!(cache.evaluate(&tm, batch.planes(), &p, EvalMode::Infer).is_empty());
        assert_eq!(cache.accuracy(&tm, &batch, &p), 0.0);
        assert_eq!(cache.stats().cold_builds, 0);
    }
}
