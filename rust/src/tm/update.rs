//! The sequenced online-update log shared by the serving layer's shard
//! replicas (`crate::serve`) and its scalar oracle.
//!
//! The paper's operating mode interleaves training with inference during
//! operation; the serving layer replicates one [`MultiTm`] across shard
//! workers and must keep every replica **bit-identical** without any
//! cross-thread state sharing. The contract here makes that trivial:
//! an update is a [`ShardUpdate`] — a monotone sequence number plus what
//! happened (a labelled sample, or a clause-output fault edit) — and
//! *all* randomness a `Learn` update consumes is derived from
//! `(base_seed, seq)` alone ([`update_rands`]). Replicas that apply the
//! same log in sequence order therefore converge to the same TA states,
//! action caches and mutation-clock observable behaviour as the scalar
//! oracle fed the same log, regardless of which thread applies it or
//! when (`train_step_fast` is deterministic given its [`StepRands`]).
//!
//! This is the software form of the paper's §3.5 online data manager
//! feeding TM management: arrival order *is* the log order, and the log
//! is the only channel through which serving-time learning mutates a
//! model.

use crate::tm::clause::Input;
use crate::tm::engine::train_step_fast;
use crate::tm::feedback::StepActivity;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::{StepRands, Xoshiro256};

/// An absolute virtual-tick deadline carried by an inference request
/// through the serving stack. The clock is the same deterministic tick
/// base every batching decision already uses, so deadline expiry is a
/// pure function of the trace: a request arriving at tick `t` with a
/// time-to-live of `ttl` carries `Deadline(t + ttl)` and is *expired*
/// at any flush happening strictly after that tick. Expired requests
/// are answered with a typed deadline response at flush time — never
/// dispatched, never silently dropped (`crate::net::frontend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Deadline(pub u64);

impl Deadline {
    /// Deadline for a request arriving at `now` with `ttl` ticks to
    /// live (saturating: a huge ttl means "never expires").
    pub fn after(now: u64, ttl: u64) -> Self {
        Deadline(now.saturating_add(ttl))
    }

    /// True once the virtual clock has moved strictly past the
    /// deadline tick: a request flushed *at* its deadline still makes
    /// it.
    pub fn expired(&self, now: u64) -> bool {
        now > self.0
    }
}

/// What one sequenced update does to a replica.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UpdateKind {
    /// A labelled sample arriving mid-stream: one online training step
    /// through the word-parallel engine.
    Learn { input: Input, label: usize },
    /// A clause-output fault edit (§7 fault injection) arriving over the
    /// same sequenced channel, so fault campaigns replay deterministically
    /// against serving traffic; `None` clears the gate.
    ClauseFault { class: usize, clause: usize, force: Option<bool> },
}

/// One entry of the replica update log: a sequence number (1-based,
/// assigned in arrival order by whoever owns the log) plus the update.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardUpdate {
    pub seq: u64,
    pub kind: UpdateKind,
}

/// The eager step randomness of update `seq` under `base_seed` — a fresh
/// splitmix-seeded generator per update, so randomness depends only on
/// `(base_seed, seq)` and never on which replica draws it or how many
/// updates it applied before.
pub fn update_rands(shape: &TmShape, base_seed: u64, seq: u64) -> StepRands {
    let mut rng = Xoshiro256::new(update_seed(base_seed, seq));
    StepRands::draw(&mut rng, shape)
}

/// Refill a pre-allocated record with update `seq`'s randomness — the
/// allocation-free hot-path twin of [`update_rands`], producing
/// bit-identical draws (`StepRands::draw` is exactly a zeroed allocation
/// plus this refill).
pub fn update_rands_into(rands: &mut StepRands, shape: &TmShape, base_seed: u64, seq: u64) {
    let mut rng = Xoshiro256::new(update_seed(base_seed, seq));
    rands.refill(&mut rng, shape);
}

/// Golden-ratio spread keeps distinct (base_seed, seq) pairs from
/// colliding before Xoshiro256::new's splitmix mixing.
#[inline]
fn update_seed(base_seed: u64, seq: u64) -> u64 {
    base_seed ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl MultiTm {
    /// Apply one sequenced update to this replica. `Learn` runs a
    /// [`train_step_fast`] step on randomness derived from
    /// `(base_seed, update.seq)` and returns its activity; fault edits
    /// return `None`. Applying the same log in sequence order with the
    /// same `base_seed` and `params` leaves any two replicas of the same
    /// initial machine bit-identical.
    pub fn apply_update(
        &mut self,
        update: &ShardUpdate,
        params: &TmParams,
        base_seed: u64,
    ) -> Option<StepActivity> {
        self.apply_update_with(update, params, base_seed, &mut None)
    }

    /// [`MultiTm::apply_update`] with a caller-owned randomness scratch:
    /// the record is allocated on first use and refilled per update
    /// thereafter ([`update_rands_into`]), so long-lived appliers — the
    /// shard workers and the serving oracle — pay zero steady-state
    /// allocation. Bit-identical to the allocating path.
    pub fn apply_update_with(
        &mut self,
        update: &ShardUpdate,
        params: &TmParams,
        base_seed: u64,
        scratch: &mut Option<StepRands>,
    ) -> Option<StepActivity> {
        let activity = match &update.kind {
            UpdateKind::Learn { input, label } => {
                let shape = self.shape().clone();
                match scratch {
                    Some(r) => update_rands_into(r, &shape, base_seed, update.seq),
                    None => *scratch = Some(update_rands(&shape, base_seed, update.seq)),
                }
                let rands = scratch.as_ref().expect("scratch was just filled");
                Some(train_step_fast(self, input, *label, params, rands))
            }
            UpdateKind::ClauseFault { class, clause, force } => {
                self.set_clause_fault(*class, *clause, *force);
                None
            }
        };
        crate::verify::contracts::enforce(self, "MultiTm::apply_update");
        activity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::clause::EvalMode;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn random_log(n: usize, seed: u64) -> Vec<ShardUpdate> {
        let s = shape();
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|i| {
                let kind = if rng.next_f32() < 0.9 {
                    let bits = crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5);
                    UpdateKind::Learn {
                        input: Input::pack(&s, &bits),
                        label: rng.next_below(s.classes),
                    }
                } else {
                    UpdateKind::ClauseFault {
                        class: rng.next_below(s.classes),
                        clause: rng.next_below(s.max_clauses),
                        force: [None, Some(false), Some(true)][rng.next_below(3)],
                    }
                };
                ShardUpdate { seq: (i + 1) as u64, kind }
            })
            .collect()
    }

    /// Replicas fed the same log converge bit-identically, even when one
    /// of them interleaves (read-only) inference between updates.
    #[test]
    fn same_log_converges_replicas() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let base = MultiTm::new(&s).unwrap();
        let log = random_log(120, 0xA11CE);
        let mut a = base.clone();
        let mut b = base.clone();
        let mut rng = Xoshiro256::new(7);
        let probe =
            Input::pack(&s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
        for u in &log {
            a.apply_update(u, &p, 0xBA5E);
            b.apply_update(u, &p, 0xBA5E);
            // Replica b also serves inference mid-log; this must not
            // perturb convergence (evaluate only touches scratch).
            b.evaluate(&probe, &p, EvalMode::Infer);
        }
        assert_eq!(a.ta().states(), b.ta().states());
        for c in 0..s.classes {
            for j in 0..s.max_clauses {
                assert_eq!(a.action_words(c, j), b.action_words(c, j));
                assert_eq!(a.clause_fault(c, j), b.clause_fault(c, j));
            }
        }
    }

    /// The scratch path is bit-identical to the allocating path along a
    /// whole log, and fills its scratch on first use.
    #[test]
    fn scratch_path_matches_allocating_path() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let base = MultiTm::new(&s).unwrap();
        let log = random_log(80, 0x5CAC);
        let mut plain = base.clone();
        let mut scratched = base.clone();
        let mut scratch = None;
        for u in &log {
            let a = plain.apply_update(u, &p, 0x11);
            let b = scratched.apply_update_with(u, &p, 0x11, &mut scratch);
            assert_eq!(a, b, "seq {}", u.seq);
        }
        assert_eq!(plain.ta().states(), scratched.ta().states());
        assert!(scratch.is_some(), "a Learn update must have filled the scratch");
    }

    /// Update randomness depends on (base_seed, seq) only: the same
    /// update applied by two fresh machines moves them identically, and
    /// a different base seed or seq moves them differently.
    #[test]
    fn learn_randomness_is_keyed_by_seed_and_seq() {
        let s = shape();
        let a = update_rands(&s, 1, 5);
        let b = update_rands(&s, 1, 5);
        assert_eq!(a.clause_rand, b.clause_rand);
        assert_eq!(a.ta_rand, b.ta_rand);
        assert_eq!(a.neg_class_draw, b.neg_class_draw);
        let c = update_rands(&s, 2, 5);
        let d = update_rands(&s, 1, 6);
        assert_ne!(a.ta_rand, c.ta_rand);
        assert_ne!(a.ta_rand, d.ta_rand);
    }

    /// Learn updates are exactly a train_step_fast on the derived draws.
    #[test]
    fn learn_update_is_train_step_fast() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut via_update = MultiTm::new(&s).unwrap();
        let mut manual = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(3);
        for seq in 1..=60u64 {
            let x = Input::pack(&s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
            let y = (seq % 3) as usize;
            let u = ShardUpdate {
                seq,
                kind: UpdateKind::Learn { input: x.clone(), label: y },
            };
            let act = via_update.apply_update(&u, &p, 0xF00D).unwrap();
            let rands = update_rands(&s, 0xF00D, seq);
            let act2 = train_step_fast(&mut manual, &x, y, &p, &rands);
            assert_eq!(act, act2, "seq {seq}");
            assert_eq!(via_update.ta().states(), manual.ta().states(), "seq {seq}");
        }
    }

    /// Deadlines are inclusive of their own tick and saturate instead
    /// of wrapping.
    #[test]
    fn deadline_semantics() {
        let d = Deadline::after(10, 5);
        assert!(!d.expired(10));
        assert!(!d.expired(15), "a flush at the deadline tick still makes it");
        assert!(d.expired(16));
        let never = Deadline::after(10, u64::MAX);
        assert!(!never.expired(u64::MAX));
    }

    /// Fault updates program the clause-output gate and return no
    /// activity.
    #[test]
    fn fault_update_programs_gate() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut tm = MultiTm::new(&s).unwrap();
        let u = ShardUpdate {
            seq: 1,
            kind: UpdateKind::ClauseFault { class: 1, clause: 2, force: Some(true) },
        };
        assert!(tm.apply_update(&u, &p, 0).is_none());
        assert_eq!(tm.clause_fault(1, 2), Some(true));
        let clear = ShardUpdate {
            seq: 2,
            kind: UpdateKind::ClauseFault { class: 1, clause: 2, force: None },
        };
        tm.apply_update(&clear, &p, 0);
        assert_eq!(tm.clause_fault(1, 2), None);
        assert_eq!(tm.clause_fault_count(), 0);
    }
}
