//! The Tsetlin machine core (§2 of the paper): automata, clauses,
//! multiclass machine, Type I/II feedback, fault gates, the word-parallel
//! training engine and the deterministic randomness contract shared with
//! the L2/L1 layers.
//!
//! Two training paths coexist deliberately: [`feedback::train_step`] is
//! the scalar oracle pinned bit-for-bit to the L2 HLO graph
//! (`rust/tests/parity.rs`), and [`engine`] is the word-parallel fast
//! path — bit-identical to the oracle given the same [`rng::StepRands`],
//! with an additional lazy-randomness mode for the hot loops. Batched
//! inference has a row-major path (`machine.rs`) and a sample-sliced
//! bitplane path ([`bitplane`], 64 samples per AND) that are
//! differentially pinned bit-identical; [`rescore`] adds the incremental
//! dirty-clause re-scoring engine over cached plane batches for the
//! interleaved online train/infer loop, pinned bit-identical to a cold
//! plane pass; [`train_planes`] is the training-side twin — a
//! lane-speculative 64-wide trainer that batch-evaluates clause
//! fired-masks per lane, repairs only mid-lane action flips, and stays
//! bit-identical to the per-step engines.

pub mod automaton;
pub mod bitplane;
pub mod clause;
pub mod engine;
pub mod explain;
pub mod fault;
pub mod feedback;
pub mod machine;
pub mod params;
pub mod rescore;
pub mod rng;
pub mod state;
pub mod train_planes;
pub mod update;

pub use automaton::TaBlock;
pub use bitplane::{BitPlanes, PlaneBatch};
pub use clause::{EvalMode, Input};
pub use engine::{
    train_step_fast, train_step_fast_with, train_step_lazy, train_step_lazy_with, EpochStats,
    FeedbackPlan,
};
pub use fault::{Fault, FaultMap};
pub use feedback::{train_step, StepActivity};
pub use machine::{argmax_class, MultiTm};
pub use params::{polarity, word_mask, TmParams, TmShape};
pub use rescore::{RescoreCache, RescoreStats};
pub use rng::{BernoulliPlan, StepRands, Xoshiro256};
pub use train_planes::{train_rows_seq, TrainScratch};
pub use update::{ShardUpdate, UpdateKind};
