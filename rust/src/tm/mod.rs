//! The Tsetlin machine core (§2 of the paper): automata, clauses,
//! multiclass machine, Type I/II feedback, fault gates and the
//! deterministic randomness contract shared with the L2/L1 layers.

pub mod automaton;
pub mod clause;
pub mod explain;
pub mod fault;
pub mod feedback;
pub mod machine;
pub mod params;
pub mod rng;
pub mod state;

pub use automaton::TaBlock;
pub use clause::{EvalMode, Input};
pub use fault::{Fault, FaultMap};
pub use feedback::{train_step, StepActivity};
pub use machine::MultiTm;
pub use params::{polarity, TmParams, TmShape};
pub use rng::{StepRands, Xoshiro256};
