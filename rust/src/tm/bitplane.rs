//! Sample-sliced (64-wide) batch inference — the transposed twin of the
//! clause-parallel evaluator in `machine.rs`.
//!
//! The row-major batched path ([`MultiTm::evaluate_batch`]) walks one
//! sample at a time: per clause it ANDs the packed *literal* words of a
//! single row. This module transposes a batch of packed [`Input`] rows
//! into **literal-major bitplanes**: [`BitPlanes`] holds, for every
//! literal `k`, a row of `u64` *lanes* in which bit `i` of lane `l` is
//! the value of literal `k` in sample `l * 64 + i`. A clause's fired-mask
//! over 64 samples is then the AND of the bitplanes of its included
//! literals — the same AND/popcount structure the runtime-tunable eFPGA
//! TM (arXiv 2502.07823) and MATADOR (arXiv 2403.10538) exploit across
//! wide data lanes, mapped onto software words.
//!
//! Votes are tallied without leaving the sliced domain: fired-masks are
//! accumulated into bit-sliced ripple-carry counters (one `u64` per
//! counter bit, 64 samples per add), and per-sample sums are extracted
//! once per lane. [`MultiTm::evaluate_planes`] is **bit-identical** to
//! [`MultiTm::evaluate_batch`] — clause-force gates, TA fault gates
//! (applied to the action words, which is exactly what the row-major
//! gate application computes), the empty-clause convention and the
//! T-clamped sums are all preserved; `rust/tests/integration_bitplane.rs`
//! is the differential proof.
//!
//! Because the planes depend only on the data (not on the machine), they
//! are cached on the dataset side (`BoolDataset::pack_planes`,
//! [`crate::data::blocks::PackedSets`], the accuracy analyzer's
//! per-(set, filter) cache) and reused across every analysis point that
//! rescores the same rows.

use crate::tm::clause::{EvalMode, Input};
use crate::tm::machine::{argmax_rows, MultiTm, SPAWN_WORK};
use crate::tm::params::{word_mask, TmParams, TmShape};

/// A batch of inputs transposed into literal-major bitplanes:
/// `plane(k)[l]` packs the value of literal `k` for samples
/// `l * 64 ..` (64 samples per `u64` lane; tail bits are zero).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPlanes {
    /// `planes[k * lanes + l]` = lane `l` of literal `k`.
    planes: Vec<u64>,
    literals: usize,
    lanes: usize,
    len: usize,
    /// Content fingerprint (FNV over shape + plane words), stamped at
    /// transpose time — the batch-identity key of the incremental
    /// re-scorer's caches (`tm::rescore`): equal content ⇒ equal
    /// fingerprint, so a rebuilt-but-identical batch keeps its cache and
    /// a mutated batch conservatively invalidates it.
    fingerprint: u64,
}

impl BitPlanes {
    /// Transpose a batch of packed rows (one pass over every set literal
    /// bit; paid once per cached batch).
    pub fn from_inputs(shape: &TmShape, inputs: &[Input]) -> Self {
        Self::build(shape, inputs.len(), |i| &inputs[i])
    }

    /// Transpose the inputs of a labelled batch.
    pub fn from_labelled(shape: &TmShape, rows: &[(Input, usize)]) -> Self {
        Self::build(shape, rows.len(), |i| &rows[i].0)
    }

    /// Transpose `n` rows produced by an arbitrary projection — the
    /// generic entry the lane-speculative trainer (`tm::train_planes`)
    /// and the serve workers' coalesced Learn runs use for row types
    /// that are not `(Input, usize)` tuples.
    pub(crate) fn from_rows<'a>(
        shape: &TmShape,
        n: usize,
        row: impl Fn(usize) -> &'a Input,
    ) -> Self {
        Self::build(shape, n, row)
    }

    fn build<'a>(shape: &TmShape, n: usize, row: impl Fn(usize) -> &'a Input) -> Self {
        let literals = shape.literals();
        let lanes = n.div_ceil(64);
        let mut planes = vec![0u64; literals * lanes];
        for i in 0..n {
            let x = row(i);
            assert_eq!(x.literals(), literals, "input/plane literal width mismatch");
            let (lane, bit) = (i / 64, 1u64 << (i % 64));
            for (w, &iw) in x.words().iter().enumerate() {
                let mut a = iw;
                while a != 0 {
                    let k = w * 64 + a.trailing_zeros() as usize;
                    planes[k * lanes + lane] |= bit;
                    a &= a - 1;
                }
            }
        }
        // Order-sensitive FNV over the content (shared fold with the
        // analyzer's stream fingerprint) — O(literals · lanes), a small
        // fraction of the transpose above.
        let mut h = fnv_fold(FNV_OFFSET, n as u64);
        h = fnv_fold(h, literals as u64);
        for &w in &planes {
            h = fnv_fold(h, w);
        }
        BitPlanes { planes, literals, lanes, len: n, fingerprint: h }
    }

    /// Number of samples in the batch.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Literal row width (must match the machine's `shape.literals()`).
    #[inline]
    pub fn literals(&self) -> usize {
        self.literals
    }

    /// Number of 64-sample lanes.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// One lane of one literal's plane.
    #[inline]
    pub(crate) fn plane_word(&self, lit: usize, lane: usize) -> u64 {
        self.planes[lit * self.lanes + lane]
    }

    /// Content fingerprint (see the field doc).
    #[inline]
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Bits of `lane` that correspond to real samples (the tail lane of a
    /// non-multiple-of-64 batch is partial).
    #[inline]
    pub fn lane_mask(&self, lane: usize) -> u64 {
        debug_assert!(lane < self.lanes);
        word_mask(self.len, lane)
    }

    /// Value of literal `k` in sample `i` (the transpose inverse; used by
    /// the differential tests).
    pub fn literal(&self, lit: usize, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.planes[lit * self.lanes + i / 64] & (1u64 << (i % 64)) != 0
    }
}

/// A labelled batch transposed once: bitplanes plus labels — the unit the
/// dataset layer caches so cross-validation folds, sweep grids and
/// monitor snapshots pay the transpose once and rescore it many times.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlaneBatch {
    planes: BitPlanes,
    labels: Vec<usize>,
}

impl PlaneBatch {
    pub fn from_labelled(shape: &TmShape, rows: &[(Input, usize)]) -> Self {
        PlaneBatch {
            planes: BitPlanes::from_labelled(shape, rows),
            labels: rows.iter().map(|(_, y)| *y).collect(),
        }
    }

    #[inline]
    pub fn planes(&self) -> &BitPlanes {
        &self.planes
    }

    #[inline]
    pub fn labels(&self) -> &[usize] {
        &self.labels
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// FNV-1a 64-bit offset basis — the seed of both content fingerprints
/// ([`BitPlanes::fingerprint`] and the analyzer's stream fingerprint in
/// `fpga::accuracy`): one definition so the two invalidation layers
/// cannot drift apart.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a-style fold step over a 64-bit value (shared with
/// `fpga::accuracy::stream_fingerprint`).
#[inline]
pub(crate) fn fnv_fold(h: u64, v: u64) -> u64 {
    const FNV_PRIME: u64 = 0x100_0000_01b3;
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// Ripple-carry add of a 64-lane 0/1 mask into a bit-sliced counter
/// (`counter[b]` holds bit `b` of all 64 lane counts). Shared with the
/// lane-speculative trainer (`tm::train_planes`), which tallies one
/// lane's speculative vote totals through the same adder.
#[inline]
pub(crate) fn add_mask(counter: &mut [u64], mut mask: u64) {
    for plane in counter.iter_mut() {
        let carry = *plane & mask;
        *plane ^= mask;
        mask = carry;
        if mask == 0 {
            return;
        }
    }
    debug_assert_eq!(mask, 0, "bit-sliced counter overflow");
}

/// Fired-mask of one clause over one 64-sample lane: force gate first,
/// the empty-clause convention second, then the AND chain over the
/// effective included literals' planes (early exit on all-zero). The
/// single definition of clause semantics in the sliced domain — shared
/// by the batched kernel below and the incremental re-scorer
/// (`tm::rescore`) so the two cannot drift apart.
#[inline]
pub(crate) fn clause_fired_mask(
    planes: &BitPlanes,
    lane: usize,
    valid: u64,
    train: bool,
    force: i8,
    lits: &[u32],
) -> u64 {
    match force {
        0 => 0u64,
        1 => valid,
        _ if lits.is_empty() => {
            // Empty clause: fires in train mode only.
            if train {
                valid
            } else {
                0
            }
        }
        _ => {
            let mut m = valid;
            for &k in lits {
                m &= planes.plane_word(k as usize, lane);
                if m == 0 {
                    break;
                }
            }
            m
        }
    }
}

/// Lane-invariant evaluation prep for one class: per clause, the force
/// state and the *effective* (post-fault-gate) included literals —
/// computed once per `evaluate_planes` call and shared read-only by
/// every sample-chunk task of that class, so gate application and
/// action-bit extraction are not repeated per chunk.
struct ClassPrep {
    /// Effective included literal indices, concatenated across clauses.
    lits: Vec<u32>,
    /// Per clause: (force state, start, end) — the range into `lits`.
    clauses: Vec<(i8, usize, usize)>,
}

impl ClassPrep {
    /// No clause of this class can fire: nothing is effectively included
    /// anywhere, no clause is forced to 1, and inference mode silences
    /// empty clauses — so the class's sums are identically zero and the
    /// whole lane sweep can be skipped (common for over-provisioned or
    /// freshly reset classes).
    fn silent(&self, train: bool) -> bool {
        !train && self.lits.is_empty() && self.clauses.iter().all(|&(f, _, _)| f != 1)
    }
}

impl MultiTm {
    /// Sample-sliced batched evaluation: clamped sums for every active
    /// class over a transposed batch, class-major
    /// (`result[c * planes.len() + i]`) — bit-identical to
    /// [`MultiTm::evaluate_batch`] on the same rows, computing each
    /// clause's fired-mask over 64 samples per AND.
    ///
    /// Work is fanned out over scoped threads by **class × sample-chunk**
    /// (lane-aligned), so large batches saturate all cores instead of
    /// capping at `active_classes` threads like the row-major path.
    pub fn evaluate_planes(
        &self,
        planes: &BitPlanes,
        params: &TmParams,
        mode: EvalMode,
    ) -> Vec<i32> {
        assert_eq!(
            planes.literals(),
            self.shape().literals(),
            "plane/machine literal width mismatch"
        );
        let n = planes.len();
        let nc = params.active_classes;
        if n == 0 || nc == 0 {
            return Vec::new();
        }
        let mut sums = vec![0i32; nc * n];
        // Lane-invariant per-class prep (force states + effective
        // includes), computed once and shared by every chunk task.
        let preps: Vec<ClassPrep> = (0..nc).map(|c| self.class_prep(c, params)).collect();
        // Silent classes (no effective includes, no force-1, infer mode)
        // produce identically-zero sums: skip their lane sweeps entirely
        // — the sums buffer is already zeroed.
        let train = mode == EvalMode::Train;
        let work = n * nc * params.active_clauses;
        let workers = if work < SPAWN_WORK {
            1
        } else {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4)
        };
        if workers <= 1 {
            for (c, chunk) in sums.chunks_mut(n).enumerate() {
                if preps[c].silent(train) {
                    continue;
                }
                self.class_plane_sums(&preps[c], planes, params, mode, 0, chunk);
            }
            return sums;
        }
        // Class × sample-chunk fan-out: split each class's output row
        // into lane-aligned chunks so the task count scales with the
        // batch, then deal tasks round-robin onto scoped worker threads.
        let chunks_per_class = workers.div_ceil(nc).min(planes.lanes().max(1));
        let chunk_samples = planes.lanes().div_ceil(chunks_per_class) * 64;
        let mut tasks: Vec<(usize, usize, &mut [i32])> = Vec::new();
        for (c, class_chunk) in sums.chunks_mut(n).enumerate() {
            if preps[c].silent(train) {
                continue;
            }
            let mut lane0 = 0usize;
            for sub in class_chunk.chunks_mut(chunk_samples) {
                tasks.push((c, lane0, sub));
                lane0 += chunk_samples / 64;
            }
        }
        let mut bins: Vec<Vec<(usize, usize, &mut [i32])>> = Vec::new();
        for _ in 0..workers {
            bins.push(Vec::new());
        }
        for (i, task) in tasks.into_iter().enumerate() {
            bins[i % workers].push(task);
        }
        let preps = &preps;
        std::thread::scope(|scope| {
            for bin in bins {
                if bin.is_empty() {
                    continue; // fewer tasks than workers: spawn no idlers
                }
                scope.spawn(move || {
                    for (c, lane0, out) in bin {
                        self.class_plane_sums(&preps[c], planes, params, mode, lane0, out);
                    }
                });
            }
        });
        sums
    }

    /// Build one class's [`ClassPrep`]: apply the fault gates to the
    /// packed action words and extract the effective included literals
    /// ([`MultiTm::push_eff_lits`]), once per clause (not per 64-sample
    /// lane).
    fn class_prep(&self, c: usize, params: &TmParams) -> ClassPrep {
        let mut lits: Vec<u32> = Vec::new();
        let mut clauses: Vec<(i8, usize, usize)> =
            Vec::with_capacity(params.active_clauses);
        for j in 0..params.active_clauses {
            let start = lits.len();
            let force = self.push_eff_lits(c, j, &mut lits);
            clauses.push((force, start, lits.len()));
        }
        ClassPrep { lits, clauses }
    }

    /// Clamped sums of one class (prepared as `prep`) over the sample
    /// range `[lane0 * 64, lane0 * 64 + out.len())` of a transposed
    /// batch.
    fn class_plane_sums(
        &self,
        prep: &ClassPrep,
        planes: &BitPlanes,
        params: &TmParams,
        mode: EvalMode,
        lane0: usize,
        out: &mut [i32],
    ) {
        let train = mode == EvalMode::Train;
        // Bit-sliced vote counters: one per polarity, wide enough for
        // `active_clauses / 2` fired clauses.
        let half = prep.clauses.len() / 2;
        let width = (usize::BITS - half.leading_zeros()) as usize;
        let mut pos = vec![0u64; width];
        let mut neg = vec![0u64; width];
        let t = params.t;
        let n_lanes = out.len().div_ceil(64);
        for l in 0..n_lanes {
            let lane = lane0 + l;
            let s0 = l * 64;
            let lane_len = (out.len() - s0).min(64);
            // Plane tail bits are zero, so ANDed masks stay in range;
            // the explicit mask covers empty / forced-1 clauses.
            let valid = planes.lane_mask(lane);
            pos.fill(0);
            neg.fill(0);
            for (j, &(force, start, end)) in prep.clauses.iter().enumerate() {
                let m =
                    clause_fired_mask(planes, lane, valid, train, force, &prep.lits[start..end]);
                if m != 0 {
                    add_mask(if j % 2 == 0 { &mut pos } else { &mut neg }, m);
                }
            }
            for b in 0..lane_len {
                let mut p = 0i32;
                let mut q = 0i32;
                // Single zip over both counters (same width by
                // construction) — one bounds check pair eliminated per
                // counter bit.
                for (w, (&pp, &nn)) in pos.iter().zip(neg.iter()).enumerate() {
                    p |= (((pp >> b) & 1) as i32) << w;
                    q |= (((nn >> b) & 1) as i32) << w;
                }
                out[s0 + b] = (p - q).clamp(-t, t);
            }
        }
    }

    /// Batched prediction off transposed planes (argmax over active
    /// classes, ties to the lowest index — row-identical to
    /// [`MultiTm::predict_batch`]).
    pub fn predict_planes(&self, planes: &BitPlanes, params: &TmParams) -> Vec<usize> {
        let sums = self.evaluate_planes(planes, params, EvalMode::Infer);
        argmax_rows(&sums, planes.len(), params.active_classes)
    }

    /// Classification accuracy over a cached labelled plane batch —
    /// equal to [`MultiTm::accuracy_batch`] on the rows the batch was
    /// transposed from.
    pub fn accuracy_planes(&self, batch: &PlaneBatch, params: &TmParams) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        let preds = self.predict_planes(batch.planes(), params);
        let correct =
            preds.iter().zip(batch.labels().iter()).filter(|(p, y)| p == y).count();
        correct as f64 / batch.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::rng::Xoshiro256;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn params() -> TmParams {
        TmParams::paper_offline(&shape())
    }

    fn random_inputs(s: &TmShape, n: usize, rng: &mut Xoshiro256) -> Vec<Input> {
        (0..n)
            .map(|_| {
                let bits: Vec<bool> =
                    (0..s.features).map(|_| rng.next_f32() < 0.5).collect();
                Input::pack(s, &bits)
            })
            .collect()
    }

    fn random_machine(s: &TmShape, seed: u64) -> (MultiTm, Xoshiro256) {
        let mut rng = Xoshiro256::new(seed);
        let states: Vec<u32> = (0..s.num_tas())
            .map(|_| rng.next_below(2 * s.states as usize) as u32)
            .collect();
        (MultiTm::from_states(s, states).unwrap(), rng)
    }

    #[test]
    fn fresh_machine_empty_clause_convention() {
        let s = shape();
        let tm = MultiTm::new(&s).unwrap();
        let p = params();
        let mut rng = Xoshiro256::new(1);
        let inputs = random_inputs(&s, 10, &mut rng);
        let planes = BitPlanes::from_inputs(&s, &inputs);
        // Infer: empty clauses are silent -> all sums 0.
        let infer = tm.evaluate_planes(&planes, &p, EvalMode::Infer);
        assert!(infer.iter().all(|&v| v == 0));
        // Train: all clauses fire, polarities cancel -> still 0, but via
        // full counters (differential against the row-major path).
        let train = tm.evaluate_planes(&planes, &p, EvalMode::Train);
        assert_eq!(train, tm.evaluate_batch(&inputs, &p, EvalMode::Train));
    }

    #[test]
    fn forced_clause_fires_for_every_sample() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = params();
        tm.set_clause_fault(0, 0, Some(true));
        let mut rng = Xoshiro256::new(2);
        let inputs = random_inputs(&s, 70, &mut rng);
        let planes = BitPlanes::from_inputs(&s, &inputs);
        let sums = tm.evaluate_planes(&planes, &p, EvalMode::Infer);
        for i in 0..70 {
            assert_eq!(sums[i], 1, "forced + clause votes on sample {i}");
        }
        assert_eq!(sums, tm.evaluate_batch(&inputs, &p, EvalMode::Infer));
    }

    #[test]
    fn prop_matches_row_major_on_random_machines() {
        let s = shape();
        for trial in 0..20u64 {
            let (tm, mut rng) = random_machine(&s, 0xB17 + trial);
            let mut p = params();
            p.active_clauses = [4, 8, 16][(trial % 3) as usize];
            p.active_classes = 1 + (trial % 3) as usize;
            p.t = [1, 5, 15][(trial % 3) as usize];
            let n = [1, 5, 63, 64, 65, 100][(trial % 6) as usize];
            let inputs = random_inputs(&s, n, &mut rng);
            let planes = BitPlanes::from_inputs(&s, &inputs);
            for mode in [EvalMode::Train, EvalMode::Infer] {
                assert_eq!(
                    tm.evaluate_planes(&planes, &p, mode),
                    tm.evaluate_batch(&inputs, &p, mode),
                    "trial {trial} n {n} {mode:?}"
                );
            }
            assert_eq!(
                tm.predict_planes(&planes, &p),
                tm.predict_batch(&inputs, &p),
                "trial {trial}"
            );
        }
    }

    #[test]
    fn counter_width_handles_minimum_clause_count() {
        let s = shape();
        let (mut tm, mut rng) = random_machine(&s, 0x33);
        let mut p = params();
        p.active_clauses = 2; // one positive + one negative clause
        tm.set_clause_fault(0, 0, Some(true));
        tm.set_clause_fault(0, 1, Some(true));
        let inputs = random_inputs(&s, 130, &mut rng);
        let planes = BitPlanes::from_inputs(&s, &inputs);
        let sums = tm.evaluate_planes(&planes, &p, EvalMode::Infer);
        assert_eq!(sums, tm.evaluate_batch(&inputs, &p, EvalMode::Infer));
        for i in 0..130 {
            assert_eq!(sums[i], 0, "forced +1 and -1 cancel on sample {i}");
        }
    }

    #[test]
    fn add_mask_counts_in_binary() {
        let mut counter = vec![0u64; 3];
        for _ in 0..5 {
            add_mask(&mut counter, 0b11);
        }
        add_mask(&mut counter, 0b10);
        // Lane 0 counted 5 (101b), lane 1 counted 6 (110b).
        let count = |bit: u64| {
            counter
                .iter()
                .enumerate()
                .map(|(w, &p)| (((p >> bit) & 1) as u64) << w)
                .sum::<u64>()
        };
        assert_eq!(count(0), 5);
        assert_eq!(count(1), 6);
        assert_eq!(count(2), 0);
    }
}
