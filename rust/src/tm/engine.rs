//! The word-parallel training engine — the software twin's answer to the
//! paper's "all clauses in two clock cycles" datapath (§6), and the
//! word-level/bit-parallel design MATADOR (arXiv 2403.10538) and the
//! runtime-tunable eFPGA TM (arXiv 2502.07823) use to get throughput.
//!
//! Two coordinated optimisations over the scalar oracle
//! [`crate::tm::feedback::train_step`]:
//!
//! 1. **Bit-parallel feedback** ([`train_step_fast`]): Type I/II updates
//!    are computed per 64-literal word as Bernoulli bitmasks intersected
//!    with the packed input/action words, then applied through
//!    `MultiTm::apply_word_feedback` — one action-cache read-modify-write
//!    per word instead of per literal. Given the same eager
//!    [`StepRands`], this path is **bit-identical** to the scalar oracle
//!    (asserted by `rust/tests/integration_engine.rs`), so it slots under
//!    every deterministic experiment without moving a single figure.
//!
//! 2. **Lazy step randomness** ([`train_step_lazy`] / [`FeedbackPlan`]):
//!    the eager path materialises `classes × clauses × literals` uniforms
//!    per step even though the selection probability `(T − sign·v)/2T`
//!    leaves most clauses without feedback — RNG output was ~49% of the
//!    training profile (see EXPERIMENTS.md §Perf). The lazy plan draws
//!    the per-clause selection uniform first, only for the two signed
//!    classes, and generates per-TA randomness only for clauses that were
//!    actually selected — as bit-sliced Bernoulli masks
//!    ([`crate::tm::rng::BernoulliPlan`]) rather than per-literal floats.
//!    Statistically equivalent to the oracle (same event probabilities,
//!    quantised to 2^-16), not bit-identical; the eager `StepRands` path
//!    remains the parity oracle against the L2 HLO graph.
//!
//! [`MultiTm::train_epoch`] drives the lazy path over a labelled set —
//! since PR 5 through the lane-speculative walker (`tm::train_planes`),
//! which batches clause evaluation 64 samples per AND and stays
//! bit-identical to the per-step loop; batched inference lives in
//! `MultiTm::evaluate_batch`/`predict_batch` (machine.rs), which fan
//! classes out across scoped threads.

use crate::tm::bitplane::BitPlanes;
use crate::tm::clause::{EvalMode, Input};
use crate::tm::feedback::StepActivity;
use crate::tm::machine::MultiTm;
use crate::tm::params::{polarity, word_mask, TmParams, TmShape};
use crate::tm::rng::{BernoulliPlan, StepRands, Xoshiro256};
use crate::tm::train_planes::{fill_signs, TrainScratch};

/// One training step with bit-parallel feedback, consuming the same eager
/// [`StepRands`] record as the scalar oracle — and producing bit-identical
/// TA states, activity counts and action caches. This is the engine the
/// deterministic drivers (FPGA system model, figure sweeps, unlabelled
/// learning) run on.
///
/// Allocates a throwaway sign buffer per call; hot loops should carry a
/// [`TrainScratch`] and call [`train_step_fast_with`] instead (or batch
/// whole row runs through `MultiTm::train_plane_batch`).
pub fn train_step_fast(
    tm: &mut MultiTm,
    input: &Input,
    target: usize,
    params: &TmParams,
    rands: &StepRands,
) -> StepActivity {
    train_step_fast_with(tm, input, target, params, rands, &mut TrainScratch::new())
}

/// [`train_step_fast`] with a caller-owned [`TrainScratch`]: the per-step
/// sign buffer lives in the scratch, so long-lived steppers pay zero
/// steady-state allocation. Bit-identical to the allocating path.
pub fn train_step_fast_with(
    tm: &mut MultiTm,
    input: &Input,
    target: usize,
    params: &TmParams,
    rands: &StepRands,
    scratch: &mut TrainScratch,
) -> StepActivity {
    let shape = tm.shape().clone();
    tm.evaluate(input, params, EvalMode::Train);
    let signs = scratch.signs_mut(shape.classes);
    fill_signs(signs, target, params.active_classes, || rands.neg_class_draw);

    let two_t = (2 * params.t) as f32;
    let p_reinforce = params.p_reinforce();
    let p_weaken = params.p_weaken();
    let lits = shape.literals();
    let fault_free = tm.fault().is_fault_free();
    let mut act = StepActivity::default();

    for c in 0..params.active_classes {
        let sign = signs[c];
        if sign == 0 {
            continue;
        }
        let v = tm.sums[c] as f32;
        let p_sel = (params.t as f32 - sign as f32 * v) / two_t;
        for j in 0..params.active_clauses {
            if !(rands.clause(&shape, c, j) < p_sel) {
                continue;
            }
            let out = tm.clause_out[c * shape.max_clauses + j];
            if sign as i32 * polarity(j) == 1 {
                // Type I: masks from the eager per-TA draws — the same
                // strict-< comparisons the scalar path makes, packed.
                act.type1_clauses += 1;
                for (w, &iw) in input.words().iter().enumerate() {
                    let valid = word_mask(lits, w);
                    let lo = w * 64;
                    let n = (lits - lo).min(64);
                    let (mut reinforce, mut weaken) = (0u64, 0u64);
                    for k in 0..n {
                        let r = rands.ta(&shape, c, j, lo + k);
                        if r < p_reinforce {
                            reinforce |= 1u64 << k;
                        }
                        if r < p_weaken {
                            weaken |= 1u64 << k;
                        }
                    }
                    let (inc, dec) = if out {
                        (iw & reinforce & valid, !iw & weaken & valid)
                    } else {
                        (0, weaken & valid)
                    };
                    let (i, d) = tm.apply_word_feedback(c, j, w, inc, dec);
                    act.ta_increments += i;
                    act.ta_decrements += d;
                }
            } else if out {
                // Type II: deterministic — push every 0-valued literal
                // whose effective (post-fault-gate) action is exclude
                // toward include.
                act.type2_clauses += 1;
                for (w, &iw) in input.words().iter().enumerate() {
                    let valid = word_mask(lits, w);
                    let a = tm.action_words(c, j)[w];
                    let eff = if fault_free { a } else { tm.fault().apply(c, j, w, a) };
                    let inc = !iw & !eff & valid;
                    let (i, _) = tm.apply_word_feedback(c, j, w, inc, 0);
                    act.ta_increments += i;
                }
            }
        }
    }
    act
}

/// Precomputed per-`TmParams` state for the lazy word-parallel trainer:
/// the bit-sliced Bernoulli generators for the two Type-I event
/// probabilities (`r < (s−1)/s` reinforce, `r < p_weaken` weaken).
///
/// When the two probabilities coincide (the paper's inaction-biased `s`
/// mapping makes them both `(s−1)/s`) a single mask serves both events —
/// sound because a Type-I step consults the reinforce event only on
/// 1-valued literals and the weaken event only on 0-valued ones, so the
/// two masks are never read on the same lane.
#[derive(Debug, Clone)]
pub struct FeedbackPlan {
    reinforce: BernoulliPlan,
    weaken: BernoulliPlan,
    /// Reinforce and weaken probabilities coincide — draw one mask.
    shared: bool,
}

impl FeedbackPlan {
    pub fn new(params: &TmParams) -> Self {
        let reinforce = BernoulliPlan::new(params.p_reinforce());
        let weaken = BernoulliPlan::new(params.p_weaken());
        let shared = reinforce == weaken;
        FeedbackPlan { reinforce, weaken, shared }
    }

    /// Draw the (reinforce, weaken) masks for one word — shared with the
    /// lane-speculative walker (`tm::train_planes`), which must consume
    /// the generator exactly as [`train_step_lazy`] does.
    #[inline]
    pub(crate) fn masks(&self, rng: &mut Xoshiro256) -> (u64, u64) {
        if self.shared {
            let m = self.weaken.mask(rng);
            (m, m)
        } else {
            (self.reinforce.mask(rng), self.weaken.mask(rng))
        }
    }

    /// Draw only the weaken mask (the `out = 0` Type-I economy path).
    #[inline]
    pub(crate) fn weaken_mask(&self, rng: &mut Xoshiro256) -> u64 {
        self.weaken.mask(rng)
    }

    /// Type I is entirely inactive (both event probabilities quantise to
    /// zero — e.g. the paper's online configuration, s = 1 under the
    /// inaction-biased mapping).
    #[inline]
    pub fn type1_inert(&self) -> bool {
        self.reinforce.is_never() && self.weaken.is_never()
    }
}

/// One training step with lazy randomness: draws only what the step
/// actually consumes — the contrast-class draw, one selection uniform per
/// active clause of the two signed classes, and bit-sliced Bernoulli
/// masks for the clauses that were selected. Statistically equivalent to
/// the scalar oracle (event probabilities quantised to 2^-16), not
/// bit-identical — use [`train_step_fast`] where determinism against the
/// `StepRands` contract matters.
pub fn train_step_lazy(
    tm: &mut MultiTm,
    input: &Input,
    target: usize,
    params: &TmParams,
    plan: &FeedbackPlan,
    rng: &mut Xoshiro256,
) -> StepActivity {
    train_step_lazy_with(tm, input, target, params, plan, rng, &mut TrainScratch::new())
}

/// [`train_step_lazy`] with a caller-owned [`TrainScratch`] (see
/// [`train_step_fast_with`]). Bit-identical to the allocating path.
pub fn train_step_lazy_with(
    tm: &mut MultiTm,
    input: &Input,
    target: usize,
    params: &TmParams,
    plan: &FeedbackPlan,
    rng: &mut Xoshiro256,
    scratch: &mut TrainScratch,
) -> StepActivity {
    let shape = tm.shape().clone();
    tm.evaluate(input, params, EvalMode::Train);

    // Signs, from a single draw (canonical order: neg-class draw first,
    // mirroring StepRands::draw).
    let signs = scratch.signs_mut(shape.classes);
    fill_signs(signs, target, params.active_classes, || rng.next_u64());

    let two_t = (2 * params.t) as f32;
    let lits = shape.literals();
    let fault_free = tm.fault().is_fault_free();
    let type1_inert = plan.type1_inert();
    let mut act = StepActivity::default();

    for c in 0..params.active_classes {
        let sign = signs[c];
        if sign == 0 {
            continue;
        }
        let v = tm.sums[c] as f32;
        let p_sel = (params.t as f32 - sign as f32 * v) / two_t;
        if p_sel <= 0.0 {
            // No clause of this class can be selected; skipping the
            // per-clause draws is statistically identical.
            continue;
        }
        for j in 0..params.active_clauses {
            if !(rng.next_f32() < p_sel) {
                continue;
            }
            let out = tm.clause_out[c * shape.max_clauses + j];
            if sign as i32 * polarity(j) == 1 {
                act.type1_clauses += 1;
                if type1_inert {
                    continue;
                }
                for (w, &iw) in input.words().iter().enumerate() {
                    let valid = word_mask(lits, w);
                    let (inc, dec) = if out {
                        let (reinforce, weaken) = plan.masks(rng);
                        (iw & reinforce & valid, !iw & weaken & valid)
                    } else {
                        // out = 0 consults only the weaken event — don't
                        // burn draws on an unused reinforce mask.
                        (0, plan.weaken_mask(rng) & valid)
                    };
                    let (i, d) = tm.apply_word_feedback(c, j, w, inc, dec);
                    act.ta_increments += i;
                    act.ta_decrements += d;
                }
            } else if out {
                act.type2_clauses += 1;
                for (w, &iw) in input.words().iter().enumerate() {
                    let valid = word_mask(lits, w);
                    let a = tm.action_words(c, j)[w];
                    let eff = if fault_free { a } else { tm.fault().apply(c, j, w, a) };
                    let inc = !iw & !eff & valid;
                    let (i, _) = tm.apply_word_feedback(c, j, w, inc, 0);
                    act.ta_increments += i;
                }
            }
        }
    }
    act
}

/// Aggregate statistics of one [`MultiTm::train_epoch`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EpochStats {
    /// Datapoints consumed.
    pub steps: usize,
    /// Summed switching activity across all steps.
    pub activity: StepActivity,
}

impl EpochStats {
    pub(crate) fn absorb(&mut self, a: StepActivity) {
        self.steps += 1;
        self.activity.type1_clauses += a.type1_clauses;
        self.activity.type2_clauses += a.type2_clauses;
        self.activity.ta_increments += a.ta_increments;
        self.activity.ta_decrements += a.ta_decrements;
    }
}

impl MultiTm {
    /// One labelled pass over `data` through the lazy word-parallel
    /// engine. Training is inherently sequential (each step reads the
    /// states the previous one wrote), so instead of thread fan-out this
    /// runs the **lane-speculative** walk
    /// (`MultiTm::train_plane_batch_lazy`, `tm::train_planes`): clause
    /// evaluation is batched 64 samples per AND and repaired only for
    /// the rare mid-lane action flips — bit-identical, draw for draw, to
    /// the historical per-step [`train_step_lazy`] loop (asserted by
    /// `train_epoch_is_deterministic_step_loop` below and the
    /// `integration_train_planes` suite).
    pub fn train_epoch(
        &mut self,
        data: &[(Input, usize)],
        params: &TmParams,
        rng: &mut Xoshiro256,
    ) -> EpochStats {
        let plan = FeedbackPlan::new(params);
        let planes = BitPlanes::from_labelled(self.shape(), data);
        let mut scratch = TrainScratch::new();
        self.train_plane_batch_lazy(data, &planes, params, &plan, rng, &mut scratch)
    }
}

/// Expected `next_u64` draws consumed by one *eager* [`StepRands`] refill
/// for `shape` — the cost the lazy plan avoids; used by the perf report.
pub fn eager_draws_per_step(shape: &TmShape) -> usize {
    let nc = shape.classes * shape.max_clauses;
    // neg-class draw + paired-f32 fills of clause_rand and ta_rand.
    1 + nc.div_ceil(2) + (nc * shape.literals()).div_ceil(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::fault::{Fault, FaultMap};
    use crate::tm::feedback::train_step;
    use crate::tm::params::SStyle;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    /// The fast path is bit-identical to the scalar oracle along a full
    /// random trajectory (same eager draws).
    #[test]
    fn fast_matches_oracle_trajectory() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut oracle = MultiTm::new(&s).unwrap();
        let mut fast = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(0xE1);
        for step in 0..600 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&s, &bits);
            let r = StepRands::draw(&mut rng, &s);
            let a = train_step(&mut oracle, &x, step % 3, &p, &r);
            let b = train_step_fast(&mut fast, &x, step % 3, &p, &r);
            assert_eq!(a, b, "activity diverged at step {step}");
            assert_eq!(
                oracle.ta().states(),
                fast.ta().states(),
                "states diverged at step {step}"
            );
        }
        // Action caches coherent too.
        for c in 0..3 {
            for j in 0..16 {
                assert_eq!(oracle.action_words(c, j), fast.action_words(c, j));
            }
        }
    }

    /// Bit-parity under TA fault gates (Type II reads effective actions).
    #[test]
    fn fast_matches_oracle_under_faults() {
        let s = shape();
        let mut p = TmParams::paper_online(&s);
        p.active_clauses = 12;
        let map = FaultMap::even_spread(&s, 0.25, Fault::StuckAt0, 3).unwrap();
        let mut oracle = MultiTm::new(&s).unwrap();
        oracle.set_fault_map(map.clone());
        let mut fast = MultiTm::new(&s).unwrap();
        fast.set_fault_map(map);
        let mut rng = Xoshiro256::new(0xF2);
        for step in 0..300 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&s, &bits);
            let r = StepRands::draw(&mut rng, &s);
            let a = train_step(&mut oracle, &x, step % 3, &p, &r);
            let b = train_step_fast(&mut fast, &x, step % 3, &p, &r);
            assert_eq!(a, b, "step {step}");
            assert_eq!(oracle.ta().states(), fast.ta().states(), "step {step}");
        }
    }

    /// Multiword shapes (literals spanning >1 u64) stay bit-identical,
    /// across s-styles and boost.
    #[test]
    fn fast_matches_oracle_multiword() {
        let s = TmShape { classes: 2, max_clauses: 4, features: 40, states: 8 };
        for (style, boost) in [
            (SStyle::InactionBiased, false),
            (SStyle::Canonical, false),
            (SStyle::Canonical, true),
        ] {
            let mut p = TmParams::paper_offline(&s);
            p.s = 2.5;
            p.s_style = style;
            p.boost_true_positive = boost;
            let mut oracle = MultiTm::new(&s).unwrap();
            let mut fast = MultiTm::new(&s).unwrap();
            let mut rng = Xoshiro256::new(0xAB);
            for step in 0..300 {
                let bits: Vec<bool> = (0..40).map(|_| rng.next_f32() < 0.5).collect();
                let x = Input::pack(&s, &bits);
                let r = StepRands::draw(&mut rng, &s);
                let a = train_step(&mut oracle, &x, step % 2, &p, &r);
                let b = train_step_fast(&mut fast, &x, step % 2, &p, &r);
                assert_eq!(a, b, "{style:?} boost={boost} step {step}");
                assert_eq!(
                    oracle.ta().states(),
                    fast.ta().states(),
                    "{style:?} boost={boost} step {step}"
                );
            }
        }
    }

    /// The lazy plan's s = 1 (inaction-biased) configuration never draws
    /// Type-I masks and never moves a TA through Type I.
    #[test]
    fn lazy_online_config_is_type1_inert() {
        let s = shape();
        let p = TmParams::paper_online(&s);
        let plan = FeedbackPlan::new(&p);
        assert!(plan.type1_inert());
        let mut tm = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(5);
        let bits: Vec<bool> = (0..16).map(|k| k % 2 == 0).collect();
        let x = Input::pack(&s, &bits);
        let act = train_step_lazy(&mut tm, &x, 0, &p, &plan, &mut rng);
        assert_eq!(act.ta_decrements, 0, "no Type-I weakening at s = 1");
        assert!(act.ta_increments > 0, "Type II still fires");
    }

    /// Lazy training is deterministic given the seed, and train_epoch is
    /// exactly the per-step loop.
    #[test]
    fn train_epoch_is_deterministic_step_loop() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let plan = FeedbackPlan::new(&p);
        let mut seed_rng = Xoshiro256::new(9);
        let data: Vec<(Input, usize)> = (0..40)
            .map(|i| {
                let bits: Vec<bool> = (0..16).map(|_| seed_rng.next_f32() < 0.5).collect();
                (Input::pack(&s, &bits), i % 3)
            })
            .collect();
        let mut a = MultiTm::new(&s).unwrap();
        let mut rng_a = Xoshiro256::new(77);
        let stats = a.train_epoch(&data, &p, &mut rng_a);
        assert_eq!(stats.steps, 40);
        let mut b = MultiTm::new(&s).unwrap();
        let mut rng_b = Xoshiro256::new(77);
        let mut manual = EpochStats::default();
        for (x, y) in &data {
            manual.absorb(train_step_lazy(&mut b, x, *y, &p, &plan, &mut rng_b));
        }
        assert_eq!(a.ta().states(), b.ta().states());
        assert_eq!(stats, manual);
    }

    /// Training through the lazy engine keeps the machine invariants: the
    /// action cache stays coherent and states stay in range.
    #[test]
    fn prop_lazy_training_preserves_invariants() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let plan = FeedbackPlan::new(&p);
        let mut tm = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(0xDEED);
        for step in 0..2000 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&s, &bits);
            train_step_lazy(&mut tm, &x, step % 3, &p, &plan, &mut rng);
        }
        assert!(tm.ta().states().iter().all(|&v| v <= s.max_state()));
        let mut tm2 = tm.clone();
        tm2.rebuild_actions();
        for c in 0..3 {
            for j in 0..16 {
                assert_eq!(tm.action_words(c, j), tm2.action_words(c, j));
            }
        }
    }

    /// Lazy feedback converges on a single repeated datapoint, like the
    /// oracle does (prop_single_point_converges in feedback.rs).
    #[test]
    fn prop_lazy_single_point_converges() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let plan = FeedbackPlan::new(&p);
        let mut tm = MultiTm::new(&s).unwrap();
        let mut rng = Xoshiro256::new(0x5EED);
        let mut bits = vec![false; 16];
        for k in [0, 4, 8, 12] {
            bits[k] = true;
        }
        let x = Input::pack(&s, &bits);
        for _ in 0..300 {
            train_step_lazy(&mut tm, &x, 2, &p, &plan, &mut rng);
        }
        let (sums, pred) = tm.infer(&x, &p);
        assert_eq!(pred, 2, "sums were {sums:?}");
    }

    #[test]
    fn eager_draw_count_iris() {
        // 1 neg draw + 48/2 clause uniforms + 1536/2 TA uniforms.
        assert_eq!(eager_draws_per_step(&shape()), 1 + 24 + 768);
    }

    /// The selection probability gate holds: a class saturated at +T
    /// receives no feedback through the lazy path either.
    #[test]
    fn lazy_respects_selection_gate() {
        let s = shape();
        let mut p = TmParams::paper_offline(&s);
        p.t = 1;
        let mut tm = MultiTm::new(&s).unwrap();
        // Make every positive clause of class 0 fire on x0=1 and every
        // negative clause blocked (as in feedback.rs's selection test).
        for j in 0..16 {
            let lit = if j % 2 == 0 { 0 } else { 1 };
            for _ in 0..2 {
                tm.ta_increment(0, j, lit);
            }
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = Input::pack(&s, &bits);
        let plan = FeedbackPlan::new(&p);
        let before: Vec<u32> = tm.ta().states().to_vec();
        let mut rng = Xoshiro256::new(1);
        // Only class 0 signed: restrict to 1 active class so no contrast
        // class exists and the saturated target is the only candidate.
        p.active_classes = 1;
        for _ in 0..50 {
            train_step_lazy(&mut tm, &x, 0, &p, &plan, &mut rng);
        }
        assert_eq!(tm.ta().states(), &before[..], "p_sel = 0 ⇒ untouched");
    }
}
