//! The multiclass Tsetlin machine (§2) — behavioural software twin of the
//! paper's RTL core.
//!
//! One [`MultiTm`] owns the TA state block, the fault-gate mappings and a
//! bit-packed cache of the *true* (pre-fault) include actions, kept
//! coherent incrementally as feedback moves TAs across the decision
//! boundary. Clause evaluation applies the fault gates on the fly, exactly
//! like the RTL (the gates sit on the TA action outputs, not the state
//! registers).
//!
//! Everything here is deterministic given a [`crate::tm::rng::StepRands`];
//! see `rust/tests/parity.rs` for the bit-parity proof against the
//! AOT-lowered L2 graph.

use crate::tm::automaton::{TaBlock, Transition};
use crate::tm::clause::{EvalMode, Input};
use crate::tm::fault::FaultMap;
use crate::tm::params::{polarity, TmParams, TmShape};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-unique machine ids, so incremental re-scoring caches
/// (`tm::rescore`) can tell two machines — including a clone and its
/// original, whose revision clocks would otherwise alias — apart.
static NEXT_MACHINE_UID: AtomicU64 = AtomicU64::new(1);

fn next_machine_uid() -> u64 {
    NEXT_MACHINE_UID.fetch_add(1, Ordering::Relaxed)
}

/// Multiclass Tsetlin machine.
#[derive(Debug)]
pub struct MultiTm {
    shape: TmShape,
    ta: TaBlock,
    fault: FaultMap,
    /// Packed true include actions, `[row * words + w]`,
    /// row = class * max_clauses + clause (read by the sample-sliced
    /// kernel in `tm::bitplane`).
    pub(crate) actions: Vec<u64>,
    /// Clause-output-level forcing (§7 future work: "injecting faults at
    /// the clause output level"): per clause row, `-1` = fault-free,
    /// `0`/`1` = output forced. Gates sit on the clause output wire, so
    /// they apply in both train and infer modes (active clauses only).
    pub(crate) clause_force: Vec<i8>,
    /// Number of forced clause outputs (O(1) hot-path check).
    clause_faults: usize,
    /// Scratch: per-(class,clause) outputs of the last evaluation.
    pub(crate) clause_out: Vec<bool>,
    /// Scratch: per-class sums of the last evaluation.
    pub(crate) sums: Vec<i32>,
    /// Cache-binding id (see [`next_machine_uid`]).
    uid: u64,
    /// Monotone mutation clock: bumped once per event that can change any
    /// clause's effective evaluation (TA action flip, clause-force edit,
    /// fault-map load, bulk state load). The counter itself is never read
    /// directly — `clause_rev`/`global_rev` record *which* value a given
    /// mutation stamped, so `tm::rescore` caches can re-score only the
    /// clauses whose stamp moved past the one they last saw.
    rev: u64,
    /// Per clause row: `rev` at the row's last action/force flip.
    clause_rev: Vec<u64>,
    /// `rev` at the last whole-machine invalidation (fault-map load,
    /// [`MultiTm::rebuild_actions`] bulk rebuild, raw fault-map access).
    global_rev: u64,
}

impl Clone for MultiTm {
    /// Clones carry the revision clock but get a **fresh cache-binding
    /// id**: a clone diverges from its original on the very next feedback
    /// step, so a [`crate::tm::rescore::RescoreCache`] bound to one must
    /// do a full rebuild when handed the other rather than trusting
    /// revision values that stopped being comparable at the fork.
    fn clone(&self) -> Self {
        let fork = MultiTm {
            shape: self.shape.clone(),
            ta: self.ta.clone(),
            fault: self.fault.clone(),
            actions: self.actions.clone(),
            clause_force: self.clause_force.clone(),
            clause_faults: self.clause_faults,
            clause_out: self.clause_out.clone(),
            sums: self.sums.clone(),
            uid: next_machine_uid(),
            rev: self.rev,
            clause_rev: self.clause_rev.clone(),
            global_rev: self.global_rev,
        };
        crate::verify::contracts::enforce(&fork, "MultiTm::clone");
        fork
    }
}

impl MultiTm {
    pub fn new(shape: &TmShape) -> Result<Self> {
        shape.validate()?;
        let ta = TaBlock::new(shape);
        let rows = shape.classes * shape.max_clauses;
        let mut tm = MultiTm {
            shape: shape.clone(),
            ta,
            fault: FaultMap::none(shape),
            actions: vec![0u64; rows * shape.words()],
            clause_force: vec![-1; rows],
            clause_faults: 0,
            clause_out: vec![false; rows],
            sums: vec![0; shape.classes],
            uid: next_machine_uid(),
            rev: 0,
            clause_rev: vec![0; rows],
            global_rev: 0,
        };
        tm.rebuild_actions();
        Ok(tm)
    }

    /// Restore a machine from raw TA states (e.g. from the PJRT path or a
    /// checkpoint).
    pub fn from_states(shape: &TmShape, states: Vec<u32>) -> Result<Self> {
        let mut tm = Self::new(shape)?;
        tm.ta = TaBlock::from_states(shape, states)?;
        tm.rebuild_actions();
        Ok(tm)
    }

    pub fn shape(&self) -> &TmShape {
        &self.shape
    }

    pub fn ta(&self) -> &TaBlock {
        &self.ta
    }

    pub fn fault(&self) -> &FaultMap {
        &self.fault
    }

    /// Stamp one clause row as changed (action flip or force edit).
    #[inline]
    fn mark_clause_dirty(&mut self, row: usize) {
        self.rev += 1;
        self.clause_rev[row] = self.rev;
    }

    /// Stamp the whole machine as changed (fault-map load, bulk rebuild).
    fn mark_all_dirty(&mut self) {
        self.rev += 1;
        self.global_rev = self.rev;
    }

    /// Cache-binding id: process-unique, fresh per construction *and* per
    /// clone (read by `tm::rescore`).
    #[inline]
    pub fn uid(&self) -> u64 {
        self.uid
    }

    /// Revision stamp of one clause row: the mutation-clock value of the
    /// latest event that could have changed the row's effective
    /// evaluation — its own action/force flips or any whole-machine
    /// invalidation. A cache entry recorded at stamp `r` is still exact
    /// iff `row_rev` has not moved past `r`.
    #[inline]
    pub(crate) fn row_rev(&self, row: usize) -> u64 {
        self.clause_rev[row].max(self.global_rev)
    }

    /// Mutation-clock counters `(rev, clause_rev, global_rev)` — read by
    /// the invariant checker (`crate::verify::contracts`), which asserts
    /// the per-row and global stamps never run ahead of the master
    /// counter.
    #[inline]
    pub(crate) fn rev_counters(&self) -> (u64, &[u64], u64) {
        (self.rev, &self.clause_rev, self.global_rev)
    }

    /// Program the fault-gate mappings (the fault controller write port).
    /// The true-action cache is unaffected: gates sit after the registers.
    pub fn set_fault_map(&mut self, map: FaultMap) {
        self.fault = map;
        // Gates rewire effective actions everywhere: conservatively dirty
        // every clause (per-gate diffing is not worth the bookkeeping for
        // an MCU-rate event).
        self.mark_all_dirty();
    }

    pub fn fault_map_mut(&mut self) -> &mut FaultMap {
        // The caller holds a raw write port into the gates; assume the
        // worst (stamp before handing the borrow out — the cache can only
        // observe the machine again once the &mut borrow ends).
        self.mark_all_dirty();
        &mut self.fault
    }

    /// Force one clause's output (§7 clause-output fault injection);
    /// `None` clears the gate.
    pub fn set_clause_fault(&mut self, class: usize, clause: usize, force: Option<bool>) {
        let row = self.row(class, clause);
        let was = self.clause_force[row] >= 0;
        let now = force.is_some();
        match (was, now) {
            (false, true) => self.clause_faults += 1,
            (true, false) => self.clause_faults -= 1,
            _ => {}
        }
        let v = match force {
            None => -1,
            Some(false) => 0,
            Some(true) => 1,
        };
        if self.clause_force[row] != v {
            self.clause_force[row] = v;
            self.mark_clause_dirty(row);
        }
    }

    /// Programmed clause-output fault, if any.
    pub fn clause_fault(&self, class: usize, clause: usize) -> Option<bool> {
        match self.clause_force[class * self.shape.max_clauses + clause] {
            0 => Some(false),
            1 => Some(true),
            _ => None,
        }
    }

    /// Number of forced clause outputs.
    pub fn clause_fault_count(&self) -> usize {
        self.clause_faults
    }

    /// Clause-output force codes, one per clause row (`-1` = fault-free,
    /// `0`/`1` = forced) — the serve-checkpoint payload view.
    pub fn clause_force_codes(&self) -> &[i8] {
        &self.clause_force
    }

    /// Program every clause-output gate from checkpoint codes (the bulk
    /// twin of [`MultiTm::set_clause_fault`], going through it per row so
    /// the fault counter and mutation clock stay exact).
    pub fn load_clause_force_codes(&mut self, codes: &[i8]) -> Result<()> {
        let rows = self.shape.classes * self.shape.max_clauses;
        anyhow::ensure!(
            codes.len() == rows,
            "clause force codes: want {} rows, got {}",
            rows,
            codes.len()
        );
        for (row, &code) in codes.iter().enumerate() {
            let force = match code {
                -1 => None,
                0 => Some(false),
                1 => Some(true),
                other => anyhow::bail!("clause force codes: invalid code {other} at row {row}"),
            };
            self.set_clause_fault(row / self.shape.max_clauses, row % self.shape.max_clauses, force);
        }
        Ok(())
    }

    /// FNV-1a-64 digest over the full serve-visible replica state: TA
    /// states, clause-output force codes and the TA fault-gate words.
    /// Two machines with equal digests behave identically under every
    /// serve-path operation (the action cache is a pure function of the
    /// TA states), so recovery tests can compare replicas in O(1) space.
    pub fn state_digest(&self) -> u64 {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        };
        for &st in self.ta.states() {
            eat(&st.to_le_bytes());
        }
        for &f in &self.clause_force {
            eat(&[f as u8]);
        }
        let (and_words, or_words) = self.fault.words();
        for &w in and_words.iter().chain(or_words) {
            eat(&w.to_le_bytes());
        }
        h
    }

    /// Recompute the packed action cache from TA states (used after bulk
    /// state loads; incremental updates handle the common path).
    pub fn rebuild_actions(&mut self) {
        let words = self.shape.words();
        for c in 0..self.shape.classes {
            for j in 0..self.shape.max_clauses {
                let row = c * self.shape.max_clauses + j;
                for w in 0..words {
                    self.actions[row * words + w] = 0;
                }
                for (k, inc) in self.ta.clause_includes(c, j).enumerate() {
                    if inc {
                        self.actions[row * words + k / 64] |= 1u64 << (k % 64);
                    }
                }
            }
        }
        // Bulk path: any clause may have changed — conservatively dirty
        // everything rather than diffing the rebuilt cache.
        self.mark_all_dirty();
        crate::verify::contracts::enforce(self, "MultiTm::rebuild_actions");
    }

    #[inline]
    fn row(&self, class: usize, clause: usize) -> usize {
        class * self.shape.max_clauses + clause
    }

    /// Packed true action words of one clause.
    #[inline]
    pub fn action_words(&self, class: usize, clause: usize) -> &[u64] {
        let w = self.shape.words();
        let row = self.row(class, clause);
        &self.actions[row * w..(row + 1) * w]
    }

    /// Effective (post-fault-gate) action of a single TA.
    #[inline]
    pub fn eff_action(&self, class: usize, clause: usize, lit: usize) -> bool {
        let word = self.action_words(class, clause)[lit / 64];
        let gated = self.fault.apply(class, clause, lit / 64, word);
        gated & (1u64 << (lit % 64)) != 0
    }

    /// Evaluate one clause with fault gates applied.
    pub fn clause_output(
        &self,
        class: usize,
        clause: usize,
        input: &Input,
        mode: EvalMode,
    ) -> bool {
        let words = self.shape.words();
        let row = self.row(class, clause);
        let actions = &self.actions[row * words..(row + 1) * words];
        let mut any = false;
        if self.fault.is_fault_free() {
            // Fast path (O(1) check): the gates are identity — evaluate
            // straight off the packed action cache. Trained clauses are
            // include-sparse, so most multiword rows are all-zero: skip
            // them without touching the input word. The zip walks both
            // packed rows without per-word bounds checks.
            for (&a, &iw) in actions.iter().zip(input.words()) {
                if a == 0 {
                    continue;
                }
                if a & !iw != 0 {
                    return false;
                }
                any = true;
            }
        } else {
            // Apply the gates word-by-word without allocating. The
            // zero-word skip runs *after* the gates: a stuck-at-1 gate
            // can raise bits out of an all-zero action word.
            for (w, (&a, &iw)) in actions.iter().zip(input.words()).enumerate() {
                let eff = self.fault.apply(class, clause, w, a);
                if eff == 0 {
                    continue;
                }
                if eff & !iw != 0 {
                    return false;
                }
                any = true;
            }
        }
        any || mode == EvalMode::Train
    }

    /// Append the *effective* (post-fault-gate) included literal indices
    /// of one clause to `lits`, returning the clause-force state
    /// (`-1` = none, `0`/`1` = output forced; forced clauses push no
    /// literals — their output ignores the input). Shared by the
    /// sample-sliced kernel's lane-invariant prep (`tm::bitplane`) and
    /// the incremental re-scorer (`tm::rescore`) so the gate algebra
    /// cannot drift between the two.
    pub(crate) fn push_eff_lits(&self, class: usize, clause: usize, lits: &mut Vec<u32>) -> i8 {
        let words = self.shape.words();
        let row = self.row(class, clause);
        let force = self.clause_force[row];
        if force >= 0 {
            return force;
        }
        let fault_free = self.fault.is_fault_free();
        for w in 0..words {
            let raw = self.actions[row * words + w];
            let aw = if fault_free { raw } else { self.fault.apply(class, clause, w, raw) };
            let mut a = aw;
            while a != 0 {
                lits.push((w * 64) as u32 + a.trailing_zeros());
                a &= a - 1;
            }
        }
        force
    }

    /// Single-word fault-free clause predicate: fires iff no included
    /// literal is 0, with the empty-clause convention folded in. Shared
    /// by the per-row and batched fast paths so the semantics cannot
    /// drift apart.
    #[inline]
    fn clause_fires_fast1(action_word: u64, input_word: u64, train: bool) -> bool {
        (action_word & !input_word == 0) & (train | (action_word != 0))
    }

    /// Clause output with the clause-force gate applied (general path) —
    /// shared by [`MultiTm::evaluate_general`] and the batched kernel.
    #[inline]
    fn clause_out_gated(&self, c: usize, j: usize, x: &Input, mode: EvalMode) -> bool {
        match self.clause_force[c * self.shape.max_clauses + j] {
            0 => false,
            1 => true,
            _ => self.clause_output(c, j, x, mode),
        }
    }

    /// Fault-free single-word clause evaluation over a whole class row —
    /// the dominant configuration (iris: 32 literals = 1 word), kept
    /// branch-light so the compiler vectorises the clause loop.
    #[inline]
    fn evaluate_class_fast1(
        &mut self,
        c: usize,
        input_word: u64,
        params: &TmParams,
        train: bool,
    ) {
        let base = c * self.shape.max_clauses;
        let mut sum = 0i32;
        for j in 0..params.active_clauses {
            let a = self.actions[base + j];
            let out = Self::clause_fires_fast1(a, input_word, train);
            self.clause_out[base + j] = out;
            if out {
                sum += polarity(j);
            }
        }
        for j in params.active_clauses..self.shape.max_clauses {
            self.clause_out[base + j] = false;
        }
        self.sums[c] = sum.clamp(-params.t, params.t);
    }

    /// Evaluate every clause of every class into the scratch buffers and
    /// compute clamped per-class sums. Inactive clauses/classes output 0.
    /// Returns the scratch sums slice.
    pub fn evaluate(&mut self, input: &Input, params: &TmParams, mode: EvalMode) -> &[i32] {
        // Hot path: fault-free, single-word machines skip the gate logic
        // entirely (see EXPERIMENTS.md §Perf).
        if self.shape.words() == 1 && self.fault.is_fault_free() && self.clause_faults == 0
        {
            let w = input.words()[0];
            let train = mode == EvalMode::Train;
            for c in 0..params.active_classes {
                self.evaluate_class_fast1(c, w, params, train);
            }
            for c in params.active_classes..self.shape.classes {
                let base = c * self.shape.max_clauses;
                self.clause_out[base..base + self.shape.max_clauses].fill(false);
                self.sums[c] = 0;
            }
            return &self.sums;
        }
        self.evaluate_general(input, params, mode)
    }

    /// The general (gate-aware, any-word-count) evaluation path; the
    /// fast single-word path in [`MultiTm::evaluate`] must agree with
    /// this exactly whenever both apply (differential-tested below).
    pub(crate) fn evaluate_general(
        &mut self,
        input: &Input,
        params: &TmParams,
        mode: EvalMode,
    ) -> &[i32] {
        for c in 0..self.shape.classes {
            let mut sum = 0i32;
            for j in 0..self.shape.max_clauses {
                let row = c * self.shape.max_clauses + j;
                let out = if c < params.active_classes && j < params.active_clauses {
                    // Clause-output force gate (active clauses only — a
                    // clock-gated clause cannot drive the vote wire).
                    self.clause_out_gated(c, j, input, mode)
                } else {
                    false
                };
                self.clause_out[row] = out;
                if out {
                    sum += polarity(j);
                }
            }
            self.sums[c] = sum.clamp(-params.t, params.t);
        }
        &self.sums
    }

    /// Clamped sums of one active class over a batch of rows, written
    /// into `out[i]` for row `i` — the read-only kernel behind
    /// [`MultiTm::evaluate_batch`]'s class fan-out (no scratch, so class
    /// rows can run on separate threads). `proj` extracts the input from
    /// a row (identity for `&[Input]`, `.0` for labelled tuples), so
    /// labelled datasets evaluate without cloning their inputs.
    fn class_sums_into<T: Sync>(
        &self,
        c: usize,
        items: &[T],
        proj: fn(&T) -> &Input,
        params: &TmParams,
        mode: EvalMode,
        out: &mut [i32],
    ) {
        debug_assert_eq!(items.len(), out.len());
        let train = mode == EvalMode::Train;
        if self.shape.words() == 1 && self.fault.is_fault_free() && self.clause_faults == 0
        {
            // Single-word fault-free fast path, as in evaluate().
            let base = c * self.shape.max_clauses;
            for (i, it) in items.iter().enumerate() {
                let w = proj(it).words()[0];
                let mut sum = 0i32;
                for j in 0..params.active_clauses {
                    if Self::clause_fires_fast1(self.actions[base + j], w, train) {
                        sum += polarity(j);
                    }
                }
                out[i] = sum.clamp(-params.t, params.t);
            }
            return;
        }
        for (i, it) in items.iter().enumerate() {
            let x = proj(it);
            let mut sum = 0i32;
            for j in 0..params.active_clauses {
                if self.clause_out_gated(c, j, x, mode) {
                    sum += polarity(j);
                }
            }
            out[i] = sum.clamp(-params.t, params.t);
        }
    }

    /// Class-major clamped sums over a batch (`result[c * n + i]`),
    /// classes fanned out across scoped threads when the batch is large
    /// enough to amortise spawning (the `coordinator::sweep` fan-out
    /// pattern, §6 "the parallel nature of a hardware-implemented TM")
    /// — class rows touch disjoint state, so this is a pure
    /// data-parallel split.
    fn batch_sums<T: Sync>(
        &self,
        items: &[T],
        proj: fn(&T) -> &Input,
        params: &TmParams,
        mode: EvalMode,
    ) -> Vec<i32> {
        let n = items.len();
        let nc = params.active_classes;
        if n == 0 || nc == 0 {
            return Vec::new();
        }
        let mut sums = vec![0i32; nc * n];
        let work = n * nc * params.active_clauses;
        if nc == 1 || work < SPAWN_WORK {
            for (c, chunk) in sums.chunks_mut(n).enumerate() {
                self.class_sums_into(c, items, proj, params, mode, chunk);
            }
        } else {
            std::thread::scope(|scope| {
                for (c, chunk) in sums.chunks_mut(n).enumerate() {
                    scope.spawn(move || {
                        self.class_sums_into(c, items, proj, params, mode, chunk)
                    });
                }
            });
        }
        sums
    }

    /// Batched evaluation: clamped sums for every active class over a
    /// batch of inputs, class-major (`result[c * inputs.len() + i]` is
    /// class `c` on row `i`).
    pub fn evaluate_batch(
        &self,
        inputs: &[Input],
        params: &TmParams,
        mode: EvalMode,
    ) -> Vec<i32> {
        fn ident(x: &Input) -> &Input {
            x
        }
        self.batch_sums(inputs, ident, params, mode)
    }

    /// Batched prediction (argmax over active classes, ties to the lowest
    /// index — identical to [`MultiTm::predict`] row by row).
    pub fn predict_batch(&self, inputs: &[Input], params: &TmParams) -> Vec<usize> {
        let sums = self.evaluate_batch(inputs, params, EvalMode::Infer);
        argmax_rows(&sums, inputs.len(), params.active_classes)
    }

    /// [`MultiTm::predict_batch`] over labelled rows, borrowing the
    /// inputs in place (no per-row clone).
    pub fn predict_batch_labelled(
        &self,
        data: &[(Input, usize)],
        params: &TmParams,
    ) -> Vec<usize> {
        fn fst(x: &(Input, usize)) -> &Input {
            &x.0
        }
        let sums = self.batch_sums(data, fst, params, EvalMode::Infer);
        argmax_rows(&sums, data.len(), params.active_classes)
    }

    /// Classification accuracy over packed labelled rows via the batched
    /// inference path (`&self` — no scratch mutation, no input clones).
    pub fn accuracy_batch(&self, data: &[(Input, usize)], params: &TmParams) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let preds = self.predict_batch_labelled(data, params);
        let correct =
            preds.iter().zip(data.iter()).filter(|(p, (_, y))| **p == *y).count();
        correct as f64 / data.len() as f64
    }

    /// Classify one datapoint: clamped class sums + argmax over active
    /// classes (ties break toward the lowest class index, matching the L2
    /// graph's argmax).
    pub fn infer(&mut self, input: &Input, params: &TmParams) -> (Vec<i32>, usize) {
        self.evaluate(input, params, EvalMode::Infer);
        let sums = self.sums[..params.active_classes].to_vec();
        let best = argmax_class(sums.len(), |c| sums[c]);
        (sums, best)
    }

    /// Prediction only — allocation-free hot path (accuracy analysis runs
    /// this once per stored row per analysis point).
    pub fn predict(&mut self, input: &Input, params: &TmParams) -> usize {
        self.evaluate(input, params, EvalMode::Infer);
        argmax_class(params.active_classes, |c| self.sums[c])
    }

    /// Apply one saturating TA move and keep the action cache coherent.
    #[inline]
    pub(crate) fn ta_increment(&mut self, class: usize, clause: usize, lit: usize) {
        if self.ta.increment(class, clause, lit) == Transition::NowInclude {
            let w = self.shape.words();
            let row = self.row(class, clause);
            self.actions[row * w + lit / 64] |= 1u64 << (lit % 64);
            self.mark_clause_dirty(row);
        }
        crate::verify::contracts::enforce_ta(self, class, clause, lit);
    }

    #[inline]
    pub(crate) fn ta_decrement(&mut self, class: usize, clause: usize, lit: usize) {
        if self.ta.decrement(class, clause, lit) == Transition::NowExclude {
            let w = self.shape.words();
            let row = self.row(class, clause);
            self.actions[row * w + lit / 64] &= !(1u64 << (lit % 64));
            self.mark_clause_dirty(row);
        }
        crate::verify::contracts::enforce_ta(self, class, clause, lit);
    }

    /// Word-batched TA feedback: apply disjoint increment/decrement masks
    /// to one 64-literal word of clause `(class, clause)` and patch the
    /// packed action cache with a single read-modify-write, instead of a
    /// cache update per literal (the word-parallel engine's bulk path —
    /// see EXPERIMENTS.md §Perf). Returns the applied (non-saturated)
    /// increment/decrement counts, matching
    /// [`crate::tm::feedback::StepActivity`] semantics.
    #[inline]
    pub(crate) fn apply_word_feedback(
        &mut self,
        class: usize,
        clause: usize,
        word: usize,
        inc_mask: u64,
        dec_mask: u64,
    ) -> (u32, u32) {
        if inc_mask == 0 && dec_mask == 0 {
            return (0, 0);
        }
        let up = self.ta.update_word(class, clause, word, inc_mask, dec_mask);
        if up.action_flipped() {
            let w = self.shape.words();
            let row = self.row(class, clause);
            let a = &mut self.actions[row * w + word];
            *a = (*a | up.now_include) & !up.now_exclude;
            self.mark_clause_dirty(row);
        }
        crate::verify::contracts::enforce_word(self, class, clause, word);
        (up.applied_incs, up.applied_decs)
    }

    /// Classification accuracy over a set of packed datapoints.
    pub fn accuracy(&mut self, data: &[(Input, usize)], params: &TmParams) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let correct = data
            .iter()
            .filter(|(x, y)| {
                // Borrow juggling: predict needs &mut self.
                let p = self.predict(x, params);
                p == *y
            })
            .count();
        correct as f64 / data.len() as f64
    }
}

/// Spawn threshold for batched evaluation, in clause-evaluations across
/// the whole batch — shared by the row-major ([`MultiTm::evaluate_batch`])
/// and sample-sliced (`tm::bitplane`) paths so the two parallelise at the
/// same batch scale.
pub(crate) const SPAWN_WORK: usize = 1 << 15;

/// THE argmax of this repo: index of the largest class sum, **ties to the
/// lowest class index** (matching the L2 graph's argmax). Every
/// prediction path — [`MultiTm::infer`], [`MultiTm::predict`], the
/// row-major batch and the sample-sliced plane kernels — routes through
/// this one helper so the tie-break semantics cannot drift.
#[inline]
pub fn argmax_class(classes: usize, sum: impl Fn(usize) -> i32) -> usize {
    let mut best = 0usize;
    for c in 1..classes {
        if sum(c) > sum(best) {
            best = c;
        }
    }
    best
}

/// Row-wise [`argmax_class`] over class-major sums (`sums[c * n + i]`).
pub(crate) fn argmax_rows(sums: &[i32], n: usize, nc: usize) -> Vec<usize> {
    (0..n).map(|i| argmax_class(nc, |c| sums[c * n + i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::rng::{StepRands, Xoshiro256};

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn params() -> TmParams {
        TmParams::paper_offline(&shape())
    }

    fn input_from(bits: &[bool]) -> Input {
        Input::pack(&shape(), bits)
    }

    #[test]
    fn fresh_machine_predicts_class0_with_zero_sums() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let x = input_from(&vec![true; 16]);
        let (sums, pred) = tm.infer(&x, &params());
        assert_eq!(sums, vec![0, 0, 0]);
        assert_eq!(pred, 0, "tie breaks to lowest class");
    }

    #[test]
    fn action_cache_matches_states_after_manual_sets() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        // Force TA (1, 2, 7) into include via increments.
        tm.ta_increment(1, 2, 7);
        assert!(tm.ta().action(1, 2, 7));
        assert_eq!(tm.action_words(1, 2)[0], 1 << 7);
        tm.ta_decrement(1, 2, 7);
        assert!(!tm.ta().action(1, 2, 7));
        assert_eq!(tm.action_words(1, 2)[0], 0);
    }

    #[test]
    fn rebuild_matches_incremental() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let mut rng = Xoshiro256::new(99);
        for _ in 0..5000 {
            let c = rng.next_below(3);
            let j = rng.next_below(16);
            let k = rng.next_below(32);
            if rng.next_f32() < 0.6 {
                tm.ta_increment(c, j, k);
            } else {
                tm.ta_decrement(c, j, k);
            }
        }
        let incremental = tm.actions.clone();
        tm.rebuild_actions();
        assert_eq!(incremental, tm.actions);
    }

    #[test]
    fn clause_votes_follow_polarity() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let p = params();
        // Make clause (0,0) [positive] include literal 0 and clause (0,1)
        // [negative] include literal 0 as well.
        for j in 0..2 {
            for _ in 0..2 {
                tm.ta_increment(0, j, 0);
            }
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = input_from(&bits);
        let (sums, _) = tm.infer(&x, &p);
        assert_eq!(sums[0], 0, "one + and one - vote cancel");
        // Disable the negative clause's literal: make it include ¬x0 too
        // so it stops firing.
        for _ in 0..2 {
            tm.ta_increment(0, 1, 16);
        }
        let (sums, pred) = tm.infer(&x, &p);
        assert_eq!(sums[0], 1);
        assert_eq!(pred, 0);
    }

    #[test]
    fn over_provisioned_clauses_do_not_vote() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let mut p = params();
        // Clause 14 (active under 16, inactive under 14... index >= 14).
        for _ in 0..2 {
            tm.ta_increment(0, 14, 0);
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = input_from(&bits);
        let (sums, _) = tm.infer(&x, &p);
        assert_eq!(sums[0], 1);
        p.active_clauses = 14;
        let (sums, _) = tm.infer(&x, &p);
        assert_eq!(sums[0], 0, "clause 14 gated off by the clause-number port");
    }

    #[test]
    fn over_provisioned_classes_do_not_vote() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let mut p = params();
        p.active_classes = 2;
        for _ in 0..2 {
            tm.ta_increment(2, 0, 0);
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = input_from(&bits);
        let (sums, pred) = tm.infer(&x, &p);
        assert_eq!(sums.len(), 2);
        assert!(pred < 2);
    }

    #[test]
    fn stuck_at_0_fault_blocks_include() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let p = params();
        for _ in 0..2 {
            tm.ta_increment(0, 0, 0); // include literal 0
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = input_from(&bits);
        assert_eq!(tm.infer(&x, &p).0[0], 1);
        // Stuck-at-0 on that TA: clause becomes empty -> infer output 0.
        tm.fault_map_mut().set(0, 0, 0, crate::tm::fault::Fault::StuckAt0);
        assert_eq!(tm.infer(&x, &p).0[0], 0);
        assert!(!tm.eff_action(0, 0, 0));
        assert!(tm.ta().action(0, 0, 0), "true state untouched by the gate");
    }

    #[test]
    fn stuck_at_1_fault_forces_include() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let p = params();
        // Clause (0,0) empty; stuck-at-1 on complement literal of x0.
        tm.fault_map_mut().set(0, 0, 16, crate::tm::fault::Fault::StuckAt1);
        let mut bits = vec![false; 16];
        let x0 = input_from(&bits);
        // ¬x0 = 1 -> forced include satisfied -> clause fires even in infer.
        assert_eq!(tm.infer(&x0, &p).0[0], 1);
        bits[0] = true;
        let x1 = input_from(&bits);
        assert_eq!(tm.infer(&x1, &p).0[0], 0, "forced literal now 0");
    }

    #[test]
    fn sums_clamped_to_t() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let mut p = params();
        p.t = 3;
        // Make all 8 positive clauses of class 0 fire on x.
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = input_from(&bits);
        for j in (0..16).step_by(2) {
            for _ in 0..2 {
                tm.ta_increment(0, j, 0);
            }
        }
        let (sums, _) = tm.infer(&x, &p);
        assert_eq!(sums[0], 3, "clamped to T");
    }

    #[test]
    fn accuracy_counts_correct_predictions() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let p = params();
        // Teach class 1's positive clause 0 to fire on x0=1 by hand.
        for _ in 0..2 {
            tm.ta_increment(1, 0, 0);
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        let x = input_from(&bits);
        let data = vec![(x.clone(), 1), (x, 0)];
        let acc = tm.accuracy(&data, &p);
        assert!((acc - 0.5).abs() < 1e-9);
        assert_eq!(tm.accuracy(&[], &p), 0.0);
    }

    #[test]
    fn clause_fault_forces_output_both_modes() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let p = params();
        let x = input_from(&vec![true; 16]);
        // Force positive clause (0,0) to 1: votes +1 even though empty.
        tm.set_clause_fault(0, 0, Some(true));
        assert_eq!(tm.clause_fault(0, 0), Some(true));
        assert_eq!(tm.clause_fault_count(), 1);
        let (sums, _) = tm.infer(&x, &p);
        assert_eq!(sums[0], 1, "forced-1 clause votes in infer mode");
        // Force it to 0: silent even in train mode (empty would fire).
        tm.set_clause_fault(0, 0, Some(false));
        tm.evaluate(&x, &p, EvalMode::Train);
        assert!(!tm.clause_out[0]);
        // Clear restores normal behaviour.
        tm.set_clause_fault(0, 0, None);
        assert_eq!(tm.clause_fault_count(), 0);
        tm.evaluate(&x, &p, EvalMode::Train);
        assert!(tm.clause_out[0], "empty clause fires in train mode again");
    }

    #[test]
    fn clause_fault_respects_clause_gating() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        let mut p = params();
        p.active_clauses = 2;
        let x = input_from(&vec![true; 16]);
        tm.set_clause_fault(0, 4, Some(true)); // clause 4 is gated off
        let (sums, _) = tm.infer(&x, &p);
        assert_eq!(sums[0], 0, "gated clause cannot drive the vote wire");
    }

    #[test]
    fn clause_fault_counter_tracks_set_clear() {
        let mut tm = MultiTm::new(&shape()).unwrap();
        tm.set_clause_fault(0, 0, Some(true));
        tm.set_clause_fault(0, 0, Some(false)); // overwrite, still 1 fault
        tm.set_clause_fault(1, 5, Some(true));
        assert_eq!(tm.clause_fault_count(), 2);
        tm.set_clause_fault(0, 0, None);
        tm.set_clause_fault(0, 0, None); // double clear is idempotent
        assert_eq!(tm.clause_fault_count(), 1);
    }

    /// Build a machine with uniformly random TA states (exercising
    /// random include patterns) on the given shape.
    fn random_machine(s: &TmShape, seed: u64) -> (MultiTm, Xoshiro256) {
        let mut rng = Xoshiro256::new(seed);
        let states: Vec<u32> =
            (0..s.num_tas()).map(|_| rng.next_below(2 * s.states as usize) as u32).collect();
        (MultiTm::from_states(s, states).unwrap(), rng)
    }

    /// Differential: the fast single-word path (`evaluate_class_fast1`)
    /// must agree with the general gate-aware path on sums AND clause
    /// outputs, over randomized states/inputs/params.
    #[test]
    fn prop_fast1_matches_general_eval() {
        let s = shape();
        for trial in 0..200u64 {
            let (mut tm, mut rng) = random_machine(&s, 0xFA51 + trial);
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&s, &bits);
            let mut p = params();
            p.active_clauses = [4, 8, 16][(trial % 3) as usize];
            p.active_classes = 1 + (trial % 3) as usize;
            p.t = [1, 5, 15][(trial % 3) as usize];
            for mode in [EvalMode::Train, EvalMode::Infer] {
                // Fast path (words()==1, fault-free, no clause faults).
                let fast_sums = tm.evaluate(&x, &p, mode).to_vec();
                let fast_out = tm.clause_out.clone();
                let gen_sums = tm.evaluate_general(&x, &p, mode).to_vec();
                let gen_out = tm.clause_out.clone();
                assert_eq!(fast_sums, gen_sums, "trial {trial} {mode:?}");
                assert_eq!(fast_out, gen_out, "trial {trial} {mode:?}");
            }
        }
    }

    /// Differential: `evaluate_batch`/`predict_batch` match per-row
    /// `evaluate`/`predict`, including on multiword shapes and under TA
    /// fault gates.
    #[test]
    fn prop_batch_eval_matches_per_row() {
        for (si, s) in [
            shape(),
            TmShape { classes: 4, max_clauses: 6, features: 40, states: 8 },
        ]
        .iter()
        .enumerate()
        {
            let (mut tm, mut rng) = random_machine(s, 0xBA7C + si as u64);
            if si == 1 {
                let map = crate::tm::fault::FaultMap::even_spread(
                    s,
                    0.15,
                    crate::tm::fault::Fault::StuckAt0,
                    7,
                )
                .unwrap();
                tm.set_fault_map(map);
            }
            let mut p = TmParams::paper_offline(s);
            p.active_clauses = s.max_clauses - 2;
            p.active_classes = s.classes - 1;
            let inputs: Vec<Input> = (0..50)
                .map(|_| {
                    let bits: Vec<bool> =
                        (0..s.features).map(|_| rng.next_f32() < 0.5).collect();
                    Input::pack(s, &bits)
                })
                .collect();
            for mode in [EvalMode::Train, EvalMode::Infer] {
                let batch = tm.evaluate_batch(&inputs, &p, mode);
                assert_eq!(batch.len(), p.active_classes * inputs.len());
                for (i, x) in inputs.iter().enumerate() {
                    let sums = tm.evaluate(x, &p, mode).to_vec();
                    for c in 0..p.active_classes {
                        assert_eq!(
                            batch[c * inputs.len() + i],
                            sums[c],
                            "shape {si} row {i} class {c} {mode:?}"
                        );
                    }
                }
            }
            let preds = tm.predict_batch(&inputs, &p);
            for (i, x) in inputs.iter().enumerate() {
                assert_eq!(preds[i], tm.predict(x, &p), "shape {si} row {i}");
            }
            let labelled: Vec<(Input, usize)> =
                inputs.iter().map(|x| (x.clone(), 0usize)).collect();
            assert_eq!(tm.predict_batch_labelled(&labelled, &p), preds);
            assert!((tm.accuracy_batch(&labelled, &p) - tm.accuracy(&labelled, &p)).abs() < 1e-12);
        }
    }

    /// The clause-output force gate routes evaluation off the fast
    /// single-word path; forcing, clearing, and re-forcing must keep the
    /// two paths consistent at every stage.
    #[test]
    fn clause_fault_gate_vs_fast_path_consistency() {
        let s = shape();
        let p = params();
        let (mut tm, mut rng) = random_machine(&s, 0xC1F7);
        let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
        let x = Input::pack(&s, &bits);
        // Baseline: fast path result (no clause faults).
        let base_sums = tm.evaluate(&x, &p, EvalMode::Infer).to_vec();
        let base_out0 = tm.clause_out[0];
        // Forcing clause (0,0) to the value it already has must not move
        // the sums, but goes through the general path.
        tm.set_clause_fault(0, 0, Some(base_out0));
        assert_eq!(tm.clause_fault_count(), 1);
        let forced_same = tm.evaluate(&x, &p, EvalMode::Infer).to_vec();
        assert_eq!(forced_same, base_sums, "agreeing force is a no-op");
        // Forcing the opposite value moves class 0's sum by exactly the
        // clause's polarity (clause 0 votes +1).
        tm.set_clause_fault(0, 0, Some(!base_out0));
        let flipped = tm.evaluate(&x, &p, EvalMode::Infer).to_vec();
        let delta = if base_out0 { -1 } else { 1 };
        assert_eq!(
            flipped[0],
            (base_sums[0] + delta).clamp(-p.t, p.t),
            "forced flip shifts the vote by polarity"
        );
        assert_eq!(flipped[1..], base_sums[1..], "other classes untouched");
        // Clearing the gate restores the fast path bit-for-bit.
        tm.set_clause_fault(0, 0, None);
        assert_eq!(tm.clause_fault_count(), 0);
        let cleared = tm.evaluate(&x, &p, EvalMode::Infer).to_vec();
        assert_eq!(cleared, base_sums);
        // And batch evaluation honours the gate exactly like evaluate.
        tm.set_clause_fault(0, 0, Some(!base_out0));
        let batch = tm.evaluate_batch(std::slice::from_ref(&x), &p, EvalMode::Infer);
        assert_eq!(batch[0], flipped[0]);
        assert_eq!(&batch[1..], &flipped[1..p.active_classes]);
    }

    /// Word-batched feedback application agrees with the scalar
    /// ta_increment/ta_decrement path, action cache included.
    #[test]
    fn prop_apply_word_feedback_matches_scalar() {
        let s = shape();
        for trial in 0..300u64 {
            let (mut a, mut rng) = random_machine(&s, 0x33AA + trial);
            let mut b = a.clone();
            let c = rng.next_below(s.classes);
            let j = rng.next_below(s.max_clauses);
            let valid = crate::tm::params::word_mask(s.literals(), 0);
            let inc = rng.next_u64() & valid;
            let dec = rng.next_u64() & valid & !inc;
            let (ai, ad) = a.apply_word_feedback(c, j, 0, inc, dec);
            let (mut bi, mut bd) = (0u32, 0u32);
            for k in 0..s.literals() {
                let before = b.ta().state(c, j, k);
                if inc & (1u64 << k) != 0 {
                    b.ta_increment(c, j, k);
                    if b.ta().state(c, j, k) != before {
                        bi += 1;
                    }
                } else if dec & (1u64 << k) != 0 {
                    b.ta_decrement(c, j, k);
                    if b.ta().state(c, j, k) != before {
                        bd += 1;
                    }
                }
            }
            assert_eq!(a.ta().states(), b.ta().states(), "trial {trial}");
            assert_eq!(a.actions, b.actions, "trial {trial}");
            assert_eq!((ai, ad), (bi, bd), "trial {trial}");
        }
    }

    /// The revision clock moves exactly when a clause's effective
    /// evaluation can change: action flips and force edits stamp the row,
    /// within-half TA moves do not, fault-map loads stamp everything.
    #[test]
    fn revision_clock_tracks_effective_changes() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let r0 = tm.row_rev(0);
        // Within-half move (99 -> 100 flips; so start from a deep state).
        tm.ta_increment(0, 0, 0); // 99 -> 100: NowInclude, flips
        let r1 = tm.row_rev(0);
        assert!(r1 > r0, "boundary crossing must stamp the row");
        tm.ta_increment(0, 0, 0); // 100 -> 101: same action
        assert_eq!(tm.row_rev(0), r1, "within-half move must not stamp");
        let other = tm.row_rev(s.max_clauses); // class 1, clause 0
        // Word feedback with a flip stamps only its row.
        tm.apply_word_feedback(0, 1, 0, 0b1, 0); // 99 -> 100 on lit 0
        assert!(tm.row_rev(1) > r1);
        assert_eq!(tm.row_rev(s.max_clauses), other);
        // Saturated / non-flip word feedback leaves the stamp alone.
        let r2 = tm.row_rev(1);
        tm.apply_word_feedback(0, 1, 0, 0b1, 0); // 100 -> 101
        assert_eq!(tm.row_rev(1), r2);
        // Force edits stamp; re-setting the same value does not.
        tm.set_clause_fault(0, 2, Some(true));
        let r3 = tm.row_rev(2);
        assert!(r3 > r2);
        tm.set_clause_fault(0, 2, Some(true));
        assert_eq!(tm.row_rev(2), r3);
        // Fault-map load stamps every row (conservative).
        let before: Vec<u64> = (0..4).map(|r| tm.row_rev(r)).collect();
        tm.set_fault_map(crate::tm::fault::FaultMap::none(&s));
        for (r, &b) in before.iter().enumerate() {
            assert!(tm.row_rev(r) > b, "row {r} must be globally stamped");
        }
    }

    #[test]
    fn clones_get_fresh_uids() {
        let s = shape();
        let a = MultiTm::new(&s).unwrap();
        let b = a.clone();
        let c = MultiTm::new(&s).unwrap();
        assert_ne!(a.uid(), b.uid(), "clone must not alias the original");
        assert_ne!(a.uid(), c.uid());
        assert_ne!(b.uid(), c.uid());
    }

    /// Smoke: training decreases nothing structurally — full training
    /// behaviour is covered in feedback.rs and the integration tests.
    #[test]
    fn train_step_runs_and_keeps_cache_coherent() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(1234);
        let bits: Vec<bool> = (0..16).map(|k| k % 2 == 0).collect();
        let x = input_from(&bits);
        for step in 0..200 {
            let r = StepRands::draw(&mut rng, &s);
            crate::tm::feedback::train_step(&mut tm, &x, step % 3, &p, &r);
        }
        let incremental = tm.actions.clone();
        tm.rebuild_actions();
        assert_eq!(incremental, tm.actions, "cache must stay coherent");
    }
}
