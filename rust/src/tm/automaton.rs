//! Tsetlin automata (TA) state storage.
//!
//! A TA is a finite reinforcement automaton (§2): states `0..states-1`
//! produce the *exclude* action, states `states..2*states-1` produce
//! *include*. Rewards push the automaton deeper into its current action's
//! half; penalties push it toward (and across) the decision boundary.
//!
//! [`TaBlock`] stores one state per (class, clause, literal) in a flat
//! `Vec<u32>` with the same row-major layout the L2 HLO graph uses for its
//! `[classes, clauses, literals]` state tensor, so the two paths can be
//! compared element-for-element.

use crate::tm::params::TmShape;
use anyhow::{bail, Result};

/// Flat block of TA states for a whole machine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaBlock {
    shape: TmShape,
    states: Vec<u32>,
}

/// Result of one batched word update ([`TaBlock::update_word`]): applied
/// move counts plus bitmasks of the TAs whose include/exclude action
/// flipped, so the machine can patch its packed action cache with one
/// read-modify-write per word instead of one per literal.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WordUpdate {
    /// Increments actually applied (saturated TAs excluded).
    pub applied_incs: u32,
    /// Decrements actually applied (saturated TAs excluded).
    pub applied_decs: u32,
    /// Bits whose action flipped exclude → include.
    pub now_include: u64,
    /// Bits whose action flipped include → exclude.
    pub now_exclude: u64,
}

impl WordUpdate {
    /// Did any TA cross the include/exclude boundary? This is the signal
    /// the machine forwards into its per-clause dirty tracking
    /// (`tm::rescore`): a clause whose actions did not flip cannot change
    /// any cached fired-mask, so word updates with pure within-half moves
    /// leave incremental re-scoring caches untouched.
    #[inline]
    pub fn action_flipped(&self) -> bool {
        (self.now_include | self.now_exclude) != 0
    }
}

/// What a saturating transition did — used by the machine to keep its
/// packed include-action cache coherent without re-scanning all TAs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    /// State changed but the include/exclude action did not.
    Moved,
    /// Action flipped exclude → include.
    NowInclude,
    /// Action flipped include → exclude.
    NowExclude,
    /// Already saturated; state unchanged.
    Saturated,
}

impl TaBlock {
    /// New block with every TA in the weakest exclude state adjacent to
    /// the decision boundary (`states - 1`) — the paper's RTL reset value
    /// and the canonical TM initialisation.
    pub fn new(shape: &TmShape) -> Self {
        let n = shape.num_tas();
        TaBlock { shape: shape.clone(), states: vec![shape.states - 1; n] }
    }

    /// Construct from raw states (e.g. read back from the PJRT path).
    pub fn from_states(shape: &TmShape, states: Vec<u32>) -> Result<Self> {
        if states.len() != shape.num_tas() {
            bail!(
                "TaBlock: expected {} states, got {}",
                shape.num_tas(),
                states.len()
            );
        }
        if let Some(&bad) = states.iter().find(|&&s| s > shape.max_state()) {
            bail!("TaBlock: state {} exceeds max {}", bad, shape.max_state());
        }
        Ok(TaBlock { shape: shape.clone(), states })
    }

    pub fn shape(&self) -> &TmShape {
        &self.shape
    }

    /// Raw flat view (row-major `[class][clause][literal]`).
    pub fn states(&self) -> &[u32] {
        &self.states
    }

    #[inline]
    pub fn idx(&self, class: usize, clause: usize, lit: usize) -> usize {
        debug_assert!(class < self.shape.classes);
        debug_assert!(clause < self.shape.max_clauses);
        debug_assert!(lit < self.shape.literals());
        (class * self.shape.max_clauses + clause) * self.shape.literals() + lit
    }

    #[inline]
    pub fn state(&self, class: usize, clause: usize, lit: usize) -> u32 {
        self.states[self.idx(class, clause, lit)]
    }

    pub fn set_state(&mut self, class: usize, clause: usize, lit: usize, v: u32) {
        assert!(v <= self.shape.max_state(), "state {v} out of range");
        let i = self.idx(class, clause, lit);
        self.states[i] = v;
    }

    /// True (fault-free) include action of one TA.
    #[inline]
    pub fn action(&self, class: usize, clause: usize, lit: usize) -> bool {
        self.state(class, clause, lit) >= self.shape.include_threshold()
    }

    /// Saturating reward/penalty step toward include (`+1`).
    #[inline]
    pub fn increment(&mut self, class: usize, clause: usize, lit: usize) -> Transition {
        let thr = self.shape.include_threshold();
        let max = self.shape.max_state();
        let i = self.idx(class, clause, lit);
        let s = self.states[i];
        if s == max {
            return Transition::Saturated;
        }
        self.states[i] = s + 1;
        if s + 1 == thr {
            Transition::NowInclude
        } else {
            Transition::Moved
        }
    }

    /// Saturating reward/penalty step toward exclude (`-1`).
    #[inline]
    pub fn decrement(&mut self, class: usize, clause: usize, lit: usize) -> Transition {
        let thr = self.shape.include_threshold();
        let i = self.idx(class, clause, lit);
        let s = self.states[i];
        if s == 0 {
            return Transition::Saturated;
        }
        self.states[i] = s - 1;
        if s == thr {
            Transition::NowExclude
        } else {
            Transition::Moved
        }
    }

    /// Batched saturating updates over one 64-literal word of clause
    /// `(class, clause)`: increment the TAs at set bits of `inc`,
    /// decrement those at set bits of `dec`. The masks must be disjoint
    /// and must only cover valid literals of the word (`word * 64 + bit <
    /// literals`). Equivalent to per-bit [`TaBlock::increment`] /
    /// [`TaBlock::decrement`] calls — the word-parallel feedback engine's
    /// bulk path.
    #[inline]
    pub fn update_word(
        &mut self,
        class: usize,
        clause: usize,
        word: usize,
        inc: u64,
        dec: u64,
    ) -> WordUpdate {
        debug_assert_eq!(inc & dec, 0, "inc/dec masks must be disjoint");
        let thr = self.shape.include_threshold();
        let max = self.shape.max_state();
        let base = self.idx(class, clause, word * 64);
        let mut up = WordUpdate::default();
        let mut m = inc;
        while m != 0 {
            let k = m.trailing_zeros() as usize;
            m &= m - 1;
            let s = &mut self.states[base + k];
            if *s < max {
                *s += 1;
                up.applied_incs += 1;
                if *s == thr {
                    up.now_include |= 1u64 << k;
                }
            }
        }
        let mut m = dec;
        while m != 0 {
            let k = m.trailing_zeros() as usize;
            m &= m - 1;
            let s = &mut self.states[base + k];
            if *s > 0 {
                *s -= 1;
                up.applied_decs += 1;
                if *s + 1 == thr {
                    up.now_exclude |= 1u64 << k;
                }
            }
        }
        up
    }

    /// Number of TAs currently in the include action (diagnostic; the
    /// paper's explainability angle — clause composition — reads this).
    pub fn include_count(&self) -> usize {
        let thr = self.shape.include_threshold();
        self.states.iter().filter(|&&s| s >= thr).count()
    }

    /// Iterate the include bits of one clause row.
    pub fn clause_includes<'a>(
        &'a self,
        class: usize,
        clause: usize,
    ) -> impl Iterator<Item = bool> + 'a {
        let base = self.idx(class, clause, 0);
        let thr = self.shape.include_threshold();
        self.states[base..base + self.shape.literals()]
            .iter()
            .map(move |&s| s >= thr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    #[test]
    fn init_all_weak_exclude() {
        let b = TaBlock::new(&shape());
        assert_eq!(b.states().len(), 3 * 16 * 32);
        assert!(b.states().iter().all(|&s| s == 99));
        assert_eq!(b.include_count(), 0);
    }

    #[test]
    fn idx_is_row_major() {
        let b = TaBlock::new(&shape());
        assert_eq!(b.idx(0, 0, 0), 0);
        assert_eq!(b.idx(0, 0, 31), 31);
        assert_eq!(b.idx(0, 1, 0), 32);
        assert_eq!(b.idx(1, 0, 0), 16 * 32);
        assert_eq!(b.idx(2, 15, 31), 3 * 16 * 32 - 1);
    }

    #[test]
    fn increment_crosses_boundary_once() {
        let mut b = TaBlock::new(&shape());
        // 99 -> 100 crosses into include.
        assert_eq!(b.increment(0, 0, 0), Transition::NowInclude);
        assert!(b.action(0, 0, 0));
        // Further increments just move.
        assert_eq!(b.increment(0, 0, 0), Transition::Moved);
        assert_eq!(b.state(0, 0, 0), 101);
    }

    #[test]
    fn decrement_crosses_boundary_once() {
        let mut b = TaBlock::new(&shape());
        b.set_state(1, 2, 3, 100); // weakest include
        assert_eq!(b.decrement(1, 2, 3), Transition::NowExclude);
        assert!(!b.action(1, 2, 3));
        assert_eq!(b.decrement(1, 2, 3), Transition::Moved);
        assert_eq!(b.state(1, 2, 3), 98);
    }

    #[test]
    fn saturates_at_both_ends() {
        let mut b = TaBlock::new(&shape());
        b.set_state(0, 0, 0, 199);
        assert_eq!(b.increment(0, 0, 0), Transition::Saturated);
        assert_eq!(b.state(0, 0, 0), 199);
        b.set_state(0, 0, 0, 0);
        assert_eq!(b.decrement(0, 0, 0), Transition::Saturated);
        assert_eq!(b.state(0, 0, 0), 0);
    }

    #[test]
    fn from_states_validates() {
        let s = shape();
        assert!(TaBlock::from_states(&s, vec![0; 5]).is_err());
        assert!(TaBlock::from_states(&s, vec![200; s.num_tas()]).is_err());
        let ok = TaBlock::from_states(&s, vec![150; s.num_tas()]).unwrap();
        assert_eq!(ok.include_count(), s.num_tas());
    }

    #[test]
    fn clause_includes_row() {
        let mut b = TaBlock::new(&shape());
        b.set_state(1, 3, 0, 150);
        b.set_state(1, 3, 31, 199);
        let inc: Vec<bool> = b.clause_includes(1, 3).collect();
        assert_eq!(inc.len(), 32);
        assert!(inc[0] && inc[31]);
        assert_eq!(inc.iter().filter(|&&x| x).count(), 2);
    }

    /// Property: `update_word` is exactly the per-bit increment/decrement
    /// loop — states, applied counts and flip masks all agree.
    #[test]
    fn prop_update_word_matches_scalar() {
        use crate::tm::rng::Xoshiro256;
        // 80 literals -> 2 words, the second partially filled.
        let s = TmShape { classes: 2, max_clauses: 4, features: 40, states: 4 };
        let mut rng = Xoshiro256::new(0x0b17);
        for trial in 0..500 {
            let states: Vec<u32> =
                (0..s.num_tas()).map(|_| rng.next_below(2 * 4) as u32).collect();
            let mut a = TaBlock::from_states(&s, states.clone()).unwrap();
            let mut b = TaBlock::from_states(&s, states).unwrap();
            let c = rng.next_below(s.classes);
            let j = rng.next_below(s.max_clauses);
            let w = rng.next_below(s.words());
            let valid = crate::tm::params::word_mask(s.literals(), w);
            let inc = rng.next_u64() & valid;
            let dec = rng.next_u64() & valid & !inc;
            let up = a.update_word(c, j, w, inc, dec);
            // Scalar oracle.
            let (mut incs, mut decs) = (0u32, 0u32);
            let (mut now_inc, mut now_exc) = (0u64, 0u64);
            for k in 0..64u64 {
                let lit = w * 64 + k as usize;
                if inc & (1 << k) != 0 {
                    match b.increment(c, j, lit) {
                        Transition::NowInclude => {
                            incs += 1;
                            now_inc |= 1 << k;
                        }
                        Transition::Moved => incs += 1,
                        Transition::Saturated => {}
                        Transition::NowExclude => unreachable!(),
                    }
                } else if dec & (1 << k) != 0 {
                    match b.decrement(c, j, lit) {
                        Transition::NowExclude => {
                            decs += 1;
                            now_exc |= 1 << k;
                        }
                        Transition::Moved => decs += 1,
                        Transition::Saturated => {}
                        Transition::NowInclude => unreachable!(),
                    }
                }
            }
            assert_eq!(a.states(), b.states(), "trial {trial}");
            assert_eq!(up.applied_incs, incs, "trial {trial}");
            assert_eq!(up.applied_decs, decs, "trial {trial}");
            assert_eq!(up.now_include, now_inc, "trial {trial}");
            assert_eq!(up.now_exclude, now_exc, "trial {trial}");
        }
    }

    /// Property: a random walk of increments/decrements never leaves the
    /// legal state range, and action always equals `state >= threshold`.
    #[test]
    fn prop_random_walk_invariants() {
        use crate::tm::rng::Xoshiro256;
        let s = shape();
        let mut b = TaBlock::new(&s);
        let mut rng = Xoshiro256::new(0xFA57);
        for _ in 0..20_000 {
            let c = rng.next_below(s.classes);
            let j = rng.next_below(s.max_clauses);
            let k = rng.next_below(s.literals());
            if rng.next_f32() < 0.5 {
                b.increment(c, j, k);
            } else {
                b.decrement(c, j, k);
            }
            let st = b.state(c, j, k);
            assert!(st <= s.max_state());
            assert_eq!(b.action(c, j, k), st >= s.include_threshold());
        }
    }
}
