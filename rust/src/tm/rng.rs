//! Deterministic randomness shared across the native (L3) and AOT/HLO
//! (L2/L1) execution paths.
//!
//! The TM training step is stochastic. To prove the three layers compose
//! (and to make every experiment bit-reproducible), a training step never
//! draws randomness internally: it consumes an explicit [`StepRands`]
//! record. The same flattened `f32` arrays feed (a) the native Rust
//! feedback in [`crate::tm::feedback`] and (b) the lowered HLO executable
//! as input tensors — `rust/tests/parity.rs` asserts the resulting TA
//! states are bit-identical.
//!
//! The generator itself is xoshiro256++ (public-domain reference
//! algorithm), seeded via splitmix64 — no external crates.

use crate::tm::params::TmShape;

/// xoshiro256++ PRNG. Deterministic, fast, and trivially re-implementable
/// in any layer of the stack.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    /// Seed via splitmix64 so that small / similar seeds still give
    /// well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256 { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f32` in `[0, 1)` with 24 bits of mantissa entropy. The
    /// exact construction (`(x >> 40) * 2^-24`) is part of the cross-layer
    /// contract: the HLO path receives these values as tensors, so only
    /// the construction on the Rust side matters, but tests pin it down.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Two uniform `f32`s from one `u64` (bits 40..64 and 16..40) — the
    /// step-randomness bulk path; RNG output was ~49% of the training
    /// profile before this (see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn next_f32_pair(&mut self) -> (f32, f32) {
        const SCALE: f32 = 1.0 / (1u64 << 24) as f32;
        let x = self.next_u64();
        (((x >> 40) as f32) * SCALE, (((x >> 16) & 0x00FF_FFFF) as f32) * SCALE)
    }

    /// Fill a slice with uniforms using the paired extraction (odd tail
    /// falls back to [`next_f32`]).
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        let mut chunks = out.chunks_exact_mut(2);
        for c in &mut chunks {
            let (a, b) = self.next_f32_pair();
            c[0] = a;
            c[1] = b;
        }
        for v in chunks.into_remainder() {
            *v = self.next_f32();
        }
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free mapping is
    /// overkill here; modulo bias is < 2^-40 for our tiny `n`).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Bit-sliced Bernoulli mask generator: one call yields 64 i.i.d.
/// `Bernoulli(p)` bits packed in a `u64` — the word-parallel engine's
/// replacement for 64 scalar `next_f32() < p` comparisons.
///
/// `p` is quantised to 16 fixed-point bits and the binary expansion is
/// processed least-significant bit first with one `next_u64` per bit:
/// `res = r | res` for a 1-bit, `res = r & res` for a 0-bit (the
/// lane-parallel form of the bitwise `uniform < p` comparator). Trailing
/// zero bits of the expansion contribute nothing (the running result
/// starts at 0) and are trimmed, so a mask costs at most 16 draws and
/// often far fewer — `p = 0.5` costs one, `p ∈ {0, 1}` cost none.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BernoulliPlan {
    /// Trimmed binary expansion of `p`, least-significant bit first
    /// (empty when `always` short-circuits).
    bits: Vec<bool>,
    /// `Some(false)` ⇒ every bit 0 (`p = 0`); `Some(true)` ⇒ every bit 1
    /// (`p = 1`); `None` ⇒ generate via `bits`.
    always: Option<bool>,
}

impl BernoulliPlan {
    /// Fixed-point precision of the quantised probability.
    pub const PRECISION_BITS: u32 = 16;

    pub fn new(p: f32) -> Self {
        let scale = 1i64 << Self::PRECISION_BITS;
        let fixed = (p as f64 * scale as f64).round() as i64;
        if fixed <= 0 {
            return BernoulliPlan { bits: Vec::new(), always: Some(false) };
        }
        if fixed >= scale {
            return BernoulliPlan { bits: Vec::new(), always: Some(true) };
        }
        let fixed = fixed as u32;
        let tz = fixed.trailing_zeros();
        let v = fixed >> tz;
        let nbits = Self::PRECISION_BITS - tz;
        let bits = (0..nbits).map(|i| (v >> i) & 1 == 1).collect();
        BernoulliPlan { bits, always: None }
    }

    /// The event never fires (`p` quantised to 0).
    pub fn is_never(&self) -> bool {
        self.always == Some(false)
    }

    /// The event always fires (`p` quantised to 1).
    pub fn is_always(&self) -> bool {
        self.always == Some(true)
    }

    /// `next_u64` draws consumed per mask.
    pub fn draws_per_mask(&self) -> usize {
        self.bits.len()
    }

    /// 64 fresh i.i.d. `Bernoulli(p)` bits.
    #[inline]
    pub fn mask(&self, rng: &mut Xoshiro256) -> u64 {
        match self.always {
            Some(false) => 0,
            Some(true) => !0u64,
            None => {
                let mut res = 0u64;
                for &b in &self.bits {
                    let r = rng.next_u64();
                    res = if b { res | r } else { res & r };
                }
                res
            }
        }
    }
}

/// Contrast-class choice shared by the eager [`StepRands`] record and the
/// lazy word-parallel plan: uniform among active classes other than
/// `target` (`None` when fewer than 2 classes are active).
#[inline]
pub fn neg_class_from_draw(draw: u64, target: usize, active: usize) -> Option<usize> {
    if active < 2 {
        return None;
    }
    let k = (draw % (active as u64 - 1)) as usize;
    Some(if k >= target { k + 1 } else { k })
}

/// All randomness consumed by one training step (one datapoint), in the
/// canonical flattened layout shared with the L2 HLO graph:
///
/// - `clause_rand[c * max_clauses + j]` — clause-feedback selection draw
///   for class `c`, clause `j`.
/// - `ta_rand[(c * max_clauses + j) * literals + k]` — per-TA draw for
///   class `c`, clause `j`, literal `k`.
///
/// The negative-class choice (`neg_class`) is drawn on the Rust side and
/// passed to the HLO graph as a per-class sign vector — see
/// [`crate::tm::feedback::class_signs`].
#[derive(Debug, Clone)]
pub struct StepRands {
    pub clause_rand: Vec<f32>,
    pub ta_rand: Vec<f32>,
    pub neg_class_draw: u64,
}

impl StepRands {
    /// Draw a full step's randomness in the canonical order:
    /// neg-class draw, then all clause draws, then all TA draws (both
    /// arrays via the paired extraction of [`Xoshiro256::fill_f32`]).
    pub fn draw(rng: &mut Xoshiro256, shape: &TmShape) -> Self {
        let nc = shape.classes * shape.max_clauses;
        let mut r = StepRands {
            clause_rand: vec![0.0; nc],
            ta_rand: vec![0.0; nc * shape.literals()],
            neg_class_draw: 0,
        };
        r.refill(rng, shape);
        r
    }

    /// Draw into pre-allocated buffers (hot-loop variant — no allocation).
    pub fn refill(&mut self, rng: &mut Xoshiro256, shape: &TmShape) {
        let nc = shape.classes * shape.max_clauses;
        debug_assert_eq!(self.clause_rand.len(), nc);
        debug_assert_eq!(self.ta_rand.len(), nc * shape.literals());
        self.neg_class_draw = rng.next_u64();
        rng.fill_f32(&mut self.clause_rand);
        rng.fill_f32(&mut self.ta_rand);
    }

    #[inline]
    pub fn clause(&self, shape: &TmShape, class: usize, clause: usize) -> f32 {
        self.clause_rand[class * shape.max_clauses + clause]
    }

    #[inline]
    pub fn ta(&self, shape: &TmShape, class: usize, clause: usize, lit: usize) -> f32 {
        self.ta_rand[(class * shape.max_clauses + clause) * shape.literals() + lit]
    }

    /// Choose the negative (contrast) class uniformly among active classes
    /// other than `target`. `active` must be >= 2 for a draw to exist.
    pub fn neg_class(&self, target: usize, active: usize) -> Option<usize> {
        neg_class_from_draw(self.neg_class_draw, target, active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval_and_well_spread() {
        let mut rng = Xoshiro256::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x), "out of range: {x}");
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} far from 0.5");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Xoshiro256::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "50! leaves identity improbable");
    }

    #[test]
    fn f32_pair_construction_pinned() {
        // The bulk path must extract bits 40..64 and 16..40 of one u64.
        let mut a = Xoshiro256::new(77);
        let mut b = Xoshiro256::new(77);
        for _ in 0..100 {
            let x = a.next_u64();
            let (hi, lo) = b.next_f32_pair();
            let scale = 1.0 / (1u64 << 24) as f32;
            assert_eq!(hi, ((x >> 40) as f32) * scale);
            assert_eq!(lo, (((x >> 16) & 0x00FF_FFFF) as f32) * scale);
            assert!((0.0..1.0).contains(&hi) && (0.0..1.0).contains(&lo));
        }
    }

    #[test]
    fn fill_f32_matches_pairs_and_handles_odd() {
        let mut a = Xoshiro256::new(5);
        let mut b = Xoshiro256::new(5);
        let mut buf = vec![0.0f32; 7];
        a.fill_f32(&mut buf);
        let (p0, p1) = b.next_f32_pair();
        let (p2, p3) = b.next_f32_pair();
        let (p4, p5) = b.next_f32_pair();
        let tail = b.next_f32();
        assert_eq!(buf, vec![p0, p1, p2, p3, p4, p5, tail]);
    }

    #[test]
    fn step_rands_layout() {
        let shape = TmShape::iris();
        let mut rng = Xoshiro256::new(11);
        let r = StepRands::draw(&mut rng, &shape);
        assert_eq!(r.clause_rand.len(), 3 * 16);
        assert_eq!(r.ta_rand.len(), 3 * 16 * 32);
        // Indexing helpers agree with the flat layout.
        assert_eq!(r.clause(&shape, 2, 5), r.clause_rand[2 * 16 + 5]);
        assert_eq!(r.ta(&shape, 1, 3, 31), r.ta_rand[(16 + 3) * 32 + 31]);
    }

    #[test]
    fn refill_matches_draw() {
        let shape = TmShape::iris();
        let mut r1 = Xoshiro256::new(5);
        let mut r2 = Xoshiro256::new(5);
        let a = StepRands::draw(&mut r1, &shape);
        let mut b = StepRands::draw(&mut r2, &shape);
        // Advance both identically once more.
        let a2 = StepRands::draw(&mut r1, &shape);
        b.refill(&mut r2, &shape);
        assert_eq!(a2.clause_rand, b.clause_rand);
        assert_eq!(a2.ta_rand, b.ta_rand);
        assert_eq!(a2.neg_class_draw, b.neg_class_draw);
        let _ = a;
    }

    #[test]
    fn bernoulli_plan_edge_cases() {
        let mut rng = Xoshiro256::new(1);
        let never = BernoulliPlan::new(0.0);
        assert!(never.is_never());
        assert_eq!(never.mask(&mut rng), 0);
        assert_eq!(never.draws_per_mask(), 0);
        let always = BernoulliPlan::new(1.0);
        assert!(always.is_always());
        assert_eq!(always.mask(&mut rng), !0u64);
        // Negative / >1 inputs clamp.
        assert!(BernoulliPlan::new(-0.5).is_never());
        assert!(BernoulliPlan::new(1.5).is_always());
        // p = 0.5 is a single raw draw; p = 0.25 is two.
        assert_eq!(BernoulliPlan::new(0.5).draws_per_mask(), 1);
        assert_eq!(BernoulliPlan::new(0.25).draws_per_mask(), 2);
        assert_eq!(BernoulliPlan::new(0.75).draws_per_mask(), 2);
        // Sub-quantum probabilities round to never/always.
        assert!(BernoulliPlan::new(1.0 / (1 << 20) as f32).is_never());
        assert!(BernoulliPlan::new(1.0 - 1.0 / (1 << 20) as f32).is_always());
    }

    #[test]
    fn bernoulli_plan_half_is_raw_word() {
        // p = 0.5 must pass the raw xoshiro word through.
        let mut a = Xoshiro256::new(33);
        let mut b = Xoshiro256::new(33);
        let half = BernoulliPlan::new(0.5);
        for _ in 0..50 {
            assert_eq!(half.mask(&mut a), b.next_u64());
        }
    }

    #[test]
    fn bernoulli_plan_mask_density_matches_p() {
        let mut rng = Xoshiro256::new(0xB17);
        for &p in &[0.25f32, 0.272727, 0.5, 0.727273, 0.9, 1.0 / 65536.0 * 3.0] {
            let plan = BernoulliPlan::new(p);
            assert!(plan.draws_per_mask() <= 16);
            let n = 4000;
            let ones: u64 = (0..n).map(|_| plan.mask(&mut rng).count_ones() as u64).sum();
            let est = ones as f64 / (n * 64) as f64;
            let target = (p as f64 * 65536.0).round() / 65536.0;
            assert!(
                (est - target).abs() < 0.01,
                "p={p}: estimated {est:.4}, want {target:.4}"
            );
        }
    }

    #[test]
    fn bernoulli_plan_lanes_independent() {
        // Adjacent lanes must not be correlated: P(bit0 & bit1) ≈ p².
        let plan = BernoulliPlan::new(0.272727);
        let mut rng = Xoshiro256::new(0x1A2B);
        let n = 30_000;
        let (mut c0, mut c1, mut c01) = (0u32, 0u32, 0u32);
        for _ in 0..n {
            let m = plan.mask(&mut rng);
            c0 += (m & 1) as u32;
            c1 += ((m >> 1) & 1) as u32;
            c01 += (m & (m >> 1) & 1) as u32;
        }
        let (p0, p1, p01) =
            (c0 as f64 / n as f64, c1 as f64 / n as f64, c01 as f64 / n as f64);
        assert!((p01 - p0 * p1).abs() < 0.01, "{p0:.3} {p1:.3} joint {p01:.3}");
    }

    #[test]
    fn neg_class_from_draw_matches_step_rands() {
        let shape = TmShape::iris();
        let mut rng = Xoshiro256::new(21);
        for _ in 0..100 {
            let r = StepRands::draw(&mut rng, &shape);
            for target in 0..3 {
                for active in 1..=3 {
                    assert_eq!(
                        r.neg_class(target, active),
                        neg_class_from_draw(r.neg_class_draw, target, active)
                    );
                }
            }
        }
    }

    #[test]
    fn neg_class_never_target_and_covers_others() {
        let shape = TmShape::iris();
        let mut rng = Xoshiro256::new(13);
        let mut seen = [false; 3];
        for _ in 0..200 {
            let r = StepRands::draw(&mut rng, &shape);
            let neg = r.neg_class(1, 3).unwrap();
            assert_ne!(neg, 1);
            seen[neg] = true;
        }
        assert!(seen[0] && seen[2]);
        // Single active class: no contrast class exists.
        let r = StepRands::draw(&mut rng, &shape);
        assert_eq!(r.neg_class(0, 1), None);
    }
}
