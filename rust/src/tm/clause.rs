//! Bit-parallel clause evaluation.
//!
//! A clause is an AND expression over *included* literals (§2). The RTL
//! evaluates all clauses combinationally in one cycle; the software twin
//! evaluates each clause over packed `u64` words: a clause fires iff no
//! included literal is false, i.e. `include & !literals == 0` in every
//! word.
//!
//! Empty-clause convention (canonical TM, Granmo 2018): during **training**
//! an empty clause (no effective includes) outputs 1 — it can then receive
//! Type-I feedback and grow includes; during **inference** it outputs 0 so
//! untrained clauses cannot vote.

use crate::tm::params::TmShape;

/// One booleanised datapoint, bit-packed into literal words.
///
/// Literal `k` for `k < features` is input bit `x_k`; literal
/// `features + k` is its complement `¬x_k`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Input {
    words: Vec<u64>,
    literals: usize,
}

impl Input {
    /// Pack a feature vector (`bits[k]` = feature k) into literal words.
    pub fn pack(shape: &TmShape, bits: &[bool]) -> Self {
        assert_eq!(bits.len(), shape.features, "feature width mismatch");
        let lits = shape.literals();
        let mut words = vec![0u64; shape.words()];
        for k in 0..lits {
            let value = if k < shape.features { bits[k] } else { !bits[k - shape.features] };
            if value {
                words[k / 64] |= 1u64 << (k % 64);
            }
        }
        Input { words, literals: lits }
    }

    /// Packed literal words.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Value of literal `k`.
    #[inline]
    pub fn literal(&self, k: usize) -> bool {
        debug_assert!(k < self.literals);
        self.words[k / 64] & (1u64 << (k % 64)) != 0
    }

    pub fn literals(&self) -> usize {
        self.literals
    }

    /// Dense f32 view (for the L2 HLO inputs).
    pub fn to_dense(&self) -> Vec<f32> {
        (0..self.literals)
            .map(|k| if self.literal(k) { 1.0 } else { 0.0 })
            .collect()
    }
}

/// Evaluation mode: the empty-clause convention differs (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalMode {
    /// Empty clause outputs 1 (used while computing feedback).
    Train,
    /// Empty clause outputs 0 (used for classification votes).
    Infer,
}

/// Evaluate one clause from its packed *effective* (post-fault-gate)
/// include-action words.
///
/// Fires iff every included literal is 1; empty clauses follow `mode`.
#[inline]
pub fn eval_clause(action_words: &[u64], input: &Input, mode: EvalMode) -> bool {
    debug_assert_eq!(action_words.len(), input.words.len());
    let mut any_include = false;
    for (a, l) in action_words.iter().zip(input.words.iter()) {
        if *a == 0 {
            continue; // include-sparse: skip empty action words
        }
        if a & !l != 0 {
            return false; // an included literal is 0
        }
        any_include = true;
    }
    any_include || mode == EvalMode::Train
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TmShape;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    #[test]
    fn pack_sets_feature_and_complement_bits() {
        let s = shape();
        let mut bits = vec![false; 16];
        bits[0] = true;
        bits[5] = true;
        let inp = Input::pack(&s, &bits);
        assert!(inp.literal(0));
        assert!(!inp.literal(1));
        assert!(inp.literal(5));
        // Complements: literal 16+k == !x_k.
        assert!(!inp.literal(16));
        assert!(inp.literal(17));
        assert!(!inp.literal(21));
        // Exactly `features` literals are 1 (each feature contributes one).
        let ones = (0..32).filter(|&k| inp.literal(k)).count();
        assert_eq!(ones, 16);
    }

    #[test]
    fn dense_matches_bits() {
        let s = shape();
        let bits: Vec<bool> = (0..16).map(|k| k % 3 == 0).collect();
        let inp = Input::pack(&s, &bits);
        let d = inp.to_dense();
        assert_eq!(d.len(), 32);
        for (k, &v) in d.iter().enumerate() {
            assert_eq!(v == 1.0, inp.literal(k));
        }
    }

    #[test]
    fn empty_clause_mode_dependent() {
        let s = shape();
        let inp = Input::pack(&s, &vec![true; 16]);
        let actions = vec![0u64; s.words()];
        assert!(eval_clause(&actions, &inp, EvalMode::Train));
        assert!(!eval_clause(&actions, &inp, EvalMode::Infer));
    }

    #[test]
    fn clause_fires_iff_all_included_literals_true() {
        let s = shape();
        let mut bits = vec![false; 16];
        bits[2] = true;
        let inp = Input::pack(&s, &bits);
        // Include literal 2 (x2 = 1) -> fires.
        let actions = vec![1u64 << 2];
        assert!(eval_clause(&actions, &inp, EvalMode::Infer));
        // Include literal 3 as well (x3 = 0) -> blocked.
        let actions = vec![(1u64 << 2) | (1u64 << 3)];
        assert!(!eval_clause(&actions, &inp, EvalMode::Infer));
        // Include complement of x3 (literal 16+3, value 1) -> fires.
        let actions = vec![(1u64 << 2) | (1u64 << 19)];
        assert!(eval_clause(&actions, &inp, EvalMode::Infer));
    }

    #[test]
    fn multiword_inputs() {
        // 40 features -> 80 literals over 2 words.
        let s = TmShape { classes: 1, max_clauses: 2, features: 40, states: 8 };
        let mut bits = vec![true; 40];
        bits[39] = false;
        let inp = Input::pack(&s, &bits);
        assert!(!inp.literal(39));
        assert!(inp.literal(40 + 39)); // complement lives in word 1
        // Clause including complement literal 79 fires.
        let mut actions = vec![0u64; 2];
        actions[1] = 1u64 << (79 - 64);
        assert!(eval_clause(&actions, &inp, EvalMode::Infer));
        // Clause including literal 39 (false) does not.
        let actions = vec![1u64 << 39, 0];
        assert!(!eval_clause(&actions, &inp, EvalMode::Infer));
    }

    /// Property: bit-parallel evaluation agrees with a naive per-literal
    /// loop on random clauses/inputs.
    #[test]
    fn prop_matches_naive_eval() {
        use crate::tm::rng::Xoshiro256;
        let s = shape();
        let mut rng = Xoshiro256::new(0xC1A5);
        for _ in 0..500 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let inp = Input::pack(&s, &bits);
            let include: Vec<bool> = (0..32).map(|_| rng.next_f32() < 0.2).collect();
            let mut words = vec![0u64; s.words()];
            for (k, &inc) in include.iter().enumerate() {
                if inc {
                    words[k / 64] |= 1 << (k % 64);
                }
            }
            let naive_any = include.iter().any(|&i| i);
            let naive_fire =
                include.iter().enumerate().all(|(k, &inc)| !inc || inp.literal(k));
            assert_eq!(
                eval_clause(&words, &inp, EvalMode::Infer),
                naive_any && naive_fire
            );
            assert_eq!(eval_clause(&words, &inp, EvalMode::Train), naive_fire);
        }
    }
}
