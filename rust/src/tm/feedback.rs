//! Type I / Type II feedback — the TM learning rule (§2), canonical
//! semantics (Granmo 2018), shared verbatim with the L2 HLO graph.
//!
//! # Cross-layer contract
//!
//! Given a datapoint `(x, y)`, run-time params `(s, T, active_clauses,
//! active_classes)` and a [`StepRands`] record, a training step is:
//!
//! 1. Evaluate all clauses in **train** mode (empty clause ⇒ 1), with
//!    fault gates applied; per-class sums clamped to `[-T, T]`.
//! 2. Class signs: target class `y` gets `+1`; one uniformly drawn other
//!    active class gets `-1` (from `StepRands::neg_class`); others `0`.
//! 3. For every active clause `j` of a signed class `c`:
//!    - selection probability `p = (T - sign·v_c) / 2T`;
//!      the clause receives feedback iff `clause_rand[c,j] < p`.
//!    - feedback type: `sign · polarity(j)`: `+1` ⇒ Type I, `-1` ⇒ Type II.
//! 4. **Type I** on clause `c,j` (output `o`, literal `l_k`, per-TA draw
//!    `r_k = ta_rand[c,j,k]`):
//!    - `o = 1 ∧ l_k = 1`: increment iff `r_k < (s-1)/s` (or always with
//!      boost_true_positive);
//!    - `o = 1 ∧ l_k = 0`: decrement iff `r_k < 1/s`;
//!    - `o = 0`:           decrement iff `r_k < 1/s`.
//! 5. **Type II** on clause `c,j`: only if `o = 1`; for every literal with
//!    `l_k = 0` whose *effective* (post-fault-gate) action is exclude:
//!    increment (deterministic).
//!
//! All comparisons are strict `<` on `f32`. Increments/decrements saturate.
//! The effective action in step 5 is the RTL view: the feedback logic taps
//! the gated TA output signal, not the state register.
//!
//! Note on the paper's §5.1 remark that low `s` biases toward inaction:
//! under canonical semantics `s = 1` zeroes the *reinforcement*
//! probability `(s-1)/s` (those events become inaction) while weakening
//! events fire at `1/s = 1`; online learning at `s = 1` is therefore
//! driven by Type-II discrimination plus Type-I forgetting, which is what
//! our Fig-4 reproduction exercises.

use crate::tm::clause::{EvalMode, Input};
use crate::tm::machine::MultiTm;
use crate::tm::params::{polarity, TmParams};
use crate::tm::rng::StepRands;

/// Per-class feedback signs for one step: `+1` target, `-1` contrast
/// (negative) class, `0` untouched. Length = `classes` (inactive classes
/// always 0).
pub fn class_signs(
    target: usize,
    rands: &StepRands,
    classes: usize,
    active_classes: usize,
) -> Vec<i8> {
    let mut signs = vec![0i8; classes];
    if target < active_classes {
        signs[target] = 1;
        if let Some(neg) = rands.neg_class(target, active_classes) {
            signs[neg] = -1;
        }
    }
    signs
}

/// Activity counters from one training step — consumed by the FPGA power
/// model (switching activity) and by tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepActivity {
    /// Clauses that received Type I feedback.
    pub type1_clauses: u32,
    /// Clauses that received Type II feedback.
    pub type2_clauses: u32,
    /// TA state increments actually applied (not saturated away).
    pub ta_increments: u32,
    /// TA state decrements actually applied.
    pub ta_decrements: u32,
}

impl StepActivity {
    pub fn total_updates(&self) -> u32 {
        self.ta_increments + self.ta_decrements
    }
}

/// One online/offline training step on a single labelled datapoint.
pub fn train_step(
    tm: &mut MultiTm,
    input: &Input,
    target: usize,
    params: &TmParams,
    rands: &StepRands,
) -> StepActivity {
    let shape = tm.shape().clone();
    // A label outside the active classes (e.g. data for a not-yet-enabled
    // over-provisioned class, §3.1.1) receives no feedback at all:
    // class_signs() yields all-zero signs for it.

    // (1) Evaluate in train mode; clause_out + clamped sums land in scratch.
    tm.evaluate(input, params, EvalMode::Train);

    // (2) Signs.
    let signs = class_signs(target, rands, shape.classes, params.active_classes);

    let two_t = (2 * params.t) as f32;
    let p_reinforce = params.p_reinforce();
    let p_weaken = params.p_weaken();
    let mut act = StepActivity::default();

    for c in 0..params.active_classes {
        let sign = signs[c];
        if sign == 0 {
            continue;
        }
        let v = tm.sums[c] as f32;
        // (3) Selection probability for this class.
        let p_sel = (params.t as f32 - sign as f32 * v) / two_t;
        for j in 0..params.active_clauses {
            if !(rands.clause(&shape, c, j) < p_sel) {
                continue;
            }
            let out = tm.clause_out[c * shape.max_clauses + j];
            if sign as i32 * polarity(j) == 1 {
                // (4) Type I.
                act.type1_clauses += 1;
                if out {
                    for k in 0..shape.literals() {
                        let r = rands.ta(&shape, c, j, k);
                        if input.literal(k) {
                            if r < p_reinforce {
                                let before = tm.ta().state(c, j, k);
                                tm.ta_increment(c, j, k);
                                if tm.ta().state(c, j, k) != before {
                                    act.ta_increments += 1;
                                }
                            }
                        } else if r < p_weaken {
                            let before = tm.ta().state(c, j, k);
                            tm.ta_decrement(c, j, k);
                            if tm.ta().state(c, j, k) != before {
                                act.ta_decrements += 1;
                            }
                        }
                    }
                } else {
                    // out = 0: every TA weakens w.p. p_weaken — no
                    // per-literal test needed (hot-path early-out; same
                    // semantics as the fused branch above).
                    for k in 0..shape.literals() {
                        if rands.ta(&shape, c, j, k) < p_weaken {
                            let before = tm.ta().state(c, j, k);
                            tm.ta_decrement(c, j, k);
                            if tm.ta().state(c, j, k) != before {
                                act.ta_decrements += 1;
                            }
                        }
                    }
                }
            } else {
                // (5) Type II.
                if out {
                    act.type2_clauses += 1;
                    for k in 0..shape.literals() {
                        if !input.literal(k) && !tm.eff_action(c, j, k) {
                            let before = tm.ta().state(c, j, k);
                            tm.ta_increment(c, j, k);
                            if tm.ta().state(c, j, k) != before {
                                act.ta_increments += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    act
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::{TmParams, TmShape};
    use crate::tm::rng::{StepRands, Xoshiro256};

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn mk_input(on: &[usize]) -> Input {
        let mut bits = vec![false; 16];
        for &k in on {
            bits[k] = true;
        }
        Input::pack(&shape(), &bits)
    }

    /// Rands forced so every clause is selected and every TA draw is 0
    /// (all sub-threshold events fire).
    fn all_fire_rands(shape: &TmShape) -> StepRands {
        StepRands {
            clause_rand: vec![-1.0; shape.classes * shape.max_clauses],
            ta_rand: vec![-1.0; shape.classes * shape.max_clauses * shape.literals()],
            neg_class_draw: 0,
        }
    }

    /// Rands forced so no clause is ever selected.
    fn none_fire_rands(shape: &TmShape) -> StepRands {
        StepRands {
            clause_rand: vec![2.0; shape.classes * shape.max_clauses],
            ta_rand: vec![2.0; shape.classes * shape.max_clauses * shape.literals()],
            neg_class_draw: 0,
        }
    }

    #[test]
    fn class_signs_target_and_contrast() {
        let s = shape();
        let mut rng = Xoshiro256::new(8);
        let r = StepRands::draw(&mut rng, &s);
        let signs = class_signs(1, &r, 3, 3);
        assert_eq!(signs[1], 1);
        assert_eq!(signs.iter().filter(|&&x| x == -1).count(), 1);
        assert_eq!(signs.iter().map(|&x| x as i32).sum::<i32>(), 0);
        // Only one active class: no contrast.
        let signs = class_signs(0, &r, 3, 1);
        assert_eq!(signs, vec![1, 0, 0]);
        // Target outside active classes: no feedback at all.
        let signs = class_signs(2, &r, 3, 2);
        assert_eq!(signs, vec![0, 0, 0]);
    }

    #[test]
    fn no_selection_means_no_change() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let before = tm.ta().states().to_vec();
        let act = train_step(&mut tm, &mk_input(&[0, 3]), 0, &p, &none_fire_rands(&s));
        assert_eq!(act, StepActivity::default());
        assert_eq!(tm.ta().states(), &before[..]);
    }

    #[test]
    fn type_i_on_fresh_machine_decrements_zero_literals() {
        // Fresh machine: all clauses empty -> output 1 in train mode.
        // Type I with all draws firing: literals with value 1 get +1
        // (reinforce, prob (s-1)/s>0 fires since draw < p), literals with
        // value 0 get -1.
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let mut p = TmParams::paper_offline(&s); // s=1.375
        p.active_classes = 3;
        let x = mk_input(&[0]); // literal0=1, literals 1..15 =0, compl of 0 =0, compl 1..15 =1
        let r = all_fire_rands(&s);
        train_step(&mut tm, &x, 0, &p, &r);
        // Target class 0, positive clauses (even j) got Type I.
        let init = s.states - 1;
        // literal 0 (value 1): incremented.
        assert_eq!(tm.ta().state(0, 0, 0), init + 1);
        // literal 1 (value 0): decremented.
        assert_eq!(tm.ta().state(0, 0, 1), init - 1);
        // complement of x0 (literal 16, value 0): decremented.
        assert_eq!(tm.ta().state(0, 0, 16), init - 1);
        // complement of x1 (literal 17, value 1): incremented.
        assert_eq!(tm.ta().state(0, 0, 17), init + 1);
    }

    #[test]
    fn type_ii_pushes_zero_literals_toward_include() {
        // Negative-class clauses with positive polarity receive Type II.
        // Fresh machine: clause output 1 (train mode), all excluded, so
        // every 0-valued literal gets +1.
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let x = mk_input(&[0]);
        let r = all_fire_rands(&s); // neg_class_draw=0 -> contrast class deterministic
        let signs = class_signs(0, &r, 3, 3);
        let neg = signs.iter().position(|&x| x == -1).unwrap();
        train_step(&mut tm, &x, 0, &p, &r);
        let init = s.states - 1;
        // Positive clause (j=0) of neg class: Type II.
        // literal 0 (value 1): untouched.
        assert_eq!(tm.ta().state(neg, 0, 0), init);
        // literal 1 (value 0): +1 (crosses into include at 100).
        assert_eq!(tm.ta().state(neg, 0, 1), init + 1);
        assert!(tm.ta().action(neg, 0, 1));
        // Negative clause (j=1) of neg class gets Type I instead:
        // literal 1 (value 0) decremented.
        assert_eq!(tm.ta().state(neg, 1, 1), init - 1);
    }

    #[test]
    fn type_ii_respects_effective_action_under_fault() {
        // A stuck-at-1 TA reads as include to the feedback logic, so
        // Type II must NOT increment it even though its true state is
        // exclude.
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let x = mk_input(&[0]);
        let r = all_fire_rands(&s);
        let neg = class_signs(0, &r, 3, 3).iter().position(|&v| v == -1).unwrap();
        // literal 2 of clause (neg, 0): value 0. Forcing stuck-at-1 makes
        // the clause output 0 though (forced include of a 0-literal), so
        // use literal whose forcing keeps output 1: complement literal 17
        // (value 1) — then check literal 1 (value 0) still gets Type II
        // while the forced literal does not alter anything.
        tm.fault_map_mut().set(neg, 0, 1, crate::tm::fault::Fault::StuckAt1);
        // Forced include of literal 1 (value 0) kills the clause output;
        // Type II then does nothing at all.
        let before = tm.ta().states().to_vec();
        train_step(&mut tm, &x, 0, &p, &r);
        // Clause (neg,0) output was 0 -> no Type II increments there.
        for k in 0..s.literals() {
            assert_eq!(
                tm.ta().state(neg, 0, k),
                before[tm.ta().idx(neg, 0, k)],
                "literal {k} must be untouched"
            );
        }
    }

    #[test]
    fn s_equals_one_never_reinforces() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_online(&s); // s = 1
        let x = mk_input(&[0, 1, 2]);
        let r = all_fire_rands(&s);
        let act = train_step(&mut tm, &x, 0, &p, &r);
        // (s-1)/s = 0 and draws are -1 < 0 == false … strict `<` on 0
        // requires draw < 0, and our forced draws are -1, so reinforcement
        // WOULD fire with forced negative draws. Use draw = 0 to pin the
        // boundary semantics instead.
        let r0 = StepRands {
            clause_rand: vec![-1.0; s.classes * s.max_clauses],
            ta_rand: vec![0.0; s.classes * s.max_clauses * s.literals()],
            neg_class_draw: 0,
        };
        // Canonical style: with draw = 0, reinforce needs 0 < 0 -> never;
        // weaken needs 0 < 1 -> always.
        let mut p_canon = p.clone();
        p_canon.s_style = crate::tm::params::SStyle::Canonical;
        let mut tm2 = MultiTm::new(&s).unwrap();
        let act2 = train_step(&mut tm2, &x, 0, &p_canon, &r0);
        let init = s.states - 1;
        assert_eq!(tm2.ta().state(0, 0, 0), init, "lit=1: no reinforcement at s=1");
        assert_eq!(tm2.ta().state(0, 0, 3), init - 1, "lit=0: weakened (canonical)");
        assert!(act2.ta_increments > 0, "Type II still increments");
        // Inaction-biased style (the paper reading): s = 1 leaves Type I
        // fully inactive — only Type II moves TAs.
        let mut tm3 = MultiTm::new(&s).unwrap();
        let act3 = train_step(&mut tm3, &x, 0, &p, &r0);
        assert_eq!(tm3.ta().state(0, 0, 0), init);
        assert_eq!(tm3.ta().state(0, 0, 3), init, "no Type-I weakening at s=1");
        assert_eq!(act3.ta_decrements, 0);
        assert!(act3.ta_increments > 0, "Type II still fires");
        let _ = act;
    }

    #[test]
    fn boost_true_positive_reinforces_at_s1() {
        let s = shape();
        let mut p = TmParams::paper_online(&s);
        p.boost_true_positive = true;
        let x = mk_input(&[0]);
        let r0 = StepRands {
            clause_rand: vec![-1.0; s.classes * s.max_clauses],
            ta_rand: vec![0.0; s.classes * s.max_clauses * s.literals()],
            neg_class_draw: 0,
        };
        let mut tm = MultiTm::new(&s).unwrap();
        train_step(&mut tm, &x, 0, &p, &r0);
        assert_eq!(tm.ta().state(0, 0, 0), s.states, "boost: 0 < 1 fires");
    }

    #[test]
    fn inactive_clauses_and_classes_get_no_feedback() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let mut p = TmParams::paper_offline(&s);
        p.active_clauses = 4;
        p.active_classes = 2;
        let x = mk_input(&[0]);
        let r = all_fire_rands(&s);
        train_step(&mut tm, &x, 0, &p, &r);
        let init = s.states - 1;
        for j in 4..16 {
            for k in 0..32 {
                assert_eq!(tm.ta().state(0, j, k), init, "gated clause {j} touched");
            }
        }
        for k in 0..32 {
            assert_eq!(tm.ta().state(2, 0, k), init, "inactive class touched");
        }
    }

    #[test]
    fn selection_probability_depends_on_votes() {
        // When class sum saturates at +T for the target, p_sel = 0 and no
        // clause is selected even with draw 0-.
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let mut p = TmParams::paper_offline(&s);
        p.t = 1;
        // Make every positive clause of class 0 fire (include literal 0,
        // x0 = 1) and every negative clause non-empty but blocked (include
        // literal 1, x1 = 0): train-mode sum = +8, clamped to T = 1.
        for j in 0..16 {
            let lit = if j % 2 == 0 { 0 } else { 1 };
            for _ in 0..2 {
                tm.ta_increment(0, j, lit);
            }
        }
        let x = mk_input(&[0]);
        // Draws of exactly 0.0: p_sel for target = (1-1)/2 = 0; 0 < 0 false.
        let r = StepRands {
            clause_rand: vec![0.0; s.classes * s.max_clauses],
            ta_rand: vec![0.0; s.classes * s.max_clauses * s.literals()],
            neg_class_draw: 0,
        };
        let before: Vec<u32> =
            (0..32).flat_map(|k| (0..16).map(move |j| (j, k))).map(|(j, k)| tm.ta().state(0, j, k)).collect();
        train_step(&mut tm, &x, 0, &p, &r);
        // The saturated target class selects nothing (p_sel = 0); the
        // contrast class may still receive feedback.
        let after: Vec<u32> =
            (0..32).flat_map(|k| (0..16).map(move |j| (j, k))).map(|(j, k)| tm.ta().state(0, j, k)).collect();
        assert_eq!(before, after, "target class must be untouched at p_sel = 0");
    }

    /// Property: training never moves a state outside the legal range and
    /// the action cache stays coherent (checked via rebuild).
    #[test]
    fn prop_training_preserves_invariants() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0xBEEF);
        for step in 0..2000 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&s, &bits);
            let r = StepRands::draw(&mut rng, &s);
            train_step(&mut tm, &x, step % 3, &p, &r);
        }
        assert!(tm.ta().states().iter().all(|&v| v <= s.max_state()));
        let mut tm2 = tm.clone();
        tm2.rebuild_actions();
        assert_eq!(tm.action_words(0, 0), tm2.action_words(0, 0));
        for c in 0..3 {
            for j in 0..16 {
                assert_eq!(tm.action_words(c, j), tm2.action_words(c, j));
            }
        }
    }

    /// Property: feedback is monotone in expectation — training repeatedly
    /// on one labelled point makes the machine predict it.
    #[test]
    fn prop_single_point_converges() {
        let s = shape();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x5EED);
        let x = mk_input(&[0, 4, 8, 12]);
        for _ in 0..300 {
            let r = StepRands::draw(&mut rng, &s);
            train_step(&mut tm, &x, 2, &p, &r);
        }
        let (sums, pred) = tm.infer(&x, &p);
        assert_eq!(pred, 2, "sums were {sums:?}");
        assert!(sums[2] > sums[0] && sums[2] > sums[1]);
    }
}
