//! Stuck-at fault injection on TA action outputs (paper §3.1.2).
//!
//! The RTL adds an AND and an OR gate to every TA's action output:
//!
//! ```text
//! effective_action = (action AND and_bit) OR or_bit
//! ```
//!
//! `and_bit = 1, or_bit = 0` is fault-free; `and_bit = 0` forces stuck-at-0
//! and `or_bit = 1` forces stuck-at-1. A fault-controller module holds the
//! two mappings, individually addressable per TA, writable at run time
//! (from the microcontroller over AXI in the RTL model) so fault
//! configurations need no re-synthesis.
//!
//! [`FaultMap`] is the packed (one bit per TA, `u64` words per clause row)
//! software twin of those gate mappings. The identical masks are also fed
//! to the L2 HLO graph as tensors, so the lowered artifact reproduces the
//! gate-level behaviour — see `python/compile/model.py`.

use crate::tm::params::TmShape;
use crate::tm::rng::Xoshiro256;
use anyhow::{bail, Result};

/// Kind of stuck-at fault on one TA output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fault-free: AND mask 1, OR mask 0.
    None,
    /// Output forced to 0 (AND mask 0).
    StuckAt0,
    /// Output forced to 1 (OR mask 1).
    StuckAt1,
}

/// Per-TA AND/OR gate mappings, bit-packed per clause row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultMap {
    shape: TmShape,
    /// `and_words[row * words + w]`; row = class * max_clauses + clause.
    and_words: Vec<u64>,
    /// Same layout as `and_words`.
    or_words: Vec<u64>,
    /// Number of faulty TAs — kept exact by [`FaultMap::set`] so the hot
    /// path can branch on `is_fault_free()` in O(1).
    faulty: usize,
}

impl FaultMap {
    /// Fault-free map: all AND bits 1 (within the literal width), OR bits 0.
    pub fn none(shape: &TmShape) -> Self {
        let rows = shape.classes * shape.max_clauses;
        let words = shape.words();
        let mut and_words = vec![0u64; rows * words];
        for row in 0..rows {
            for w in 0..words {
                and_words[row * words + w] = Self::width_mask(shape, w);
            }
        }
        FaultMap { shape: shape.clone(), and_words, or_words: vec![0u64; rows * words], faulty: 0 }
    }

    /// Bits of word `w` that correspond to real literals (the rest stay 0
    /// so padding never leaks into clause evaluation). Defensive zero for
    /// fully-out-of-range words; in-range words share the one tail-mask
    /// definition ([`crate::tm::params::word_mask`]).
    fn width_mask(shape: &TmShape, w: usize) -> u64 {
        let lits = shape.literals();
        if w * 64 >= lits {
            0
        } else {
            crate::tm::params::word_mask(lits, w)
        }
    }

    #[inline]
    fn row(&self, class: usize, clause: usize) -> usize {
        debug_assert!(class < self.shape.classes && clause < self.shape.max_clauses);
        class * self.shape.max_clauses + clause
    }

    /// Gate mappings (AND word, OR word) for one clause row / word index.
    #[inline]
    pub fn masks(&self, class: usize, clause: usize, word: usize) -> (u64, u64) {
        let i = self.row(class, clause) * self.shape.words() + word;
        (self.and_words[i], self.or_words[i])
    }

    /// Apply the gates to a packed action word:
    /// `(action & and_mask) | or_mask`.
    #[inline]
    pub fn apply(&self, class: usize, clause: usize, word: usize, action: u64) -> u64 {
        let (a, o) = self.masks(class, clause, word);
        (action & a) | o
    }

    /// Program one TA's fault gates (the fault controller's addressable
    /// write port).
    pub fn set(&mut self, class: usize, clause: usize, lit: usize, fault: Fault) {
        assert!(lit < self.shape.literals(), "literal {lit} out of range");
        let was_faulty = self.get(class, clause, lit) != Fault::None;
        let now_faulty = fault != Fault::None;
        match (was_faulty, now_faulty) {
            (false, true) => self.faulty += 1,
            (true, false) => self.faulty -= 1,
            _ => {}
        }
        let i = self.row(class, clause) * self.shape.words() + lit / 64;
        let bit = 1u64 << (lit % 64);
        match fault {
            Fault::None => {
                self.and_words[i] |= bit;
                self.or_words[i] &= !bit;
            }
            Fault::StuckAt0 => {
                self.and_words[i] &= !bit;
                self.or_words[i] &= !bit;
            }
            Fault::StuckAt1 => {
                self.and_words[i] |= bit;
                self.or_words[i] |= bit;
            }
        }
    }

    /// Read one TA's programmed fault.
    pub fn get(&self, class: usize, clause: usize, lit: usize) -> Fault {
        let i = self.row(class, clause) * self.shape.words() + lit / 64;
        let bit = 1u64 << (lit % 64);
        let and = self.and_words[i] & bit != 0;
        let or = self.or_words[i] & bit != 0;
        match (and, or) {
            (true, false) => Fault::None,
            (false, _) => Fault::StuckAt0,
            (true, true) => Fault::StuckAt1,
        }
    }

    /// Number of faulty TAs (O(1) — maintained by [`FaultMap::set`]).
    pub fn count(&self) -> usize {
        self.faulty
    }

    /// Recount from the gate words (test/debug cross-check of the
    /// maintained counter).
    pub fn recount(&self) -> usize {
        let mut n = 0;
        for c in 0..self.shape.classes {
            for j in 0..self.shape.max_clauses {
                for k in 0..self.shape.literals() {
                    if self.get(c, j, k) != Fault::None {
                        n += 1;
                    }
                }
            }
        }
        n
    }

    /// O(1) — the hot path branches on this to skip gate application.
    pub fn is_fault_free(&self) -> bool {
        self.faulty == 0
    }

    /// The paper's §5.3.1 fault pattern: an **equal spread** of stuck-at
    /// faults across `fraction` of all TAs ("a Python script was created
    /// and used to create an equal spread of fault mappings across the
    /// TAs"). We pick `round(fraction * num_tas)` distinct TAs via a
    /// seeded shuffle — even in expectation across classes/clauses/
    /// literals — and program each with `fault`.
    pub fn even_spread(shape: &TmShape, fraction: f64, fault: Fault, seed: u64) -> Result<Self> {
        if !(0.0..=1.0).contains(&fraction) {
            bail!("fault fraction must be in [0,1], got {fraction}");
        }
        let mut map = Self::none(shape);
        let n = shape.num_tas();
        let k = (fraction * n as f64).round() as usize;
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Xoshiro256::new(seed);
        rng.shuffle(&mut idx);
        let lits = shape.literals();
        for &flat in idx.iter().take(k) {
            let lit = flat % lits;
            let clause = (flat / lits) % shape.max_clauses;
            let class = flat / (lits * shape.max_clauses);
            map.set(class, clause, lit, fault);
        }
        Ok(map)
    }

    /// Raw gate words `(and_words, or_words)` in row-major layout
    /// (`[row * words + w]`) — the serve-checkpoint payload view.
    pub fn words(&self) -> (&[u64], &[u64]) {
        (&self.and_words, &self.or_words)
    }

    /// Rebuild a map from raw gate words (checkpoint restore). Rejects
    /// wrong lengths, gate bits escaping the literal width, and the
    /// unreachable `(and = 0, or = 1)` encoding — [`FaultMap::set`] never
    /// writes it, and `apply` and `get` would disagree on its meaning —
    /// then recounts `faulty` from scratch so the O(1) counter is exact.
    pub fn from_words(shape: &TmShape, and_words: Vec<u64>, or_words: Vec<u64>) -> Result<Self> {
        let rows = shape.classes * shape.max_clauses;
        let words = shape.words();
        if and_words.len() != rows * words || or_words.len() != rows * words {
            bail!(
                "FaultMap::from_words: want {} words per plane, got {} and / {} or",
                rows * words,
                and_words.len(),
                or_words.len()
            );
        }
        let mut faulty = 0usize;
        for row in 0..rows {
            for w in 0..words {
                let i = row * words + w;
                let width = Self::width_mask(shape, w);
                let (a, o) = (and_words[i], or_words[i]);
                if a & !width != 0 || o & !width != 0 {
                    bail!("FaultMap::from_words: gate bits escape the literal width (row {row} word {w})");
                }
                if o & !a != 0 {
                    bail!("FaultMap::from_words: inconsistent (and=0, or=1) gate encoding (row {row} word {w})");
                }
                // StuckAt0 = cleared AND bit; StuckAt1 = set OR bit.
                faulty += ((width & !a) | o).count_ones() as usize;
            }
        }
        Ok(FaultMap { shape: shape.clone(), and_words, or_words, faulty })
    }

    /// Dense boolean views for the L2 HLO inputs (`[classes, clauses,
    /// literals]`, row-major, 1.0 = gate bit set).
    pub fn to_dense(&self) -> (Vec<f32>, Vec<f32>) {
        let mut and_d = Vec::with_capacity(self.shape.num_tas());
        let mut or_d = Vec::with_capacity(self.shape.num_tas());
        for c in 0..self.shape.classes {
            for j in 0..self.shape.max_clauses {
                for k in 0..self.shape.literals() {
                    let i = self.row(c, j) * self.shape.words() + k / 64;
                    let bit = 1u64 << (k % 64);
                    and_d.push(if self.and_words[i] & bit != 0 { 1.0 } else { 0.0 });
                    or_d.push(if self.or_words[i] & bit != 0 { 1.0 } else { 0.0 });
                }
            }
        }
        (and_d, or_d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    #[test]
    fn fault_free_is_identity() {
        let m = FaultMap::none(&shape());
        assert!(m.is_fault_free());
        let action = 0xDEAD_BEEFu64 & 0xFFFF_FFFF; // 32 literals
        assert_eq!(m.apply(0, 0, 0, action), action);
    }

    #[test]
    fn stuck_at_0_forces_zero() {
        let mut m = FaultMap::none(&shape());
        m.set(1, 2, 5, Fault::StuckAt0);
        assert_eq!(m.get(1, 2, 5), Fault::StuckAt0);
        let all_on = (1u64 << 32) - 1;
        let out = m.apply(1, 2, 0, all_on);
        assert_eq!(out & (1 << 5), 0);
        assert_eq!(out | (1 << 5), all_on);
        // Other rows untouched.
        assert_eq!(m.apply(1, 3, 0, all_on), all_on);
    }

    #[test]
    fn stuck_at_1_forces_one() {
        let mut m = FaultMap::none(&shape());
        m.set(0, 0, 31, Fault::StuckAt1);
        assert_eq!(m.get(0, 0, 31), Fault::StuckAt1);
        let out = m.apply(0, 0, 0, 0);
        assert_eq!(out, 1 << 31);
    }

    #[test]
    fn clearing_restores_fault_free() {
        let mut m = FaultMap::none(&shape());
        m.set(2, 7, 0, Fault::StuckAt1);
        m.set(2, 7, 1, Fault::StuckAt0);
        assert_eq!(m.count(), 2);
        m.set(2, 7, 0, Fault::None);
        m.set(2, 7, 1, Fault::None);
        assert!(m.is_fault_free());
    }

    #[test]
    fn counter_matches_recount() {
        let s = shape();
        let mut m = FaultMap::none(&s);
        assert_eq!(m.count(), m.recount());
        m.set(0, 0, 0, Fault::StuckAt0);
        m.set(0, 0, 0, Fault::StuckAt0); // idempotent re-set
        m.set(1, 2, 3, Fault::StuckAt1);
        m.set(1, 2, 3, Fault::StuckAt0); // swap kind, still one fault
        assert_eq!(m.count(), 2);
        assert_eq!(m.count(), m.recount());
        m.set(0, 0, 0, Fault::None);
        assert_eq!(m.count(), 1);
        assert_eq!(m.count(), m.recount());
    }

    #[test]
    fn even_spread_hits_requested_fraction() {
        let s = shape();
        let m = FaultMap::even_spread(&s, 0.20, Fault::StuckAt0, 42).unwrap();
        let expect = (0.20 * s.num_tas() as f64).round() as usize;
        assert_eq!(m.count(), expect);
        assert_eq!(m.count(), m.recount());
        // All injected faults are the requested kind.
        for c in 0..s.classes {
            for j in 0..s.max_clauses {
                for k in 0..s.literals() {
                    assert_ne!(m.get(c, j, k), Fault::StuckAt1);
                }
            }
        }
    }

    #[test]
    fn even_spread_is_spread_across_classes() {
        let s = shape();
        let m = FaultMap::even_spread(&s, 0.20, Fault::StuckAt0, 7).unwrap();
        // With 307 faults over 3 classes, each class should hold a
        // non-trivial share (loose bound: > 1/6 of total each).
        for c in 0..s.classes {
            let mut n = 0;
            for j in 0..s.max_clauses {
                for k in 0..s.literals() {
                    if m.get(c, j, k) != Fault::None {
                        n += 1;
                    }
                }
            }
            assert!(n > m.count() / 6, "class {c} got only {n} faults");
        }
    }

    #[test]
    fn even_spread_rejects_bad_fraction() {
        assert!(FaultMap::even_spread(&shape(), 1.5, Fault::StuckAt0, 0).is_err());
        assert!(FaultMap::even_spread(&shape(), -0.1, Fault::StuckAt0, 0).is_err());
    }

    #[test]
    fn even_spread_deterministic_per_seed() {
        let s = shape();
        let a = FaultMap::even_spread(&s, 0.1, Fault::StuckAt1, 5).unwrap();
        let b = FaultMap::even_spread(&s, 0.1, Fault::StuckAt1, 5).unwrap();
        let c = FaultMap::even_spread(&s, 0.1, Fault::StuckAt1, 6).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn dense_view_roundtrips() {
        let s = shape();
        let mut m = FaultMap::none(&s);
        m.set(0, 1, 2, Fault::StuckAt0);
        m.set(2, 15, 31, Fault::StuckAt1);
        let (and_d, or_d) = m.to_dense();
        assert_eq!(and_d.len(), s.num_tas());
        let at = |c: usize, j: usize, k: usize| (c * 16 + j) * 32 + k;
        assert_eq!(and_d[at(0, 1, 2)], 0.0);
        assert_eq!(or_d[at(0, 1, 2)], 0.0);
        assert_eq!(and_d[at(2, 15, 31)], 1.0);
        assert_eq!(or_d[at(2, 15, 31)], 1.0);
        assert_eq!(and_d[at(1, 0, 0)], 1.0);
    }

    #[test]
    fn words_roundtrip_preserves_everything() {
        let s = shape();
        let mut m = FaultMap::even_spread(&s, 0.15, Fault::StuckAt0, 9).unwrap();
        m.set(1, 3, 7, Fault::StuckAt1);
        let (a, o) = m.words();
        let back = FaultMap::from_words(&s, a.to_vec(), o.to_vec()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.count(), back.recount());
    }

    #[test]
    fn from_words_rejects_bad_input() {
        let s = shape();
        let m = FaultMap::none(&s);
        let (a, o) = m.words();
        // Wrong length.
        assert!(FaultMap::from_words(&s, a[1..].to_vec(), o.to_vec()).is_err());
        // Padding escape: iris rows are 32 literals wide, bit 40 is pad.
        let mut bad_or = o.to_vec();
        bad_or[0] = 1u64 << 40;
        assert!(FaultMap::from_words(&s, a.to_vec(), bad_or).is_err());
        // Inconsistent (and=0, or=1) encoding within the width.
        let mut bad_a = a.to_vec();
        let mut bad_o = o.to_vec();
        bad_a[0] &= !1u64;
        bad_o[0] |= 1u64;
        assert!(FaultMap::from_words(&s, bad_a, bad_o).is_err());
    }

    #[test]
    fn width_mask_handles_padding() {
        // 40 features -> 80 literals -> 2 words, second word half-used.
        let s = TmShape { classes: 1, max_clauses: 2, features: 40, states: 8 };
        let m = FaultMap::none(&s);
        let (a0, _) = m.masks(0, 0, 0);
        let (a1, _) = m.masks(0, 0, 1);
        assert_eq!(a0, u64::MAX);
        assert_eq!(a1, (1u64 << 16) - 1);
        // Faulty stuck-at-1 never escapes literal width either.
        let mut m = FaultMap::none(&s);
        m.set(0, 0, 79, Fault::StuckAt1);
        assert_eq!(m.apply(0, 0, 1, 0) >> 16, 0);
    }
}
