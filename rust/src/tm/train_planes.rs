//! Lane-speculative 64-wide training — the training-side twin of the
//! sample-sliced inference kernel (`tm::bitplane`, PR 2) and the
//! dirty-clause re-scorer (`tm::rescore`, PR 3).
//!
//! The paper's T-threshold makes feedback — and therefore TA action
//! flips — increasingly rare as the machine converges, yet every
//! training step still pays a full clause evaluation: MATADOR
//! (arXiv 2403.10538) and the runtime-tunable eFPGA TM
//! (arXiv 2502.07823) both observe that clause evaluation, not
//! feedback, dominates TM training cost. This module amortizes that
//! evaluation across a 64-sample lane:
//!
//! 1. **Speculate**: for one `BitPlanes` lane, compute every active
//!    clause's fired-mask in one bit-sliced pass (the shared
//!    [`clause_fired_mask`] AND kernel) and tally per-sample *unclamped*
//!    vote totals through the shared ripple-carry adder ([`add_mask`]).
//! 2. **Walk**: visit the lane's samples strictly in order, reading each
//!    sample's clause outputs and class sums out of the precomputed
//!    masks/totals and applying feedback exactly as the scalar engine
//!    would — same comparisons, same `apply_word_feedback` word
//!    sequence, same randomness consumption.
//! 3. **Repair**: when a feedback application flips any include/exclude
//!    action bit (observable as a [`MultiTm::row_rev`] move, stamped by
//!    the `TaBlock::update_word` flip masks from PR 3), only the flipped
//!    clauses' fired-masks are re-ANDed and the vote totals patched by
//!    delta — for the *remaining* samples of the lane only.
//!
//! The result is **bit-identical** to running the scalar step
//! sample-by-sample with the same randomness — eager
//! ([`MultiTm::train_plane_batch`] vs a `train_step_fast` loop given the
//! same per-sample [`StepRands`]) and lazy
//! ([`MultiTm::train_plane_batch_lazy`] vs a `train_step_lazy` loop
//! given the same generator) — while the common converged case (zero
//! flips in a lane) pays one batched evaluation instead of 64 scalar
//! ones. `rust/tests/integration_train_planes.rs` is the differential
//! proof across non-×64 tails, mid-lane flip repair under low-T
//! configs, fault/force injection between batches, and clones.
//!
//! Correctness rests on three invariants of the scalar step:
//!
//! - a step's clause outputs and class sums are snapshotted *before* any
//!   of its feedback is applied (the scalar engine evaluates first), so
//!   deferring repair to the end of each sample cannot be observed;
//! - each active clause receives at most one feedback application per
//!   step, and Type II reads only its *own* clause's live action words,
//!   so intra-step liveness reduces to prior-step state;
//! - a clause's fired-mask can change mid-lane only through an action
//!   flip (training never edits force gates or fault maps), and every
//!   flip stamps the mutation clock — so `row_rev` is a sound, complete
//!   dirtiness signal for the lane's speculative state.

use crate::tm::bitplane::{add_mask, clause_fired_mask, BitPlanes};
use crate::tm::clause::Input;
use crate::tm::engine::{EpochStats, FeedbackPlan};
use crate::tm::machine::MultiTm;
use crate::tm::params::{polarity, word_mask, TmParams};
use crate::tm::rng::{neg_class_from_draw, StepRands, Xoshiro256};

/// Reusable scratch for the training hot paths: the per-step sign
/// buffer the scalar engines used to allocate per call (hoisted here —
/// see `train_step_fast_with` / `train_step_lazy_with`), the eager
/// randomness record, and the lane-speculative state (fired-masks,
/// unclamped vote totals, ripple counters, effective-literal and repair
/// buffers). One scratch serves machines of any shape back to back:
/// every buffer is re-sized on entry and fully rewritten before use.
///
/// Also carries the lane engine's observability counters
/// ([`TrainScratch::lane_flips`] / [`TrainScratch::lanes_walked`]):
/// mean flips per lane is the quantity that decides whether the
/// speculative batch pays off, and the perf_table training scenario
/// prints it next to the measured speedup.
#[derive(Debug, Clone, Default)]
pub struct TrainScratch {
    /// Per-step class signs (`+1` target, `-1` contrast, `0` untouched).
    signs: Vec<i8>,
    /// Eager per-sample randomness record, refilled by the caller's
    /// provider; `None` until first eager use (the lazy path never
    /// touches it).
    pub(crate) rands: Option<StepRands>,
    /// Current lane's fired-masks, `[c * active_clauses + j]`.
    fired: Vec<u64>,
    /// Current lane's unclamped vote totals, `[c * 64 + sample_bit]`.
    totals: Vec<i32>,
    /// Bit-sliced ripple counters for the speculative tally.
    pos: Vec<u64>,
    neg: Vec<u64>,
    /// Effective included literal indices of the clause being (re)ANDed.
    lits: Vec<u32>,
    /// Clauses fed back during the current step: `(class, clause,
    /// row_rev before feedback)` — the repair worklist.
    touched: Vec<(u32, u32, u64)>,
    /// Cumulative flip-repair events (one per clause whose actions
    /// flipped during a walked sample).
    lane_flips: u64,
    /// Cumulative 64-sample lanes walked.
    lanes_walked: u64,
}

impl TrainScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch whose eager randomness record is pre-drawn from `rng` —
    /// the drivers' historical `StepRands::draw` + per-step `refill`
    /// discipline. Constructing the scratch this way consumes exactly
    /// the draws the old per-step loops consumed before their first
    /// refill, so wiring the lane engine into an existing driver moves
    /// no trajectory.
    pub fn seeded(rng: &mut Xoshiro256, shape: &crate::tm::params::TmShape) -> Self {
        let mut s = Self::new();
        s.rands = Some(StepRands::draw(rng, shape));
        s
    }

    /// Flip-repair events observed so far (cumulative across batches).
    pub fn lane_flips(&self) -> u64 {
        self.lane_flips
    }

    /// 64-sample lanes walked so far (cumulative across batches).
    pub fn lanes_walked(&self) -> u64 {
        self.lanes_walked
    }

    /// Mean flip repairs per walked lane — the quantity the speculative
    /// engine bets on being near zero at convergence.
    pub fn mean_flips_per_lane(&self) -> f64 {
        if self.lanes_walked == 0 {
            0.0
        } else {
            self.lane_flips as f64 / self.lanes_walked as f64
        }
    }

    /// Zero the observability counters (buffers are unaffected).
    pub fn reset_counters(&mut self) {
        self.lane_flips = 0;
        self.lanes_walked = 0;
    }

    /// Per-step sign buffer of length `classes`, zeroed.
    pub(crate) fn signs_mut(&mut self, classes: usize) -> &mut [i8] {
        self.signs.clear();
        self.signs.resize(classes, 0);
        &mut self.signs
    }

    /// Take the eager randomness record, reallocating when the shape
    /// moved (a scratch can serve differently-shaped machines in turn).
    fn take_rands(&mut self, shape: &crate::tm::params::TmShape) -> StepRands {
        let nc = shape.classes * shape.max_clauses;
        let nt = nc * shape.literals();
        match self.rands.take() {
            Some(r) if r.clause_rand.len() == nc && r.ta_rand.len() == nt => r,
            _ => StepRands {
                clause_rand: vec![0.0; nc],
                ta_rand: vec![0.0; nt],
                neg_class_draw: 0,
            },
        }
    }

    /// Size every lane buffer for one walk and clear the worklist.
    fn ensure(&mut self, classes: usize, nc: usize, najc: usize, width: usize) {
        self.signs.clear();
        self.signs.resize(classes, 0);
        self.fired.clear();
        self.fired.resize(nc * najc, 0);
        self.totals.clear();
        self.totals.resize(nc * 64, 0);
        self.pos.clear();
        self.pos.resize(width, 0);
        self.neg.clear();
        self.neg.resize(width, 0);
        self.touched.clear();
        self.lits.clear();
    }
}

/// The per-step randomness discipline of a lane walk. The walker is
/// written once against this trait; the eager implementation reads a
/// caller-provided [`StepRands`] record positionally (bit-identity with
/// `train_step_fast`), the lazy one consumes a generator in exactly the
/// decision order `train_step_lazy` does (bit-identity with it).
trait StepDraws {
    /// Lazy skips a signed class's per-clause selection draws entirely
    /// when `p_sel <= 0`; eager reads are positional and must not skip
    /// (forced test records can hold negative draws that select at
    /// `p_sel = 0`, exactly like the scalar engines).
    const SKIPS_NONPOSITIVE_PSEL: bool;
    /// Prepare sample `i`'s randomness (eager: refill the record).
    fn begin(&mut self, i: usize);
    /// The contrast-class draw — called only when the target class is
    /// active, matching both scalar paths' consumption.
    fn neg_draw(&mut self) -> u64;
    /// Type I is entirely inert (lazy plan with both event
    /// probabilities quantised to zero); eager always applies masks.
    fn type1_inert(&self) -> bool;
    /// Clause-selection draw for `(c, j)`.
    fn clause(&mut self, c: usize, j: usize) -> f32;
    /// `(reinforce, weaken)` masks for the `n` literals starting at
    /// `lo` of clause `(c, j)`; `out` is the clause output (the lazy
    /// path draws only the weaken mask when `out = 0`).
    fn type1_masks(&mut self, c: usize, j: usize, lo: usize, n: usize, out: bool)
        -> (u64, u64);
}

/// Eager discipline: every value comes out of a [`StepRands`] record
/// the provider refills per sample. Reads consume nothing, so mask
/// computation is identical whatever the clause output — exactly like
/// `train_step_fast`.
struct EagerDraws<'a, F: FnMut(usize, &mut StepRands)> {
    shape: &'a crate::tm::params::TmShape,
    rands: StepRands,
    fill: F,
    p_reinforce: f32,
    p_weaken: f32,
}

impl<F: FnMut(usize, &mut StepRands)> StepDraws for EagerDraws<'_, F> {
    const SKIPS_NONPOSITIVE_PSEL: bool = false;

    #[inline]
    fn begin(&mut self, i: usize) {
        (self.fill)(i, &mut self.rands);
    }

    #[inline]
    fn neg_draw(&mut self) -> u64 {
        self.rands.neg_class_draw
    }

    #[inline]
    fn type1_inert(&self) -> bool {
        false
    }

    #[inline]
    fn clause(&mut self, c: usize, j: usize) -> f32 {
        self.rands.clause(self.shape, c, j)
    }

    #[inline]
    fn type1_masks(
        &mut self,
        c: usize,
        j: usize,
        lo: usize,
        n: usize,
        _out: bool,
    ) -> (u64, u64) {
        let (mut reinforce, mut weaken) = (0u64, 0u64);
        for k in 0..n {
            let r = self.rands.ta(self.shape, c, j, lo + k);
            if r < self.p_reinforce {
                reinforce |= 1u64 << k;
            }
            if r < self.p_weaken {
                weaken |= 1u64 << k;
            }
        }
        (reinforce, weaken)
    }
}

/// Lazy discipline: draws come off the generator in `train_step_lazy`'s
/// canonical decision order — neg-class word, per-clause selection
/// uniforms of the signed classes only (skipped wholesale at
/// `p_sel <= 0`), then bit-sliced Bernoulli masks only for selected
/// Type-I clauses.
struct LazyDraws<'a> {
    plan: &'a FeedbackPlan,
    rng: &'a mut Xoshiro256,
}

impl StepDraws for LazyDraws<'_> {
    const SKIPS_NONPOSITIVE_PSEL: bool = true;

    #[inline]
    fn begin(&mut self, _i: usize) {}

    #[inline]
    fn neg_draw(&mut self) -> u64 {
        self.rng.next_u64()
    }

    #[inline]
    fn type1_inert(&self) -> bool {
        self.plan.type1_inert()
    }

    #[inline]
    fn clause(&mut self, _c: usize, _j: usize) -> f32 {
        self.rng.next_f32()
    }

    #[inline]
    fn type1_masks(
        &mut self,
        _c: usize,
        _j: usize,
        _lo: usize,
        _n: usize,
        out: bool,
    ) -> (u64, u64) {
        if out {
            self.plan.masks(self.rng)
        } else {
            // out = 0 consults only the weaken event — same draw
            // economy as train_step_lazy.
            (0, self.plan.weaken_mask(self.rng))
        }
    }
}

fn row_input(r: &(Input, usize)) -> &Input {
    &r.0
}

fn row_label(r: &(Input, usize)) -> usize {
    r.1
}

/// THE per-step sign rule, in one place: `+1` on an active target, `-1`
/// on the contrast class picked from one draw (`draw` is consulted only
/// when the target is active — the lazy path's draw economy). `signs`
/// must arrive zeroed. Shared by the lane walker and both `_with` step
/// engines so the contrast-class rule cannot drift between them.
#[inline]
pub(crate) fn fill_signs(
    signs: &mut [i8],
    target: usize,
    active: usize,
    draw: impl FnOnce() -> u64,
) {
    if target < active {
        signs[target] = 1;
        if let Some(neg) = neg_class_from_draw(draw(), target, active) {
            signs[neg] = -1;
        }
    }
}

impl MultiTm {
    /// Lane-speculative eager training over a transposed batch:
    /// **bit-identical** to
    ///
    /// ```ignore
    /// for i in 0..rows.len() {
    ///     fill(i, &mut rands);
    ///     train_step_fast(tm, &rows[i].0, rows[i].1, params, &rands);
    /// }
    /// ```
    ///
    /// given the same per-sample records — TA states, action caches,
    /// activity counts and mutation-clock stamps all agree
    /// (`rust/tests/integration_train_planes.rs`). `planes` must be the
    /// transpose of `rows`' inputs (checked bit-for-bit in debug
    /// builds). The provider is called once per sample, in order, so a
    /// sequential-refill provider reproduces the drivers' historical
    /// rng stream and a keyed provider (serve updates) stays
    /// order-independent.
    pub fn train_plane_batch(
        &mut self,
        rows: &[(Input, usize)],
        planes: &BitPlanes,
        params: &TmParams,
        fill: impl FnMut(usize, &mut StepRands),
        scratch: &mut TrainScratch,
    ) -> EpochStats {
        self.train_plane_batch_by(rows, row_input, row_label, planes, params, fill, scratch)
    }

    /// [`MultiTm::train_plane_batch`] over arbitrary row types — the
    /// serve workers feed coalesced `Arc<ShardUpdate>` Learn runs
    /// through this without cloning their inputs.
    pub fn train_plane_batch_by<T>(
        &mut self,
        items: &[T],
        input_of: fn(&T) -> &Input,
        label_of: fn(&T) -> usize,
        planes: &BitPlanes,
        params: &TmParams,
        fill: impl FnMut(usize, &mut StepRands),
        scratch: &mut TrainScratch,
    ) -> EpochStats {
        let shape = self.shape().clone();
        let rands = scratch.take_rands(&shape);
        let mut draws = EagerDraws {
            shape: &shape,
            rands,
            fill,
            p_reinforce: params.p_reinforce(),
            p_weaken: params.p_weaken(),
        };
        let stats =
            walk_lanes(self, items, input_of, label_of, planes, params, &mut draws, scratch);
        scratch.rands = Some(draws.rands);
        stats
    }

    /// Lane-speculative lazy training: **bit-identical** to a
    /// `train_step_lazy` loop over the same rows with the same plan and
    /// generator — this is what [`MultiTm::train_epoch`] runs on.
    pub fn train_plane_batch_lazy(
        &mut self,
        rows: &[(Input, usize)],
        planes: &BitPlanes,
        params: &TmParams,
        plan: &FeedbackPlan,
        rng: &mut Xoshiro256,
        scratch: &mut TrainScratch,
    ) -> EpochStats {
        let mut draws = LazyDraws { plan, rng };
        walk_lanes(self, rows, row_input, row_label, planes, params, &mut draws, scratch)
    }
}

/// Train `rows` through the lane engine under the deterministic
/// drivers' sequential-refill discipline — bit-identical to
///
/// ```ignore
/// for (x, y) in rows {
///     rands.refill(rng, &shape);
///     train_step_fast(tm, x, *y, params, &rands);
/// }
/// ```
///
/// (`fpga::system`, `coordinator::{monitor, sweep, replay}` all ran
/// exactly that loop; they now run this). Pair with
/// [`TrainScratch::seeded`] to reproduce the historical
/// `StepRands::draw`-before-the-loop consumption.
pub fn train_rows_seq(
    tm: &mut MultiTm,
    rows: &[(Input, usize)],
    planes: &BitPlanes,
    params: &TmParams,
    rng: &mut Xoshiro256,
    scratch: &mut TrainScratch,
) -> EpochStats {
    let shape = tm.shape().clone();
    tm.train_plane_batch(rows, planes, params, |_, r| r.refill(rng, &shape), scratch)
}

/// The lane walker: speculate, walk, repair — once per 64-sample lane.
#[allow(clippy::too_many_arguments)]
fn walk_lanes<T, D: StepDraws>(
    tm: &mut MultiTm,
    items: &[T],
    input_of: fn(&T) -> &Input,
    label_of: fn(&T) -> usize,
    planes: &BitPlanes,
    params: &TmParams,
    draws: &mut D,
    scratch: &mut TrainScratch,
) -> EpochStats {
    let shape = tm.shape().clone();
    assert_eq!(
        planes.literals(),
        shape.literals(),
        "plane/machine literal width mismatch"
    );
    assert_eq!(planes.len(), items.len(), "plane/row count mismatch");
    let mut stats = EpochStats::default();
    let nc = params.active_classes;
    let najc = params.active_clauses;
    if items.is_empty() || nc == 0 {
        return stats;
    }
    // The planes must be the transpose of the rows — a desynced pair
    // would silently train on wrong clause outputs. Full bit check in
    // debug builds only (O(rows × literals)).
    #[cfg(debug_assertions)]
    for (i, it) in items.iter().enumerate() {
        let x = input_of(it);
        for k in 0..shape.literals() {
            debug_assert_eq!(
                planes.literal(k, i),
                x.literal(k),
                "planes desynced from rows at sample {i}, literal {k}"
            );
        }
    }
    let t = params.t;
    let two_t = (2 * t) as f32;
    let lits = shape.literals();
    let max_clauses = shape.max_clauses;
    let fault_free = tm.fault().is_fault_free();
    // Counter width: enough bits for `active_clauses / 2` fired clauses
    // per polarity (same sizing as the inference kernel).
    let half = najc / 2;
    let width = (usize::BITS - half.leading_zeros()) as usize;
    scratch.ensure(shape.classes, nc, najc, width);

    for lane in 0..planes.lanes() {
        scratch.lanes_walked += 1;
        let s0 = lane * 64;
        let lane_len = (items.len() - s0).min(64);
        let valid = planes.lane_mask(lane);

        // --- 1. Speculate: every clause's fired-mask + per-sample
        // unclamped vote totals, in one bit-sliced pass.
        for c in 0..nc {
            scratch.pos.fill(0);
            scratch.neg.fill(0);
            for j in 0..najc {
                scratch.lits.clear();
                let force = tm.push_eff_lits(c, j, &mut scratch.lits);
                let m = clause_fired_mask(planes, lane, valid, true, force, &scratch.lits);
                scratch.fired[c * najc + j] = m;
                if m != 0 {
                    let counter =
                        if j % 2 == 0 { &mut scratch.pos } else { &mut scratch.neg };
                    add_mask(counter, m);
                }
            }
            for b in 0..lane_len {
                let mut p = 0i32;
                let mut q = 0i32;
                for (w, (&pp, &nn)) in
                    scratch.pos.iter().zip(scratch.neg.iter()).enumerate()
                {
                    p |= (((pp >> b) & 1) as i32) << w;
                    q |= (((nn >> b) & 1) as i32) << w;
                }
                scratch.totals[c * 64 + b] = p - q;
            }
        }

        // --- 2. Walk the lane's samples in order.
        for b in 0..lane_len {
            let g = s0 + b;
            draws.begin(g);
            stats.steps += 1;
            let target = label_of(&items[g]);
            let input = input_of(&items[g]);

            // Signs, from the scratch buffer (no per-step allocation):
            // canonical order — neg-class draw first, exactly like
            // class_signs / train_step_lazy.
            scratch.signs[..nc].fill(0);
            fill_signs(&mut scratch.signs, target, nc, || draws.neg_draw());
            scratch.touched.clear();
            let type1_inert = draws.type1_inert();

            for c in 0..nc {
                let sign = scratch.signs[c];
                if sign == 0 {
                    continue;
                }
                // The step's class sum: clamp at read, like the scalar
                // engines read the T-clamped evaluation scratch.
                let v = scratch.totals[c * 64 + b].clamp(-t, t) as f32;
                let p_sel = (t as f32 - sign as f32 * v) / two_t;
                if D::SKIPS_NONPOSITIVE_PSEL && p_sel <= 0.0 {
                    continue;
                }
                for j in 0..najc {
                    if !(draws.clause(c, j) < p_sel) {
                        continue;
                    }
                    let out = ((scratch.fired[c * najc + j] >> b) & 1) != 0;
                    let row = c * max_clauses + j;
                    // Remember the pre-feedback revision stamp: a move
                    // past it after this step means an action flipped
                    // and the lane's speculation needs repair.
                    scratch.touched.push((c as u32, j as u32, tm.row_rev(row)));
                    if sign as i32 * polarity(j) == 1 {
                        stats.activity.type1_clauses += 1;
                        if type1_inert {
                            continue;
                        }
                        for (w, &iw) in input.words().iter().enumerate() {
                            let vm = word_mask(lits, w);
                            let lo = w * 64;
                            let n = (lits - lo).min(64);
                            let (reinforce, weaken) = draws.type1_masks(c, j, lo, n, out);
                            let (inc, dec) = if out {
                                (iw & reinforce & vm, !iw & weaken & vm)
                            } else {
                                (0, weaken & vm)
                            };
                            let (ai, ad) = tm.apply_word_feedback(c, j, w, inc, dec);
                            stats.activity.ta_increments += ai;
                            stats.activity.ta_decrements += ad;
                        }
                    } else if out {
                        stats.activity.type2_clauses += 1;
                        for (w, &iw) in input.words().iter().enumerate() {
                            let vm = word_mask(lits, w);
                            let a = tm.action_words(c, j)[w];
                            let eff =
                                if fault_free { a } else { tm.fault().apply(c, j, w, a) };
                            let inc = !iw & !eff & vm;
                            let (ai, _) = tm.apply_word_feedback(c, j, w, inc, 0);
                            stats.activity.ta_increments += ai;
                        }
                    }
                }
            }

            // --- 3. Repair: re-AND only the clauses whose actions
            // flipped during this step, for the remaining samples.
            for k in 0..scratch.touched.len() {
                let (cu, ju, rev_before) = scratch.touched[k];
                let (c, j) = (cu as usize, ju as usize);
                if tm.row_rev(c * max_clauses + j) <= rev_before {
                    continue; // feedback landed but no action flipped
                }
                scratch.lane_flips += 1;
                // Bits strictly after the current sample, within the
                // lane's valid range (`b + 1 == 64` would overflow the
                // shift — and has nothing left to repair).
                let rem = if b >= 63 { 0 } else { valid & (!0u64 << (b + 1)) };
                if rem == 0 {
                    continue;
                }
                scratch.lits.clear();
                let force = tm.push_eff_lits(c, j, &mut scratch.lits);
                let new = clause_fired_mask(planes, lane, valid, true, force, &scratch.lits);
                let slot = c * najc + j;
                let old = scratch.fired[slot];
                let pol = polarity(j);
                let mut gained = new & !old & rem;
                while gained != 0 {
                    let bit = gained.trailing_zeros() as usize;
                    scratch.totals[c * 64 + bit] += pol;
                    gained &= gained - 1;
                }
                let mut lost = old & !new & rem;
                while lost != 0 {
                    let bit = lost.trailing_zeros() as usize;
                    scratch.totals[c * 64 + bit] -= pol;
                    lost &= lost - 1;
                }
                scratch.fired[slot] = (old & !rem) | (new & rem);
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::engine::{train_step_fast, train_step_lazy};
    use crate::tm::params::TmShape;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn random_rows(s: &TmShape, n: usize, rng: &mut Xoshiro256) -> Vec<(Input, usize)> {
        (0..n)
            .map(|i| {
                let bits: Vec<bool> =
                    (0..s.features).map(|_| rng.next_f32() < 0.5).collect();
                (Input::pack(s, &bits), i % s.classes)
            })
            .collect()
    }

    /// Eager lane batches are bit-identical to the sequential
    /// train_step_fast loop under the same refill discipline.
    #[test]
    fn eager_lane_matches_scalar_loop() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        for &n in &[1usize, 5, 63, 64, 65, 130] {
            let mut data_rng = Xoshiro256::new(0x1000 + n as u64);
            let rows = random_rows(&s, n, &mut data_rng);
            let planes = BitPlanes::from_labelled(&s, &rows);

            let mut scalar = MultiTm::new(&s).unwrap();
            let mut rng_a = Xoshiro256::new(7);
            let mut rands = StepRands::draw(&mut rng_a, &s);
            let mut act_a = EpochStats::default();
            for (x, y) in &rows {
                rands.refill(&mut rng_a, &s);
                let a = train_step_fast(&mut scalar, x, *y, &p, &rands);
                act_a.steps += 1;
                act_a.activity.type1_clauses += a.type1_clauses;
                act_a.activity.type2_clauses += a.type2_clauses;
                act_a.activity.ta_increments += a.ta_increments;
                act_a.activity.ta_decrements += a.ta_decrements;
            }

            let mut lane = MultiTm::new(&s).unwrap();
            let mut rng_b = Xoshiro256::new(7);
            let mut scratch = TrainScratch::seeded(&mut rng_b, &s);
            let act_b = train_rows_seq(&mut lane, &rows, &planes, &p, &mut rng_b, &mut scratch);

            assert_eq!(act_a, act_b, "n = {n}");
            assert_eq!(scalar.ta().states(), lane.ta().states(), "n = {n}");
            for c in 0..s.classes {
                for j in 0..s.max_clauses {
                    assert_eq!(scalar.action_words(c, j), lane.action_words(c, j), "n = {n}");
                }
            }
        }
    }

    /// Low T makes selection (and flips) frequent: the repair path must
    /// run and still be bit-identical.
    #[test]
    fn repair_path_exercised_at_low_t() {
        let s = shape();
        let mut p = TmParams::paper_offline(&s);
        p.t = 1; // maximal selection pressure
        let mut data_rng = Xoshiro256::new(0xF11);
        let rows = random_rows(&s, 200, &mut data_rng);
        let planes = BitPlanes::from_labelled(&s, &rows);

        let mut scalar = MultiTm::new(&s).unwrap();
        let mut rng_a = Xoshiro256::new(3);
        let mut rands = StepRands::draw(&mut rng_a, &s);
        for (x, y) in &rows {
            rands.refill(&mut rng_a, &s);
            train_step_fast(&mut scalar, x, *y, &p, &rands);
        }

        let mut lane = MultiTm::new(&s).unwrap();
        let mut rng_b = Xoshiro256::new(3);
        let mut scratch = TrainScratch::seeded(&mut rng_b, &s);
        train_rows_seq(&mut lane, &rows, &planes, &p, &mut rng_b, &mut scratch);

        assert_eq!(scalar.ta().states(), lane.ta().states());
        assert!(
            scratch.lane_flips() > 0,
            "a fresh machine at T = 1 must flip actions mid-lane"
        );
        assert_eq!(scratch.lanes_walked(), 200usize.div_ceil(64) as u64);
        assert!(scratch.mean_flips_per_lane() > 0.0);
        scratch.reset_counters();
        assert_eq!(scratch.lane_flips(), 0);
        assert_eq!(scratch.lanes_walked(), 0);
    }

    /// The lazy lane walk is bit-identical to the train_step_lazy loop
    /// (and therefore train_epoch's historical behaviour).
    #[test]
    fn lazy_lane_matches_scalar_lazy_loop() {
        let s = shape();
        for (ti, t) in [1i32, 15].into_iter().enumerate() {
            let mut p = TmParams::paper_offline(&s);
            p.t = t;
            let plan = FeedbackPlan::new(&p);
            let mut data_rng = Xoshiro256::new(0x2A + ti as u64);
            let rows = random_rows(&s, 130, &mut data_rng);
            let planes = BitPlanes::from_labelled(&s, &rows);

            let mut scalar = MultiTm::new(&s).unwrap();
            let mut rng_a = Xoshiro256::new(99);
            for (x, y) in &rows {
                train_step_lazy(&mut scalar, x, *y, &p, &plan, &mut rng_a);
            }

            let mut lane = MultiTm::new(&s).unwrap();
            let mut rng_b = Xoshiro256::new(99);
            let mut scratch = TrainScratch::new();
            lane.train_plane_batch_lazy(&rows, &planes, &p, &plan, &mut rng_b, &mut scratch);

            assert_eq!(scalar.ta().states(), lane.ta().states(), "T = {t}");
            // The two generators must also end in the same position:
            // identical consumption, draw for draw.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "T = {t}");
        }
    }

    /// Empty batches are a no-op.
    #[test]
    fn empty_batch_is_noop() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut tm = MultiTm::new(&s).unwrap();
        let rows: Vec<(Input, usize)> = Vec::new();
        let planes = BitPlanes::from_labelled(&s, &rows);
        let mut rng = Xoshiro256::new(1);
        let mut scratch = TrainScratch::new();
        let stats = train_rows_seq(&mut tm, &rows, &planes, &p, &mut rng, &mut scratch);
        assert_eq!(stats, EpochStats::default());
        assert_eq!(scratch.lanes_walked(), 0);
    }

    /// One scratch serves differently-shaped machines back to back.
    #[test]
    fn scratch_survives_shape_changes() {
        let small = shape();
        let big = TmShape { classes: 2, max_clauses: 4, features: 40, states: 8 };
        let mut scratch = TrainScratch::new();
        for (si, s) in [&small, &big, &small].into_iter().enumerate() {
            let p = TmParams::paper_offline(s);
            let mut data_rng = Xoshiro256::new(0x600 + si as u64);
            let rows = random_rows(s, 70, &mut data_rng);
            let planes = BitPlanes::from_labelled(s, &rows);

            let mut scalar = MultiTm::new(s).unwrap();
            let mut rng_a = Xoshiro256::new(42);
            let mut rands = StepRands::draw(&mut rng_a, s);
            for (x, y) in &rows {
                rands.refill(&mut rng_a, s);
                train_step_fast(&mut scalar, x, *y, &p, &rands);
            }

            let mut lane = MultiTm::new(s).unwrap();
            let mut rng_b = Xoshiro256::new(42);
            let _ = StepRands::draw(&mut rng_b, s); // mirror the seed draw
            train_rows_seq(&mut lane, &rows, &planes, &p, &mut rng_b, &mut scratch);
            assert_eq!(scalar.ta().states(), lane.ta().states(), "round {si}");
        }
    }
}
