//! TM checkpointing: save/restore TA states (and shape header) in a small
//! self-describing binary format.
//!
//! The paper's architecture keeps TA states in registers on the fabric;
//! retraining-on-chip (§5.3.2) implies snapshots are cheap. Here a
//! checkpoint backs: (a) experiment repeatability, (b) handing a trained
//! machine between the behavioural path, the RTL simulator and the PJRT
//! path, and (c) the retrain-trigger flow in `coordinator::monitor`.
//!
//! Format (little-endian):
//! ```text
//! magic   u32 = 0x544D_4650  ("TMFP")
//! version u32 = 1
//! classes u32, max_clauses u32, features u32, states u32
//! payload u32[classes * max_clauses * 2*features]  (TA states)
//! crc     u32  (FNV-1a over payload bytes)
//! ```

use crate::tm::machine::MultiTm;
use crate::tm::params::TmShape;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: u32 = 0x544D_4650;
const VERSION: u32 = 1;

// All framing CRCs in the repo share one implementation; see util.rs.
pub(crate) use crate::util::fnv1a;

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Serialize a machine's TA states to bytes.
pub fn to_bytes(tm: &MultiTm) -> Vec<u8> {
    let s = tm.shape();
    let mut buf = Vec::with_capacity(8 + 16 + tm.ta().states().len() * 4 + 4);
    push_u32(&mut buf, MAGIC);
    push_u32(&mut buf, VERSION);
    push_u32(&mut buf, s.classes as u32);
    push_u32(&mut buf, s.max_clauses as u32);
    push_u32(&mut buf, s.features as u32);
    push_u32(&mut buf, s.states);
    let payload_start = buf.len();
    for &st in tm.ta().states() {
        push_u32(&mut buf, st);
    }
    let crc = fnv1a(&buf[payload_start..]);
    push_u32(&mut buf, crc);
    buf
}

/// Restore a machine from bytes produced by [`to_bytes`].
pub fn from_bytes(bytes: &[u8]) -> Result<MultiTm> {
    let mut r = bytes;
    if read_u32(&mut r)? != MAGIC {
        bail!("checkpoint: bad magic");
    }
    let ver = read_u32(&mut r)?;
    if ver != VERSION {
        bail!("checkpoint: unsupported version {ver}");
    }
    let shape = TmShape {
        classes: read_u32(&mut r)? as usize,
        max_clauses: read_u32(&mut r)? as usize,
        features: read_u32(&mut r)? as usize,
        states: read_u32(&mut r)?,
    };
    shape.validate().context("checkpoint shape")?;
    let n = shape.num_tas();
    if r.len() != n * 4 + 4 {
        bail!("checkpoint: truncated payload ({} bytes, want {})", r.len(), n * 4 + 4);
    }
    let (payload, crc_bytes) = r.split_at(n * 4);
    let want_crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if fnv1a(payload) != want_crc {
        bail!("checkpoint: CRC mismatch");
    }
    let mut states = Vec::with_capacity(n);
    for chunk in payload.chunks_exact(4) {
        states.push(u32::from_le_bytes(chunk.try_into().unwrap()));
    }
    MultiTm::from_states(&shape, states)
}

/// Save a checkpoint to a file.
pub fn save(tm: &MultiTm, path: &Path) -> Result<()> {
    let bytes = to_bytes(tm);
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Load a checkpoint from a file.
pub fn load(path: &Path) -> Result<MultiTm> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::{TmParams, TmShape};
    use crate::tm::rng::{StepRands, Xoshiro256};

    fn trained_tm() -> MultiTm {
        let s = TmShape::iris();
        let mut tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(77);
        for step in 0..500 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = crate::tm::clause::Input::pack(&s, &bits);
            let r = StepRands::draw(&mut rng, &s);
            crate::tm::feedback::train_step(&mut tm, &x, step % 3, &p, &r);
        }
        tm
    }

    #[test]
    fn roundtrip_bytes() {
        let tm = trained_tm();
        let restored = from_bytes(&to_bytes(&tm)).unwrap();
        assert_eq!(restored.ta().states(), tm.ta().states());
        assert_eq!(restored.shape(), tm.shape());
    }

    #[test]
    fn roundtrip_file() {
        let tm = trained_tm();
        let dir = std::env::temp_dir().join("tmfpga_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tm.ckpt");
        save(&tm, &path).unwrap();
        let restored = load(&path).unwrap();
        assert_eq!(restored.ta().states(), tm.ta().states());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_detected() {
        let tm = trained_tm();
        let mut bytes = to_bytes(&tm);
        // Flip a payload byte.
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(from_bytes(&bytes).is_err(), "CRC must catch corruption");
    }

    #[test]
    fn truncation_detected() {
        let tm = trained_tm();
        let bytes = to_bytes(&tm);
        assert!(from_bytes(&bytes[..bytes.len() - 8]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = to_bytes(&trained_tm());
        bytes[0] ^= 1;
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn restored_machine_predicts_identically() {
        let s = TmShape::iris();
        let p = TmParams::paper_offline(&s);
        let mut tm = trained_tm();
        let mut restored = from_bytes(&to_bytes(&tm)).unwrap();
        let mut rng = Xoshiro256::new(123);
        for _ in 0..50 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = crate::tm::clause::Input::pack(&s, &bits);
            assert_eq!(tm.infer(&x, &p), restored.infer(&x, &p));
        }
    }
}
