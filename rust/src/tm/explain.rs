//! Explainability: clause-composition introspection.
//!
//! The TM's propositional structure is directly interpretable (the
//! explainability angle of the paper's own reference line, Shafik et al.
//! "Explainability and dependability analysis of learning automata based
//! AI hardware"): each clause is a readable AND expression over named
//! literals, and a classification decomposes exactly into per-clause
//! votes. This module renders both.

use crate::tm::clause::{EvalMode, Input};
use crate::tm::machine::MultiTm;
use crate::tm::params::{polarity, TmParams};

/// One clause's composition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClauseDesc {
    pub class: usize,
    pub clause: usize,
    /// +1 / -1 vote polarity.
    pub polarity: i32,
    /// Included plain literals (feature indices).
    pub positive: Vec<usize>,
    /// Included complement literals (feature indices of the negated bits).
    pub negated: Vec<usize>,
}

impl ClauseDesc {
    /// Render as a propositional expression, e.g. `x2 ∧ ¬x5 ∧ x7`.
    pub fn expression(&self) -> String {
        let mut terms: Vec<(usize, String)> = self
            .positive
            .iter()
            .map(|&f| (f, format!("x{f}")))
            .chain(self.negated.iter().map(|&f| (f, format!("¬x{f}"))))
            .collect();
        terms.sort();
        if terms.is_empty() {
            "⊤ (empty)".to_string()
        } else {
            terms.into_iter().map(|(_, t)| t).collect::<Vec<_>>().join(" ∧ ")
        }
    }

    pub fn is_empty(&self) -> bool {
        self.positive.is_empty() && self.negated.is_empty()
    }
}

/// Describe one clause from the machine's *effective* (post-fault-gate)
/// actions — what the hardware actually computes.
pub fn describe_clause(tm: &MultiTm, class: usize, clause: usize) -> ClauseDesc {
    let f = tm.shape().features;
    let mut positive = Vec::new();
    let mut negated = Vec::new();
    for k in 0..tm.shape().literals() {
        if tm.eff_action(class, clause, k) {
            if k < f {
                positive.push(k);
            } else {
                negated.push(k - f);
            }
        }
    }
    ClauseDesc { class, clause, polarity: polarity(clause), positive, negated }
}

/// Describe a whole machine (active clauses only).
pub fn describe_machine(tm: &MultiTm, params: &TmParams) -> Vec<ClauseDesc> {
    let mut out = Vec::new();
    for c in 0..params.active_classes {
        for j in 0..params.active_clauses {
            out.push(describe_clause(tm, c, j));
        }
    }
    out
}

/// Vote attribution for one classification: which clauses fired and how
/// they compose into each class sum.
#[derive(Debug, Clone)]
pub struct Attribution {
    pub prediction: usize,
    pub class_sums: Vec<i32>,
    /// Firing clauses: (class, clause, polarity).
    pub firing: Vec<(usize, usize, i32)>,
}

/// Explain one prediction.
pub fn explain(tm: &mut MultiTm, x: &Input, params: &TmParams) -> Attribution {
    tm.evaluate(x, params, EvalMode::Infer);
    let shape = tm.shape().clone();
    let mut firing = Vec::new();
    for c in 0..params.active_classes {
        for j in 0..params.active_clauses {
            if tm.clause_out[c * shape.max_clauses + j] {
                firing.push((c, j, polarity(j)));
            }
        }
    }
    let (class_sums, prediction) = tm.infer(x, params);
    Attribution { prediction, class_sums, firing }
}

/// Render an attribution report.
pub fn report(tm: &mut MultiTm, x: &Input, params: &TmParams) -> String {
    use std::fmt::Write as _;
    let att = explain(tm, x, params);
    let mut s = String::new();
    let _ = writeln!(s, "prediction: class {} (sums {:?})", att.prediction, att.class_sums);
    for (c, j, pol) in &att.firing {
        let d = describe_clause(tm, *c, *j);
        let _ = writeln!(
            s,
            "  class {c} clause {j} [{}] fired: {}",
            if *pol > 0 { "+" } else { "-" },
            d.expression()
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::{TmParams, TmShape};

    fn setup() -> (MultiTm, TmParams) {
        let shape = TmShape::iris();
        let tm = MultiTm::new(&shape).unwrap();
        let p = TmParams::paper_offline(&shape);
        (tm, p)
    }

    #[test]
    fn empty_clause_renders_top() {
        let (tm, _) = setup();
        let d = describe_clause(&tm, 0, 0);
        assert!(d.is_empty());
        assert_eq!(d.expression(), "⊤ (empty)");
        assert_eq!(d.polarity, 1);
        assert_eq!(describe_clause(&tm, 0, 1).polarity, -1);
    }

    #[test]
    fn composition_tracks_included_literals() {
        let (mut tm, _) = setup();
        for _ in 0..2 {
            tm.ta_increment(1, 2, 0); // x0
            tm.ta_increment(1, 2, 16 + 5); // ¬x5
        }
        let d = describe_clause(&tm, 1, 2);
        assert_eq!(d.positive, vec![0]);
        assert_eq!(d.negated, vec![5]);
        assert_eq!(d.expression(), "x0 ∧ ¬x5");
    }

    #[test]
    fn faulty_gates_visible_in_description() {
        let (mut tm, _) = setup();
        tm.fault_map_mut().set(0, 0, 3, crate::tm::fault::Fault::StuckAt1);
        let d = describe_clause(&tm, 0, 0);
        assert_eq!(d.positive, vec![3], "forced include shows up (hardware view)");
    }

    #[test]
    fn attribution_sums_match_votes() {
        let (mut tm, p) = setup();
        // Two includes: class 0 clause 0 (+) on x0; class 0 clause 1 (-)
        // on x1.
        for _ in 0..2 {
            tm.ta_increment(0, 0, 0);
            tm.ta_increment(0, 1, 1);
        }
        let mut bits = vec![false; 16];
        bits[0] = true;
        bits[1] = true;
        let x = Input::pack(tm.shape(), &bits);
        let att = explain(&mut tm, &x, &p);
        let recomputed: i32 = att
            .firing
            .iter()
            .filter(|(c, _, _)| *c == 0)
            .map(|(_, _, pol)| *pol)
            .sum();
        assert_eq!(recomputed, att.class_sums[0]);
        assert!(att.firing.contains(&(0, 0, 1)));
        assert!(att.firing.contains(&(0, 1, -1)));
    }

    #[test]
    fn report_is_readable() {
        let (mut tm, p) = setup();
        for _ in 0..2 {
            tm.ta_increment(2, 0, 4);
        }
        let mut bits = vec![false; 16];
        bits[4] = true;
        let x = Input::pack(tm.shape(), &bits);
        let r = report(&mut tm, &x, &p);
        assert!(r.contains("prediction: class 2"), "{r}");
        assert!(r.contains("x4"), "{r}");
    }

    #[test]
    fn describe_machine_covers_active_slice() {
        let (tm, mut p) = setup();
        p.active_classes = 2;
        p.active_clauses = 4;
        let all = describe_machine(&tm, &p);
        assert_eq!(all.len(), 8);
        assert!(all.iter().all(|d| d.class < 2 && d.clause < 4));
    }
}
