//! Small shared utilities.
//!
//! `fnv1a` is THE record/digest checksum of this repo: the TMFP v1 and
//! TMFS v2 checkpoint codecs, the serve snapshot action-cache
//! cross-check and the durable store's WAL/manifest record framing all
//! hash through this one implementation, so the checksum semantics
//! cannot drift between the framing layers. (The 64-bit state digest in
//! `tm::machine::MultiTm::state_digest` is the separate FNV-1a-64
//! variant — a digest, not a framing checksum.)

/// Incremental 32-bit FNV-1a: feed byte slices in any chunking, the
/// result is identical to one [`fnv1a`] call over the concatenation.
/// Used where hashing would otherwise force an intermediate buffer
/// (e.g. packed `u64` payloads hashed word by word).
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u32);

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(0x811C_9DC5)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u32;
            self.0 = self.0.wrapping_mul(0x0100_0193);
        }
    }

    pub fn finish(self) -> u32 {
        self.0
    }
}

/// 32-bit FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a 32-bit test vectors.
        assert_eq!(fnv1a(b""), 0x811C_9DC5);
        assert_eq!(fnv1a(b"a"), 0xE40C_292C);
        assert_eq!(fnv1a(b"foobar"), 0xBF9C_F968);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).collect();
        for split in [0usize, 1, 7, 128, 255, 256] {
            let mut h = Fnv1a::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), fnv1a(&data), "split at {split}");
        }
    }
}
