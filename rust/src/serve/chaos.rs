//! Deterministic fault schedules for the serving stack.
//!
//! A [`ChaosPlan`] is a list of faults keyed to points of the sequenced
//! update log — kill shard `k` after update `s`, stall worker `w` for
//! `n` work items, corrupt the `n`-th checkpoint shard `c` ships. The
//! supervisor arms each event exactly when the log clock reaches its
//! trigger, so the same plan against the same trace produces the same
//! failure history on every run — which is what lets the chaos soak
//! assert *bit-identity* with the never-failed oracle rather than
//! eyeballing "it recovered". Plans are either hand-built (the recovery
//! suite's kill-at-every-seq sweep) or generated from a seed
//! ([`ChaosPlan::seeded`], the `--chaos-seed` CLI path).
//!
//! Malformed-request injection is deliberately *not* here: requests are
//! driver-side objects, so the chaos soak rewrites the trace itself
//! (`coordinator::soak::run_chaos_soak`) and the batcher quarantines
//! them at admission — both arms see the identical stream.

use crate::tm::rng::Xoshiro256;
use std::fs;
use std::path::{Path, PathBuf};

/// How a scheduled kill lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KillKind {
    /// The worker panics as soon as the kill command reaches it — after
    /// the trigger update, before anything later.
    Immediate,
    /// The worker is armed and panics when its *next micro-batch*
    /// arrives, mid-scoring — the batch is lost with it and must be
    /// recovered by re-dispatch.
    OnNextBatch,
}

/// One scheduled fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChaosEvent {
    /// Kill shard `shard` once update `after_seq` has been broadcast.
    Kill { shard: usize, after_seq: u64, kind: KillKind },
    /// Stall shard `shard` after update `after_seq`: its worker buffers
    /// the next `items` work items without processing (or replying —
    /// heartbeats go stale), then drains them in order and resumes.
    Stall { shard: usize, after_seq: u64, items: usize },
    /// Corrupt the `nth` (1-based) checkpoint shard `shard` ships to the
    /// supervisor — a single byte flip, exactly what the restore CRC
    /// must catch, forcing fallback to an older snapshot.
    CorruptSnapshot { shard: usize, nth: u64 },
}

/// Shape of a seeded schedule: how many of each fault to draw.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosSpec {
    pub kills: usize,
    pub stalls: usize,
    pub corrupts: usize,
}

/// A deterministic fault schedule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    pub events: Vec<ChaosEvent>,
}

impl ChaosPlan {
    /// Generate a schedule from a seed: `spec.kills` kills (alternating
    /// immediate / on-next-batch) and `spec.stalls` stalls at distinct
    /// update seqs drawn from `1..=total_updates`, spread over `shards`
    /// shards, plus `spec.corrupts` checkpoint corruptions. The same
    /// `(seed, shards, total_updates, spec)` always yields the same
    /// plan.
    pub fn seeded(seed: u64, shards: usize, total_updates: u64, spec: &ChaosSpec) -> ChaosPlan {
        let mut plan = ChaosPlan::default();
        if shards == 0 || total_updates == 0 {
            return plan;
        }
        let mut rng = Xoshiro256::new(seed);
        let mut used_seqs: Vec<u64> = Vec::new();
        let mut draw_seq = |rng: &mut Xoshiro256| -> u64 {
            // Distinct trigger seqs keep events from racing each other
            // at one log point; with more events than updates the
            // distinctness requirement is dropped rather than looping
            // forever.
            for _ in 0..64 {
                let s = 1 + rng.next_below(total_updates as usize) as u64;
                if !used_seqs.contains(&s) || used_seqs.len() >= total_updates as usize {
                    used_seqs.push(s);
                    return s;
                }
            }
            1 + rng.next_below(total_updates as usize) as u64
        };
        for i in 0..spec.kills {
            plan.events.push(ChaosEvent::Kill {
                shard: rng.next_below(shards),
                after_seq: draw_seq(&mut rng),
                kind: if i % 2 == 0 { KillKind::Immediate } else { KillKind::OnNextBatch },
            });
        }
        for _ in 0..spec.stalls {
            plan.events.push(ChaosEvent::Stall {
                shard: rng.next_below(shards),
                after_seq: draw_seq(&mut rng),
                items: 3 + rng.next_below(17),
            });
        }
        for _ in 0..spec.corrupts {
            plan.events.push(ChaosEvent::CorruptSnapshot {
                shard: rng.next_below(shards),
                nth: 1 + rng.next_below(3) as u64,
            });
        }
        plan.events.sort_by_key(|e| match e {
            ChaosEvent::Kill { after_seq, .. } | ChaosEvent::Stall { after_seq, .. } => *after_seq,
            ChaosEvent::CorruptSnapshot { .. } => 0,
        });
        plan
    }

    /// Number of scheduled kill events.
    pub fn kills(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ChaosEvent::Kill { .. })).count()
    }
}

/// One connection-level fault, attached to a simulated client session.
/// Where shard chaos keys off the update-log clock, connection chaos
/// keys off the client's own request stream — `after_requests` counts
/// the requests the client has written before the fault lands — so the
/// same script always fails at the same byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetFault {
    /// Deliver every frame shattered into `fragment`-byte slivers, one
    /// sliver per tick — the parser must reassemble torn frames and
    /// never act on a partial line.
    TornFrames { fragment: usize },
    /// The client half-closes after writing `after_requests` requests:
    /// its write side goes silent (no further requests, no clean
    /// shutdown) while its read side stays open awaiting answers.
    HalfOpen { after_requests: u64 },
    /// The connection aborts entirely after `after_requests` requests —
    /// mid-response from the server's point of view; everything queued
    /// for the client is undeliverable from that point.
    Disconnect { after_requests: u64 },
    /// A slow-loris reader: the client grants read windows of only
    /// `window` response frames at a time, every `every` ticks, so the
    /// server's write buffer for it fills and the slow-client cap must
    /// shed with exact accounting.
    SlowLoris { window: u64, every: u64 },
    /// A flooder: the client fires `burst` requests per tick with no
    /// think time, driving the admission controller past its in-flight
    /// depth.
    Flood { burst: usize },
}

/// Shape of a seeded connection-fault schedule: how many clients get
/// each fault. Clients beyond the faulted ones behave normally.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NetChaosSpec {
    pub torn: usize,
    pub half_open: usize,
    pub disconnects: usize,
    pub slow_loris: usize,
    pub floods: usize,
}

impl NetChaosSpec {
    /// The full matrix: one client per fault kind.
    pub fn full_matrix() -> Self {
        NetChaosSpec { torn: 1, half_open: 1, disconnects: 1, slow_loris: 1, floods: 1 }
    }

    fn total(&self) -> usize {
        self.torn + self.half_open + self.disconnects + self.slow_loris + self.floods
    }
}

/// A deterministic connection-fault schedule: at most one fault per
/// client slot (`faults[i]` applies to client `i`, `None` = a healthy
/// client).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetChaosPlan {
    pub faults: Vec<Option<NetFault>>,
}

impl NetChaosPlan {
    /// A plan with `clients` healthy sessions and no faults.
    pub fn healthy(clients: usize) -> Self {
        NetChaosPlan { faults: vec![None; clients] }
    }

    /// Generate a schedule from a seed: draw the spec'd fault kinds with
    /// seeded parameters and deal them onto distinct client slots in a
    /// seeded shuffle. `requests_per_client` bounds the `after_requests`
    /// draws so half-opens and disconnects land mid-script, not after
    /// it. The same `(seed, clients, requests_per_client, spec)` always
    /// yields the same plan; with more faults than clients the excess is
    /// dropped.
    pub fn seeded(
        seed: u64,
        clients: usize,
        requests_per_client: u64,
        spec: &NetChaosSpec,
    ) -> NetChaosPlan {
        let mut plan = NetChaosPlan::healthy(clients);
        if clients == 0 || requests_per_client == 0 {
            return plan;
        }
        let mut rng = Xoshiro256::new(seed);
        let mid = |rng: &mut Xoshiro256| -> u64 {
            // Strike points in the middle half of the script, so the
            // fault interrupts live traffic.
            let span = (requests_per_client / 2).max(1);
            requests_per_client / 4 + rng.next_below(span as usize) as u64
        };
        let mut faults = Vec::with_capacity(spec.total());
        for _ in 0..spec.torn {
            faults.push(NetFault::TornFrames { fragment: 1 + rng.next_below(5) });
        }
        for _ in 0..spec.half_open {
            faults.push(NetFault::HalfOpen { after_requests: mid(&mut rng) });
        }
        for _ in 0..spec.disconnects {
            faults.push(NetFault::Disconnect { after_requests: mid(&mut rng) });
        }
        for _ in 0..spec.slow_loris {
            faults.push(NetFault::SlowLoris {
                window: 1 + rng.next_below(2) as u64,
                every: 3 + rng.next_below(5) as u64,
            });
        }
        for _ in 0..spec.floods {
            faults.push(NetFault::Flood { burst: 4 + rng.next_below(13) });
        }
        // Seeded deal onto distinct slots (partial Fisher–Yates over the
        // client indices).
        let mut slots: Vec<usize> = (0..clients).collect();
        for (k, fault) in faults.into_iter().enumerate() {
            if k >= slots.len() {
                break;
            }
            let pick = k + rng.next_below(slots.len() - k);
            slots.swap(k, pick);
            plan.faults[slots[k]] = Some(fault);
        }
        plan
    }

    /// Number of faulted client slots.
    pub fn faulted(&self) -> usize {
        self.faults.iter().filter(|f| f.is_some()).count()
    }
}

/// One injected durable-storage fault, applied to a *closed* store
/// directory between a crash and the restart that must survive it.
/// Where [`crate::store::FaultDisk`] injects faults at the write
/// boundary (ENOSPC, short writes, crashes mid-append), these mutate
/// the bytes already on disk — the damage a power cut, media rot or an
/// interrupted retention pass leaves behind. Every kind must be either
/// repaired with exact counter accounting on the next
/// [`crate::store::Store::open`] or refused with a typed error; none
/// may ever yield a silently wrong recovery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiskFault {
    /// Tear the final WAL record: truncate the newest segment mid-frame,
    /// dropping the last `bytes` bytes of the final record (clamped so
    /// at least one byte of the frame survives). This is exactly the
    /// state an in-flight append leaves, so recovery truncates it away
    /// and loses only the unacknowledged record.
    TornTail { bytes: u64 },
    /// Flip one bit inside the first sealed record of the oldest WAL
    /// segment — latent media corruption in acknowledged history, which
    /// tearing can never produce. Recovery must refuse typed
    /// (`CorruptRecord`), never replay around it.
    BitFlipWal,
    /// Delete a middle WAL segment (needs ≥ 3), leaving a hole the
    /// position-contiguity check must refuse typed (`MissingSegment`).
    MissingSegment,
    /// Truncate the oldest of ≥ 2 WAL segments to zero bytes: the file
    /// is still listed under its positional name but yields no records,
    /// so the successor segment no longer starts where the name
    /// promises — refused typed, same as a deleted segment.
    ZeroLengthSegment,
    /// Roll the manifest back to the previous on-disk checkpoint of some
    /// model (rewritten with a valid CRC) — the legal crash window
    /// between checkpoint publication and manifest rewrite. Recovery
    /// prefers the newest *verifying* checkpoint file, counts the stale
    /// row and repairs the manifest durably.
    StaleManifest,
    /// Flip one bit mid-file in the newest checkpoint on disk. Restore's
    /// CRC must reject it (counted) and fall back to an older snapshot
    /// or the WAL's genesis record — or fail typed when nothing usable
    /// remains.
    CorruptCheckpoint,
}

impl DiskFault {
    /// The full injection matrix, one of each kind.
    pub fn full_matrix() -> Vec<DiskFault> {
        vec![
            DiskFault::TornTail { bytes: 3 },
            DiskFault::BitFlipWal,
            DiskFault::MissingSegment,
            DiskFault::ZeroLengthSegment,
            DiskFault::StaleManifest,
            DiskFault::CorruptCheckpoint,
        ]
    }
}

/// Files under `dir` whose name ends in `suffix`, lexically sorted —
/// which for the store's zero-padded names is positional order.
fn sorted_files(dir: &Path, suffix: &str) -> std::io::Result<Vec<PathBuf>> {
    let mut v = Vec::new();
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(suffix)) {
            v.push(p);
        }
    }
    v.sort();
    Ok(v)
}

/// Apply one [`DiskFault`] to the closed store rooted at `root`.
/// Returns `Ok(false)` when the directory does not hold enough state
/// for the fault to land (e.g. [`DiskFault::MissingSegment`] with fewer
/// than three segments) — the caller decides whether that skip is
/// acceptable for its sweep.
pub fn inject_disk_fault(root: &Path, fault: DiskFault) -> anyhow::Result<bool> {
    use crate::store::{ckpt, RealDisk};
    let wal_dir = root.join("wal");
    let ckpt_dir = root.join("ckpt");
    match fault {
        DiskFault::TornTail { bytes } => {
            let segs = sorted_files(&wal_dir, ".wal")?;
            let Some(path) = segs.last() else { return Ok(false) };
            let buf = fs::read(path)?;
            // Walk the frames to find where the final record starts.
            let mut off = 0usize;
            let mut last = None;
            while off + 8 <= buf.len() {
                let len = u32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]])
                    as usize;
                if off + 8 + len > buf.len() {
                    break;
                }
                last = Some((off, 8 + len));
                off += 8 + len;
            }
            let Some((start, frame_len)) = last else { return Ok(false) };
            let keep = frame_len.saturating_sub((bytes as usize).max(1)).max(1);
            let f = fs::OpenOptions::new().write(true).open(path)?;
            f.set_len((start + keep) as u64)?;
            Ok(true)
        }
        DiskFault::BitFlipWal => {
            let segs = sorted_files(&wal_dir, ".wal")?;
            let Some(path) = segs.first() else { return Ok(false) };
            let mut buf = fs::read(path)?;
            if buf.len() < 9 {
                return Ok(false);
            }
            // Offset 8 is the first payload byte of the first record:
            // the frame stays complete, its CRC no longer matches.
            buf[8] ^= 0x01;
            fs::write(path, &buf)?;
            Ok(true)
        }
        DiskFault::MissingSegment => {
            let segs = sorted_files(&wal_dir, ".wal")?;
            if segs.len() < 3 {
                return Ok(false);
            }
            fs::remove_file(&segs[1])?;
            Ok(true)
        }
        DiskFault::ZeroLengthSegment => {
            let segs = sorted_files(&wal_dir, ".wal")?;
            if segs.len() < 2 {
                return Ok(false);
            }
            let f = fs::OpenOptions::new().write(true).open(&segs[0])?;
            f.set_len(0)?;
            Ok(true)
        }
        DiskFault::StaleManifest => {
            let mut disk = RealDisk;
            let Some(mut man) = ckpt::load_manifest(&mut disk, root)? else {
                return Ok(false);
            };
            let files = ckpt::scan(&mut disk, &ckpt_dir)?;
            let pick = man.iter().rev().find_map(|(id, e)| {
                let list = files.get(id)?;
                let &(older, _) = list.iter().rev().find(|&&(s, _)| s < e.ckpt_seq)?;
                Some((*id, older))
            });
            let Some((id, older)) = pick else { return Ok(false) };
            man.get_mut(&id).expect("picked from this map").ckpt_seq = older;
            ckpt::write_manifest(&mut disk, root, &man)?;
            Ok(true)
        }
        DiskFault::CorruptCheckpoint => {
            let files = sorted_files(&ckpt_dir, ".tmfs")?;
            let Some(path) = files.last() else { return Ok(false) };
            let mut bytes = fs::read(path)?;
            if bytes.is_empty() {
                return Ok(false);
            }
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x40;
            fs::write(path, &bytes)?;
            Ok(true)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic() {
        let spec = ChaosSpec { kills: 3, stalls: 2, corrupts: 1 };
        let a = ChaosPlan::seeded(7, 4, 100, &spec);
        let b = ChaosPlan::seeded(7, 4, 100, &spec);
        let c = ChaosPlan::seeded(8, 4, 100, &spec);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.kills(), 3);
        assert_eq!(a.events.len(), 6);
    }

    #[test]
    fn seeded_plans_respect_bounds() {
        let spec = ChaosSpec { kills: 8, stalls: 8, corrupts: 4 };
        let plan = ChaosPlan::seeded(0xC4A05, 3, 50, &spec);
        for ev in &plan.events {
            match ev {
                ChaosEvent::Kill { shard, after_seq, .. }
                | ChaosEvent::Stall { shard, after_seq, items: _ } => {
                    assert!(*shard < 3);
                    assert!((1..=50).contains(after_seq));
                }
                ChaosEvent::CorruptSnapshot { shard, nth } => {
                    assert!(*shard < 3);
                    assert!((1..=3).contains(nth));
                }
            }
        }
    }

    #[test]
    fn degenerate_inputs_yield_empty_plans() {
        let spec = ChaosSpec { kills: 2, stalls: 2, corrupts: 2 };
        assert!(ChaosPlan::seeded(1, 0, 100, &spec).events.is_empty());
        assert!(ChaosPlan::seeded(1, 4, 0, &spec).events.is_empty());
    }

    #[test]
    fn seeded_net_plans_are_deterministic_and_distinct_per_client() {
        let spec = NetChaosSpec::full_matrix();
        let a = NetChaosPlan::seeded(9, 8, 40, &spec);
        let b = NetChaosPlan::seeded(9, 8, 40, &spec);
        let c = NetChaosPlan::seeded(10, 8, 40, &spec);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.faults.len(), 8);
        assert_eq!(a.faulted(), 5, "each matrix fault lands on its own client");
    }

    #[test]
    fn seeded_net_plans_respect_bounds() {
        let spec =
            NetChaosSpec { torn: 3, half_open: 3, disconnects: 3, slow_loris: 3, floods: 3 };
        // More faults than clients: excess dropped, never doubled up.
        let plan = NetChaosPlan::seeded(0x5EED, 6, 20, &spec);
        assert_eq!(plan.faulted(), 6);
        for fault in plan.faults.iter().flatten() {
            match fault {
                NetFault::TornFrames { fragment } => assert!((1..=5).contains(fragment)),
                NetFault::HalfOpen { after_requests }
                | NetFault::Disconnect { after_requests } => {
                    assert!((5..15).contains(after_requests), "mid-script strike");
                }
                NetFault::SlowLoris { window, every } => {
                    assert!((1..=2).contains(window));
                    assert!((3..=7).contains(every));
                }
                NetFault::Flood { burst } => assert!((4..=16).contains(burst)),
            }
        }
        assert!(NetChaosPlan::seeded(1, 0, 20, &spec).faults.is_empty());
        assert_eq!(NetChaosPlan::seeded(1, 4, 0, &spec).faulted(), 0);
    }
}
