//! Deterministic fault schedules for the serving stack.
//!
//! A [`ChaosPlan`] is a list of faults keyed to points of the sequenced
//! update log — kill shard `k` after update `s`, stall worker `w` for
//! `n` work items, corrupt the `n`-th checkpoint shard `c` ships. The
//! supervisor arms each event exactly when the log clock reaches its
//! trigger, so the same plan against the same trace produces the same
//! failure history on every run — which is what lets the chaos soak
//! assert *bit-identity* with the never-failed oracle rather than
//! eyeballing "it recovered". Plans are either hand-built (the recovery
//! suite's kill-at-every-seq sweep) or generated from a seed
//! ([`ChaosPlan::seeded`], the `--chaos-seed` CLI path).
//!
//! Malformed-request injection is deliberately *not* here: requests are
//! driver-side objects, so the chaos soak rewrites the trace itself
//! (`coordinator::soak::run_chaos_soak`) and the batcher quarantines
//! them at admission — both arms see the identical stream.

use crate::tm::rng::Xoshiro256;

/// How a scheduled kill lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KillKind {
    /// The worker panics as soon as the kill command reaches it — after
    /// the trigger update, before anything later.
    Immediate,
    /// The worker is armed and panics when its *next micro-batch*
    /// arrives, mid-scoring — the batch is lost with it and must be
    /// recovered by re-dispatch.
    OnNextBatch,
}

/// One scheduled fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChaosEvent {
    /// Kill shard `shard` once update `after_seq` has been broadcast.
    Kill { shard: usize, after_seq: u64, kind: KillKind },
    /// Stall shard `shard` after update `after_seq`: its worker buffers
    /// the next `items` work items without processing (or replying —
    /// heartbeats go stale), then drains them in order and resumes.
    Stall { shard: usize, after_seq: u64, items: usize },
    /// Corrupt the `nth` (1-based) checkpoint shard `shard` ships to the
    /// supervisor — a single byte flip, exactly what the restore CRC
    /// must catch, forcing fallback to an older snapshot.
    CorruptSnapshot { shard: usize, nth: u64 },
}

/// Shape of a seeded schedule: how many of each fault to draw.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosSpec {
    pub kills: usize,
    pub stalls: usize,
    pub corrupts: usize,
}

/// A deterministic fault schedule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    pub events: Vec<ChaosEvent>,
}

impl ChaosPlan {
    /// Generate a schedule from a seed: `spec.kills` kills (alternating
    /// immediate / on-next-batch) and `spec.stalls` stalls at distinct
    /// update seqs drawn from `1..=total_updates`, spread over `shards`
    /// shards, plus `spec.corrupts` checkpoint corruptions. The same
    /// `(seed, shards, total_updates, spec)` always yields the same
    /// plan.
    pub fn seeded(seed: u64, shards: usize, total_updates: u64, spec: &ChaosSpec) -> ChaosPlan {
        let mut plan = ChaosPlan::default();
        if shards == 0 || total_updates == 0 {
            return plan;
        }
        let mut rng = Xoshiro256::new(seed);
        let mut used_seqs: Vec<u64> = Vec::new();
        let mut draw_seq = |rng: &mut Xoshiro256| -> u64 {
            // Distinct trigger seqs keep events from racing each other
            // at one log point; with more events than updates the
            // distinctness requirement is dropped rather than looping
            // forever.
            for _ in 0..64 {
                let s = 1 + rng.next_below(total_updates as usize) as u64;
                if !used_seqs.contains(&s) || used_seqs.len() >= total_updates as usize {
                    used_seqs.push(s);
                    return s;
                }
            }
            1 + rng.next_below(total_updates as usize) as u64
        };
        for i in 0..spec.kills {
            plan.events.push(ChaosEvent::Kill {
                shard: rng.next_below(shards),
                after_seq: draw_seq(&mut rng),
                kind: if i % 2 == 0 { KillKind::Immediate } else { KillKind::OnNextBatch },
            });
        }
        for _ in 0..spec.stalls {
            plan.events.push(ChaosEvent::Stall {
                shard: rng.next_below(shards),
                after_seq: draw_seq(&mut rng),
                items: 3 + rng.next_below(17),
            });
        }
        for _ in 0..spec.corrupts {
            plan.events.push(ChaosEvent::CorruptSnapshot {
                shard: rng.next_below(shards),
                nth: 1 + rng.next_below(3) as u64,
            });
        }
        plan.events.sort_by_key(|e| match e {
            ChaosEvent::Kill { after_seq, .. } | ChaosEvent::Stall { after_seq, .. } => *after_seq,
            ChaosEvent::CorruptSnapshot { .. } => 0,
        });
        plan
    }

    /// Number of scheduled kill events.
    pub fn kills(&self) -> usize {
        self.events.iter().filter(|e| matches!(e, ChaosEvent::Kill { .. })).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic() {
        let spec = ChaosSpec { kills: 3, stalls: 2, corrupts: 1 };
        let a = ChaosPlan::seeded(7, 4, 100, &spec);
        let b = ChaosPlan::seeded(7, 4, 100, &spec);
        let c = ChaosPlan::seeded(8, 4, 100, &spec);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.kills(), 3);
        assert_eq!(a.events.len(), 6);
    }

    #[test]
    fn seeded_plans_respect_bounds() {
        let spec = ChaosSpec { kills: 8, stalls: 8, corrupts: 4 };
        let plan = ChaosPlan::seeded(0xC4A05, 3, 50, &spec);
        for ev in &plan.events {
            match ev {
                ChaosEvent::Kill { shard, after_seq, .. }
                | ChaosEvent::Stall { shard, after_seq, items: _ } => {
                    assert!(*shard < 3);
                    assert!((1..=50).contains(after_seq));
                }
                ChaosEvent::CorruptSnapshot { shard, nth } => {
                    assert!(*shard < 3);
                    assert!((1..=3).contains(nth));
                }
            }
        }
    }

    #[test]
    fn degenerate_inputs_yield_empty_plans() {
        let spec = ChaosSpec { kills: 2, stalls: 2, corrupts: 2 };
        assert!(ChaosPlan::seeded(1, 0, 100, &spec).events.is_empty());
        assert!(ChaosPlan::seeded(1, 4, 0, &spec).events.is_empty());
    }
}
