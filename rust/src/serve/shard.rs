//! The shard pool: replicated machines behind FIFO work channels.
//!
//! Each shard worker owns a full replica of the initial [`MultiTm`] and a
//! `std::sync::mpsc` receiver. The dispatcher (whoever drives
//! [`crate::serve::run_trace`]) broadcasts every sequenced
//! [`ShardUpdate`] to *all* shards and deals flushed micro-batches
//! round-robin to one shard each. Because each channel is FIFO and
//! updates are sent before any batch that flushed after them, a replica
//! has applied exactly the updates with `seq ≤` the batch's flush point
//! by the time it scores the batch — and since replica updates are
//! deterministic in `(base_seed, seq)` (`MultiTm::apply_update`) and
//! `predict_planes` is bit-identical to the row-major path, every
//! response is independent of shard count, thread scheduling and batch
//! placement. That is the whole determinism argument; the soak suite
//! checks it against the scalar oracle rather than trusting it.
//!
//! Workers additionally coalesce **consecutive `Learn` updates** into
//! ≤64-wide runs and train them through the lane-speculative engine
//! (`tm::train_planes`) in one batched pass. The run boundaries depend
//! on queue timing and are therefore nondeterministic — which is safe
//! precisely because the lane path is bit-identical to applying the
//! same updates one by one: randomness is keyed per update, so batch
//! shape cannot leak into replica state.
//!
//! Shutdown is by channel closure: [`ShardServer::finish`] drops the
//! work senders, workers drain and exit, and the response channel closes
//! once the last worker clone of its sender is gone — no sentinel
//! messages, no possibility of a worker outliving the pool.

use crate::serve::batcher::PendingRequest;
use crate::serve::ServeBackend;
use crate::tm::bitplane::BitPlanes;
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use crate::tm::train_planes::TrainScratch;
use crate::tm::update::{update_rands_into, ShardUpdate, UpdateKind};
use anyhow::{anyhow, ensure, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// A flushed micro-batch: request ids plus their packed inputs. The
/// bitplane transpose happens on the scoring shard (it is a pure
/// function of the batch, so placement cannot affect results), keeping
/// the dispatcher thread off the critical path — consecutive batches'
/// transposes overlap across shards.
#[derive(Debug)]
pub struct MicroBatch {
    pub ids: Vec<u64>,
    pub inputs: Vec<Input>,
}

/// Shard-pool configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker replicas (≥ 1).
    pub shards: usize,
    /// Run-time parameters every replica serves and learns under.
    pub params: TmParams,
    /// Base seed of the replica update log's derived randomness.
    pub base_seed: u64,
}

/// Per-shard work counters, reported by [`ShardServer::finish`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardStats {
    pub shard: usize,
    /// Sequenced updates applied by this replica (same on every shard).
    pub updates: u64,
    /// Micro-batches this shard scored.
    pub batches: u64,
    /// Inference samples this shard scored.
    pub samples: u64,
}

/// What one drive through the server produced.
#[derive(Debug)]
pub struct ServeOutcome {
    /// `(request_id, predicted_class)`, sorted by request id.
    pub responses: Vec<(u64, usize)>,
    /// Per-shard work counters, in shard order.
    pub shards: Vec<ShardStats>,
    /// Updates broadcast over the pool's lifetime.
    pub updates: u64,
}

enum Work {
    /// Shared, not cloned: the dispatcher is the serialization point of
    /// the serving loop, so a broadcast costs one refcount bump per
    /// shard instead of a deep copy of the update's packed input.
    Update(Arc<ShardUpdate>),
    Batch(MicroBatch),
}

/// Work-queue depth per shard. Bounded so a dispatcher outrunning its
/// shards blocks (backpressure) instead of buffering the whole trace in
/// channel memory; deep enough that the bound is never felt at sane
/// batch sizes. Deadlock-free by construction: workers drain their
/// queue unconditionally and only ever send on the *unbounded* response
/// channel, so a blocked dispatcher always unblocks.
const WORK_QUEUE_DEPTH: usize = 1024;

/// The running shard pool. Feed it through the [`ServeBackend`] trait
/// (usually via [`crate::serve::run_trace`]), then call
/// [`ShardServer::finish`] to join the workers and collect responses
/// (responses accumulate until then — drain per-trace, not per-epoch).
pub struct ShardServer {
    senders: Vec<mpsc::SyncSender<Work>>,
    handles: Vec<JoinHandle<ShardStats>>,
    results: mpsc::Receiver<(Vec<u64>, Vec<usize>)>,
    next_shard: usize,
    seq: u64,
}

impl ShardServer {
    /// Spawn `cfg.shards` workers, each owning a clone of `tm`.
    pub fn new(tm: &MultiTm, cfg: &ServeConfig) -> Result<Self> {
        ensure!(cfg.shards >= 1, "ServeConfig: shards must be >= 1, got {}", cfg.shards);
        cfg.params.validate(tm.shape())?;
        let (res_tx, res_rx) = mpsc::channel();
        let mut senders = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = mpsc::sync_channel::<Work>(WORK_QUEUE_DEPTH);
            let mut replica = tm.clone();
            let params = cfg.params.clone();
            let base_seed = cfg.base_seed;
            let out = res_tx.clone();
            handles.push(std::thread::spawn(move || {
                let mut stats = ShardStats { shard, updates: 0, batches: 0, samples: 0 };
                // Per-worker randomness scratch (single-update runs) and
                // lane scratch (coalesced Learn runs), allocated once.
                let mut rands: Option<StepRands> = None;
                let mut scratch = TrainScratch::new();
                // Consecutive Learn updates coalesce into a pending run
                // and train through the lane-speculative engine in one
                // ≤64-wide batch. Because the lane path is bit-identical
                // to applying each update in sequence (randomness is
                // keyed by `(base_seed, seq)`, not by batch shape), run
                // boundaries — queue drained, fault edit, batch to
                // score, full lane, shutdown — cannot affect results.
                let mut run: Vec<Arc<ShardUpdate>> = Vec::new();
                'worker: loop {
                    // Block only with an empty pending run (the run is
                    // always flushed before the worker sleeps).
                    let first = match rx.recv() {
                        Ok(w) => w,
                        Err(_) => break 'worker,
                    };
                    let mut next = Some(first);
                    while let Some(work) = next.take() {
                        match work {
                            Work::Update(u) => {
                                stats.updates += 1;
                                match &u.kind {
                                    UpdateKind::Learn { .. } => {
                                        run.push(u);
                                        if run.len() == 64 {
                                            flush_learn_run(
                                                &mut replica,
                                                &mut run,
                                                &params,
                                                base_seed,
                                                &mut rands,
                                                &mut scratch,
                                            );
                                        }
                                    }
                                    UpdateKind::ClauseFault { .. } => {
                                        // Fault edits must land in log
                                        // order relative to the Learns
                                        // around them.
                                        flush_learn_run(
                                            &mut replica,
                                            &mut run,
                                            &params,
                                            base_seed,
                                            &mut rands,
                                            &mut scratch,
                                        );
                                        replica.apply_update_with(
                                            &u, &params, base_seed, &mut rands,
                                        );
                                    }
                                }
                            }
                            Work::Batch(b) => {
                                // Score against every update received
                                // before the batch (FIFO order).
                                flush_learn_run(
                                    &mut replica,
                                    &mut run,
                                    &params,
                                    base_seed,
                                    &mut rands,
                                    &mut scratch,
                                );
                                let planes =
                                    BitPlanes::from_inputs(replica.shape(), &b.inputs);
                                let preds = replica.predict_planes(&planes, &params);
                                stats.batches += 1;
                                stats.samples += preds.len() as u64;
                                // One message per scored batch (not per
                                // sample) keeps channel overhead off the
                                // timed serving hot path. Receiver only
                                // drops after join: the send can't fail
                                // while we run.
                                let _ = out.send((b.ids, preds));
                            }
                        }
                        match rx.try_recv() {
                            Ok(w) => next = Some(w),
                            Err(mpsc::TryRecvError::Empty) => {
                                flush_learn_run(
                                    &mut replica,
                                    &mut run,
                                    &params,
                                    base_seed,
                                    &mut rands,
                                    &mut scratch,
                                );
                            }
                            Err(mpsc::TryRecvError::Disconnected) => {
                                flush_learn_run(
                                    &mut replica,
                                    &mut run,
                                    &params,
                                    base_seed,
                                    &mut rands,
                                    &mut scratch,
                                );
                                break 'worker;
                            }
                        }
                    }
                }
                stats
            }));
            senders.push(tx);
        }
        // Only worker clones of the response sender remain: the channel
        // closes exactly when the last worker exits.
        drop(res_tx);
        Ok(ShardServer { senders, handles, results: res_rx, next_shard: 0, seq: 0 })
    }

    /// Close the work channels, join every worker and collect all
    /// responses, sorted by request id.
    pub fn finish(self) -> Result<ServeOutcome> {
        let ShardServer { senders, handles, results, seq, .. } = self;
        drop(senders);
        let mut shards = Vec::with_capacity(handles.len());
        for h in handles {
            shards.push(h.join().map_err(|_| anyhow!("serve shard worker panicked"))?);
        }
        // All response senders are gone: this drains and terminates.
        let mut responses: Vec<(u64, usize)> = Vec::new();
        for (ids, preds) in results.iter() {
            responses.extend(ids.into_iter().zip(preds));
        }
        responses.sort_unstable_by_key(|&(id, _)| id);
        Ok(ServeOutcome { responses, shards, updates: seq })
    }
}

/// Apply a pending run of coalesced `Learn` updates to a replica —
/// bit-identical to `apply_update_with` per update in sequence order:
/// single-update runs go through exactly that path, longer runs through
/// the lane-speculative trainer with each sample's randomness keyed by
/// its own `(base_seed, seq)` pair. Clears the run.
fn flush_learn_run(
    replica: &mut MultiTm,
    run: &mut Vec<Arc<ShardUpdate>>,
    params: &TmParams,
    base_seed: u64,
    rands: &mut Option<StepRands>,
    scratch: &mut TrainScratch,
) {
    match run.len() {
        0 => return,
        1 => {
            // A 1-wide lane would pay the transpose for nothing.
            replica.apply_update_with(&run[0], params, base_seed, rands);
        }
        _ => {
            let shape = replica.shape().clone();
            let planes = BitPlanes::from_rows(&shape, run.len(), |i| learn_input(&run[i]));
            replica.train_plane_batch_by(
                run.as_slice(),
                learn_input_of,
                learn_label_of,
                &planes,
                params,
                |i, r| update_rands_into(r, &shape, base_seed, run[i].seq),
                scratch,
            );
        }
    }
    run.clear();
}

fn learn_input(u: &ShardUpdate) -> &Input {
    match &u.kind {
        UpdateKind::Learn { input, .. } => input,
        UpdateKind::ClauseFault { .. } => unreachable!("learn runs hold Learn updates only"),
    }
}

fn learn_input_of(u: &Arc<ShardUpdate>) -> &Input {
    learn_input(u)
}

fn learn_label_of(u: &Arc<ShardUpdate>) -> usize {
    match &u.kind {
        UpdateKind::Learn { label, .. } => *label,
        UpdateKind::ClauseFault { .. } => unreachable!("learn runs hold Learn updates only"),
    }
}

impl ServeBackend for ShardServer {
    fn update(&mut self, kind: UpdateKind) {
        self.seq += 1;
        let update = Arc::new(ShardUpdate { seq: self.seq, kind });
        for tx in &self.senders {
            let _ = tx.send(Work::Update(update.clone()));
        }
    }

    fn infer_batch(&mut self, batch: Vec<PendingRequest>) {
        if batch.is_empty() {
            return;
        }
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        let inputs: Vec<Input> = batch.into_iter().map(|r| r.input).collect();
        let _ = self.senders[self.next_shard].send(Work::Batch(MicroBatch { ids, inputs }));
        self.next_shard = (self.next_shard + 1) % self.senders.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TmShape;
    use crate::tm::rng::Xoshiro256;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    fn random_input(rng: &mut Xoshiro256, s: &TmShape) -> Input {
        Input::pack(s, &crate::testkit::gen::bool_vec(rng, s.features, 0.5))
    }

    #[test]
    fn rejects_zero_shards_and_bad_params() {
        let s = shape();
        let tm = MultiTm::new(&s).unwrap();
        let mut cfg = ServeConfig {
            shards: 0,
            params: TmParams::paper_offline(&s),
            base_seed: 1,
        };
        assert!(ShardServer::new(&tm, &cfg).is_err());
        cfg.shards = 1;
        cfg.params.active_clauses = 7; // odd: invalid
        assert!(ShardServer::new(&tm, &cfg).is_err());
    }

    #[test]
    fn responses_cover_every_request_exactly_once() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x51AB);
        let states: Vec<u32> =
            (0..s.num_tas()).map(|_| rng.next_below(2 * s.states as usize) as u32).collect();
        let tm = MultiTm::from_states(&s, states).unwrap();
        let cfg = ServeConfig { shards: 3, params: p.clone(), base_seed: 9 };
        let mut server = ShardServer::new(&tm, &cfg).unwrap();
        let mut scalar = tm.clone();
        let mut expected = Vec::new();
        let mut id = 0u64;
        for round in 0..12 {
            let batch: Vec<PendingRequest> = (0..(round % 5) + 1)
                .map(|_| {
                    let input = random_input(&mut rng, &s);
                    expected.push((id, scalar.predict(&input, &p)));
                    let req = PendingRequest { id, input };
                    id += 1;
                    req
                })
                .collect();
            server.infer_batch(batch);
        }
        server.infer_batch(Vec::new()); // empty batches are dropped
        let out = server.finish().unwrap();
        assert_eq!(out.responses, expected);
        assert_eq!(out.updates, 0);
        let scored: u64 = out.shards.iter().map(|st| st.samples).sum();
        assert_eq!(scored, id);
        let batches: u64 = out.shards.iter().map(|st| st.batches).sum();
        assert_eq!(batches, 12, "empty batch was not dispatched");
    }

    #[test]
    fn updates_reach_every_shard() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let tm = MultiTm::new(&s).unwrap();
        let cfg = ServeConfig { shards: 4, params: p, base_seed: 2 };
        let mut server = ShardServer::new(&tm, &cfg).unwrap();
        let mut rng = Xoshiro256::new(1);
        for i in 0..10 {
            server.update(UpdateKind::Learn {
                input: random_input(&mut rng, &s),
                label: i % 3,
            });
        }
        let out = server.finish().unwrap();
        assert_eq!(out.updates, 10);
        assert_eq!(out.shards.len(), 4);
        for st in &out.shards {
            assert_eq!(st.updates, 10, "shard {} missed a broadcast", st.shard);
        }
    }
}
