//! Shard workers: replicated machines behind FIFO work channels.
//!
//! Each shard worker owns a full replica of the served [`MultiTm`] and a
//! `std::sync::mpsc` receiver. The supervisor
//! ([`crate::serve::ShardServer`]) broadcasts every sequenced
//! [`ShardUpdate`] to *all* shards and deals flushed micro-batches
//! round-robin to one shard each. Because each channel is FIFO and
//! updates are sent before any batch that flushed after them, a replica
//! has applied exactly the updates with `seq ≤` the batch's flush point
//! by the time it scores the batch — and since replica updates are
//! deterministic in `(base_seed, seq)` (`MultiTm::apply_update`) and
//! `predict_planes` is bit-identical to the row-major path, every
//! response is independent of shard count, thread scheduling and batch
//! placement. That is the whole determinism argument; the soak suite
//! checks it against the scalar oracle rather than trusting it.
//!
//! Workers additionally coalesce **consecutive `Learn` updates** into
//! ≤64-wide runs and train them through the lane-speculative engine
//! (`tm::train_planes`) in one batched pass. The run boundaries depend
//! on queue timing and are therefore nondeterministic — which is safe
//! precisely because the lane path is bit-identical to applying the
//! same updates one by one: randomness is keyed per update, so batch
//! shape cannot leak into replica state.
//!
//! Since PR 6 the worker loop runs under `catch_unwind`: a panic —
//! organic or injected by the chaos harness ([`ChaosCmd`]) — is caught
//! at the thread boundary, reported as a [`Reply::Dead`] notice, and
//! surfaced through the join as a `panicked` exit instead of poisoning
//! the pool; the supervisor then respawns the shard from its latest
//! valid checkpoint and replays the retained log suffix. Workers also
//! answer [`Work::Snapshot`] markers with a checksummed
//! (`serve::checkpoint`) snapshot of their replica stamped with the last
//! applied seq, and honour deterministic stall windows (buffer `n` work
//! items unprocessed, then drain them in order — delaying, never
//! reordering).
//!
//! Shutdown is by channel closure: the supervisor drops the work
//! senders, workers drain and exit (returning a final snapshot for
//! post-trace state checks), and the response channel closes once the
//! last worker clone of its sender is gone — no sentinel messages, no
//! possibility of a worker outliving the pool.

use crate::serve::checkpoint;
use crate::tm::bitplane::BitPlanes;
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use crate::tm::train_planes::TrainScratch;
use crate::tm::update::{update_rands_into, ShardUpdate, UpdateKind};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Once};
use std::thread::JoinHandle;

/// A flushed micro-batch: request ids plus their packed inputs. The
/// bitplane transpose happens on the scoring shard (it is a pure
/// function of the batch, so placement cannot affect results), keeping
/// the dispatcher thread off the critical path — consecutive batches'
/// transposes overlap across shards.
#[derive(Debug)]
pub struct MicroBatch {
    pub ids: Vec<u64>,
    pub inputs: Vec<Input>,
}

/// Per-shard work counters, reported by
/// [`crate::serve::ShardServer::finish`]. Counters are summed across a
/// shard's incarnations; replayed updates and re-dispatched batches
/// count again on the incarnation that re-applies them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardStats {
    pub shard: usize,
    /// Sequenced updates applied by this replica (same on every shard in
    /// a failure-free run).
    pub updates: u64,
    /// Micro-batches this shard scored.
    pub batches: u64,
    /// Inference samples this shard scored.
    pub samples: u64,
}

/// Work items a shard worker consumes, in FIFO order.
pub(crate) enum Work {
    /// Shared, not cloned: the supervisor is the serialization point of
    /// the serving loop, so a broadcast costs one refcount bump per
    /// shard instead of a deep copy of the update's packed input.
    Update(Arc<ShardUpdate>),
    Batch(MicroBatch),
    /// Snapshot the replica now (at the seq of the last applied update)
    /// and ship it to the supervisor as [`Reply::Snapshot`].
    Snapshot,
    Chaos(ChaosCmd),
}

/// Injected-fault commands (sent only by a supervisor driving a
/// [`crate::serve::ChaosPlan`]).
#[derive(Debug, Clone, Copy)]
pub(crate) enum ChaosCmd {
    /// Panic immediately (unwound at the thread boundary, reported,
    /// recovered by the supervisor).
    Die,
    /// Arm the worker: panic when the next micro-batch arrives, losing
    /// the batch with it.
    DieOnNextBatch,
    /// Buffer the next `items` work items unprocessed (no replies, no
    /// heartbeats), then drain them in order and resume.
    Stall { items: usize },
}

/// What workers send back on the (unbounded) response channel.
pub(crate) enum Reply {
    /// A scored micro-batch; `applied_seq` doubles as the shard's
    /// heartbeat (the log position it has provably reached).
    Scored { shard: usize, ids: Vec<u64>, preds: Vec<usize>, applied_seq: u64 },
    /// A checksummed replica snapshot answering a [`Work::Snapshot`]
    /// marker, stamped with the last applied seq.
    Snapshot { shard: usize, seq: u64, bytes: Vec<u8> },
    /// The worker's loop panicked (chaos kill or organic bug); sent from
    /// the `catch_unwind` boundary just before the thread exits.
    Dead { shard: usize, gen: u64, cause: String },
}

/// How a worker thread ended, returned through its join handle.
pub(crate) struct WorkerExit {
    pub stats: ShardStats,
    /// Snapshot of the final replica state (clean exits only) — the
    /// supervisor decodes these for [`crate::serve::ServeOutcome`]'s
    /// post-trace replica checks.
    pub final_snapshot: Option<Vec<u8>>,
    pub panicked: bool,
}

/// Work-queue depth per shard. Bounded so a dispatcher outrunning its
/// shards blocks (backpressure) instead of buffering the whole trace in
/// channel memory; deep enough that the bound is never felt at sane
/// batch sizes. Deadlock-free by construction: workers drain their
/// queue unconditionally (stalled workers still *receive* — they buffer)
/// and only ever send on the *unbounded* response channel, so a blocked
/// dispatcher always unblocks.
pub(crate) const WORK_QUEUE_DEPTH: usize = 1024;

/// Marker panic payload for chaos kills: the quiet hook (installed once,
/// process-wide) suppresses the default "thread panicked" stderr report
/// for these — they are *scheduled* faults whose whole point is to be
/// caught and recovered, and libtest does not capture spawned threads'
/// panic output — while leaving organic panics as loud as ever.
pub(crate) struct ChaosKill;

static QUIET_CHAOS_HOOK: Once = Once::new();

pub(crate) fn install_quiet_chaos_hook() {
    QUIET_CHAOS_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<ChaosKill>().is_none() {
                prev(info);
            }
        }));
    });
}

/// Everything a worker owns between work items.
struct WorkerState {
    replica: MultiTm,
    /// Seq of the last update received into the replica (or its pending
    /// learn run). The run is always flushed before this value is
    /// observable (batch scoring, snapshots), so at those points the
    /// replica state *is* the log state at `applied_seq`.
    applied_seq: u64,
    /// Coalesced consecutive Learn updates (≤ 64, lane-trained on flush).
    run: Vec<Arc<ShardUpdate>>,
    rands: Option<StepRands>,
    scratch: TrainScratch,
    /// Armed by [`ChaosCmd::DieOnNextBatch`].
    doomed: bool,
    /// Remaining stall window ([`ChaosCmd::Stall`]), in work items.
    stall: usize,
    /// Work buffered during the stall window, drained in order on wake.
    held: VecDeque<Work>,
}

/// Spawn one shard worker (incarnation `gen`) owning `replica`, which
/// has applied the log up to `start_seq`. Returns its bounded work
/// sender and join handle; replies go to `out`.
pub(crate) fn spawn_worker(
    shard: usize,
    gen: u64,
    replica: MultiTm,
    start_seq: u64,
    params: TmParams,
    base_seed: u64,
    out: mpsc::Sender<Reply>,
) -> (mpsc::SyncSender<Work>, JoinHandle<WorkerExit>) {
    install_quiet_chaos_hook();
    let (tx, rx) = mpsc::sync_channel::<Work>(WORK_QUEUE_DEPTH);
    let handle = std::thread::spawn(move || {
        let mut stats = ShardStats { shard, updates: 0, batches: 0, samples: 0 };
        let mut w = WorkerState {
            replica,
            applied_seq: start_seq,
            run: Vec::new(),
            rands: None,
            scratch: TrainScratch::new(),
            doomed: false,
            stall: 0,
            held: VecDeque::new(),
        };
        // The unwind boundary: `stats` and `w` live outside so a caught
        // panic still reports the work done before it. `AssertUnwindSafe`
        // is sound here because a panicked incarnation's state is never
        // reused — the supervisor rebuilds from a checkpoint.
        let result = catch_unwind(AssertUnwindSafe(|| {
            worker_loop(&rx, &out, &mut w, &mut stats, shard, &params, base_seed);
        }));
        match result {
            Ok(()) => WorkerExit {
                stats,
                final_snapshot: Some(checkpoint::snapshot_bytes(
                    &w.replica,
                    &params,
                    w.applied_seq,
                )),
                panicked: false,
            },
            Err(payload) => {
                let cause = if payload.downcast_ref::<ChaosKill>().is_some() {
                    "chaos kill".to_string()
                } else if let Some(s) = payload.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = payload.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "non-string panic payload".to_string()
                };
                // Best-effort liveness notice; the join result is the
                // authoritative detection path.
                let _ = out.send(Reply::Dead { shard, gen, cause });
                WorkerExit { stats, final_snapshot: None, panicked: true }
            }
        }
    });
    (tx, handle)
}

fn worker_loop(
    rx: &mpsc::Receiver<Work>,
    out: &mpsc::Sender<Reply>,
    w: &mut WorkerState,
    stats: &mut ShardStats,
    shard: usize,
    params: &TmParams,
    base_seed: u64,
) {
    'worker: loop {
        // Block only with an empty pending run (the run is always
        // flushed before the worker sleeps).
        let first = match rx.recv() {
            Ok(work) => work,
            Err(_) => break 'worker,
        };
        let mut next = Some(first);
        while let Some(work) = next.take() {
            absorb(work, w, stats, out, shard, params, base_seed);
            match rx.try_recv() {
                Ok(work) => next = Some(work),
                Err(mpsc::TryRecvError::Empty) => {
                    if w.stall == 0 {
                        flush_learn_run(
                            &mut w.replica,
                            &mut w.run,
                            params,
                            base_seed,
                            &mut w.rands,
                            &mut w.scratch,
                        );
                    }
                }
                Err(mpsc::TryRecvError::Disconnected) => break 'worker,
            }
        }
    }
    // Channel closed mid-stall: the window ends at shutdown — drain the
    // buffer in order so held work is delayed, never lost.
    w.stall = 0;
    let held: Vec<Work> = w.held.drain(..).collect();
    for work in held {
        process(work, w, stats, out, shard, params, base_seed);
    }
    flush_learn_run(&mut w.replica, &mut w.run, params, base_seed, &mut w.rands, &mut w.scratch);
}

/// Route one work item through the stall buffer or straight to
/// [`process`].
#[allow(clippy::too_many_arguments)]
fn absorb(
    work: Work,
    w: &mut WorkerState,
    stats: &mut ShardStats,
    out: &mpsc::Sender<Reply>,
    shard: usize,
    params: &TmParams,
    base_seed: u64,
) {
    if w.stall > 0 {
        w.held.push_back(work);
        w.stall -= 1;
        if w.stall == 0 {
            let held: Vec<Work> = w.held.drain(..).collect();
            for item in held {
                process(item, w, stats, out, shard, params, base_seed);
            }
        }
    } else {
        process(work, w, stats, out, shard, params, base_seed);
    }
}

#[allow(clippy::too_many_arguments)]
fn process(
    work: Work,
    w: &mut WorkerState,
    stats: &mut ShardStats,
    out: &mpsc::Sender<Reply>,
    shard: usize,
    params: &TmParams,
    base_seed: u64,
) {
    match work {
        Work::Update(u) => {
            stats.updates += 1;
            let seq = u.seq;
            match &u.kind {
                UpdateKind::Learn { .. } => {
                    w.run.push(u);
                    if w.run.len() == 64 {
                        flush_learn_run(
                            &mut w.replica,
                            &mut w.run,
                            params,
                            base_seed,
                            &mut w.rands,
                            &mut w.scratch,
                        );
                    }
                }
                UpdateKind::ClauseFault { .. } => {
                    // Fault edits must land in log order relative to the
                    // Learns around them.
                    flush_learn_run(
                        &mut w.replica,
                        &mut w.run,
                        params,
                        base_seed,
                        &mut w.rands,
                        &mut w.scratch,
                    );
                    w.replica.apply_update_with(&u, params, base_seed, &mut w.rands);
                }
            }
            w.applied_seq = seq;
        }
        Work::Batch(b) => {
            if w.doomed {
                // The armed kill lands exactly when the batch does: the
                // batch is lost with the worker and must be recovered by
                // supervisor re-dispatch.
                std::panic::panic_any(ChaosKill);
            }
            // Score against every update received before the batch
            // (FIFO order).
            flush_learn_run(
                &mut w.replica,
                &mut w.run,
                params,
                base_seed,
                &mut w.rands,
                &mut w.scratch,
            );
            let planes = BitPlanes::from_inputs(w.replica.shape(), &b.inputs);
            let preds = w.replica.predict_planes(&planes, params);
            stats.batches += 1;
            stats.samples += preds.len() as u64;
            // One message per scored batch (not per sample) keeps
            // channel overhead off the timed serving hot path.
            let _ = out.send(Reply::Scored {
                shard,
                ids: b.ids,
                preds,
                applied_seq: w.applied_seq,
            });
        }
        Work::Snapshot => {
            flush_learn_run(
                &mut w.replica,
                &mut w.run,
                params,
                base_seed,
                &mut w.rands,
                &mut w.scratch,
            );
            let bytes = checkpoint::snapshot_bytes(&w.replica, params, w.applied_seq);
            let _ = out.send(Reply::Snapshot { shard, seq: w.applied_seq, bytes });
        }
        Work::Chaos(cmd) => match cmd {
            ChaosCmd::Die => std::panic::panic_any(ChaosKill),
            ChaosCmd::DieOnNextBatch => w.doomed = true,
            ChaosCmd::Stall { items } => w.stall = items,
        },
    }
}

/// Apply a pending run of coalesced `Learn` updates to a replica —
/// bit-identical to `apply_update_with` per update in sequence order:
/// single-update runs go through exactly that path, longer runs through
/// the lane-speculative trainer with each sample's randomness keyed by
/// its own `(base_seed, seq)` pair. Clears the run.
fn flush_learn_run(
    replica: &mut MultiTm,
    run: &mut Vec<Arc<ShardUpdate>>,
    params: &TmParams,
    base_seed: u64,
    rands: &mut Option<StepRands>,
    scratch: &mut TrainScratch,
) {
    match run.len() {
        0 => return,
        1 => {
            // A 1-wide lane would pay the transpose for nothing.
            replica.apply_update_with(&run[0], params, base_seed, rands);
        }
        _ => {
            let shape = replica.shape().clone();
            let planes = BitPlanes::from_rows(&shape, run.len(), |i| learn_input(&run[i]));
            replica.train_plane_batch_by(
                run.as_slice(),
                learn_input_of,
                learn_label_of,
                &planes,
                params,
                |i, r| update_rands_into(r, &shape, base_seed, run[i].seq),
                scratch,
            );
        }
    }
    run.clear();
}

fn learn_input(u: &ShardUpdate) -> &Input {
    match &u.kind {
        UpdateKind::Learn { input, .. } => input,
        UpdateKind::ClauseFault { .. } => unreachable!("learn runs hold Learn updates only"),
    }
}

fn learn_input_of(u: &Arc<ShardUpdate>) -> &Input {
    learn_input(u)
}

fn learn_label_of(u: &Arc<ShardUpdate>) -> usize {
    match &u.kind {
        UpdateKind::Learn { label, .. } => *label,
        UpdateKind::ClauseFault { .. } => unreachable!("learn runs hold Learn updates only"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TmShape;
    use crate::tm::rng::Xoshiro256;

    fn shape() -> TmShape {
        TmShape::iris()
    }

    /// The worker primitive end-to-end: updates, a snapshot marker, a
    /// batch, then channel-closure shutdown with a final snapshot.
    #[test]
    fn worker_applies_updates_snapshots_and_scores() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x11AB);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let (res_tx, res_rx) = mpsc::channel();
        let (tx, handle) = spawn_worker(0, 0, tm.clone(), 0, p.clone(), 5, res_tx);

        let mut oracle = tm.clone();
        for seq in 1..=10u64 {
            let input =
                Input::pack(&s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
            let u = Arc::new(ShardUpdate {
                seq,
                kind: UpdateKind::Learn { input, label: seq as usize % s.classes },
            });
            oracle.apply_update(&u, &p, 5);
            tx.send(Work::Update(u)).unwrap();
        }
        tx.send(Work::Snapshot).unwrap();
        let probe = Input::pack(&s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
        tx.send(Work::Batch(MicroBatch { ids: vec![42], inputs: vec![probe.clone()] }))
            .unwrap();
        drop(tx);
        let exit = handle.join().unwrap();
        assert!(!exit.panicked);
        assert_eq!(exit.stats.updates, 10);
        assert_eq!(exit.stats.batches, 1);

        let mut got_snapshot = false;
        let mut got_scored = false;
        for reply in res_rx.iter() {
            match reply {
                Reply::Snapshot { seq, bytes, .. } => {
                    assert_eq!(seq, 10);
                    let snap = checkpoint::restore(&bytes).unwrap();
                    assert_eq!(snap.machine.state_digest(), oracle.state_digest());
                    got_snapshot = true;
                }
                Reply::Scored { ids, preds, applied_seq, .. } => {
                    assert_eq!(ids, vec![42]);
                    assert_eq!(applied_seq, 10);
                    assert_eq!(preds, vec![oracle.predict(&probe, &p)]);
                    got_scored = true;
                }
                Reply::Dead { .. } => panic!("clean run produced a Dead notice"),
            }
        }
        assert!(got_snapshot && got_scored);
        let final_snap = checkpoint::restore(&exit.final_snapshot.unwrap()).unwrap();
        assert_eq!(final_snap.seq, 10);
        assert_eq!(final_snap.machine.state_digest(), oracle.state_digest());
    }

    /// A chaos kill is caught at the unwind boundary: Dead notice,
    /// panicked exit, no process-level fallout.
    #[test]
    fn chaos_kill_is_caught_and_reported() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let tm = MultiTm::new(&s).unwrap();
        let (res_tx, res_rx) = mpsc::channel();
        let (tx, handle) = spawn_worker(3, 7, tm, 0, p, 1, res_tx);
        tx.send(Work::Chaos(ChaosCmd::Die)).unwrap();
        let exit = handle.join().unwrap();
        assert!(exit.panicked);
        assert!(exit.final_snapshot.is_none());
        match res_rx.recv().unwrap() {
            Reply::Dead { shard, gen, cause } => {
                assert_eq!((shard, gen), (3, 7));
                assert_eq!(cause, "chaos kill");
            }
            _ => panic!("expected a Dead notice"),
        }
    }

    /// A stall window delays work without reordering or dropping it:
    /// the stalled worker's final state matches an unstalled twin.
    #[test]
    fn stall_delays_but_never_reorders() {
        let s = shape();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x57A);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let updates: Vec<Arc<ShardUpdate>> = (1..=8u64)
            .map(|seq| {
                let input =
                    Input::pack(&s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
                Arc::new(ShardUpdate {
                    seq,
                    kind: UpdateKind::Learn { input, label: seq as usize % s.classes },
                })
            })
            .collect();
        let run = |stall_after: Option<usize>| -> u64 {
            let (res_tx, _res_rx) = mpsc::channel();
            let (tx, handle) = spawn_worker(0, 0, tm.clone(), 0, p.clone(), 9, res_tx);
            for (i, u) in updates.iter().enumerate() {
                if stall_after == Some(i) {
                    tx.send(Work::Chaos(ChaosCmd::Stall { items: 3 })).unwrap();
                }
                tx.send(Work::Update(u.clone())).unwrap();
            }
            drop(tx);
            let exit = handle.join().unwrap();
            assert!(!exit.panicked);
            assert_eq!(exit.stats.updates, 8);
            let snap = checkpoint::restore(&exit.final_snapshot.unwrap()).unwrap();
            assert_eq!(snap.seq, 8);
            snap.machine.state_digest()
        };
        let clean = run(None);
        // Stall windows at several points, including one the shutdown
        // drain must cut short (stall issued with < 3 items left).
        for stall_after in [0, 3, 6] {
            assert_eq!(run(Some(stall_after)), clean, "stall after item {stall_after}");
        }
    }
}
