//! The supervising shard server: dispatch, checkpoints, recovery.
//!
//! [`ShardServer`] is the serialization point of the serving loop. It
//! owns the sequenced update log, broadcasts every update to all shard
//! workers (`serve::shard`), deals flushed micro-batches round-robin,
//! and — since PR 6 — keeps the pool *fault-tolerant*:
//!
//! - **Checkpoints.** Every [`FaultPolicy::checkpoint_every`] updates
//!   the supervisor sends each live shard a snapshot marker; workers
//!   answer with a checksummed replica snapshot (`serve::checkpoint`)
//!   stamped with the last applied seq. The newest
//!   [`FaultPolicy::retained_snapshots`] per shard are kept, seeded with a genesis
//!   snapshot at seq 0 so recovery is always possible.
//! - **Supervision.** Workers run under `catch_unwind`; a panic
//!   (organic or chaos-injected) surfaces as a `Dead` notice / failed
//!   send / panicked join, never as a poisoned pool. After
//!   [`FaultPolicy::recovery_lag`] further operations the supervisor
//!   respawns the shard from its newest snapshot that passes CRC
//!   verification (corrupt ones are rejected and counted, falling back
//!   to an older snapshot and a longer replay), replays the retained
//!   log suffix, and re-dispatches the shard's unscored batches at
//!   their original flush points — so the recovered run is
//!   **bit-identical** to one that never failed.
//! - **Degraded modes.** While a shard is down, surviving shards absorb
//!   its batches up to [`FaultPolicy::degraded_depth`] each; beyond
//!   that (or with every shard down) batches are *shed*: their ids are
//!   returned in [`ServeOutcome::shed`] and counted in
//!   [`RecoveryStats`] — an explicit overload response, never a silent
//!   drop.
//!
//! Why replay is exact: all update randomness is keyed by
//! `(base_seed, seq)` (`tm::update`), so applying the log suffix to a
//! restored snapshot reproduces the lost replica bit-for-bit; and FIFO
//! work channels mean a batch's responses depend only on its flush seq,
//! which the supervisor recorded at dispatch. Exactly-once scoring
//! holds because a dead worker's sends all happen-before its join: any
//! batch it scored is drained from the outstanding set before the
//! supervisor decides what to re-dispatch. `finish` additionally
//! verifies that no request id was answered twice.
//!
//! Determinism of the *failure handling itself* (which batches shed,
//! how many updates replayed) comes from driving faults off the
//! deterministic op/seq clocks via [`ChaosPlan`], not wall-clock
//! timeouts; worker heartbeats (the applied seq stamped on every scored
//! batch) are surfaced through [`ShardServer::heartbeats`] as a
//! liveness cross-check.

use crate::serve::batcher::PendingRequest;
use crate::serve::chaos::{ChaosEvent, ChaosPlan, KillKind};
use crate::serve::checkpoint;
use crate::serve::shard::{
    spawn_worker, ChaosCmd, MicroBatch, Reply, ShardStats, Work, WorkerExit,
};
use crate::serve::ServeBackend;
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::update::{ShardUpdate, UpdateKind};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Fault-tolerance policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPolicy {
    /// Send snapshot markers every this many updates (`0` = genesis
    /// snapshot only — recovery replays the whole log).
    pub checkpoint_every: u64,
    /// Operations (updates + batch dispatches) a shard stays down
    /// before the supervisor recovers it. `0` recovers at the next
    /// operation; larger values leave a window in which surviving
    /// shards absorb the load (or shed it).
    pub recovery_lag: u64,
    /// Batches each surviving shard may absorb during an outage before
    /// further batches are shed with an explicit overload response.
    pub degraded_depth: u64,
    /// Newest checkpoints retained per shard, validated ≥ 1 by
    /// `ShardServer::build`. Two by default, not one: a corrupted
    /// newest snapshot must leave an older one to fall back to (at the
    /// price of a longer replay). Memory-tight deployments can drop to
    /// 1; the durable store makes deeper retention cheap.
    pub retained_snapshots: usize,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            checkpoint_every: 64,
            recovery_lag: 0,
            degraded_depth: u64::MAX,
            retained_snapshots: 2,
        }
    }
}

/// Configuration for [`ShardServer`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker replica count (≥ 1).
    pub shards: usize,
    pub params: TmParams,
    /// Base seed for the `(base_seed, seq)` update-randomness contract.
    pub base_seed: u64,
    pub fault: FaultPolicy,
}

impl ServeConfig {
    pub fn new(shards: usize, params: TmParams, base_seed: u64) -> Self {
        ServeConfig { shards, params, base_seed, fault: FaultPolicy::default() }
    }
}

/// Fault-handling counters, reported in [`ServeOutcome`]. Exact by
/// construction: every shed request id is also listed in
/// [`ServeOutcome::shed`], and the chaos suite asserts the counters
/// against the schedule.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Successful shard recoveries (respawn + replay).
    pub recoveries: u64,
    /// Worker incarnations that ended by panic (chaos or organic).
    pub worker_panics: u64,
    /// Snapshots received and retained from workers (genesis excluded).
    pub snapshots_stored: u64,
    /// Snapshots that failed verification at restore time and were
    /// discarded in favour of an older one.
    pub corrupt_snapshots_rejected: u64,
    /// Incoming snapshots rejected because their seq regressed behind
    /// the newest retained one — storing them would rewind recovery
    /// past updates the shard provably applied.
    pub regressed_snapshots_rejected: u64,
    /// Log updates re-sent to respawned workers.
    pub replayed_updates: u64,
    /// Unscored batches re-dispatched to their shard's new incarnation.
    pub redispatched_batches: u64,
    /// Batches shed with an overload response instead of dispatched.
    pub shed_batches: u64,
    /// Request ids inside those shed batches.
    pub shed_requests: u64,
    /// Chaos events that armed (their precondition held when due).
    pub chaos_events_fired: u64,
    /// Chaos events skipped because their target was not live when due.
    pub chaos_events_skipped: u64,
}

/// What a serving run produced, returned by [`ShardServer::finish`].
#[derive(Debug)]
pub struct ServeOutcome {
    /// `(request_id, predicted_class)`, sorted by request id. Shed
    /// requests are absent here and listed in `shed` instead.
    pub responses: Vec<(u64, usize)>,
    /// Per-shard work counters (summed over a shard's incarnations).
    pub shards: Vec<ShardStats>,
    /// Total sequenced updates applied.
    pub updates: u64,
    /// Request ids shed with an overload response, sorted.
    pub shed: Vec<u64>,
    pub recovery: RecoveryStats,
    /// Each shard's final replica, decoded from its verified exit
    /// snapshot — bit-identical across shards (and to the oracle) in
    /// any run whose failures were all recovered.
    pub replicas: Vec<MultiTm>,
}

/// A retained checkpoint: the log seq it captures plus the verified
/// byte image (verification happens at restore time, so corruption
/// injected *after* storage is still caught).
struct Snapshot {
    seq: u64,
    bytes: Vec<u8>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotHealth {
    Live,
    /// Armed by a `DieOnNextBatch` chaos kill: still applying updates,
    /// will panic on its next dispatched batch.
    Doomed,
    /// Inside a known stall window: work is buffered, not processed.
    /// `left` counts work items until the worker drains and resumes.
    Stalled { left: u64 },
    /// Down since operation `since_op`; recovered once
    /// `ops - since_op > recovery_lag`.
    Dead { since_op: u64 },
}

/// A dispatched-but-unscored batch, remembered so a shard death cannot
/// lose it: `flush_seq` pins the exact log position it must be scored
/// at if re-dispatched.
struct OutstandingBatch {
    flush_seq: u64,
    ids: Vec<u64>,
    inputs: Vec<Input>,
}

struct Slot {
    shard: usize,
    /// Incarnation counter; bumped on every respawn so late replies
    /// from a dead incarnation cannot flip the new one's health.
    gen: u64,
    tx: Option<mpsc::SyncSender<Work>>,
    join: Option<JoinHandle<WorkerExit>>,
    health: SlotHealth,
    /// Oldest-first retained checkpoints (genesis-seeded).
    snaps: VecDeque<Snapshot>,
    /// Lifetime snapshot count for this shard (all incarnations) — the
    /// coordinate chaos `CorruptSnapshot { nth }` events key on.
    snaps_received: u64,
    /// Dispatch-ordered unscored batches.
    outstanding: VecDeque<OutstandingBatch>,
    /// Batches absorbed while some other shard was down (degraded-mode
    /// load accounting; reset when the outage ends).
    outage_absorbed: u64,
    /// Highest log seq this shard has provably reached (stamped on its
    /// scored batches and snapshots).
    last_heartbeat: u64,
    /// Last panic cause reported by this slot's current incarnation.
    last_cause: Option<String>,
}

struct ChaosState {
    plan: ChaosPlan,
    fired: Vec<bool>,
}

/// Replicated, supervised serving pool. See the module docs for the
/// determinism and recovery arguments.
pub struct ShardServer {
    params: TmParams,
    base_seed: u64,
    policy: FaultPolicy,
    slots: Vec<Slot>,
    res_tx: mpsc::Sender<Reply>,
    res_rx: mpsc::Receiver<Reply>,
    next_shard: usize,
    /// Update log clock: seq of the last broadcast update.
    seq: u64,
    /// Operation clock (updates + batch dispatches) — the deterministic
    /// time base for recovery lag.
    ops: u64,
    /// Retained update log, trimmed below the minimum checkpointed seq
    /// across shards (their *oldest* retained snapshots, so any
    /// fallback replay is still covered).
    log: VecDeque<Arc<ShardUpdate>>,
    responses: Vec<(u64, usize)>,
    shed: Vec<u64>,
    /// Responses already handed out through [`NetBackend`] polls; merged
    /// back in `finish` so the exactly-once audit covers the whole run.
    streamed: Vec<(u64, usize)>,
    /// Shed ids already handed out through [`NetBackend`] polls.
    streamed_shed: Vec<u64>,
    /// Per-shard stats accumulated from joined (dead) incarnations.
    agg: Vec<ShardStats>,
    recovery: RecoveryStats,
    chaos: Option<ChaosState>,
    /// First unrecoverable error; surfaced by `finish`.
    fatal: Option<anyhow::Error>,
}

impl ShardServer {
    /// Spin up `cfg.shards` worker replicas of `tm`.
    pub fn new(tm: &MultiTm, cfg: &ServeConfig) -> Result<Self> {
        Self::build(tm, cfg, None)
    }

    /// Same, with a deterministic fault schedule armed.
    pub fn with_chaos(tm: &MultiTm, cfg: &ServeConfig, plan: ChaosPlan) -> Result<Self> {
        Self::build(tm, cfg, Some(plan))
    }

    fn build(tm: &MultiTm, cfg: &ServeConfig, plan: Option<ChaosPlan>) -> Result<Self> {
        if cfg.shards == 0 {
            bail!("serve: shard count must be >= 1");
        }
        if cfg.fault.retained_snapshots == 0 {
            bail!("serve: retained_snapshots must be >= 1");
        }
        cfg.params
            .validate(tm.shape())
            .context("serve: params do not fit the served model")?;
        let (res_tx, res_rx) = mpsc::channel();
        let genesis = checkpoint::snapshot_bytes(tm, &cfg.params, 0);
        let mut slots = Vec::with_capacity(cfg.shards);
        let mut agg = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, join) = spawn_worker(
                shard,
                0,
                tm.clone(),
                0,
                cfg.params.clone(),
                cfg.base_seed,
                res_tx.clone(),
            );
            let mut snaps = VecDeque::with_capacity(cfg.fault.retained_snapshots + 1);
            snaps.push_back(Snapshot { seq: 0, bytes: genesis.clone() });
            slots.push(Slot {
                shard,
                gen: 0,
                tx: Some(tx),
                join: Some(join),
                health: SlotHealth::Live,
                snaps,
                snaps_received: 0,
                outstanding: VecDeque::new(),
                outage_absorbed: 0,
                last_heartbeat: 0,
                last_cause: None,
            });
            agg.push(ShardStats { shard, updates: 0, batches: 0, samples: 0 });
        }
        Ok(ShardServer {
            params: cfg.params.clone(),
            base_seed: cfg.base_seed,
            policy: cfg.fault,
            slots,
            res_tx,
            res_rx,
            next_shard: 0,
            seq: 0,
            ops: 0,
            log: VecDeque::new(),
            responses: Vec::new(),
            shed: Vec::new(),
            streamed: Vec::new(),
            streamed_shed: Vec::new(),
            agg,
            recovery: RecoveryStats::default(),
            chaos: plan.map(|plan| {
                let fired = vec![false; plan.events.len()];
                ChaosState { plan, fired }
            }),
            fatal: None,
        })
    }

    /// Per-shard heartbeat: the highest log seq each shard has provably
    /// applied.
    pub fn heartbeats(&self) -> Vec<u64> {
        self.slots.iter().map(|s| s.last_heartbeat).collect()
    }

    /// Send one work item to a shard, maintaining the supervisor's
    /// model of its stall window and detecting hung-up (dead) workers.
    fn send_work(&mut self, shard: usize, work: Work) {
        let slot = &mut self.slots[shard];
        let Some(tx) = slot.tx.as_ref() else { return };
        let sent = tx.send(work).is_ok();
        if let SlotHealth::Stalled { left } = &mut slot.health {
            *left = left.saturating_sub(1);
        }
        if slot.health == (SlotHealth::Stalled { left: 0 }) {
            slot.health = SlotHealth::Live;
        }
        if !sent && !matches!(slot.health, SlotHealth::Dead { .. }) {
            slot.health = SlotHealth::Dead { since_op: self.ops };
        }
    }

    fn drain_replies(&mut self) {
        while let Ok(reply) = self.res_rx.try_recv() {
            self.handle_reply(reply);
        }
    }

    fn handle_reply(&mut self, reply: Reply) {
        match reply {
            Reply::Scored { shard, ids, preds, applied_seq } => {
                let slot = &mut self.slots[shard];
                slot.last_heartbeat = slot.last_heartbeat.max(applied_seq);
                if let Some(first) = ids.first() {
                    if let Some(pos) =
                        slot.outstanding.iter().position(|b| b.ids.first() == Some(first))
                    {
                        slot.outstanding.remove(pos);
                    }
                }
                self.responses.extend(ids.into_iter().zip(preds));
            }
            Reply::Snapshot { shard, seq, mut bytes } => {
                self.slots[shard].snaps_received += 1;
                let nth = self.slots[shard].snaps_received;
                if let Some(chaos) = &mut self.chaos {
                    for (i, ev) in chaos.plan.events.iter().enumerate() {
                        if chaos.fired[i] {
                            continue;
                        }
                        if let ChaosEvent::CorruptSnapshot { shard: s, nth: n } = ev {
                            if *s == shard && *n == nth {
                                chaos.fired[i] = true;
                                self.recovery.chaos_events_fired += 1;
                                // One flipped byte mid-image: exactly the
                                // damage the restore-time CRC must catch.
                                let mid = bytes.len() / 2;
                                bytes[mid] ^= 0x40;
                                break;
                            }
                        }
                    }
                }
                // A snapshot whose seq regresses behind the newest
                // retained one would rewind recovery past updates the
                // shard provably applied: reject it, keep the ledger.
                let newest =
                    self.slots[shard].snaps.back().map(|snap| snap.seq).unwrap_or(0);
                if seq < newest {
                    self.recovery.regressed_snapshots_rejected += 1;
                    return;
                }
                self.recovery.snapshots_stored += 1;
                let slot = &mut self.slots[shard];
                slot.last_heartbeat = slot.last_heartbeat.max(seq);
                slot.snaps.push_back(Snapshot { seq, bytes });
                while slot.snaps.len() > self.policy.retained_snapshots {
                    slot.snaps.pop_front();
                }
            }
            Reply::Dead { shard, gen, cause } => {
                let slot = &mut self.slots[shard];
                if gen == slot.gen {
                    slot.last_cause = Some(cause);
                    if !matches!(slot.health, SlotHealth::Dead { .. }) {
                        slot.health = SlotHealth::Dead { since_op: self.ops };
                    }
                }
            }
        }
    }

    /// Fire chaos events scheduled at update `seq`. Events whose target
    /// is not live when due are skipped (and counted): a second kill on
    /// an already-dead shard is a no-op, not a double fault.
    fn fire_chaos_at(&mut self, seq: u64) {
        let due: Vec<(usize, ChaosEvent)> = match &self.chaos {
            None => return,
            Some(chaos) => chaos
                .plan
                .events
                .iter()
                .enumerate()
                .filter(|(i, ev)| !chaos.fired[*i] && trigger_seq(ev) == Some(seq))
                .map(|(i, ev)| (i, ev.clone()))
                .collect(),
        };
        for (i, ev) in due {
            if let Some(chaos) = &mut self.chaos {
                chaos.fired[i] = true;
            }
            let (shard, live) = match &ev {
                ChaosEvent::Kill { shard, .. } | ChaosEvent::Stall { shard, .. } => {
                    (*shard, self.slots[*shard].health == SlotHealth::Live)
                }
                ChaosEvent::CorruptSnapshot { .. } => continue, // keyed on receipt, not seq
            };
            if !live {
                self.recovery.chaos_events_skipped += 1;
                continue;
            }
            self.recovery.chaos_events_fired += 1;
            match ev {
                ChaosEvent::Kill { kind: KillKind::Immediate, .. } => {
                    self.send_work(shard, Work::Chaos(ChaosCmd::Die));
                    self.slots[shard].health = SlotHealth::Dead { since_op: self.ops };
                }
                ChaosEvent::Kill { kind: KillKind::OnNextBatch, .. } => {
                    self.send_work(shard, Work::Chaos(ChaosCmd::DieOnNextBatch));
                    self.slots[shard].health = SlotHealth::Doomed;
                }
                ChaosEvent::Stall { items, .. } => {
                    self.send_work(shard, Work::Chaos(ChaosCmd::Stall { items }));
                    self.slots[shard].health = SlotHealth::Stalled { left: items as u64 };
                }
                ChaosEvent::CorruptSnapshot { .. } => unreachable!(),
            }
        }
    }

    fn run_due_recoveries(&mut self) {
        if self.fatal.is_some() {
            return;
        }
        for i in 0..self.slots.len() {
            if let SlotHealth::Dead { since_op } = self.slots[i].health {
                if self.ops.saturating_sub(since_op) > self.policy.recovery_lag {
                    if let Err(e) = self.recover(i) {
                        self.fatal = Some(e);
                        return;
                    }
                }
            }
        }
        if !self.slots.iter().any(|s| matches!(s.health, SlotHealth::Dead { .. })) {
            for s in &mut self.slots {
                s.outage_absorbed = 0;
            }
        }
    }

    /// Respawn a dead shard from its newest valid checkpoint, replay
    /// the log suffix, and re-dispatch its unscored batches at their
    /// original flush points.
    fn recover(&mut self, shard: usize) -> Result<()> {
        // Tear down: close the channel, then join. The join is the
        // synchronization point — every reply the dead incarnation sent
        // happens-before it, so the drain below sees the complete
        // record of what was actually scored and snapshotted.
        self.slots[shard].tx = None;
        if let Some(join) = self.slots[shard].join.take() {
            let exit = join
                .join()
                .map_err(|_| anyhow!("serve: shard {shard} panicked outside its unwind boundary"))?;
            self.merge_stats(shard, exit.stats);
            if exit.panicked {
                self.recovery.worker_panics += 1;
            }
        }
        self.drain_replies();

        // Newest snapshot that passes verification wins; corrupt ones
        // are rejected (counted) and the next-older tried — a longer
        // replay, never a silent load.
        let (snap_seq, machine) = loop {
            let Some(snap) = self.slots[shard].snaps.back() else {
                let cause = self.slots[shard]
                    .last_cause
                    .clone()
                    .unwrap_or_else(|| "worker panic".into());
                bail!(
                    "serve: shard {shard} died ({cause}) with no checkpoint passing \
                     verification to recover from"
                );
            };
            let ledger_seq = snap.seq;
            match checkpoint::restore(&snap.bytes) {
                Ok(restored) if restored.seq == ledger_seq => {
                    break (ledger_seq, restored.machine);
                }
                _ => {
                    self.slots[shard].snaps.pop_back();
                    self.recovery.corrupt_snapshots_rejected += 1;
                }
            }
        };
        if snap_seq < self.seq {
            let covered =
                self.log.front().map(|u| u.seq <= snap_seq + 1).unwrap_or(false);
            if !covered {
                bail!(
                    "serve: shard {shard} needs replay from seq {snap_seq} but the log \
                     was trimmed past it"
                );
            }
        }

        self.slots[shard].gen += 1;
        self.slots[shard].last_cause = None;
        let (tx, join) = spawn_worker(
            shard,
            self.slots[shard].gen,
            machine,
            snap_seq,
            self.params.clone(),
            self.base_seed,
            self.res_tx.clone(),
        );

        // Interleaved replay: updates up to each unscored batch's flush
        // seq, the batch, then the rest of the log — the new
        // incarnation sees the exact FIFO prefix structure the dead one
        // did. Sends may block on the bounded queue; the fresh worker
        // drains concurrently, so this always makes progress.
        let outstanding: Vec<OutstandingBatch> =
            self.slots[shard].outstanding.drain(..).collect();
        let mut applied = snap_seq;
        for b in outstanding {
            self.recovery.replayed_updates +=
                log_suffix_send(&self.log, &tx, applied, b.flush_seq)?;
            applied = applied.max(b.flush_seq);
            tx.send(Work::Batch(MicroBatch { ids: b.ids.clone(), inputs: b.inputs.clone() }))
                .map_err(|_| anyhow!("serve: respawned shard {shard} hung up during replay"))?;
            self.recovery.redispatched_batches += 1;
            self.slots[shard].outstanding.push_back(b);
        }
        self.recovery.replayed_updates += log_suffix_send(&self.log, &tx, applied, self.seq)?;

        self.slots[shard].tx = Some(tx);
        self.slots[shard].join = Some(join);
        self.slots[shard].health = SlotHealth::Live;
        self.recovery.recoveries += 1;
        Ok(())
    }

    fn merge_stats(&mut self, shard: usize, stats: ShardStats) {
        let a = &mut self.agg[shard];
        a.updates += stats.updates;
        a.batches += stats.batches;
        a.samples += stats.samples;
    }

    /// Drop log entries below the minimum seq any shard's *oldest*
    /// retained snapshot captures — everything an arbitrary future
    /// recovery (including corruption fallback) could need to replay
    /// stays resident; the rest is released. This is what bounds log
    /// memory: with periodic checkpoints the ring holds a couple of
    /// checkpoint intervals, not the trace.
    fn trim_log(&mut self) {
        let floor = self
            .slots
            .iter()
            .map(|s| s.snaps.front().map(|snap| snap.seq).unwrap_or(0))
            .min()
            .unwrap_or(0);
        while matches!(self.log.front(), Some(u) if u.seq <= floor) {
            self.log.pop_front();
        }
    }

    /// Join every worker and assemble the outcome. Dead shards are
    /// recovered first (ignoring the lag) so their outstanding work is
    /// served; a worker that dies *during* shutdown is recovered and
    /// re-joined, boundedly. Errors if any request id was answered
    /// twice or an unrecoverable failure occurred.
    pub fn finish(mut self) -> Result<ServeOutcome> {
        if self.fatal.is_none() {
            for i in 0..self.slots.len() {
                if matches!(self.slots[i].health, SlotHealth::Dead { .. }) {
                    if let Err(e) = self.recover(i) {
                        self.fatal = Some(e);
                        break;
                    }
                }
            }
        }
        if let Some(e) = self.fatal.take() {
            return Err(e);
        }
        let n = self.slots.len();
        let mut replicas: Vec<Option<MultiTm>> = (0..n).map(|_| None).collect();
        let mut rounds = 0;
        loop {
            for slot in &mut self.slots {
                slot.tx = None;
            }
            let mut died = Vec::new();
            for i in 0..n {
                let Some(join) = self.slots[i].join.take() else { continue };
                let exit = join.join().map_err(|_| {
                    anyhow!("serve: shard {i} panicked outside its unwind boundary")
                })?;
                self.merge_stats(i, exit.stats);
                if exit.panicked {
                    self.recovery.worker_panics += 1;
                    self.slots[i].health = SlotHealth::Dead { since_op: self.ops };
                    died.push(i);
                } else if let Some(bytes) = exit.final_snapshot {
                    // The exit snapshot must capture every update the
                    // log ever broadcast; a regressed seq is a typed
                    // error, not a silently stale replica.
                    let snap =
                        checkpoint::restore_expecting(&bytes, self.seq).with_context(|| {
                            format!(
                                "serve: shard {i}'s final replica snapshot failed verification"
                            )
                        })?;
                    replicas[i] = Some(snap.machine);
                }
            }
            self.drain_replies();
            if died.is_empty() {
                break;
            }
            rounds += 1;
            if rounds > 4 {
                bail!("serve: a shard worker kept dying during shutdown");
            }
            for i in died {
                self.recover(i)?;
            }
        }

        for slot in &self.slots {
            if !slot.outstanding.is_empty() {
                bail!("serve: shard {} finished with unscored batches", slot.shard);
            }
        }
        let mut responses = std::mem::take(&mut self.responses);
        responses.append(&mut self.streamed);
        responses.sort_unstable_by_key(|&(id, _)| id);
        if let Some(w) = responses.windows(2).find(|w| w[0].0 == w[1].0) {
            bail!("serve: request {} was scored more than once", w[0].0);
        }
        let mut shed = std::mem::take(&mut self.shed);
        shed.append(&mut self.streamed_shed);
        shed.sort_unstable();
        let replicas = replicas
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.with_context(|| format!("serve: shard {i} left no final replica")))
            .collect::<Result<Vec<_>>>()?;
        Ok(ServeOutcome {
            responses,
            shards: self.agg.clone(),
            updates: self.seq,
            shed,
            recovery: self.recovery,
            replicas,
        })
    }
}

/// Which update seq (if any) an event triggers at.
fn trigger_seq(ev: &ChaosEvent) -> Option<u64> {
    match ev {
        ChaosEvent::Kill { after_seq, .. } | ChaosEvent::Stall { after_seq, .. } => {
            Some(*after_seq)
        }
        ChaosEvent::CorruptSnapshot { .. } => None,
    }
}

/// Send the log slice `(from_excl, to_incl]` to a worker; returns how
/// many updates that was.
fn log_suffix_send(
    log: &VecDeque<Arc<ShardUpdate>>,
    tx: &mpsc::SyncSender<Work>,
    from_excl: u64,
    to_incl: u64,
) -> Result<u64> {
    let mut sent = 0u64;
    let mut expect = from_excl + 1;
    for u in log {
        if u.seq > from_excl && u.seq <= to_incl {
            if u.seq != expect {
                bail!("serve: update log has a gap at seq {expect}");
            }
            expect += 1;
            tx.send(Work::Update(u.clone()))
                .map_err(|_| anyhow!("serve: respawned worker hung up during replay"))?;
            sent += 1;
        }
    }
    if from_excl < to_incl && sent != to_incl - from_excl {
        bail!(
            "serve: replay needs updates ({from_excl}, {to_incl}] but the log only held {sent} \
             of them"
        );
    }
    Ok(sent)
}

impl ServeBackend for ShardServer {
    fn update(&mut self, kind: UpdateKind) {
        if self.fatal.is_some() {
            return;
        }
        self.ops += 1;
        self.run_due_recoveries();
        self.drain_replies();
        self.seq += 1;
        let u = Arc::new(ShardUpdate { seq: self.seq, kind });
        self.log.push_back(u.clone());
        for i in 0..self.slots.len() {
            if !matches!(self.slots[i].health, SlotHealth::Dead { .. }) {
                self.send_work(i, Work::Update(u.clone()));
            }
        }
        if self.policy.checkpoint_every > 0 && self.seq % self.policy.checkpoint_every == 0 {
            for i in 0..self.slots.len() {
                if !matches!(self.slots[i].health, SlotHealth::Dead { .. }) {
                    self.send_work(i, Work::Snapshot);
                }
            }
        }
        self.fire_chaos_at(self.seq);
        self.trim_log();
    }

    fn infer_batch(&mut self, batch: Vec<PendingRequest>) {
        if batch.is_empty() || self.fatal.is_some() {
            return;
        }
        self.ops += 1;
        self.run_due_recoveries();
        self.drain_replies();
        let (ids, inputs): (Vec<u64>, Vec<Input>) =
            batch.into_iter().map(|r| (r.id, r.input)).unzip();
        let flush_seq = self.seq;
        let n = self.slots.len();
        let any_dead =
            self.slots.iter().any(|s| matches!(s.health, SlotHealth::Dead { .. }));
        let start = self.next_shard;
        self.next_shard = (self.next_shard + 1) % n;
        let mut target = None;
        for k in 0..n {
            let i = (start + k) % n;
            let dispatchable =
                matches!(self.slots[i].health, SlotHealth::Live | SlotHealth::Doomed);
            let overloaded =
                any_dead && self.slots[i].outage_absorbed >= self.policy.degraded_depth;
            if dispatchable && !overloaded {
                target = Some(i);
                break;
            }
        }
        let Some(i) = target else {
            // Explicit overload response: ids are accounted in both the
            // shed list and the counters, never silently dropped.
            self.recovery.shed_batches += 1;
            self.recovery.shed_requests += ids.len() as u64;
            self.shed.extend(ids);
            return;
        };
        if any_dead {
            self.slots[i].outage_absorbed += 1;
        }
        let doomed = self.slots[i].health == SlotHealth::Doomed;
        self.slots[i].outstanding.push_back(OutstandingBatch {
            flush_seq,
            ids: ids.clone(),
            inputs: inputs.clone(),
        });
        self.send_work(i, Work::Batch(MicroBatch { ids, inputs }));
        if doomed {
            // The armed kill fires on this batch: account the shard
            // dead as of this op so recovery (and re-dispatch of the
            // batch we just lost) is scheduled deterministically.
            self.slots[i].health = SlotHealth::Dead { since_op: self.ops };
        }
    }
}

impl crate::serve::NetBackend for ShardServer {
    fn poll_responses(&mut self) -> Vec<(u64, usize)> {
        self.drain_replies();
        let fresh = std::mem::take(&mut self.responses);
        self.streamed.extend_from_slice(&fresh);
        fresh
    }

    fn poll_shed(&mut self) -> Vec<u64> {
        let fresh = std::mem::take(&mut self.shed);
        self.streamed_shed.extend_from_slice(&fresh);
        fresh
    }

    fn queue_depths(&self) -> Vec<u64> {
        self.slots.iter().map(|s| s.outstanding.len() as u64).collect()
    }

    fn finalize(self) -> Result<crate::serve::NetFinal> {
        let out = self.finish()?;
        Ok(crate::serve::NetFinal {
            responses: out.responses,
            shed: out.shed,
            replicas: out.replicas,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::{run_trace, BatcherConfig, ServeEvent};
    use crate::serve::chaos::{ChaosEvent, KillKind};
    use crate::serve::ScalarOracle;
    use crate::tm::params::TmShape;
    use crate::tm::rng::Xoshiro256;

    fn trace(n: usize, seed: u64, s: &TmShape) -> Vec<ServeEvent> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|i| {
                let input =
                    Input::pack(s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
                if i % 3 == 0 {
                    ServeEvent::Update {
                        at_tick: i as u64,
                        kind: UpdateKind::Learn { input, label: i % s.classes },
                    }
                } else {
                    ServeEvent::Infer { at_tick: i as u64, input }
                }
            })
            .collect()
    }

    #[test]
    fn rejects_zero_shards_and_bad_params() {
        let s = TmShape::iris();
        let tm = MultiTm::new(&s).unwrap();
        let p = TmParams::paper_offline(&s);
        assert!(ShardServer::new(&tm, &ServeConfig::new(0, p.clone(), 1)).is_err());
        let mut bad = p;
        bad.active_clauses = s.max_clauses + 1;
        assert!(ShardServer::new(&tm, &ServeConfig::new(2, bad, 1)).is_err());
    }

    #[test]
    fn responses_cover_every_request_exactly_once() {
        let s = TmShape::iris();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0xC0FE);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let events = trace(120, 0x11, &s);
        let bcfg = BatcherConfig { max_batch: 8, latency_budget: 2, ..Default::default() };
        let mut server = ShardServer::new(&tm, &ServeConfig::new(3, p, 9)).unwrap();
        let drive = run_trace(&mut server, &events, &bcfg).unwrap();
        let out = server.finish().unwrap();
        assert_eq!(out.responses.len() as u64, drive.infer_requests);
        assert!(out.shed.is_empty());
        let ids: Vec<u64> = out.responses.iter().map(|&(id, _)| id).collect();
        let want: Vec<u64> = (0..drive.infer_requests).collect();
        assert_eq!(ids, want);
        assert_eq!(out.shards.iter().map(|st| st.batches).sum::<u64>(), drive.batches);
        assert_eq!(out.shards.iter().map(|st| st.samples).sum::<u64>(), drive.infer_requests);
    }

    #[test]
    fn updates_reach_every_shard() {
        let s = TmShape::iris();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0xFACE);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let events = trace(90, 0x22, &s);
        let bcfg = BatcherConfig { max_batch: 4, latency_budget: 1, ..Default::default() };
        let mut server = ShardServer::new(&tm, &ServeConfig::new(4, p, 3)).unwrap();
        let drive = run_trace(&mut server, &events, &bcfg).unwrap();
        let out = server.finish().unwrap();
        assert!(drive.updates > 0);
        for st in &out.shards {
            assert_eq!(st.updates, drive.updates, "shard {}", st.shard);
        }
        assert_eq!(out.updates, drive.updates);
        // Every replica converged to the same state.
        let d0 = out.replicas[0].state_digest();
        for r in &out.replicas[1..] {
            assert_eq!(r.state_digest(), d0);
        }
    }

    /// One immediate kill, recovered next op: responses and final
    /// replicas bit-identical to the oracle, nothing shed.
    #[test]
    fn immediate_kill_recovers_bit_identically() {
        let s = TmShape::iris();
        let p = TmParams::paper_online(&s);
        let mut rng = Xoshiro256::new(0xDEAD);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let events = trace(100, 0x33, &s);
        let bcfg = BatcherConfig { max_batch: 8, latency_budget: 2, ..Default::default() };
        let mut cfg = ServeConfig::new(2, p.clone(), 5);
        cfg.fault.checkpoint_every = 4;
        let plan = ChaosPlan {
            events: vec![ChaosEvent::Kill {
                shard: 1,
                after_seq: 9,
                kind: KillKind::Immediate,
            }],
        };
        let mut server = ShardServer::with_chaos(&tm, &cfg, plan).unwrap();
        run_trace(&mut server, &events, &bcfg).unwrap();
        let out = server.finish().unwrap();
        assert_eq!(out.recovery.recoveries, 1);
        assert_eq!(out.recovery.worker_panics, 1);
        assert!(out.shed.is_empty());

        let mut oracle = ScalarOracle::new(tm.clone(), p, 5);
        run_trace(&mut oracle, &events, &bcfg).unwrap();
        let oracle_digest = oracle.machine().state_digest();
        let want = oracle.into_responses();
        assert_eq!(out.responses, want);
        for r in &out.replicas {
            assert_eq!(r.state_digest(), oracle_digest, "replica diverged from oracle");
        }
    }

    /// Retention depth is what recovery can fall back through. Corrupt
    /// the two newest snapshots of a shard, then kill it: with
    /// `retained_snapshots = 3` the ring still holds the genesis
    /// snapshot, so recovery rejects both corrupt images (counted) and
    /// replays the full log from genesis — bit-identical to the oracle.
    /// With `retained_snapshots = 1` the same damage leaves no valid
    /// checkpoint and the run must fail typed, not answer wrongly.
    #[test]
    fn retention_depth_bounds_corruption_fallback() {
        let s = TmShape::iris();
        let p = TmParams::paper_online(&s);
        let mut rng = Xoshiro256::new(0xBEEF);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let events = trace(100, 0x44, &s);
        let bcfg = BatcherConfig { max_batch: 8, latency_budget: 2, ..Default::default() };
        // checkpoint_every = 4 and a kill after seq 9 means shard 1 has
        // shipped exactly two snapshots (seq 4 and 8) before dying; the
        // chaos plan corrupts both in transit.
        let plan = || ChaosPlan {
            events: vec![
                ChaosEvent::CorruptSnapshot { shard: 1, nth: 1 },
                ChaosEvent::CorruptSnapshot { shard: 1, nth: 2 },
                ChaosEvent::Kill { shard: 1, after_seq: 9, kind: KillKind::Immediate },
            ],
        };

        let mut cfg = ServeConfig::new(2, p.clone(), 7);
        cfg.fault.checkpoint_every = 4;
        cfg.fault.retained_snapshots = 3;
        let mut server = ShardServer::with_chaos(&tm, &cfg, plan()).unwrap();
        run_trace(&mut server, &events, &bcfg).unwrap();
        let out = server.finish().unwrap();
        assert_eq!(out.recovery.corrupt_snapshots_rejected, 2);
        assert_eq!(out.recovery.recoveries, 1);
        assert!(out.shed.is_empty());
        let mut oracle = ScalarOracle::new(tm.clone(), p.clone(), 7);
        run_trace(&mut oracle, &events, &bcfg).unwrap();
        let oracle_digest = oracle.machine().state_digest();
        assert_eq!(out.responses, oracle.into_responses());
        for r in &out.replicas {
            assert_eq!(r.state_digest(), oracle_digest, "replica diverged from oracle");
        }

        // Depth 1: snap 8 evicted genesis and snap 4; it is corrupt, so
        // nothing survives verification — typed failure, no wrong answer.
        let mut cfg = ServeConfig::new(2, p.clone(), 7);
        cfg.fault.checkpoint_every = 4;
        cfg.fault.retained_snapshots = 1;
        let mut server = ShardServer::with_chaos(&tm, &cfg, plan()).unwrap();
        let _ = run_trace(&mut server, &events, &bcfg);
        let err = server.finish().expect_err("depth-1 ring cannot survive double corruption");
        assert!(
            format!("{err:#}").contains("no checkpoint passing verification"),
            "unexpected error: {err:#}"
        );

        // Depth 0 is rejected up front.
        let mut cfg = ServeConfig::new(2, p, 7);
        cfg.fault.retained_snapshots = 0;
        assert!(ShardServer::new(&tm, &cfg).is_err());
    }
}
