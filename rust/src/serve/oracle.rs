//! The single-threaded serving reference.
//!
//! One machine, the same sequenced update log, every response computed
//! by the scalar row-major [`MultiTm::predict`] at the moment the batch
//! flushes. Anything the sharded server answers must match this
//! bit-for-bit — the oracle is deliberately boring so that the
//! interesting machinery (replica broadcast, micro-batch placement, the
//! sample-sliced kernel) has a fixed point to be measured against.

use crate::serve::batcher::PendingRequest;
use crate::serve::{NetBackend, NetFinal, ServeBackend};
use crate::tm::machine::MultiTm;
use crate::tm::params::TmParams;
use crate::tm::rng::StepRands;
use crate::tm::update::{ShardUpdate, UpdateKind};

/// Scalar reference backend for [`crate::serve::run_trace`].
pub struct ScalarOracle {
    tm: MultiTm,
    params: TmParams,
    base_seed: u64,
    seq: u64,
    responses: Vec<(u64, usize)>,
    /// How many of `responses` have already been handed out through
    /// [`NetBackend::poll_responses`].
    polled: usize,
    /// Update-randomness scratch (allocated on first Learn update).
    rands: Option<StepRands>,
}

impl ScalarOracle {
    /// Must be handed a clone of the same initial machine, the same
    /// params and the same base seed as the server it checks.
    pub fn new(tm: MultiTm, params: TmParams, base_seed: u64) -> Self {
        ScalarOracle {
            tm,
            params,
            base_seed,
            seq: 0,
            responses: Vec::new(),
            polled: 0,
            rands: None,
        }
    }

    /// `(request_id, predicted_class)`, sorted by request id — already
    /// in order by construction: ids are assigned in arrival order and
    /// batches flush in arrival order on this single-threaded backend.
    pub fn into_responses(self) -> Vec<(u64, usize)> {
        debug_assert!(
            self.responses.windows(2).all(|w| w[0].0 <= w[1].0),
            "oracle responses must already be id-sorted"
        );
        self.responses
    }

    /// The machine after every update applied so far (for post-trace
    /// state checks).
    pub fn machine(&self) -> &MultiTm {
        &self.tm
    }
}

impl ServeBackend for ScalarOracle {
    fn update(&mut self, kind: UpdateKind) {
        self.seq += 1;
        let u = ShardUpdate { seq: self.seq, kind };
        self.tm.apply_update_with(&u, &self.params, self.base_seed, &mut self.rands);
    }

    fn infer_batch(&mut self, batch: Vec<PendingRequest>) {
        for req in batch {
            let pred = self.tm.predict(&req.input, &self.params);
            self.responses.push((req.id, pred));
        }
    }
}

impl NetBackend for ScalarOracle {
    fn poll_responses(&mut self) -> Vec<(u64, usize)> {
        let fresh = self.responses[self.polled..].to_vec();
        self.polled = self.responses.len();
        fresh
    }

    fn poll_shed(&mut self) -> Vec<u64> {
        // The single-threaded reference never sheds: every dispatched
        // request is scored synchronously at flush time.
        Vec::new()
    }

    fn finalize(self) -> anyhow::Result<NetFinal> {
        Ok(NetFinal {
            responses: self.responses,
            shed: Vec::new(),
            replicas: vec![self.tm],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::{run_trace, BatcherConfig, ServeEvent};
    use crate::tm::clause::Input;
    use crate::tm::params::TmShape;
    use crate::tm::rng::Xoshiro256;

    /// The oracle through the driver equals a hand-rolled sequential
    /// loop: apply updates as they arrive, predict at flush time.
    #[test]
    fn oracle_is_the_sequential_semantics() {
        let s = TmShape::iris();
        let p = TmParams::paper_offline(&s);
        let mut rng = Xoshiro256::new(0x0AC1E);
        let tm = crate::testkit::gen::machine(&mut rng, &s);
        let events: Vec<ServeEvent> = (0..60)
            .map(|i| {
                let input =
                    Input::pack(&s, &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5));
                if i % 4 == 0 {
                    ServeEvent::Update {
                        at_tick: i as u64,
                        kind: UpdateKind::Learn { input, label: i % 3 },
                    }
                } else {
                    ServeEvent::Infer { at_tick: i as u64, input }
                }
            })
            .collect();
        let cfg = BatcherConfig { max_batch: 1, latency_budget: 0, ..Default::default() };
        let mut oracle = ScalarOracle::new(tm.clone(), p.clone(), 0xBEE);
        run_trace(&mut oracle, &events, &cfg).unwrap();
        let got = oracle.into_responses();

        // Hand-rolled: with max_batch 1 every request is served at its
        // arrival point, after all preceding updates.
        let mut manual = tm.clone();
        let mut seq = 0u64;
        let mut id = 0u64;
        let mut want = Vec::new();
        for ev in &events {
            match ev {
                ServeEvent::Update { kind, .. } => {
                    seq += 1;
                    manual.apply_update(
                        &ShardUpdate { seq, kind: kind.clone() },
                        &p,
                        0xBEE,
                    );
                }
                ServeEvent::Infer { input, .. } => {
                    want.push((id, manual.predict(input, &p)));
                    id += 1;
                }
            }
        }
        assert_eq!(got, want);
    }
}
