//! Request coalescing under a latency budget, on a virtual clock.
//!
//! The batcher holds at most one *open* micro-batch. A request joins the
//! open batch; the batch flushes when it reaches `max_batch` lanes
//! (**full** flush) or when the driver's clock reaches the first
//! request's arrival tick plus `latency_budget` (**deadline** flush) —
//! whichever comes first. Whatever is still open when the trace ends is
//! flushed as the **final** batch. All decisions are functions of the
//! event sequence and the config alone — no wall clock — so the same
//! trace always produces the same batches, which is what lets the soak
//! driver cross-check the threaded server bit-for-bit against a scalar
//! oracle.
//!
//! Admission is where malformed requests die: when the config pins the
//! model's literal width, a request packed under the wrong shape is
//! rejected with a typed [`BadRequest`] *before* it can join a batch —
//! a wrong-width row silently packed into a 64-sample bitplane lane
//! would corrupt every other sample in the lane. Rejections are counted
//! ([`DriveStats::quarantined`]), never silently dropped.

use crate::serve::ServeBackend;
use crate::tm::clause::Input;
use crate::tm::update::{Deadline, UpdateKind};
use anyhow::{ensure, Context, Result};

/// A single-sample inference request admitted to the batcher. `id` is
/// assigned in arrival order and is how responses are matched back.
/// `deadline`, when set, is the absolute virtual tick past which the
/// request must be answered with a typed deadline response instead of
/// being scored ([`split_expired`]); `None` means "never expires" (the
/// in-process trace drivers, which have no per-request budgets).
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub id: u64,
    pub input: Input,
    pub deadline: Option<Deadline>,
}

impl PendingRequest {
    /// A request with no deadline budget (trusted in-process traces).
    pub fn unbounded(id: u64, input: Input) -> Self {
        PendingRequest { id, input, deadline: None }
    }
}

/// Split a flushed batch into the requests still worth scoring and the
/// ids whose deadline budget expired while they waited (strictly past
/// their deadline tick at `now`). Expiry is checked exactly once, at
/// flush time: a dispatched request is always scored, an expired one is
/// never dispatched — so the deadline outcome of every request is a
/// deterministic function of the trace and the batching config, and the
/// two soak arms cannot disagree about it.
pub fn split_expired(batch: Vec<PendingRequest>, now: u64) -> (Vec<PendingRequest>, Vec<u64>) {
    let mut live = Vec::with_capacity(batch.len());
    let mut expired = Vec::new();
    for req in batch {
        match req.deadline {
            Some(d) if d.expired(now) => expired.push(req.id),
            _ => live.push(req),
        }
    }
    (live, expired)
}

/// A request rejected at admission: its input's literal count does not
/// match the served model's. The id is consumed (responses keep their
/// arrival-order alignment) and the request is quarantined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BadRequest {
    pub id: u64,
    pub got_literals: usize,
    pub want_literals: usize,
}

impl std::fmt::Display for BadRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "request {} malformed: {} literals where the served model wants {}",
            self.id, self.got_literals, self.want_literals
        )
    }
}

impl std::error::Error for BadRequest {}

/// Micro-batching policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many requests are open. 1..=64 (one 64-sample
    /// bitplane lane — `max_batch = 1` disables coalescing entirely).
    pub max_batch: usize,
    /// Flush when `now − oldest_arrival ≥ latency_budget` (virtual
    /// ticks). 0 means a batch never survives past its arrival tick.
    pub latency_budget: u64,
    /// When set, requests whose input does not carry exactly this many
    /// literals are rejected at admission with [`BadRequest`]. `None`
    /// disables the check (trusted, pre-validated traces).
    pub expect_literals: Option<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, latency_budget: 8, expect_literals: None }
    }
}

impl BatcherConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=64).contains(&self.max_batch),
            "BatcherConfig: max_batch must be in 1..=64 (one bitplane lane), got {}",
            self.max_batch
        );
        if let Some(want) = self.expect_literals {
            ensure!(
                want > 0 && want % 2 == 0,
                "BatcherConfig: expect_literals must be a positive even literal count \
                 (x and ¬x pairs), got {want}"
            );
        }
        Ok(())
    }
}

/// The micro-batcher: one open batch plus its oldest arrival tick.
#[derive(Debug)]
pub struct MicroBatcher {
    cfg: BatcherConfig,
    open: Vec<PendingRequest>,
    /// Arrival tick of `open[0]`; meaningful only when `open` is
    /// non-empty.
    oldest: u64,
}

impl MicroBatcher {
    /// Errors on an invalid config — propagated, not panicked, so a bad
    /// CLI flag surfaces as a message instead of a backtrace.
    pub fn new(cfg: BatcherConfig) -> Result<Self> {
        cfg.validate()?;
        let cap = cfg.max_batch;
        Ok(MicroBatcher { cfg, open: Vec::with_capacity(cap), oldest: 0 })
    }

    pub fn len(&self) -> usize {
        self.open.len()
    }

    pub fn is_empty(&self) -> bool {
        self.open.is_empty()
    }

    /// The open batch's deadline has passed at `now`.
    pub fn due(&self, now: u64) -> bool {
        !self.open.is_empty() && now >= self.oldest.saturating_add(self.cfg.latency_budget)
    }

    /// Validate and admit one request arriving at `now`. A wrong-width
    /// input is rejected *before* it can touch the open batch; on
    /// success behaves as [`MicroBatcher::push`].
    pub fn admit(
        &mut self,
        req: PendingRequest,
        now: u64,
    ) -> std::result::Result<Option<Vec<PendingRequest>>, BadRequest> {
        if let Some(want) = self.cfg.expect_literals {
            let got = req.input.literals();
            if got != want {
                return Err(BadRequest { id: req.id, got_literals: got, want_literals: want });
            }
        }
        Ok(self.push(req, now))
    }

    /// Admit one request arriving at `now` without shape validation;
    /// returns the batch when this push filled it.
    pub fn push(&mut self, req: PendingRequest, now: u64) -> Option<Vec<PendingRequest>> {
        if self.open.is_empty() {
            self.oldest = now;
        }
        self.open.push(req);
        if self.open.len() >= self.cfg.max_batch {
            self.flush()
        } else {
            None
        }
    }

    /// Take the open batch (deadline / end-of-trace flushes).
    pub fn flush(&mut self) -> Option<Vec<PendingRequest>> {
        if self.open.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.open))
        }
    }
}

/// One event of a serving trace, stamped with its (virtual) arrival
/// tick. Ticks must be non-decreasing along the trace.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// An unlabelled sample: an inference request wanting a response.
    Infer { at_tick: u64, input: Input },
    /// A sequenced model update (labelled sample, fault edit).
    Update { at_tick: u64, kind: UpdateKind },
}

impl ServeEvent {
    pub fn at_tick(&self) -> u64 {
        match self {
            ServeEvent::Infer { at_tick, .. } | ServeEvent::Update { at_tick, .. } => *at_tick,
        }
    }
}

/// Counters of one [`run_trace`] drive — flush-cause breakdown and the
/// achieved batch width the perf rows report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DriveStats {
    /// Requests admitted to batches (excludes quarantined ones).
    pub infer_requests: u64,
    pub updates: u64,
    pub batches: u64,
    /// Batches flushed because they reached `max_batch` lanes.
    pub full_flushes: u64,
    /// Batches flushed because their latency budget expired.
    pub deadline_flushes: u64,
    /// The end-of-trace flush (0 or 1).
    pub final_flushes: u64,
    /// Summed width of all flushed batches (= `infer_requests` once the
    /// trace is fully drained).
    pub width_sum: u64,
    /// Requests rejected at admission ([`BadRequest`]). Their ids are
    /// consumed but never reach a backend; `infer_requests +
    /// quarantined` equals the trace's `Infer` event count.
    pub quarantined: u64,
}

enum FlushKind {
    Full,
    Deadline,
    Final,
}

impl DriveStats {
    fn record(&mut self, width: usize, kind: FlushKind) {
        self.batches += 1;
        self.width_sum += width as u64;
        match kind {
            FlushKind::Full => self.full_flushes += 1,
            FlushKind::Deadline => self.deadline_flushes += 1,
            FlushKind::Final => self.final_flushes += 1,
        }
    }

    /// Mean achieved micro-batch width (samples per flushed batch).
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.width_sum as f64 / self.batches as f64
        }
    }
}

/// Drive a serving trace through a backend: updates are forwarded in
/// arrival order, inference requests are micro-batched, deadline flushes
/// happen before any event at or past the deadline tick is processed,
/// and the tail batch is flushed at end of trace. Request ids are
/// assigned 0.. in arrival order over the `Infer` events — including
/// quarantined ones, so ids stay aligned between a backend and its
/// oracle regardless of rejections.
///
/// The whole function is deterministic given (`events`, `cfg`), so
/// running it once against [`crate::serve::ShardServer`] and once
/// against [`crate::serve::ScalarOracle`] scores the *same* batches
/// against the *same* sequenced updates — the differential contract of
/// `rust/tests/integration_serve.rs`. Errors only on an invalid config;
/// malformed *requests* are quarantined and counted, not fatal.
pub fn run_trace<B: ServeBackend>(
    backend: &mut B,
    events: &[ServeEvent],
    cfg: &BatcherConfig,
) -> Result<DriveStats> {
    let mut batcher = MicroBatcher::new(cfg.clone()).context("serve trace driver")?;
    let mut stats = DriveStats::default();
    let mut next_id = 0u64;
    let mut clock = 0u64;
    for ev in events {
        debug_assert!(ev.at_tick() >= clock, "trace ticks must be non-decreasing");
        // Monotonize in release builds too: a backwards tick would
        // otherwise silently disable deadline flushing (time cannot run
        // backwards, so an out-of-order event reads as "now").
        let now = ev.at_tick().max(clock);
        clock = now;
        if batcher.due(now) {
            if let Some(batch) = batcher.flush() {
                stats.record(batch.len(), FlushKind::Deadline);
                backend.infer_batch(batch);
            }
        }
        match ev {
            ServeEvent::Infer { at_tick, input } => {
                let req = PendingRequest::unbounded(next_id, input.clone());
                next_id += 1;
                match batcher.admit(req, *at_tick) {
                    Ok(Some(batch)) => {
                        stats.infer_requests += 1;
                        stats.record(batch.len(), FlushKind::Full);
                        backend.infer_batch(batch);
                    }
                    Ok(None) => stats.infer_requests += 1,
                    Err(_rejected) => stats.quarantined += 1,
                }
            }
            ServeEvent::Update { kind, .. } => {
                stats.updates += 1;
                backend.update(kind.clone());
            }
        }
    }
    if let Some(batch) = batcher.flush() {
        stats.record(batch.len(), FlushKind::Final);
        backend.infer_batch(batch);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TmShape;

    fn input(bit: usize) -> Input {
        let s = TmShape::iris();
        let mut bits = vec![false; s.features];
        bits[bit % s.features] = true;
        Input::pack(&s, &bits)
    }

    /// A recording backend: logs batch widths and update count.
    #[derive(Default)]
    struct Recorder {
        widths: Vec<usize>,
        ids: Vec<u64>,
        updates: usize,
    }

    impl ServeBackend for Recorder {
        fn update(&mut self, _kind: UpdateKind) {
            self.updates += 1;
        }

        fn infer_batch(&mut self, batch: Vec<PendingRequest>) {
            self.widths.push(batch.len());
            self.ids.extend(batch.iter().map(|r| r.id));
        }
    }

    fn infer_at(tick: u64, bit: usize) -> ServeEvent {
        ServeEvent::Infer { at_tick: tick, input: input(bit) }
    }

    fn cfg(max_batch: usize, latency_budget: u64) -> BatcherConfig {
        BatcherConfig { max_batch, latency_budget, ..Default::default() }
    }

    #[test]
    fn config_bounds_enforced() {
        assert!(cfg(0, 1).validate().is_err());
        assert!(cfg(65, 1).validate().is_err());
        assert!(cfg(1, 0).validate().is_ok());
        assert!(cfg(64, 0).validate().is_ok());
        let odd = BatcherConfig { expect_literals: Some(31), ..Default::default() };
        assert!(odd.validate().is_err(), "literal counts come in x/¬x pairs");
        assert!(MicroBatcher::new(cfg(0, 1)).is_err(), "constructor propagates, not panics");
    }

    #[test]
    fn invalid_config_is_a_typed_error_from_the_driver() {
        let mut rec = Recorder::default();
        let err = run_trace(&mut rec, &[infer_at(0, 0)], &cfg(0, 1));
        assert!(err.is_err());
        assert!(rec.widths.is_empty(), "nothing reaches the backend");
    }

    #[test]
    fn full_flush_at_max_batch() {
        let events: Vec<ServeEvent> = (0..10).map(|i| infer_at(0, i)).collect();
        let mut rec = Recorder::default();
        let stats = run_trace(&mut rec, &events, &cfg(4, 100)).unwrap();
        assert_eq!(rec.widths, vec![4, 4, 2], "two full + one final flush");
        assert_eq!(rec.ids, (0..10).collect::<Vec<u64>>(), "ids in arrival order");
        assert_eq!(stats.full_flushes, 2);
        assert_eq!(stats.final_flushes, 1);
        assert_eq!(stats.deadline_flushes, 0);
        assert_eq!(stats.infer_requests, 10);
        assert_eq!(stats.width_sum, 10);
        assert_eq!(stats.quarantined, 0);
    }

    #[test]
    fn deadline_flush_before_late_event() {
        // Requests at ticks 0 and 3 share a batch (3 < 0+5); the request
        // at tick 5 arrives at the deadline, so the open batch flushes
        // first and the late request starts a new one.
        let events = vec![infer_at(0, 0), infer_at(3, 1), infer_at(5, 2)];
        let mut rec = Recorder::default();
        let stats = run_trace(&mut rec, &events, &cfg(64, 5)).unwrap();
        assert_eq!(rec.widths, vec![2, 1]);
        assert_eq!(stats.deadline_flushes, 1);
        assert_eq!(stats.final_flushes, 1);
        assert_eq!(stats.mean_batch_width(), 1.5);
    }

    #[test]
    fn zero_budget_never_coalesces_across_events() {
        let events = vec![infer_at(0, 0), infer_at(0, 1), infer_at(1, 2)];
        let mut rec = Recorder::default();
        let stats = run_trace(&mut rec, &events, &cfg(64, 0)).unwrap();
        assert_eq!(rec.widths, vec![1, 1, 1]);
        assert_eq!(stats.batches, stats.infer_requests);
    }

    #[test]
    fn updates_pass_through_without_flushing() {
        let events = vec![
            infer_at(0, 0),
            ServeEvent::Update {
                at_tick: 1,
                kind: UpdateKind::ClauseFault { class: 0, clause: 0, force: Some(true) },
            },
            infer_at(2, 1),
        ];
        let mut rec = Recorder::default();
        let stats = run_trace(&mut rec, &events, &cfg(8, 10)).unwrap();
        assert_eq!(rec.updates, 1);
        assert_eq!(rec.widths, vec![2], "update did not split the batch");
        assert_eq!(stats.updates, 1);
        assert_eq!(stats.final_flushes, 1);
    }

    #[test]
    fn split_expired_is_strict_and_exact() {
        use crate::tm::update::Deadline;
        let batch = vec![
            PendingRequest { id: 0, input: input(0), deadline: Some(Deadline(4)) },
            PendingRequest { id: 1, input: input(1), deadline: Some(Deadline(5)) },
            PendingRequest { id: 2, input: input(2), deadline: None },
            PendingRequest { id: 3, input: input(3), deadline: Some(Deadline(9)) },
        ];
        let (live, expired) = split_expired(batch, 5);
        assert_eq!(expired, vec![0], "only strictly-past deadlines expire");
        assert_eq!(live.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn empty_trace_is_a_no_op() {
        let mut rec = Recorder::default();
        let stats = run_trace(&mut rec, &[], &cfg(8, 1)).unwrap();
        assert_eq!(stats, DriveStats::default());
        assert!(rec.widths.is_empty());
        assert_eq!(stats.mean_batch_width(), 0.0);
    }

    /// A wrong-width request is rejected at admission with exact
    /// accounting: its id is consumed (alignment preserved) but it never
    /// reaches a batch or the backend.
    #[test]
    fn malformed_requests_are_quarantined_at_admission() {
        let s = TmShape::iris();
        let wrong_shape = TmShape { features: s.features + 3, ..s.clone() };
        let malformed = ServeEvent::Infer {
            at_tick: 1,
            input: Input::pack(&wrong_shape, &vec![false; wrong_shape.features]),
        };
        let events = vec![infer_at(0, 0), malformed, infer_at(2, 1)];
        let config = BatcherConfig {
            max_batch: 8,
            latency_budget: 10,
            expect_literals: Some(s.literals()),
        };
        let mut rec = Recorder::default();
        let stats = run_trace(&mut rec, &events, &config).unwrap();
        assert_eq!(stats.quarantined, 1);
        assert_eq!(stats.infer_requests, 2);
        assert_eq!(rec.widths, vec![2], "the survivors still share one batch");
        assert_eq!(rec.ids, vec![0, 2], "the malformed request's id 1 was consumed");

        // Without the width contract the same trace admits everything.
        let mut rec2 = Recorder::default();
        let lax = run_trace(&mut rec2, &events, &cfg(8, 10)).unwrap();
        assert_eq!(lax.quarantined, 0);
        assert_eq!(lax.infer_requests, 3);
    }
}
