//! Versioned, checksummed serving snapshots: everything a shard replica
//! needs to resume bit-identically from a point in the update log.
//!
//! The base checkpoint format (`tm::state`, "TMFP" v1) captures TA
//! states only — enough for offline retrain flows, not for crash
//! recovery of a *serving* replica, whose observable behaviour also
//! depends on the clause-output force gates, the TA fault-gate words and
//! the run-time params, and whose position in the sequenced update log
//! must be known exactly for replay. This module's "TMFS" v2 format
//! carries all of it:
//!
//! ```text
//! magic    u32 = 0x544D_4653  ("TMFS")
//! version  u32 = 2
//! classes  u32, max_clauses u32, features u32, states u32
//! seq      u64                      (last applied ShardUpdate seq)
//! s        u32 (f32 bits), t i32
//! active_clauses u32, active_classes u32
//! boost    u8,  s_style u8, pad u8×2
//! ta       u32[num_tas]             (TA states)
//! force    u8[rows]                 (clause-output gates; 0xFF = free)
//! and      u64[rows*words], or u64[rows*words]   (TA fault gates)
//! a_crc    u32   (FNV-1a over the action-cache bytes at snapshot time)
//! crc      u32   (FNV-1a over every preceding byte)
//! ```
//!
//! Restore is **paranoid by design**: bad magic/version, any length
//! mismatch, a trailing-CRC mismatch, invalid shape/params/gate
//! encodings, and an action cache that no longer matches the TA states
//! (`a_crc`, recomputed from the rebuilt cache) are all hard errors — a
//! corrupted snapshot is rejected, never silently loaded, and the
//! supervisor falls back to an older one plus a longer replay.
//!
//! The mutation clock (`MultiTm` uid/revision stamps) is deliberately
//! *not* serialized: uids are process-unique and re-scoring caches bind
//! to them, so a restored machine starting a fresh clock is exactly the
//! conservative behaviour the cache contract requires. The `seq` stamp
//! is the log clock — the only clock replay needs.

use crate::tm::fault::FaultMap;
use crate::tm::machine::MultiTm;
use crate::tm::params::{SStyle, TmParams, TmShape};
use crate::tm::state::fnv1a;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: u32 = 0x544D_4653;
const VERSION: u32 = 2;

/// A decoded serving snapshot: the replica, the params it served under,
/// and the seq of the last update it has applied.
#[derive(Debug)]
pub struct ServeSnapshot {
    pub seq: u64,
    pub params: TmParams,
    pub machine: MultiTm,
}

/// Typed rejection for a snapshot whose log position regresses behind
/// the seq its consumer has provably applied: loading it would rewind
/// the replica past updates that already took effect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqRegression {
    pub snapshot_seq: u64,
    pub applied_seq: u64,
}

impl std::fmt::Display for SeqRegression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "serve snapshot: seq {} regresses behind applied seq {}",
            self.snapshot_seq, self.applied_seq
        )
    }
}

impl std::error::Error for SeqRegression {}

/// [`restore`], plus the regression guard: the decoded snapshot must
/// capture at least `applied_seq`. Fails with a downcastable
/// [`SeqRegression`] otherwise — never a silently stale replica.
pub fn restore_expecting(bytes: &[u8], applied_seq: u64) -> Result<ServeSnapshot> {
    let snap = restore(bytes)?;
    if snap.seq < applied_seq {
        return Err(anyhow::Error::new(SeqRegression {
            snapshot_seq: snap.seq,
            applied_seq,
        }));
    }
    Ok(snap)
}

/// Cheap integrity probe for the durable store: verifies the trailing
/// whole-buffer CRC, magic and version, and returns the embedded `seq`
/// without rebuilding the machine. `None` means the bytes are not a
/// valid TMFS v2 snapshot (the store then falls back to an older
/// checkpoint); a `Some` here still gets the full paranoid [`restore`]
/// before the bytes are trusted to produce a replica.
pub fn quick_check(bytes: &[u8]) -> Option<u64> {
    if bytes.len() < 36 {
        return None;
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    if fnv1a(body) != le_u32(crc_bytes) {
        return None;
    }
    if le_u32(&body[0..4]) != MAGIC || le_u32(&body[4..8]) != VERSION {
        return None;
    }
    let mut seq = [0u8; 8];
    seq.copy_from_slice(&body[24..32]);
    Some(u64::from_le_bytes(seq))
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Little-endian cursor over the snapshot bytes; every read is
/// bounds-checked so truncation anywhere surfaces as a typed error.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.bytes.len() - self.pos < n {
            bail!(
                "serve snapshot: truncated ({} bytes left at offset {}, want {n})",
                self.bytes.len() - self.pos,
                self.pos
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(le_u32(self.take(4)?))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
}

/// `b` must hold exactly 4 bytes (guaranteed by every caller's
/// length-checked `take`/`split_at`/`chunks_exact`).
fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

/// FNV-1a over the packed action-cache words — the cross-check that the
/// TA payload and the action cache describe the same machine.
fn action_crc(tm: &MultiTm) -> u32 {
    let s = tm.shape();
    let mut h = crate::util::Fnv1a::new();
    for c in 0..s.classes {
        for j in 0..s.max_clauses {
            for &w in tm.action_words(c, j) {
                h.update(&w.to_le_bytes());
            }
        }
    }
    h.finish()
}

/// Serialize a serving snapshot: replica state + params, stamped with
/// the last applied update `seq`.
pub fn snapshot_bytes(tm: &MultiTm, params: &TmParams, seq: u64) -> Vec<u8> {
    let s = tm.shape();
    let rows = s.classes * s.max_clauses;
    let (and_words, or_words) = tm.fault().words();
    let mut buf = Vec::with_capacity(
        48 + tm.ta().states().len() * 4 + rows + (and_words.len() + or_words.len()) * 8 + 8,
    );
    push_u32(&mut buf, MAGIC);
    push_u32(&mut buf, VERSION);
    push_u32(&mut buf, s.classes as u32);
    push_u32(&mut buf, s.max_clauses as u32);
    push_u32(&mut buf, s.features as u32);
    push_u32(&mut buf, s.states);
    push_u64(&mut buf, seq);
    push_u32(&mut buf, params.s.to_bits());
    push_u32(&mut buf, params.t as u32);
    push_u32(&mut buf, params.active_clauses as u32);
    push_u32(&mut buf, params.active_classes as u32);
    buf.push(params.boost_true_positive as u8);
    buf.push(match params.s_style {
        SStyle::Canonical => 0,
        SStyle::InactionBiased => 1,
    });
    buf.extend_from_slice(&[0u8, 0u8]);
    for &st in tm.ta().states() {
        push_u32(&mut buf, st);
    }
    for &f in tm.clause_force_codes() {
        buf.push(f as u8); // -1 encodes as 0xFF
    }
    for &w in and_words.iter().chain(or_words) {
        push_u64(&mut buf, w);
    }
    push_u32(&mut buf, action_crc(tm));
    let crc = fnv1a(&buf);
    push_u32(&mut buf, crc);
    buf
}

/// Decode and verify a snapshot produced by [`snapshot_bytes`]. Any
/// corruption or truncation is a hard error; a successful restore is a
/// machine bit-identical (states, gates, action cache) to the one
/// snapshotted.
pub fn restore(bytes: &[u8]) -> Result<ServeSnapshot> {
    // Trailing CRC over everything before it, checked first: a random
    // bit-flip anywhere (header included) fails here before any field is
    // trusted.
    if bytes.len() < 4 {
        bail!("serve snapshot: truncated ({} bytes)", bytes.len());
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let want_crc = le_u32(crc_bytes);
    if fnv1a(body) != want_crc {
        bail!("serve snapshot: CRC mismatch");
    }
    let mut r = Cursor { bytes: body, pos: 0 };
    if r.u32()? != MAGIC {
        bail!("serve snapshot: bad magic");
    }
    let ver = r.u32()?;
    if ver != VERSION {
        bail!("serve snapshot: unsupported version {ver}");
    }
    let shape = TmShape {
        classes: r.u32()? as usize,
        max_clauses: r.u32()? as usize,
        features: r.u32()? as usize,
        states: r.u32()?,
    };
    shape.validate().context("serve snapshot shape")?;
    let seq = r.u64()?;
    let params = TmParams {
        s: f32::from_bits(r.u32()?),
        t: r.u32()? as i32,
        active_clauses: r.u32()? as usize,
        active_classes: r.u32()? as usize,
        boost_true_positive: match r.take(1)?[0] {
            0 => false,
            1 => true,
            v => bail!("serve snapshot: invalid boost flag {v}"),
        },
        s_style: match r.take(1)?[0] {
            0 => SStyle::Canonical,
            1 => SStyle::InactionBiased,
            v => bail!("serve snapshot: invalid s_style {v}"),
        },
    };
    r.take(2)?; // pad
    params.validate(&shape).context("serve snapshot params")?;

    // Hostile-header guard: the payload size this shape implies,
    // computed in 128-bit arithmetic (forged u32 dimensions can
    // overflow `num_tas()` itself), checked against the bytes actually
    // present *before* any shape-sized allocation. A forged header can
    // cost at most the frame it arrived in, never a huge reservation.
    let rows128 = shape.classes as u128 * shape.max_clauses as u128;
    let lits128 = 2 * shape.features as u128;
    let tas128 = rows128 * lits128;
    let gate_words128 = rows128 * lits128.div_ceil(64);
    let want_payload = tas128 * 4 + rows128 + 2 * gate_words128 * 8 + 4;
    let have_payload = (body.len() - r.pos) as u128;
    if want_payload != have_payload {
        bail!(
            "serve snapshot: header claims a {want_payload}-byte payload but {have_payload} \
             bytes follow"
        );
    }

    let n = shape.num_tas();
    let mut states = Vec::with_capacity(n);
    for chunk in r.take(n * 4)?.chunks_exact(4) {
        states.push(le_u32(chunk));
    }
    let rows = shape.classes * shape.max_clauses;
    let force: Vec<i8> = r.take(rows)?.iter().map(|&b| b as i8).collect();
    let gate_words = rows * shape.words();
    let mut and_words = Vec::with_capacity(gate_words);
    for chunk in r.take(gate_words * 8)?.chunks_exact(8) {
        let mut a = [0u8; 8];
        a.copy_from_slice(chunk);
        and_words.push(u64::from_le_bytes(a));
    }
    let mut or_words = Vec::with_capacity(gate_words);
    for chunk in r.take(gate_words * 8)?.chunks_exact(8) {
        let mut a = [0u8; 8];
        a.copy_from_slice(chunk);
        or_words.push(u64::from_le_bytes(a));
    }
    let want_action_crc = r.u32()?;
    if r.pos != body.len() {
        bail!("serve snapshot: {} trailing bytes", body.len() - r.pos);
    }

    let mut machine = MultiTm::from_states(&shape, states).context("serve snapshot TA states")?;
    machine.load_clause_force_codes(&force).context("serve snapshot clause forces")?;
    machine.set_fault_map(
        FaultMap::from_words(&shape, and_words, or_words).context("serve snapshot fault gates")?,
    );
    // The action cache was rebuilt from the restored TA states; if its
    // CRC disagrees with the one recorded at snapshot time, the states
    // and the cache described different machines — refuse to serve it.
    if action_crc(&machine) != want_action_crc {
        bail!("serve snapshot: action cache does not match TA states");
    }
    crate::verify::contracts::enforce(&machine, "checkpoint::restore");
    Ok(ServeSnapshot { seq, params, machine })
}

/// Save a serving snapshot to a file.
pub fn save_snapshot(tm: &MultiTm, params: &TmParams, seq: u64, path: &Path) -> Result<()> {
    std::fs::write(path, snapshot_bytes(tm, params, seq))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load and verify a serving snapshot from a file.
pub fn load_snapshot(path: &Path) -> Result<ServeSnapshot> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    restore(&bytes).with_context(|| format!("restoring {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::fault::Fault;
    use crate::tm::rng::Xoshiro256;
    use crate::tm::update::{ShardUpdate, UpdateKind};

    fn snapshot_machine() -> (MultiTm, TmParams) {
        let s = TmShape::iris();
        let mut rng = Xoshiro256::new(0x57A7E);
        let mut tm = crate::testkit::gen::machine(&mut rng, &s);
        let p = TmParams::paper_online(&s);
        // Non-trivial gates on both levels so the payload sections carry
        // real content.
        tm.set_clause_fault(0, 3, Some(true));
        tm.set_clause_fault(2, 1, Some(false));
        tm.fault_map_mut().set(1, 2, 5, Fault::StuckAt0);
        tm.fault_map_mut().set(0, 0, 31, Fault::StuckAt1);
        (tm, p)
    }

    #[test]
    fn roundtrip_preserves_full_serving_state() {
        let (tm, p) = snapshot_machine();
        let snap = restore(&snapshot_bytes(&tm, &p, 1234)).unwrap();
        assert_eq!(snap.seq, 1234);
        assert_eq!(snap.params, p);
        assert_eq!(snap.machine.ta().states(), tm.ta().states());
        assert_eq!(snap.machine.clause_force_codes(), tm.clause_force_codes());
        assert_eq!(snap.machine.fault(), tm.fault());
        assert_eq!(snap.machine.state_digest(), tm.state_digest());
    }

    #[test]
    fn restored_replica_resumes_bit_identically() {
        // The recovery contract in miniature: snapshot at seq c, replay
        // updates (c, n] — the restored machine must land exactly where
        // the unfailed one does.
        let (mut live, p) = snapshot_machine();
        let s = live.shape().clone();
        let mut rng = Xoshiro256::new(0xFEED);
        let mut log = Vec::new();
        for seq in 1..=40u64 {
            let kind = if seq % 7 == 0 {
                UpdateKind::ClauseFault {
                    class: rng.next_below(s.classes),
                    clause: rng.next_below(s.max_clauses),
                    force: [None, Some(false), Some(true)][rng.next_below(3)],
                }
            } else {
                UpdateKind::Learn {
                    input: crate::tm::clause::Input::pack(
                        &s,
                        &crate::testkit::gen::bool_vec(&mut rng, s.features, 0.5),
                    ),
                    label: rng.next_below(s.classes),
                }
            };
            log.push(ShardUpdate { seq, kind });
        }
        let mut snap_bytes = None;
        for u in &log {
            live.apply_update(u, &p, 0xBA5E);
            if u.seq == 25 {
                snap_bytes = Some(snapshot_bytes(&live, &p, 25));
            }
        }
        let snap = restore(&snap_bytes.unwrap()).unwrap();
        let mut recovered = snap.machine;
        for u in log.iter().filter(|u| u.seq > snap.seq) {
            recovered.apply_update(u, &snap.params, 0xBA5E);
        }
        assert_eq!(recovered.ta().states(), live.ta().states());
        assert_eq!(recovered.state_digest(), live.state_digest());
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let (tm, p) = snapshot_machine();
        let bytes = snapshot_bytes(&tm, &p, 7);
        // Stride through the snapshot flipping one bit per position —
        // header, payload sections and both CRCs included.
        for pos in (0..bytes.len()).step_by(13) {
            let mut bad = bytes.clone();
            bad[pos] ^= 1 << (pos % 8);
            assert!(restore(&bad).is_err(), "flip at byte {pos} went undetected");
        }
    }

    #[test]
    fn every_truncation_is_detected() {
        let (tm, p) = snapshot_machine();
        let bytes = snapshot_bytes(&tm, &p, 7);
        for keep in (0..bytes.len()).step_by(17) {
            assert!(restore(&bytes[..keep]).is_err(), "truncation to {keep} bytes loaded");
        }
        assert!(restore(&[]).is_err());
        // Extension is rejected too (the trailing CRC moves).
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 8]);
        assert!(restore(&long).is_err());
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let (tm, p) = snapshot_machine();
        let good = snapshot_bytes(&tm, &p, 7);
        // Patch the field, then re-stamp the trailing CRC so only the
        // magic/version check can reject it.
        let patch = |at: usize, v: u32| {
            let mut b = good.clone();
            b[at..at + 4].copy_from_slice(&v.to_le_bytes());
            let n = b.len();
            let crc = fnv1a(&b[..n - 4]);
            b[n - 4..].copy_from_slice(&crc.to_le_bytes());
            b
        };
        assert!(restore(&patch(0, 0x544D_4650)).is_err(), "v1 magic must not decode as v2");
        assert!(restore(&patch(4, 3)).is_err(), "unknown version");
    }

    /// A forged shape header — dimensions claiming terabytes of payload
    /// with a valid trailing CRC — must be rejected by the size check
    /// before any allocation, including values whose `num_tas` product
    /// overflows 64-bit arithmetic entirely.
    #[test]
    fn hostile_shape_header_cannot_trigger_huge_allocation() {
        let (tm, p) = snapshot_machine();
        let good = snapshot_bytes(&tm, &p, 7);
        let patch = |fields: &[(usize, u32)]| {
            let mut b = good.clone();
            for &(at, v) in fields {
                b[at..at + 4].copy_from_slice(&v.to_le_bytes());
            }
            let n = b.len();
            let crc = fnv1a(&b[..n - 4]);
            b[n - 4..].copy_from_slice(&crc.to_le_bytes());
            b
        };
        // Offsets: classes @8, max_clauses @12, features @16.
        for bad in [
            patch(&[(8, 0x4000_0000)]),                                // ~4e9 rows
            patch(&[(16, 0x7FFF_FFFF)]),                               // huge literal rows
            patch(&[(8, u32::MAX), (12, 0xFFFF_FFFE), (16, u32::MAX)]), // num_tas overflows
        ] {
            let err = restore(&bad).expect_err("hostile header must be rejected");
            assert!(
                err.to_string().contains("payload") || err.to_string().contains("params"),
                "unexpected rejection path: {err:#}"
            );
        }
    }

    /// `restore_expecting` pins the regression contract: a snapshot
    /// behind the consumer's applied seq fails with a downcastable
    /// [`SeqRegression`]; at or ahead of it, restore succeeds.
    #[test]
    fn seq_regression_is_a_typed_error() {
        let (tm, p) = snapshot_machine();
        let bytes = snapshot_bytes(&tm, &p, 7);
        assert_eq!(restore_expecting(&bytes, 7).unwrap().seq, 7);
        assert_eq!(restore_expecting(&bytes, 0).unwrap().seq, 7);
        let err = restore_expecting(&bytes, 8).expect_err("regressed snapshot must fail");
        let reg = err.downcast_ref::<SeqRegression>().expect("typed SeqRegression");
        assert_eq!(*reg, SeqRegression { snapshot_seq: 7, applied_seq: 8 });
        assert!(err.to_string().contains("regresses behind"));
    }

    /// The committed golden fixture pins the TMFS v2 bytes for good:
    /// durable checkpoints written by older builds must keep decoding,
    /// and re-encoding the decoded snapshot must reproduce the exact
    /// bytes. Regenerate only with a deliberate format-version bump
    /// (the generator ramp is `state[i] = (i*37 + 11) % 200` on the
    /// iris shape with `paper_online` params, seq 4242).
    #[test]
    fn golden_snapshot_bytes_stay_stable() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/proto/tmfs_v2_golden.bin");
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(quick_check(&bytes), Some(4242));
        let snap = restore(&bytes).unwrap();
        assert_eq!(snap.seq, 4242);
        let shape = snap.machine.shape().clone();
        assert_eq!(shape, TmShape::iris());
        assert_eq!(snap.params, TmParams::paper_online(&shape));
        let states = snap.machine.ta().states();
        assert_eq!(states.len(), 1536);
        for (i, &st) in states.iter().enumerate() {
            assert_eq!(st as usize, (i * 37 + 11) % 200, "TA state {i}");
        }
        assert!(snap.machine.clause_force_codes().iter().all(|&f| f == -1));
        assert_eq!(snap.machine.fault(), &FaultMap::none(&shape));
        assert_eq!(
            snapshot_bytes(&snap.machine, &snap.params, snap.seq),
            bytes,
            "re-encoding the golden snapshot must be byte-identical"
        );
    }

    #[test]
    fn file_roundtrip() {
        let (tm, p) = snapshot_machine();
        let dir = std::env::temp_dir().join("tmfpga_serve_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard0.snap");
        save_snapshot(&tm, &p, 99, &path).unwrap();
        let snap = load_snapshot(&path).unwrap();
        assert_eq!(snap.seq, 99);
        assert_eq!(snap.machine.state_digest(), tm.state_digest());
        std::fs::remove_file(&path).ok();
    }
}
