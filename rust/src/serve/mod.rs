//! The sharded online-inference serving layer — the front door the fast
//! engines were missing.
//!
//! Every driver so far assumed a pre-packed offline dataset; the paper's
//! operating regime is the opposite: single-sample requests arriving one
//! at a time, with labelled samples interleaved mid-stream ("training can
//! be interleaved with inference during operation", §1) behind the
//! modular data-input interface of §3.5. This module serves that regime
//! without giving up the batch-oriented fast paths:
//!
//! - [`MicroBatcher`] coalesces single-sample inference requests into
//!   up-to-64-wide micro-batches under a latency budget — flush on a full
//!   64-lane batch or on deadline, whichever comes first — so requests
//!   reach the sample-sliced kernel (`tm::bitplane`, 64 samples per AND)
//!   instead of the scalar path. Time is *virtual* (ticks supplied by the
//!   caller), so every batching decision is deterministic and replayable.
//!   Malformed requests (wrong literal width) are rejected at admission
//!   with a typed [`BadRequest`] and quarantined — counted, never packed
//!   into a lane they would corrupt.
//! - [`ShardServer`] (`supervisor`) replicates one [`crate::tm::MultiTm`]
//!   across supervised worker threads (`shard`). Labelled samples become
//!   sequenced [`crate::tm::ShardUpdate`] log entries broadcast to every
//!   shard over its FIFO work channel; each replica applies them in
//!   sequence order on randomness derived from `(base_seed, seq)`, so
//!   all replicas converge bit-identically and a micro-batch is scored
//!   against exactly the updates that arrived before its flush — on
//!   whichever shard it lands.
//! - **Fault tolerance** (PR 6): workers run under `catch_unwind` and
//!   periodically ship checksummed snapshots (`checkpoint`); the
//!   supervisor respawns a dead shard from its newest valid checkpoint,
//!   replays the retained log suffix and re-dispatches its unscored
//!   batches — recovered runs are bit-identical to unfailed ones. Under
//!   overload (all shards down, or survivors past
//!   [`FaultPolicy::degraded_depth`]) requests are *shed* with explicit
//!   accounting, never silently dropped. Deterministic fault schedules
//!   ([`ChaosPlan`], `chaos`) drive the whole machinery under test.
//! - [`ScalarOracle`] is the single-threaded reference: the same update
//!   log applied to one machine, every response computed by the scalar
//!   row-major `predict`. The soak driver (`coordinator::soak`) pins the
//!   server's responses **bit-identical** to the oracle's across shard
//!   counts, batch widths, mid-stream fault injection and injected
//!   worker failures (`rust/tests/integration_serve.rs`,
//!   `rust/tests/integration_recovery.rs`).
//!
//! MATADOR (arXiv 2403.10538) and the runtime-tunable eFPGA TM
//! (arXiv 2502.07823) both make the point that edge TM deployments are
//! won or lost at this system-integration layer — streaming I/O and
//! run-time reconfiguration — not in the core datapath.

pub mod batcher;
pub mod chaos;
pub mod checkpoint;
pub mod oracle;
pub mod shard;
pub mod supervisor;

use crate::tm::machine::MultiTm;
use crate::tm::update::UpdateKind;

pub use batcher::{
    run_trace, split_expired, BadRequest, BatcherConfig, DriveStats, MicroBatcher,
    PendingRequest, ServeEvent,
};
pub use chaos::{
    inject_disk_fault, ChaosEvent, ChaosPlan, ChaosSpec, DiskFault, KillKind, NetChaosPlan,
    NetChaosSpec, NetFault,
};
pub use checkpoint::{
    load_snapshot, quick_check, restore, restore_expecting, save_snapshot, snapshot_bytes,
    SeqRegression, ServeSnapshot,
};
pub use oracle::ScalarOracle;
pub use shard::{MicroBatch, ShardStats};
pub use supervisor::{FaultPolicy, RecoveryStats, ServeConfig, ServeOutcome, ShardServer};

/// Anything that can consume the deterministic event stream produced by
/// [`run_trace`]: the sharded server and the scalar oracle implement
/// this, so one driver exercises both and batching decisions can never
/// drift between the arm under test and its reference.
pub trait ServeBackend {
    /// A sequenced model update arrived (labelled sample / fault edit).
    /// Takes effect before any *later-flushed* micro-batch is scored.
    fn update(&mut self, kind: UpdateKind);
    /// A flushed micro-batch of inference requests, scored against the
    /// model state after every update received so far.
    fn infer_batch(&mut self, batch: Vec<PendingRequest>);
}

/// Everything a finished [`NetBackend`] produced: the complete
/// response and shed lists (previously polled items included, so the
/// exactly-once audit covers the whole run) plus each replica's final
/// state — the "checkpoint shards" leg of a graceful drain.
#[derive(Debug)]
pub struct NetFinal {
    /// `(request_id, predicted_class)`, sorted by request id.
    pub responses: Vec<(u64, usize)>,
    /// Request ids shed with an overload response, sorted.
    pub shed: Vec<u64>,
    /// Final replica state(s), decoded from verified exit snapshots.
    pub replicas: Vec<MultiTm>,
}

/// A [`ServeBackend`] the network front end (`crate::net`) can stream
/// from: responses and shed notices are *polled incrementally* while
/// the trace is still running (the sharded server surfaces worker
/// replies as they land; the scalar oracle answers at flush time), and
/// [`NetBackend::finalize`] ends the run — joining workers, collecting
/// whatever was still in flight, and verifying the exactly-once
/// response contract over the whole run, polled items included.
pub trait NetBackend: ServeBackend + Sized {
    /// Drain responses produced since the last poll, in production
    /// order (not necessarily id order across shards).
    fn poll_responses(&mut self) -> Vec<(u64, usize)>;
    /// Drain request ids shed with an overload response since the last
    /// poll.
    fn poll_shed(&mut self) -> Vec<u64>;
    /// Snapshot of per-shard queue depths (outstanding batches), for
    /// the telemetry surface. Backends without internal queues report
    /// an empty list.
    fn queue_depths(&self) -> Vec<u64> {
        Vec::new()
    }
    /// Finish the run: flush everything in flight, checkpoint the
    /// replica state(s), and return the complete record.
    fn finalize(self) -> anyhow::Result<NetFinal>;
}
