//! Baseline implementations the paper compares against (§6's software
//! comparator).

pub mod naive;

pub use naive::NaiveTm;
