//! Naive software TM baseline — the §6 comparator.
//!
//! "The parallel nature of a hardware-implemented TM is unrivalled by
//! software implementations … This decreases execution times from minutes,
//! or longer, on a computer using a software implementation down to a
//! matter of seconds."
//!
//! This is the straightforward per-literal scalar implementation a
//! software TM would use: no bit-packing, no action caching, clause
//! evaluation as a boolean loop. Semantics are identical to
//! [`crate::tm::MultiTm`] (tested), so throughput comparisons isolate the
//! implementation, not the algorithm.

use crate::tm::clause::Input;
use crate::tm::params::{polarity, TmParams, TmShape};
use crate::tm::rng::StepRands;

/// Scalar multiclass TM.
#[derive(Debug, Clone)]
pub struct NaiveTm {
    shape: TmShape,
    /// `states[class][clause][literal]`.
    states: Vec<Vec<Vec<u32>>>,
    /// Fault gates, dense booleans (AND, OR).
    and_mask: Vec<Vec<Vec<bool>>>,
    or_mask: Vec<Vec<Vec<bool>>>,
}

impl NaiveTm {
    pub fn new(shape: &TmShape) -> Self {
        let init = shape.states - 1;
        let c = shape.classes;
        let j = shape.max_clauses;
        let l = shape.literals();
        NaiveTm {
            shape: shape.clone(),
            states: vec![vec![vec![init; l]; j]; c],
            and_mask: vec![vec![vec![true; l]; j]; c],
            or_mask: vec![vec![vec![false; l]; j]; c],
        }
    }

    pub fn shape(&self) -> &TmShape {
        &self.shape
    }

    /// Flat row-major state view (comparison against `MultiTm`).
    pub fn flat_states(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.shape.num_tas());
        for c in &self.states {
            for j in c {
                v.extend_from_slice(j);
            }
        }
        v
    }

    pub fn set_fault(&mut self, class: usize, clause: usize, lit: usize, and: bool, or: bool) {
        self.and_mask[class][clause][lit] = and;
        self.or_mask[class][clause][lit] = or;
    }

    fn eff_action(&self, c: usize, j: usize, k: usize) -> bool {
        let a = self.states[c][j][k] >= self.shape.include_threshold();
        (a && self.and_mask[c][j][k]) || self.or_mask[c][j][k]
    }

    fn clause_output(&self, c: usize, j: usize, x: &Input, train: bool) -> bool {
        let mut any = false;
        for k in 0..self.shape.literals() {
            if self.eff_action(c, j, k) {
                any = true;
                if !x.literal(k) {
                    return false;
                }
            }
        }
        any || train
    }

    fn sums(&self, x: &Input, params: &TmParams, train: bool) -> Vec<i32> {
        (0..self.shape.classes)
            .map(|c| {
                if c >= params.active_classes {
                    return 0;
                }
                let mut v = 0;
                for j in 0..params.active_clauses {
                    if self.clause_output(c, j, x, train) {
                        v += polarity(j);
                    }
                }
                v.clamp(-params.t, params.t)
            })
            .collect()
    }

    pub fn infer(&self, x: &Input, params: &TmParams) -> (Vec<i32>, usize) {
        let sums = self.sums(x, params, false);
        let active = &sums[..params.active_classes];
        let mut best = 0;
        for (c, &v) in active.iter().enumerate() {
            if v > active[best] {
                best = c;
            }
        }
        (active.to_vec(), best)
    }

    pub fn predict(&self, x: &Input, params: &TmParams) -> usize {
        self.infer(x, params).1
    }

    /// Training step with the identical contract as
    /// `tm::feedback::train_step` (same `StepRands` consumption).
    pub fn train_step(&mut self, x: &Input, target: usize, params: &TmParams, rands: &StepRands) {
        let shape = self.shape.clone();
        let sums = self.sums(x, params, true);
        let signs = crate::tm::feedback::class_signs(
            target,
            rands,
            shape.classes,
            params.active_classes,
        );
        let two_t = (2 * params.t) as f32;
        let max_state = shape.max_state();
        for c in 0..params.active_classes {
            let sign = signs[c];
            if sign == 0 {
                continue;
            }
            let p_sel = (params.t as f32 - sign as f32 * sums[c] as f32) / two_t;
            for j in 0..params.active_clauses {
                if !(rands.clause(&shape, c, j) < p_sel) {
                    continue;
                }
                let out = self.clause_output(c, j, x, true);
                if sign as i32 * polarity(j) == 1 {
                    for k in 0..shape.literals() {
                        let r = rands.ta(&shape, c, j, k);
                        if out && x.literal(k) {
                            if r < params.p_reinforce() && self.states[c][j][k] < max_state {
                                self.states[c][j][k] += 1;
                            }
                        } else if r < params.p_weaken() && self.states[c][j][k] > 0 {
                            self.states[c][j][k] -= 1;
                        }
                    }
                } else if out {
                    for k in 0..shape.literals() {
                        if !x.literal(k)
                            && !self.eff_action(c, j, k)
                            && self.states[c][j][k] < max_state
                        {
                            self.states[c][j][k] += 1;
                        }
                    }
                }
            }
        }
    }

    pub fn accuracy(&self, data: &[(Input, usize)], params: &TmParams) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let ok = data.iter().filter(|(x, y)| self.predict(x, params) == *y).count();
        ok as f64 / data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::machine::MultiTm;
    use crate::tm::rng::Xoshiro256;

    /// The baseline must be *semantically identical* to the optimized
    /// machine: same states after the same training trajectory.
    #[test]
    fn matches_multitm_bit_for_bit() {
        let shape = TmShape::iris();
        let params = TmParams::paper_offline(&shape);
        let mut fast = MultiTm::new(&shape).unwrap();
        let mut naive = NaiveTm::new(&shape);
        let mut rng = Xoshiro256::new(0xD1FF);
        for step in 0..300 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&shape, &bits);
            let r = StepRands::draw(&mut rng, &shape);
            crate::tm::feedback::train_step(&mut fast, &x, step % 3, &params, &r);
            naive.train_step(&x, step % 3, &params, &r);
        }
        assert_eq!(fast.ta().states(), &naive.flat_states()[..]);
        // And inference agrees.
        for _ in 0..20 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&shape, &bits);
            assert_eq!(fast.infer(&x, &params), naive.infer(&x, &params));
        }
    }

    #[test]
    fn matches_under_faults() {
        let shape = TmShape::iris();
        let params = TmParams::paper_offline(&shape);
        let mut fast = MultiTm::new(&shape).unwrap();
        let mut naive = NaiveTm::new(&shape);
        let map = crate::tm::fault::FaultMap::even_spread(
            &shape,
            0.2,
            crate::tm::fault::Fault::StuckAt0,
            5,
        )
        .unwrap();
        for c in 0..shape.classes {
            for j in 0..shape.max_clauses {
                for k in 0..shape.literals() {
                    match map.get(c, j, k) {
                        crate::tm::fault::Fault::None => {}
                        crate::tm::fault::Fault::StuckAt0 => naive.set_fault(c, j, k, false, false),
                        crate::tm::fault::Fault::StuckAt1 => naive.set_fault(c, j, k, true, true),
                    }
                }
            }
        }
        fast.set_fault_map(map);
        let mut rng = Xoshiro256::new(0xF00D);
        for step in 0..200 {
            let bits: Vec<bool> = (0..16).map(|_| rng.next_f32() < 0.5).collect();
            let x = Input::pack(&shape, &bits);
            let r = StepRands::draw(&mut rng, &shape);
            crate::tm::feedback::train_step(&mut fast, &x, step % 3, &params, &r);
            naive.train_step(&x, step % 3, &params, &r);
        }
        assert_eq!(fast.ta().states(), &naive.flat_states()[..]);
    }
}
