//! Replay mitigation for catastrophic forgetting — the paper's §5.1
//! suggestion: "It could be advantageous to use a replay method,
//! continuing training with occasional datapoints from the offline
//! training set during online operation."
//!
//! Implemented as an online-pass variant that interleaves one offline-set
//! row after every `replay_interval` online rows; the ablation bench
//! compares forgetting (offline-set accuracy drop) with and without it.

use crate::data::blocks::{BlockPlan, SetAllocation};
use crate::data::iris;
use crate::tm::bitplane::BitPlanes;
use crate::tm::clause::Input;
use crate::tm::machine::MultiTm;
use crate::tm::params::{TmParams, TmShape};
use crate::tm::rng::Xoshiro256;
use crate::tm::train_planes::{train_rows_seq, TrainScratch};
use anyhow::Result;

/// Result of one replay-vs-plain comparison.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    /// Offline-set accuracy per iteration (forgetting indicator).
    pub offline_curve: Vec<f64>,
    pub validation_curve: Vec<f64>,
    pub online_curve: Vec<f64>,
}

/// Run the Fig-4 flow with optional replay.
///
/// `replay_interval = None` reproduces the plain Fig-4 behavioural flow;
/// `Some(k)` inserts one offline row after every `k` online rows.
pub fn run_with_replay(
    ordering: &[usize],
    iterations: usize,
    replay_interval: Option<usize>,
    seed: u64,
) -> Result<ReplayOutcome> {
    let shape = TmShape::iris();
    let plan = BlockPlan::stratified(iris::booleanised(), 5, seed)?;
    let sets = plan.sets(ordering, SetAllocation::paper())?;
    let offline_train = sets.offline.truncate(20).pack(&shape);
    let offline_full = sets.offline.pack(&shape);
    let validation = sets.validation.pack(&shape);
    let online = sets.online.pack(&shape);

    let p_off = TmParams::paper_offline(&shape);
    let p_on = TmParams::paper_online(&shape);
    let mut tm = MultiTm::new(&shape)?;
    let mut rng = Xoshiro256::new(seed ^ 0x5EED_CAFE);
    let mut scratch = TrainScratch::seeded(&mut rng, &shape);

    let offline_train_planes = BitPlanes::from_labelled(&shape, &offline_train);
    for _ in 0..10 {
        train_rows_seq(
            &mut tm,
            &offline_train,
            &offline_train_planes,
            &p_off,
            &mut rng,
            &mut scratch,
        );
    }

    let mut out = ReplayOutcome {
        offline_curve: vec![tm.accuracy(&offline_full, &p_off)],
        validation_curve: vec![tm.accuracy(&validation, &p_off)],
        online_curve: vec![tm.accuracy(&online, &p_off)],
    };

    let mut replay_pos = 0usize;
    for _ in 1..=iterations {
        // The pass's schedule — online rows with one offline row spliced
        // in after every `k` — is a pure function of the counters, not of
        // training, so the whole pass precomputes and lane-trains as one
        // batch (bit-identical refill order to the per-step loop).
        let mut pass: Vec<(Input, usize)> = Vec::with_capacity(2 * online.len());
        let mut since_replay = 0usize;
        for (x, y) in &online {
            pass.push((x.clone(), *y));
            since_replay += 1;
            if let Some(k) = replay_interval {
                if since_replay >= k {
                    since_replay = 0;
                    let (rx, ry) = &offline_train[replay_pos % offline_train.len()];
                    replay_pos += 1;
                    pass.push((rx.clone(), *ry));
                }
            }
        }
        let pass_planes = BitPlanes::from_labelled(&shape, &pass);
        train_rows_seq(&mut tm, &pass, &pass_planes, &p_on, &mut rng, &mut scratch);
        out.offline_curve.push(tm.accuracy(&offline_full, &p_off));
        out.validation_curve.push(tm.accuracy(&validation, &p_off));
        out.online_curve.push(tm.accuracy(&online, &p_off));
    }
    Ok(out)
}

/// Mean offline-set accuracy over the online phase — higher = less
/// forgetting.
pub fn retention(curve: &[f64]) -> f64 {
    if curve.len() <= 1 {
        return f64::NAN;
    }
    curve[1..].iter().sum::<f64>() / (curve.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_reduces_forgetting_on_average() {
        let orderings = crate::data::blocks::all_orderings(5);
        let mut plain_r = 0.0;
        let mut replay_r = 0.0;
        let n = 8;
        for (i, ord) in orderings.iter().take(n).enumerate() {
            let plain = run_with_replay(ord, 8, None, 40 + i as u64).unwrap();
            let replay = run_with_replay(ord, 8, Some(5), 40 + i as u64).unwrap();
            plain_r += retention(&plain.offline_curve);
            replay_r += retention(&replay.offline_curve);
        }
        plain_r /= n as f64;
        replay_r /= n as f64;
        assert!(
            replay_r > plain_r - 0.01,
            "replay retention {replay_r:.3} should not lose to plain {plain_r:.3}"
        );
    }

    #[test]
    fn curves_have_expected_length() {
        let ord = [0, 1, 2, 3, 4];
        let o = run_with_replay(&ord, 4, Some(10), 1).unwrap();
        assert_eq!(o.offline_curve.len(), 5);
        assert_eq!(o.online_curve.len(), 5);
        assert!(o.online_curve.iter().all(|a| (0.0..=1.0).contains(a)));
    }

    #[test]
    fn retention_math() {
        assert!((retention(&[0.9, 0.8, 0.6]) - 0.7).abs() < 1e-12);
        assert!(retention(&[0.9]).is_nan());
    }
}
